"""Quickstart: the paper in one script.

Builds a synthetic OSN dataset, indexes it with cosine-LSH over a CAN-style
overlay, and compares LSH / Layered-LSH / NB-LSH / CNB-LSH search quality at
their Table-1 network costs — reproducing the paper's headline: CNB-LSH
gives NB-LSH quality at LSH cost.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    EngineConfig, LshEngine, LshParams, make_hyperplanes, metrics,
    paper_topology,
)
from repro.core.corpus import exact_topk_sparse, sparse_densify_host
from repro.core.hashing import sketch_codes_batched
from repro.core.store import build_store_host
from repro.data import osn


def main():
    spec = osn.tiny_spec()
    print(f"dataset: {spec.num_users} users x {spec.num_interests} interests "
          f"(k={spec.k})")
    corpus = osn.generate(spec)
    params = LshParams(d=spec.num_interests, k=spec.k, L=4, seed=7)
    h = make_hyperplanes(params)

    dense = sparse_densify_host(corpus, np.arange(corpus.n))
    codes = sketch_codes_batched(jnp.asarray(dense), h)
    store = build_store_host(codes, params.num_buckets, capacity=128)

    nq, m = 128, 10
    qidx = np.random.default_rng(0).choice(corpus.n, nq, replace=False)
    qd = dense[qidx]
    qd /= np.maximum(np.linalg.norm(qd, axis=1, keepdims=True), 1e-12)
    ideal_s, ideal_i = exact_topk_sparse(corpus, qd, m + 1)
    keep_s = np.empty((nq, m), np.float32)
    keep_i = np.empty((nq, m), np.int32)
    for i in range(nq):
        mask = ideal_i[i] != qidx[i]
        keep_s[i], keep_i[i] = ideal_s[i][mask][:m], ideal_i[i][mask][:m]

    topo = paper_topology(spec.k)
    print(f"{'variant':10s} {'msgs/query':>10s} {'recall@10':>10s} "
          f"{'NCS@10':>8s}")
    for variant in ("lsh", "layered", "nb", "cnb"):
        e = LshEngine(params, h, store, corpus, topo,
                      EngineConfig(variant=variant))
        r = e.search(jnp.asarray(qd), m=m, exclude=qidx)
        rec = metrics.recall_at_m(r.ids, keep_i)
        ncs = metrics.ncs_at_m(r.scores, keep_s)
        print(f"{variant:10s} {r.cost.messages:10.0f} {rec:10.3f} {ncs:8.3f}")


if __name__ == "__main__":
    main()
