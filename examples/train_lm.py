"""End-to-end training driver example: train a reduced assigned arch for a
few hundred steps with fault-tolerant checkpointing, then resume.

    PYTHONPATH=src python examples/train_lm.py [--arch gemma2-2b] [--steps 200]
"""

import argparse
import shutil
import tempfile

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        half = args.steps // 2
        print(f"=== phase 1: steps 0..{half} (then simulated preemption) ===")
        train_mod.main([
            "--arch", args.arch, "--smoke", "--steps", str(half),
            "--batch", "8", "--seq", "128", "--lr", "1e-3",
            "--ckpt-dir", ckpt, "--ckpt-every", "25", "--log-every", "20",
        ])
        print(f"=== phase 2: resume from checkpoint to {args.steps} ===")
        train_mod.main([
            "--arch", args.arch, "--smoke", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--lr", "1e-3",
            "--ckpt-dir", ckpt, "--ckpt-every", "25", "--log-every", "20",
            "--resume",
        ])
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
