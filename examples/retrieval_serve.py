"""Framework integration: model-produced embeddings behind NearBucket-LSH.

Embeds "users" (token histories) with an assigned-architecture backbone,
indexes the embeddings in the LSH store, and serves similar-user queries
through the ONLINE serving frontend (`repro.serve`, DESIGN.md Sec. 7) —
dynamic batching plus the sketch-keyed result cache, with the modern
twist that the interest vectors come from an LM.  Users re-query (second
pass over the same queries), so the cache hit rate and the resulting
messages/query saving are visible alongside the paper's community-purity
quality check.

    PYTHONPATH=src python examples/retrieval_serve.py
"""

import numpy as np
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (
    DenseCorpus, EngineConfig, LshEngine, LshParams, make_hyperplanes,
)
from repro.core.hashing import sketch_codes_batched
from repro.core.store import build_store_host
from repro.models import model as M
from repro.models import sharding as sh
from repro.serve import FrontendConfig, RetrievalFrontend, RuntimeBackend


def main():
    cfg = get_config("gemma2-2b", smoke=True)
    params, _ = M.init_model(cfg, seed=0)
    from repro.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(0)

    n_users, seq, n_comm = 512, 16, 16
    comm = rng.integers(0, n_comm, n_users)
    toks = rng.integers(0, cfg.vocab_size, (n_users, seq))
    proto = rng.integers(0, cfg.vocab_size, (n_comm, 8))
    toks[:, :8] = proto[comm]  # community members share a token prefix

    print(f"embedding {n_users} users with {cfg.name} ...")
    embs = []
    with sh.use_mesh(mesh):
        for s in range(0, n_users, 128):
            hidden, _, _ = M.forward(
                params, cfg,
                {"tokens": jnp.asarray(toks[s:s + 128], jnp.int32)})
            embs.append(np.array(hidden.mean(axis=1), np.float32))
    emb = np.concatenate(embs)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)

    lsh = LshParams(d=emb.shape[1], k=6, L=4, seed=1)
    h = make_hyperplanes(lsh)
    codes = sketch_codes_batched(jnp.asarray(emb), h)
    store = build_store_host(codes, lsh.num_buckets, capacity=128)
    engine = LshEngine(lsh, h, store, DenseCorpus(jnp.asarray(emb)), None,
                       EngineConfig(variant="cnb"))

    frontend = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=10, max_batch=32, queue_capacity=128),
    )

    nq = 64
    ids, _scores = frontend.search(emb[:nq], exclude=np.arange(nq))
    # the served ids are bit-identical to a direct engine.search (CI-pinned
    # in tests/test_serve.py); the purity check is unchanged
    total = match = 0
    for i in range(nq):
        for j in ids[i]:
            if j >= 0:
                total += 1
                match += int(comm[j] == comm[i])

    # second pass: the users re-query — served from the sketch-keyed cache
    ids2, _ = frontend.search(emb[:nq], exclude=np.arange(nq))
    assert np.array_equal(ids2, ids)

    s = frontend.stats.summary()
    print(f"community purity of retrieved neighbors: {match/total:.2f} "
          f"({match}/{total})")
    print(f"cache hit rate = {s['hit_rate']:.2f}; "
          f"messages/query = {s['messages_per_query']:.1f} "
          f"(no-cache closed form {frontend.backend.cost().messages:.0f}); "
          f"p99 latency = {s['p99_us']:.0f}us")
    assert match / total > 0.5
    assert s["hit_rate"] >= 0.5  # the whole second pass hit


if __name__ == "__main__":
    main()
