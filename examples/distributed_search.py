"""Distributed CNB-LSH on a multi-device mesh (the shard_map runtime).

Maps the CAN overlay onto a (data x model) device mesh: bucket shards on
the `model` axis, query batch on `data`, neighbor-bucket caches refreshed
by collective_permute off the query path.  Runs on 8 host devices.

    python examples/distributed_search.py        # sets its own XLA_FLAGS
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np                                            # noqa: E402
import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P    # noqa: E402

from repro.core import LshParams, make_hyperplanes            # noqa: E402
from repro.core import distributed as dist                    # noqa: E402
from repro.core.hashing import sketch_codes_batched           # noqa: E402
from repro.core.store import build_store_host                 # noqa: E402


def main():
    from repro.compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    N, D = 20_000, 128
    params = LshParams(d=D, k=7, L=4, seed=3)
    H = make_hyperplanes(params)
    # centered embeddings (the model-produced case): sign-hash buckets are
    # balanced; the paper's non-negative interest vectors skew buckets and
    # need higher capacity (see tests/test_distributed.py)
    vecs = rng.standard_normal((N, D)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    codes = sketch_codes_batched(jnp.asarray(vecs), H)
    store = dist.shard_store(
        mesh, build_store_host(codes, params.num_buckets, 384, payload=vecs))

    cfg = dist.DistConfig(params=params, n_shards=4, variant="cnb", m=10)
    refresh = dist.make_refresh_cache(cfg, mesh)
    cache_ids, cache_payload = refresh(store.ids, store.payload)
    search = dist.make_search_step(cfg, mesh)

    B = 64
    q = jax.device_put(jnp.asarray(vecs[:B]),
                       NamedSharding(mesh, P(("data", "model"), None)))
    ids, scores, dropped = search(H, store.ids, store.payload,
                                  cache_ids, cache_payload, q)
    ids, scores = np.asarray(ids), np.asarray(scores)
    self_hit = float(np.mean(ids[:, 0] == np.arange(B)))
    est = dist.estimate_query_bytes(cfg, batch=B, d=D, n_total=8)
    print(f"searched {B} queries over {N} vectors on mesh "
          f"{dict(mesh.shape)}")
    print(f"top-1 self-hit rate: {self_hit:.2f} (should be ~1.0)")
    print(f"dropped probes (routing overflow): {int(dropped)} "
          f"(0 in healthy operation; raise cap_factor otherwise)")
    print(f"estimated wire bytes/step: {est['total']:.0f} "
          f"(routing {est['query_routing']}, results {est['results']}, "
          f"neighbor {est['neighbor']})")
    assert self_hit > 0.95
    assert int(dropped) == 0

    # margin-ranked probe budget (beyond paper): probe only the p=3 most
    # promising near buckets per table — same planner as the single-host
    # engine, so results stay engine-identical at the same budget.
    cfg_p3 = dist.DistConfig(params=params, n_shards=4, variant="cnb",
                             m=10, num_probes=3, ranked_probes=True)
    search_p3 = dist.make_search_step(cfg_p3, mesh)
    ids3, _, _ = search_p3(H, store.ids, store.payload,
                           cache_ids, cache_payload, q)
    p3_hit = float(np.mean(np.asarray(ids3)[:, 0] == np.arange(B)))
    print(f"ranked p=3 probes: top-1 self-hit {p3_hit:.2f} at "
          f"{cfg_p3.probe_spec.probes_per_table}/"
          f"{cfg.probe_spec.probes_per_table} buckets per table")

    # distributed `contains` (paper Sec. 6.3): was y's id inside ANY bucket
    # the query searched — metadata-only routing, no payload bytes.
    contains = dist.make_contains_step(cfg, mesh)
    targets = jax.device_put(jnp.arange(B, dtype=jnp.int32),
                             NamedSharding(mesh, P(("data", "model"))))
    hits, _ = contains(H, store.ids, cache_ids, q, targets)
    print(f"contains(self) success probability: "
          f"{float(np.mean(np.asarray(hits))):.2f}")


if __name__ == "__main__":
    main()
