"""Packed sketch-code layout invariants (core/packed.py, DESIGN.md
Sec. 11).

Property tests (hypothesis, behind the conftest guard) with seeded
example twins, so the invariants are always exercised tier-1 even
without hypothesis installed:

  * pack -> unpack is the identity for random k, L, widths;
  * packed hamming == sum of per-table unpacked hamming distances;
  * the multi-word Pallas hamming kernel matches the jnp oracle.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, st  # hypothesis or skip-fallback

from repro.core import packed
from repro.core.hashing import hamming_distance


def _random_codes(seed: int, n: int, k: int, L: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << k, size=(n, L), dtype=np.uint32)


def _check_roundtrip(seed: int, k: int, L: int, n: int = 16):
    codes = jnp.asarray(_random_codes(seed, n, k, L))
    words = packed.pack_codes(codes, k)
    assert words.shape == (n, packed.num_words(k, L))
    assert words.dtype == jnp.uint32
    np.testing.assert_array_equal(
        np.asarray(packed.unpack_codes(words, k, L)), np.asarray(codes))


def _check_distance(seed: int, k: int, L: int, n: int = 16):
    a = jnp.asarray(_random_codes(seed, n, k, L))
    b = jnp.asarray(_random_codes(seed + 1, n, k, L))
    got = packed.hamming_words(packed.pack_codes(a, k),
                               packed.pack_codes(b, k))
    want = jnp.sum(hamming_distance(a, b), axis=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(0, 2**31 - 1), st.integers(1, 30), st.integers(1, 8))
def test_pack_unpack_roundtrip_property(seed, k, L):
    _check_roundtrip(seed, k, L)


@given(st.integers(0, 2**31 - 1), st.integers(1, 30), st.integers(1, 8))
def test_packed_hamming_matches_unpacked_property(seed, k, L):
    _check_distance(seed, k, L)


def test_pack_unpack_roundtrip_examples():
    """Seeded twins of the property: word-boundary-straddling widths
    (k*L = 31, 32, 33, 64, 65) and the single-word/multi-word edges."""
    for seed, (k, L) in enumerate(
            [(1, 1), (30, 1), (10, 3), (8, 4), (11, 3), (16, 2),
             (13, 5), (30, 8)]):
        _check_roundtrip(seed, k, L)
        _check_distance(seed, k, L)


def test_num_words():
    assert packed.num_words(8, 4) == 1   # 32 bits exactly
    assert packed.num_words(8, 5) == 2   # 40 bits
    assert packed.num_words(1, 1) == 1   # never zero words
    assert packed.num_words(30, 8) == 8  # 240 bits


def test_pack_masks_high_bits():
    """Raw uint32 codes may carry garbage above bit k-1; pack ignores it."""
    k, L = 5, 3
    clean = jnp.asarray(_random_codes(7, 8, k, L))
    dirty = clean | jnp.uint32(0xFFFFFFE0)  # set every bit >= k
    np.testing.assert_array_equal(
        np.asarray(packed.pack_codes(dirty, k)),
        np.asarray(packed.pack_codes(clean, k)))


def test_hamming_words_kernel_matches_oracle():
    """ops.hamming on multi-word rows == packed.hamming_words == the
    ref oracle (all three own a SWAR popcount; they must not drift)."""
    from repro.kernels import ops, ref

    rng = np.random.default_rng(3)
    n, kc, w = 33, 17, 3
    codes = jnp.asarray(rng.integers(0, 2**32, size=(n, w), dtype=np.uint32))
    cand = jnp.asarray(
        rng.integers(0, 2**32, size=(n, kc, w), dtype=np.uint32))
    want = ref.hamming_words_ref(codes, cand)
    np.testing.assert_array_equal(
        np.asarray(ops.hamming(codes, cand)), np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(packed.hamming_words(codes[:, None, :], cand)),
        np.asarray(want))


def test_pack_boundary_k_validation():
    """The layout contract holds only for k <= 30 (MAX_K): k=30 works,
    k=31 and k=0 raise a clear ValueError at the pack boundary instead of
    silently breaking the unpack(pack(c)) round-trip (PR 10 bugfix)."""
    codes = jnp.asarray(_random_codes(5, 4, 30, 2))
    w = packed.pack_codes(codes, 30)  # k = MAX_K is legal
    np.testing.assert_array_equal(
        np.asarray(packed.unpack_codes(w, 30, 2)), np.asarray(codes))
    for bad in (0, 31, -3):
        with pytest.raises(ValueError, match="k in"):
            packed.num_words(bad, 2)
        with pytest.raises(ValueError, match="k in"):
            packed.pack_codes(codes, bad)
        with pytest.raises(ValueError, match="k in"):
            packed.unpack_codes(w, bad, 2)


def test_pack_store_payload_validates_hyperplanes():
    """A hyperplane stack that does not match the store ([L', k', d']
    with wrong L or d) must raise naming the expected [L, k, d] — not
    shape-error deep inside sketch_codes or build a wrong-W payload
    (PR 10 bugfix)."""
    from repro.core import LshParams, make_hyperplanes
    from repro.core.hashing import sketch_codes_batched
    from repro.core.store import build_store_host

    params = LshParams(d=16, k=4, L=3, seed=1)
    h = make_hyperplanes(params)
    rng = np.random.default_rng(5)
    vecs = rng.standard_normal((64, 16)).astype(np.float32)
    codes = sketch_codes_batched(jnp.asarray(vecs), h)
    store = build_store_host(codes, params.num_buckets, capacity=8,
                             payload=vecs)
    # wrong d'
    bad_d = make_hyperplanes(LshParams(d=8, k=4, L=3, seed=1))
    with pytest.raises(ValueError, match=r"\[L, k, d\]"):
        packed.pack_store_payload(store, bad_d)
    # wrong L'
    bad_l = make_hyperplanes(LshParams(d=16, k=4, L=2, seed=1))
    with pytest.raises(ValueError, match=r"\[L, k, d\]"):
        packed.pack_store_payload(store, bad_l)
    # wrong rank
    with pytest.raises(ValueError, match=r"\[L, k, d\]"):
        packed.pack_store_payload(store, h[0])
    # matching stack still works and matches scratch-built packing
    out = packed.pack_store_payload(store, h)
    assert out.payload.shape[-1] == packed.num_words(4, 3)
