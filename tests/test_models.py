"""Per-architecture smoke tests (reduced configs, CPU) + decode consistency.

Every assigned arch: one forward pass with output-shape and finiteness
asserts, plus a teacher-forced prefill/decode vs full-forward equivalence
check (validates KV caches, recurrent states, cross-attention caches).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import model as M
from repro.models import sharding as sh


def _batch_for(cfg, rng, B=2, S=16):
    batch = {}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, 12, cfg.d_model)), jnp.float32) * 0.1
    if cfg.modality == "vision_patches":
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_prefix_embeds, cfg.d_model)),
            jnp.float32) * 0.1
    batch["tokens"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_smoke(arch, single_mesh, rng):
    cfg = get_config(arch, smoke=True)
    params, specs = M.init_model(cfg, seed=0)
    # spec tree mirrors the param tree
    assert jax.tree.structure(
        jax.tree.map(lambda _: 0, params)
    ) == jax.tree.structure(
        jax.tree.map(lambda _: 0, specs, is_leaf=lambda x: isinstance(x, tuple))
    )
    batch = _batch_for(cfg, rng, B=2, S=16)
    with sh.use_mesh(single_mesh):
        hidden, aux, _ = M.forward(params, cfg, batch)
        logits = M.logits_from_hidden(params, cfg, hidden)
    S_total = 16 + (cfg.num_prefix_embeds if cfg.modality == "vision_patches"
                    else 0)
    assert hidden.shape == (2, S_total, cfg.d_model)
    assert logits.shape == (2, S_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert bool(jnp.all(jnp.isfinite(aux)))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_consistency(arch, single_mesh, rng):
    """Teacher-forced decode must reproduce the full forward's logits."""
    cfg = get_config(arch, smoke=True)
    params, _ = M.init_model(cfg, seed=0)
    B, S = 2, 16
    batch = _batch_for(cfg, rng, B, S)
    toks = batch["tokens"]
    off = cfg.num_prefix_embeds if cfg.modality == "vision_patches" else 0
    with sh.use_mesh(single_mesh):
        hidden, _, _ = M.forward(params, cfg, batch)
        full = M.logits_from_hidden(params, cfg, hidden)
        pre = dict(batch)
        pre["tokens"] = toks[:, : S - 4]
        last, states, _ = M.prefill(params, cfg, pre, max_len=S + 8)
        pos0 = (S - 4) + off
        errs = [float(jnp.max(jnp.abs(last - full[:, pos0 - 1])))]
        for t in range(4):
            logits, states = M.decode_step(
                params, cfg, toks[:, S - 4 + t], states, jnp.int32(pos0 + t)
            )
            errs.append(float(jnp.max(jnp.abs(logits - full[:, pos0 + t]))))
    assert max(errs) < 0.08, (arch, errs)


def test_param_counts_match_closed_form():
    """init param count == config.count_params (keeps 6ND roofline honest).
    Checked on the reduced configs (same code path as the full ones)."""
    from repro.models.config import count_params

    for arch in ARCH_NAMES:
        cfg = get_config(arch, smoke=True)
        params, _ = M.init_model(cfg, seed=0)
        actual = sum(x.size for x in jax.tree.leaves(params))
        expected = count_params(cfg)
        assert abs(actual - expected) / expected < 0.02, (
            arch, actual, expected)


def test_gemma2_softcaps_bound_logits(single_mesh, rng):
    cfg = get_config("gemma2-2b", smoke=True)
    params, _ = M.init_model(cfg, 0)
    batch = _batch_for(cfg, rng)
    with sh.use_mesh(single_mesh):
        hidden, _, _ = M.forward(params, cfg, batch)
        logits = M.logits_from_hidden(params, cfg, hidden)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_logit_softcap + 1e-3


def test_local_attention_window(single_mesh, rng):
    """gemma2 local layers must not attend beyond the window: a token far
    outside the window cannot influence the last position's logits."""
    cfg = get_config("gemma2-2b", smoke=True)  # window 16
    params, _ = M.init_model(cfg, 0)
    S = 24
    t1 = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S)), jnp.int32)
    with sh.use_mesh(single_mesh):
        h1, _, _ = M.forward(params, cfg, {"tokens": t1})
    # sanity only: full forward finite & causal shape
    assert bool(jnp.all(jnp.isfinite(h1)))


def test_chunked_attention_matches_dense(single_mesh, rng):
    """The q-chunked (flash-style) path must equal the dense-mask path."""
    from repro.models import layers as ly

    cfg = get_config("phi3-medium-14b", smoke=True)
    p, _ = ly.init_attention(cfg, jax.random.PRNGKey(0))
    B, S = 2, 4096  # > Q_CHUNK_THRESHOLD => chunked
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)),
                    jnp.float32) * 0.1
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    with sh.use_mesh(single_mesh):
        out_chunked = ly.attention(p, x, cfg, pos)
        # force dense path via a temporarily huge threshold
        thr = ly.Q_CHUNK_THRESHOLD
        ly.Q_CHUNK_THRESHOLD = 10**9
        try:
            out_dense = ly.attention(p, x, cfg, pos)
        finally:
            ly.Q_CHUNK_THRESHOLD = thr
    np.testing.assert_allclose(
        np.asarray(out_chunked), np.asarray(out_dense), rtol=2e-4, atol=2e-4
    )
