"""CAN overlay geometry."""

import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or skip-fallback

from repro.core import multiprobe
from repro.core.can import CanTopology, paper_topology


def test_paper_topology():
    t = paper_topology(6)
    assert t.n_nodes == 64 and t.local_bits == 0 and t.buckets_per_node == 1
    assert t.expected_lookup_hops == 3.0


def test_zone_decomposition():
    t = CanTopology(k=8, n_nodes=16)
    assert t.node_bits == 4 and t.local_bits == 4
    codes = np.arange(256, dtype=np.uint32)
    nodes = t.node_of_np(codes)
    locals_ = t.local_of_np(codes)
    # roundtrip
    assert all(
        t.code_of(n, l) == c for c, n, l in zip(codes, nodes, locals_)
    )
    # contiguous prefix ranges
    assert nodes[0] == 0 and nodes[255] == 15
    assert np.all(np.diff(nodes.astype(int)) >= 0)


def test_coordinate_backends_agree():
    """The traced (jnp) and host (np) coordinate helpers are twins: same
    values, explicit backend types (no duck-typed dispatch)."""
    import jax

    t = CanTopology(k=9, n_nodes=8)
    codes_np = np.arange(512, dtype=np.uint32)
    n_np, l_np = t.node_of_np(codes_np), t.local_of_np(codes_np)
    assert isinstance(n_np, np.ndarray) and isinstance(l_np, np.ndarray)
    n_j, l_j = t.node_of(codes_np), t.local_of(codes_np)
    assert isinstance(n_j, jax.Array) and isinstance(l_j, jax.Array)
    assert np.array_equal(np.asarray(n_j), n_np)
    assert np.array_equal(np.asarray(l_j), l_np)
    # the jnp path is jit-traceable (the planner runs it inside jit)
    n_jit = jax.jit(t.node_of)(codes_np)
    assert np.array_equal(np.asarray(n_jit), n_np)
    # python-int scalars go through the np path (simulator convention)
    assert int(t.node_of_np(np.uint32(0b111000000))) == 0b111
    assert int(t.local_of_np(np.uint32(0b111000001))) == 0b000001


def test_neighbors_differ_one_bit():
    t = CanTopology(k=10, n_nodes=32)
    for node in (0, 7, 31):
        for nb in t.node_neighbors(node):
            assert bin(int(nb) ^ node).count("1") == 1


def test_neighbor_perm_is_matching():
    t = CanTopology(k=6, n_nodes=8)
    for bit in range(3):
        perm = t.neighbor_perm(bit)
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        assert sorted(srcs) == list(range(8)) == sorted(dsts)
        # involution
        m = dict(perm)
        assert all(m[m[s]] == s for s in srcs)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12), st.integers(1, 6))
def test_lookup_hops_hamming(k, a):
    a = min(a, k)
    t = CanTopology(k=k, n_nodes=1 << a)
    rng = np.random.default_rng(k * 31 + a)
    s, d = rng.integers(0, t.n_nodes, 2)
    assert t.lookup_hops(s, d) == bin(int(s) ^ int(d)).count("1")


def test_bad_topology():
    with pytest.raises(ValueError):
        CanTopology(k=3, n_nodes=16)
    with pytest.raises(ValueError):
        CanTopology(k=4, n_nodes=6)


def test_near_codes_properties(rng):
    import jax.numpy as jnp

    codes = jnp.asarray(rng.integers(0, 2**12, 20), jnp.uint32)
    near = multiprobe.near_codes(codes, 12)
    assert near.shape == (20, 12)
    nc = np.asarray(near)
    c = np.asarray(codes)
    for i in range(20):
        # each differs in exactly one bit, all distinct
        dists = [bin(int(x) ^ int(c[i])).count("1") for x in nc[i]]
        assert dists == [1] * 12
        assert len(set(int(x) for x in nc[i])) == 12


def test_probe_plan_sizes():
    assert multiprobe.probe_plan_size(12, 4, "lsh") == 4
    assert multiprobe.probe_plan_size(12, 4, "nb") == 52
    assert multiprobe.probe_plan_size(12, 4, "cnb") == 52
    assert multiprobe.probe_plan_size(12, 4, "cnb", num_probes=3) == 16


def test_b_near_enumeration():
    out = multiprobe.b_near_codes_host(0b1010, 4, 2)
    assert len(out) == 6  # C(4,2)
    assert all(bin(int(x) ^ 0b1010).count("1") == 2 for x in out)
