"""Observability layer (DESIGN.md Sec. 12).

Pins the obs contracts:
  * registry: label-set aggregation, idempotent registration (kind
    mismatch is a TypeError), JSON snapshot schema, Prometheus text
    format, bucket-resolution histogram quantiles;
  * tracer: span nesting via plain stack, stopwatch semantics with
    recording disabled, Chrome-trace JSON that round-trips and carries
    the required event keys;
  * flight recorder: bounded ring, drop-spike auto dump (dispatch/epoch
    records only), anomaly snapshots preserving the ring, `total()`
    accounting over direct fields and `extra` entries;
  * frontend integration: per-query + per-dispatch records agree with
    `ServeStats`, all six pipeline-stage spans appear, the sampled
    recall probe lands in the registry;
  * ZERO-RETRACE: obs-on serves the SAME compiled executables as
    obs-off (trace counters), with bit-identical results;
  * churn: flight epoch records sum to the driver's aggregate arrays
    bit-for-bit;
  * `core.metrics` edge cases (empty ideal sets, duplicates, m >
    candidates) that the recall probe leans on.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    DenseCorpus, EngineConfig, LshEngine, LshParams, make_hyperplanes,
    metrics,
)
from repro.core.churn import ChurnConfig, run_churn_distributed
from repro.core.hashing import sketch_codes_batched
from repro.core.store import build_store_host
from repro.obs import ObsConfig, Observability
from repro.obs.flight import FlightRecorder, QueryRecord
from repro.obs.registry import Registry
from repro.obs.trace import Span, Tracer, span_or_null
from repro.serve import FrontendConfig, RetrievalFrontend, RuntimeBackend

K, L, D, M = 5, 3, 16, 8


def _make_engine(n=400, seed=0, capacity=32):
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((n, D)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    params = LshParams(d=D, k=K, L=L, seed=seed + 1)
    h = make_hyperplanes(params)
    codes = sketch_codes_batched(jnp.asarray(emb), h)
    store = build_store_host(codes, params.num_buckets, capacity=capacity)
    engine = LshEngine(params, h, store, DenseCorpus(jnp.asarray(emb)), None,
                       EngineConfig(variant="cnb"))
    return emb, engine


# -----------------------------------------------------------------------------
# registry
# -----------------------------------------------------------------------------


def test_counter_aggregates_per_label_set():
    reg = Registry()
    c = reg.counter("msgs_total", "messages")
    c.inc(3, node="a")
    c.inc(2, node="a")
    c.inc(7, node="b")
    c.inc()  # unlabeled series is its own label set
    assert c.value(node="a") == 5
    assert c.value(node="b") == 7
    assert c.value() == 1
    assert c.value(node="never") == 0
    with pytest.raises(ValueError):
        c.inc(-1)


def test_registration_is_idempotent_and_kind_checked():
    reg = Registry()
    a = reg.counter("x")
    assert reg.counter("x") is a
    with pytest.raises(TypeError):
        reg.gauge("x")
    assert reg.value("missing", default=-1) == -1
    g = reg.gauge("y")
    assert g.value(k="v") is None  # never set
    assert reg.value("y", default=0.0, k="v") == 0.0


def test_histogram_counts_and_quantile():
    reg = Registry()
    h = reg.histogram("lat_us", buckets=(10.0, 100.0, 1000.0))
    for v in (5, 5, 50, 500, 5000):
        h.observe(v, stage="dispatch")
    assert h.value(stage="dispatch") == 5
    assert h.value(stage="other") == 0
    assert h.quantile(0.2, stage="dispatch") == 10.0
    assert h.quantile(0.6, stage="dispatch") == 100.0
    assert h.quantile(1.0, stage="dispatch") == float("inf")  # 5000 > top edge
    assert h.quantile(0.5, stage="other") == 0.0


def test_histogram_observe_many_matches_observe():
    reg = Registry()
    one = reg.histogram("a", buckets=(10.0, 100.0, 1000.0))
    many = reg.histogram("b", buckets=(10.0, 100.0, 1000.0))
    vals = [5.0, 10.0, 99.0, 100.0, 5000.0, 0.0]  # edges land identically
    for v in vals:
        one.observe(v, stage="s")
    many.observe_many(vals, stage="s")
    assert one._series == many._series
    many.observe_many([], stage="s")  # no-op, no series mutation
    assert one._series == many._series
    assert many.value(stage="s") == len(vals)


def test_snapshot_schema_and_prometheus_text():
    reg = Registry()
    reg.counter("c_total", "help text").inc(4, node="0")
    reg.gauge("g").set(1.5)
    reg.histogram("h", buckets=(10.0,)).observe(3.0)
    snap = reg.snapshot()
    assert set(snap) == {"c_total", "g", "h"}
    assert snap["c_total"]["type"] == "counter"
    assert snap["c_total"]["help"] == "help text"
    assert snap["c_total"]["samples"] == [
        dict(labels={"node": "0"}, value=4)]
    hs = snap["h"]["samples"][0]
    assert hs["count"] == 1 and hs["sum"] == 3.0
    assert hs["buckets"] == {"10": 1, "+Inf": 1}  # cumulative
    json.dumps(snap)  # JSON-able end to end

    text = reg.prometheus_text()
    assert "# HELP c_total help text" in text
    assert "# TYPE c_total counter" in text
    assert 'c_total{node="0"} 4' in text
    assert "g 1.5" in text
    assert 'h_bucket{le="10"} 1' in text
    assert 'h_bucket{le="+Inf"} 1' in text
    assert "h_sum 3" in text and "h_count 1" in text
    assert text.endswith("\n")


# -----------------------------------------------------------------------------
# tracer
# -----------------------------------------------------------------------------


def test_span_nesting_depth_and_stopwatch():
    tr = Tracer()
    assert tr.depth == 0
    with tr.span("outer") as outer:
        assert tr.depth == 1
        with tr.span("inner") as inner:
            assert tr.depth == 2
            assert inner.elapsed_s >= 0.0
    assert tr.depth == 0
    assert outer.duration_s >= inner.duration_s >= 0.0
    assert outer.duration_us == pytest.approx(outer.duration_s * 1e6)
    by_name = {e[1]: e for e in tr.events()}
    assert by_name["outer"][5] == 0 and by_name["inner"][5] == 1  # depths


def test_disabled_tracer_still_times_but_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("work") as sp:
        pass
    assert sp.duration_s >= 0.0
    assert tr.events() == []
    tr.instant("marker")
    assert tr.events() == []


def test_span_or_null_without_tracer_yields_null_context():
    with span_or_null(None, "anything") as sp:
        assert sp is None
    tr = Tracer()
    with span_or_null(tr, "named", n=3) as sp:
        assert isinstance(sp, Span)
    assert tr.events()[0][1] == "named"


def test_span_records_even_when_body_raises():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("doomed"):
            raise RuntimeError("boom")
    assert tr.depth == 0  # stack unwound
    assert [e[1] for e in tr.events()] == ["doomed"]


def test_tracer_ring_is_bounded():
    tr = Tracer(capacity=4)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    ev = tr.events()
    assert len(ev) == 4
    assert [e[1] for e in ev] == ["s6", "s7", "s8", "s9"]


def test_chrome_trace_round_trips_with_required_keys(tmp_path):
    tr = Tracer()
    with tr.span("stage", cat="serve", rows=7):
        pass
    tr.instant("blip")
    path = tmp_path / "trace.json"
    tr.export(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert len(evs) == 2
    for ev in evs:
        assert {"name", "cat", "ph", "ts", "pid", "tid"} <= set(ev)
    complete = next(e for e in evs if e["ph"] == "X")
    assert complete["dur"] >= 0.0 and complete["args"]["rows"] == 7
    instant = next(e for e in evs if e["ph"] == "i")
    assert instant["s"] == "t"


# -----------------------------------------------------------------------------
# flight recorder
# -----------------------------------------------------------------------------


def test_flight_ring_is_bounded():
    fl = FlightRecorder(capacity=3, drop_spike=0)
    for i in range(7):
        fl.record(QueryRecord(qid=i))
    assert len(fl) == 3
    assert [r.qid for r in fl.records()] == [4, 5, 6]
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


def test_drop_spike_dumps_on_dispatch_not_query_records():
    fl = FlightRecorder(capacity=16, drop_spike=2)
    fl.record(QueryRecord(qid=0, kind="query", dropped_probes=99))
    assert fl.dumps == []  # query records never trigger the dump
    fl.record(QueryRecord(qid=1, kind="dispatch", dropped_probes=1))
    assert fl.dumps == []  # below the spike threshold
    fl.record(QueryRecord(qid=2, kind="dispatch", dropped_probes=2))
    assert len(fl.dumps) == 1
    d = fl.dumps[0]
    assert d["reason"] == "drop_spike"
    assert d["detail"]["dropped_probes"] == 2
    assert d["n_records"] == 3 == len(d["records"])
    fl2 = FlightRecorder(capacity=16, drop_spike=0)  # <=0 disables
    fl2.record(QueryRecord(kind="dispatch", dropped_probes=100))
    assert fl2.dumps == []


def test_note_anomaly_snapshots_the_ring():
    fl = FlightRecorder(capacity=2, drop_spike=0)
    for i in range(4):
        fl.record(QueryRecord(qid=i))
    dump = fl.note_anomaly("kill_node", node=3)
    assert dump["reason"] == "kill_node" and dump["detail"] == {"node": 3}
    # only the surviving (ring) records are in the snapshot ...
    assert [r["qid"] for r in dump["records"]] == [2, 3]
    # ... and they survive the ring wrapping past them afterwards
    for i in range(10, 14):
        fl.record(QueryRecord(qid=i))
    assert [r["qid"] for r in fl.dumps[0]["records"]] == [2, 3]


def test_total_sums_direct_fields_and_extra_entries():
    fl = FlightRecorder(drop_spike=0)
    fl.record(QueryRecord(kind="epoch", dropped_probes=2,
                          extra=dict(replication_bytes=100)))
    fl.record(QueryRecord(kind="epoch", dropped_probes=3,
                          extra=dict(replication_bytes=50)))
    fl.record(QueryRecord(kind="query", dropped_probes=999))  # other kind
    assert fl.total("dropped_probes") == 5
    assert fl.total("replication_bytes") == 150
    assert fl.total("dropped_probes", kind="query") == 999
    assert fl.total("never_charged") == 0


def test_prestamped_t_us_is_preserved():
    fl = FlightRecorder(drop_spike=0)
    r1 = fl.record(QueryRecord(qid=0, t_us=fl.to_us(0.0)))
    assert r1.t_us < 0  # recorder started after perf_counter epoch 0
    r2 = fl.record(QueryRecord(qid=1))
    assert r2.t_us > 0  # stamped by record()


def test_flight_export_and_chrome_events(tmp_path):
    fl = FlightRecorder(drop_spike=0)
    fl.record(QueryRecord(qid=7, kind="query", t_us=500.0, latency_us=120.0))
    fl.record(QueryRecord(qid=0, kind="dispatch", dropped_probes=1))
    fl.note_anomaly("reshard", old_n=2, new_n=4)
    doc = fl.to_chrome_trace()
    q = next(e for e in doc["traceEvents"] if e["name"] == "query:7")
    assert q["ph"] == "X" and q["ts"] == 380.0 and q["dur"] == 120.0
    d = next(e for e in doc["traceEvents"] if e["name"] == "dispatch:0")
    assert d["ph"] == "i" and d["s"] == "t"
    a = next(e for e in doc["traceEvents"] if e["name"] == "anomaly:reshard")
    assert a["ph"] == "i" and a["s"] == "p" and a["args"]["old_n"] == 2

    path = tmp_path / "flight.json"
    fl.export(str(path))
    blob = json.loads(path.read_text())
    assert [r["qid"] for r in blob["records"]] == [7, 0]
    assert blob["dumps"][0]["reason"] == "reshard"
    assert blob["capacity"] == fl.capacity


def test_obs_bundle_merges_and_exports(tmp_path):
    obs = Observability()
    with obs.tracer.span("stage"):
        pass
    obs.flight.record(QueryRecord(qid=1, kind="query", latency_us=10.0))
    obs.registry.counter("c").inc(2)
    doc = obs.chrome_trace()
    names = [e["name"] for e in doc["traceEvents"]]
    assert "stage" in names and "query:1" in names
    tp, mp = tmp_path / "t.json", tmp_path / "m.json"
    obs.export_trace(str(tp))
    obs.export_metrics(str(mp))
    assert len(json.loads(tp.read_text())["traceEvents"]) == 2
    assert json.loads(mp.read_text())["c"]["samples"][0]["value"] == 2
    with pytest.raises(ValueError):
        ObsConfig(flight_capacity=0)


# -----------------------------------------------------------------------------
# frontend integration
# -----------------------------------------------------------------------------


def test_frontend_obs_records_agree_with_stats():
    emb, engine = _make_engine()
    obs = Observability()
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=16, queue_capacity=64, cache=False),
        obs=obs,
    )
    q, ex = emb[:24], np.arange(24)
    fe.search(q, exclude=ex)
    s = fe.stats.summary()
    queries = obs.flight.records(kind="query")
    assert len(queries) == s["completed"] == 24
    assert all(r.latency_us > 0 and r.cache_hit is False for r in queries)
    dispatches = obs.flight.records(kind="dispatch")
    assert len(dispatches) == s["batches"]
    assert (obs.flight.total("dropped_probes", kind="dispatch")
            == s["dropped_probes"])
    # every query record points at a real dispatch and carries its share
    by_seq = {d.qid: d for d in dispatches}
    for r in queries:
        d = by_seq[r.batch]
        assert r.batch_size == d.batch_size
        assert r.probes_issued == d.probes_issued // d.batch_size
    span_names = {e[1] for e in obs.tracer.events()}
    assert {"serve/intake", "serve/enqueue", "serve/stage", "serve/compute",
            "serve/reap", "serve/respond"} <= span_names


def test_cache_hits_become_hit_records():
    emb, engine = _make_engine()
    obs = Observability()
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=16, queue_capacity=64, cache=True),
        obs=obs,
    )
    q, ex = emb[:8], np.arange(8)
    fe.search(q, exclude=ex)
    fe.search(q, exclude=ex)  # identical -> all hits
    hits = [r for r in obs.flight.records(kind="query") if r.cache_hit]
    assert len(hits) == 8
    assert all(r.batch == -1 for r in hits)  # hits ride no dispatch


def test_obs_on_is_zero_retrace_and_bit_identical():
    emb, engine = _make_engine()
    backend = RuntimeBackend(engine)
    fe_off = RetrievalFrontend(
        backend, FrontendConfig(m=M, max_batch=16, queue_capacity=64,
                                cache=False))
    q, ex = emb[:24], np.arange(24)
    ids_off, sc_off = fe_off.search(q, exclude=ex)
    traces = (backend.traces, backend.sketch_traces)

    fe_on = RetrievalFrontend(
        backend, FrontendConfig(m=M, max_batch=16, queue_capacity=64,
                                cache=False),
        obs=Observability())
    ids_on, sc_on = fe_on.search(q, exclude=ex)
    # the SAME executables served both frontends: not one extra retrace
    assert (backend.traces, backend.sketch_traces) == traces
    np.testing.assert_array_equal(ids_on, ids_off)
    np.testing.assert_array_equal(sc_on, sc_off)


def test_recall_probe_publishes_registry_gauge():
    emb, engine = _make_engine()
    obs = Observability(ObsConfig(recall_probe_every=1))  # probe every miss
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=16, queue_capacity=64, cache=False),
        obs=obs,
    )
    fe.search(emb[:16], exclude=np.arange(16))
    reg = obs.registry
    assert reg.value("serve_recall_probes_total") == 16
    last = reg.value("serve_recall_probe", window="last")
    mean = reg.value("serve_recall_probe", window="mean")
    assert last is not None and 0.0 <= last <= 1.0
    assert mean is not None and 0.0 <= mean <= 1.0


# -----------------------------------------------------------------------------
# churn accounting
# -----------------------------------------------------------------------------


def test_churn_epoch_records_sum_to_aggregates_exactly():
    cfg = ChurnConfig(num_users=300, dim=16, k=4, L=2, capacity=32,
                      epochs=4, num_queries=32, refresh_every=2, seed=11)
    obs = Observability()
    out = run_churn_distributed(cfg, n_shards=1, obs=obs)
    eps = obs.flight.records(kind="epoch")
    # one record per loop epoch: the epoch-0 announce plus every read epoch
    assert len(eps) == len(out["recalls"]) + 1
    fl = obs.flight
    assert fl.total("dropped_probes") == int(out["dropped_probes"].sum())
    assert fl.total("replication_bytes") == out["total_replication_bytes"]
    assert fl.total("recovery_bytes") == out["total_recovery_bytes"]
    assert fl.total("handoff_bytes") == out["total_handoff_bytes"]
    assert fl.total("refresh_bytes") == out["total_refresh_bytes"]
    # per-epoch reconstruction, not just totals (eps[0] is the announce)
    assert ([r.extra["refresh_bytes"] for r in eps[1:]]
            == out["refresh_bytes"].tolist())
    assert ([r.extra["recall"] for r in eps[1:]]
            == out["recalls"].tolist())
    reg = obs.registry
    assert (reg.value("churn_dropped_probes_total")
            == int(out["dropped_probes"].sum()))
    assert (reg.value("churn_replication_bytes_total")
            == out["total_replication_bytes"])
    assert (reg.value("churn_recall", window="last")
            == pytest.approx(out["final_recall"]))
    assert (reg.value("churn_recall", window="mean")
            == pytest.approx(out["mean_recall"]))


# -----------------------------------------------------------------------------
# core.metrics edge cases (the recall probe's foundation)
# -----------------------------------------------------------------------------


def test_recall_empty_ideal_set_counts_as_perfect():
    approx = np.array([[1, 2, -1]], np.int32)
    ideal = np.full((1, 3), -1, np.int32)  # nothing to find
    assert metrics.recall_at_m(approx, ideal) == 1.0


def test_recall_duplicate_ids_count_once():
    approx = np.array([[5, 5, 5, -1]], np.int32)
    ideal = np.array([[5, 6, -1, -1]], np.int32)
    assert metrics.recall_at_m(approx, ideal) == pytest.approx(0.5)
    # duplicates in the ideal collapse too: {5} fully covered
    assert metrics.recall_at_m(
        np.array([[5, -1]], np.int32), np.array([[5, 5]], np.int32)) == 1.0


def test_recall_fewer_candidates_than_m():
    approx = np.array([[3, -1, -1, -1]], np.int32)  # 1 found, m=4 asked
    ideal = np.array([[3, 7, 9, 11]], np.int32)
    assert metrics.recall_at_m(approx, ideal) == pytest.approx(0.25)
    # and per-query averaging over a mixed batch
    approx2 = np.array([[3, -1], [7, 8]], np.int32)
    ideal2 = np.array([[3, 4], [7, 8]], np.int32)
    assert metrics.recall_at_m(approx2, ideal2) == pytest.approx(0.75)


def test_ncs_zero_and_missing_scores():
    approx = np.array([[0.5, 0.0]], np.float64)
    ideal = np.array([[1.0, 1.0]], np.float64)
    assert metrics.ncs_at_m(approx, ideal) == pytest.approx(0.25)
    # all-zero ideal: guarded denominator, no division blow-up
    z = np.zeros((1, 2))
    assert metrics.ncs_at_m(z, z) == 0.0
    # -inf padding (missing results) contributes nothing
    pad = np.array([[0.5, -np.inf]], np.float64)
    assert metrics.ncs_at_m(pad, ideal) == pytest.approx(0.25)
    # negative similarities clamp to 0 on both sides
    neg = np.array([[-0.5, -0.5]], np.float64)
    assert metrics.ncs_at_m(neg, ideal) == 0.0


def test_query_record_asdict_schema_stable():
    # the flight export feeds external tooling: pin the field set
    fields = set(QueryRecord.__dataclass_fields__)
    assert {"qid", "kind", "t_us", "latency_us", "cache_hit", "generation",
            "batch", "batch_size", "probes_issued", "probes_routed",
            "dropped_probes", "dropped_by_dest", "nodes_contacted",
            "replica_fanout", "stage_us", "extra"} == fields
    d = dataclasses.asdict(QueryRecord(qid=3, extra=dict(x=1)))
    assert d["qid"] == 3 and d["extra"] == {"x": 1}
