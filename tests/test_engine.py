"""Reference engine: Algorithms 1-2, Table-1 costs, Layered equivalence."""


import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DenseCorpus, EngineConfig, LshEngine, LshParams,
    make_hyperplanes, paper_topology,
)
from repro.core import layered as lay
from repro.core import hashing
from repro.core.store import build_store_host
from repro.core.engine import dedupe_topk


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(7)
    N, D, k, L = 3000, 48, 6, 3
    params = LshParams(d=D, k=k, L=L, seed=11)
    h = make_hyperplanes(params)
    vecs = np.abs(rng.standard_normal((N, D))).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    codes = np.asarray(hashing.sketch_codes(jnp.asarray(vecs), h))
    store = build_store_host(codes, params.num_buckets, capacity=256)
    corpus = DenseCorpus(jnp.asarray(vecs))
    topo = paper_topology(k)
    q = jnp.asarray(vecs[:64])
    return params, h, store, corpus, topo, q, vecs


def _engine(setup, variant, **kw):
    params, h, store, corpus, topo, q, _ = setup
    return LshEngine(params, h, store, corpus, topo,
                     EngineConfig(variant=variant, **kw))


def test_nb_equals_cnb_results(setup):
    q = setup[5]
    r_nb = _engine(setup, "nb").search(q, m=10)
    r_cnb = _engine(setup, "cnb").search(q, m=10)
    assert np.array_equal(r_nb.ids, r_cnb.ids)
    # but costs differ per Table 1
    assert r_nb.cost.messages == 3 * r_cnb.cost.messages


def test_nb_candidates_superset_of_lsh(setup):
    q = setup[5]
    r_lsh = _engine(setup, "lsh").search(q, m=10)
    r_nb = _engine(setup, "nb").search(q, m=10)
    # every LSH hit must appear in NB's candidate pool: its top-m scores
    # cannot be worse
    lsh_min = np.where(np.isfinite(r_lsh.scores), r_lsh.scores, 0).sum(1)
    nb_min = np.where(np.isfinite(r_nb.scores), r_nb.scores, 0).sum(1)
    assert np.all(nb_min >= lsh_min - 1e-5)


def test_simulated_messages_match_table1(setup):
    q = setup[5]
    for variant in ("lsh", "nb", "cnb"):
        e = _engine(setup, variant)
        r = e.search(q, m=10, simulate_messages=True,
                     rng=np.random.default_rng(3))
        # expected-hops simulation converges to the closed form
        assert abs(r.sim_messages - r.cost.messages) < 0.15 * r.cost.messages


def test_self_exclusion(setup):
    q = setup[5]
    e = _engine(setup, "cnb")
    r = e.search(q, m=10, exclude=np.arange(64))
    assert not np.any(r.ids == np.arange(64)[:, None])


def test_contains_probability_reasonable(setup):
    """The empirical success probability of finding a 1-near neighbor's id
    must be >= LSH's (more buckets searched)."""
    params, h, store, corpus, topo, q, vecs = setup
    rng = np.random.default_rng(5)
    targets = rng.integers(0, vecs.shape[0], size=64)
    p_lsh = _engine(setup, "lsh").contains(q, targets).mean()
    p_nb = _engine(setup, "nb").contains(q, targets).mean()
    assert p_nb >= p_lsh


def test_ranked_probes_subset(setup):
    """Beyond-paper: probing p < k margin-ranked near buckets costs less
    and finds at least what unranked p probes find on average."""
    q = setup[5]
    e_full = _engine(setup, "cnb")
    e_p2 = _engine(setup, "cnb", num_probes=2, ranked_probes=True)
    assert e_p2.probes_per_table == 3
    assert e_full.probes_per_table == 7
    r = e_p2.search(q, m=10)
    assert r.ids.shape == (64, 10)


def test_dedupe_topk():
    ids = jnp.asarray([[3, 1, 3, 2, -1]])
    scores = jnp.asarray([[0.5, 0.9, 0.5, 0.7, 100.0]])
    top_i, top_s = dedupe_topk(ids, scores, 3)
    assert np.asarray(top_i).tolist() == [[1, 2, 3]]
    assert np.allclose(np.asarray(top_s), [[0.9, 0.7, 0.5]])


@pytest.mark.parametrize("variant", ["lsh", "nb", "cnb"])
@pytest.mark.parametrize(
    "probe_kw",
    [dict(), dict(num_probes=2, ranked_probes=True), dict(num_probes=3)],
    ids=["all-probes", "ranked-p2", "unranked-p3"],
)
def test_kernel_path_equals_reference(setup, variant, probe_kw):
    """use_kernels=True (fused Pallas simhash + bucket_topk, interpret mode
    on CPU) returns bit-identical ids to the reference path."""
    params, h, store, corpus, topo, q, _ = setup
    nq = q.shape[0]
    exclude = np.arange(nq)
    ref = LshEngine(
        params, h, store, corpus, topo, EngineConfig(variant=variant, **probe_kw)
    ).search(q, m=10, exclude=exclude)
    ker = LshEngine(
        params, h, store, corpus, topo,
        EngineConfig(variant=variant, use_kernels=True, **probe_kw),
    ).search(q, m=10, exclude=exclude)
    assert np.array_equal(ref.ids, ker.ids)
    # empty slots must be -inf on BOTH paths (score_topk contract), and the
    # finite scores must agree to float tolerance.
    assert np.array_equal(np.isfinite(ref.scores), np.isfinite(ker.scores))
    np.testing.assert_allclose(
        np.where(np.isfinite(ref.scores), ref.scores, 0.0),
        np.where(np.isfinite(ker.scores), ker.scores, 0.0),
        atol=1e-5,
    )


def test_kernel_path_rejects_sparse_corpus(setup):
    """The fused kernel scores dense payloads; sparse corpora must refuse
    the knob instead of silently densifying."""
    from repro.core.corpus import SparseCorpus
    import jax.numpy as jnp2

    params, h, store, _, topo, _, _ = setup
    sparse = SparseCorpus(
        jnp2.zeros((4, 2), jnp2.int32), jnp2.zeros((4, 2), jnp2.float32),
        d=params.d,
    )
    with pytest.raises(ValueError, match="use_kernels"):
        LshEngine(params, h, store, sparse, topo,
                  EngineConfig(variant="cnb", use_kernels=True))


def test_ragged_batch_padding(setup):
    """Batch sizes that don't divide the chunk size pad internally and
    return exactly nq rows — same results as a chunk-aligned run."""
    params, h, store, corpus, topo, q, _ = setup
    e = _engine(setup, "cnb")
    r_full = e.search(q, m=10)
    odd = q[:37]  # 37 % 32 != 0
    r_odd = _engine(setup, "cnb").search(odd, m=10)
    assert r_odd.ids.shape == (37, 10)
    assert np.array_equal(r_odd.ids, r_full.ids[:37])


def test_layered_equivalence(setup):
    """Sec. 5.2: Hamming-LSH over cosine sketches == cosine-LSH(k_node)."""
    params, h, store, corpus, topo, q, vecs = setup
    lp = lay.LayeredParams(inner=params, k_node=4, seed=3)
    sel = lay.make_bit_selection(lp)
    node_of = lay.layered_node_of(q, lp, h, sel)
    h_eq = lay.equivalent_hyperplanes(lp, h, sel)
    direct = hashing.sketch_codes(q, h_eq)
    assert np.array_equal(np.asarray(node_of), np.asarray(direct))
