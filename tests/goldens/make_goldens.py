"""Regenerate the golden outputs (`engine_v1.npz`, `runtime_2node_v1.npz`,
`runtime_2node_packed_v1.npz`).

`engine_v1.npz` was captured from the PRE-runtime-refactor `LshEngine`
(PR 3 tree) and pins its exact search/contains outputs: the refactored
engine façade and the 1-node `IndexRuntime` must keep returning
bit-identical ids (tests/test_runtime.py).  `runtime_2node_v1.npz` pins
the 2-node mesh runtime's exact outputs on the SAME corpus/queries (no
exclusion — the mesh wire path has none), and is what the elastic
reshard round-trip (1 -> 2 -> 1 nodes) is checked against in the slow
suite.  `runtime_2node_packed_v1.npz` pins the packed-hamming mesh path
(PR 10): the 2-node `score="hamming"` runtime routing [.., W] uint32
sketch words over the all_to_all, asserted AT GENERATION TIME to be
bit-identical to the 1-node hamming run — the mesh must not change
results, only placement.  Regenerating any of them is ONLY legitimate
when the reference semantics intentionally change — never to make a
failing equivalence test pass.

    PYTHONPATH=src python tests/goldens/make_goldens.py

(The 2-node build needs 2 host devices; the script spawns itself in a
subprocess with XLA_FLAGS set, since the device count is fixed at jax
backend init.)
"""

from __future__ import annotations

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BucketStore, DenseCorpus, EngineConfig, LshEngine, LshParams,
    make_hyperplanes,
)
from repro.core.hashing import sketch_codes_batched
from repro.core.store import build_store_host

N, D, K, L, M, NQ = 1200, 32, 5, 3, 10, 48

PROBE_CELLS = [
    ("full", dict()),
    ("p2", dict(num_probes=2)),
    ("ranked3", dict(num_probes=3, ranked_probes=True)),
]


def _build_setup():
    """The shared corpus/store/query world of BOTH goldens."""
    rng = np.random.default_rng(17)
    vecs = rng.standard_normal((N, D)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    params = LshParams(d=D, k=K, L=L, seed=23)
    h = make_hyperplanes(params)
    codes = sketch_codes_batched(jnp.asarray(vecs), h)
    store = build_store_host(codes, params.num_buckets, capacity=64,
                             payload=vecs)
    targets = rng.integers(0, N, size=NQ).astype(np.int32)
    return params, h, store, vecs, targets


def build():
    params, h, store, vecs, targets = _build_setup()
    ids_only = BucketStore(store.ids, store.timestamps, store.write_ptr, None)
    corpus = DenseCorpus(jnp.asarray(vecs))
    q = jnp.asarray(vecs[:NQ])
    exclude = np.arange(NQ, dtype=np.int32)

    out = {}
    for variant in ("lsh", "nb", "cnb"):
        for cell, pkw in PROBE_CELLS:
            eng = LshEngine(params, h, ids_only, corpus, None,
                            EngineConfig(variant=variant, **pkw))
            r = eng.search(q, m=M, exclude=exclude)
            out[f"search_ids_{variant}_{cell}"] = r.ids
            out[f"search_scores_{variant}_{cell}"] = r.scores
            out[f"contains_{variant}_{cell}"] = eng.contains(q, targets)
    out["targets"] = targets
    return out


def build_two_node():
    """2-node mesh runtime outputs (needs 2 host devices)."""
    from repro.core.runtime import IndexRuntime, RuntimeConfig
    from repro.launch.mesh import make_zone_mesh

    params, h, store, vecs, targets = _build_setup()
    q = jnp.asarray(vecs[:NQ])
    mesh = make_zone_mesh(2)

    out = {"targets": targets}
    for variant in ("lsh", "nb", "cnb"):
        rt = IndexRuntime(
            RuntimeConfig(params=params, variant=variant, m=M, n_nodes=2,
                          cap_factor=float(L)),
            mesh=mesh,
        )
        store_sh = rt.shard_store(store)
        cache = rt.refresh_cache(store_sh) if variant == "cnb" else None
        ids, scores, dropped = rt.search(h, store_sh, q, cache=cache)
        assert int(dropped) == 0, (variant, int(dropped))
        out[f"search_ids_{variant}"] = np.asarray(ids)
        out[f"search_scores_{variant}"] = np.asarray(scores)
        hits, cdrop = rt.contains(h, store_sh, q, targets, cache=cache)
        assert int(cdrop) == 0, (variant, int(cdrop))
        out[f"contains_{variant}"] = np.asarray(hits)
    return out


def build_two_node_packed():
    """2-node packed-hamming mesh outputs (needs 2 host devices).

    Every cell is asserted bit-identical to the 1-node hamming run on
    the same packed store before it is written: exact integer popcount
    scores and the lowest-id tie-break make the routed merge and the
    local merge agree exactly, so the golden doubles as the proof that
    the mesh adds placement, not drift."""
    from repro.core import packed
    from repro.core.runtime import IndexRuntime, RuntimeConfig
    from repro.launch.mesh import make_zone_mesh

    params, h, store, vecs, targets = _build_setup()
    sth = packed.pack_store_payload(store, h)
    q = jnp.asarray(vecs[:NQ])
    mesh = make_zone_mesh(2)

    out = {"targets": targets}
    for variant in ("lsh", "nb", "cnb"):
        local = IndexRuntime(
            RuntimeConfig(params=params, variant=variant, m=M,
                          score="hamming"))
        ids_1, sc_1, _ = local.search(h, sth, q)
        hits_1, _ = local.contains(h, sth, q, targets)
        rt = IndexRuntime(
            RuntimeConfig(params=params, variant=variant, m=M, n_nodes=2,
                          score="hamming", cap_factor=float(L)),
            mesh=mesh,
        )
        store_sh = rt.shard_store(sth)
        cache = rt.refresh_cache(store_sh) if variant == "cnb" else None
        ids, scores, dropped = rt.search(h, store_sh, q, cache=cache)
        assert int(dropped) == 0, (variant, int(dropped))
        np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids_1))
        np.testing.assert_array_equal(np.asarray(scores), np.asarray(sc_1))
        hits, cdrop = rt.contains(h, store_sh, q, targets, cache=cache)
        assert int(cdrop) == 0, (variant, int(cdrop))
        np.testing.assert_array_equal(np.asarray(hits), np.asarray(hits_1))
        out[f"search_ids_{variant}"] = np.asarray(ids)
        out[f"search_scores_{variant}"] = np.asarray(scores)
        out[f"contains_{variant}"] = np.asarray(hits)
    return out


if __name__ == "__main__":
    here = os.path.dirname(os.path.abspath(__file__))
    if "--two-node" in sys.argv:
        path = os.path.join(here, "runtime_2node_v1.npz")
        np.savez_compressed(path, **build_two_node())
        print(f"wrote {path}")
    elif "--two-node-packed" in sys.argv:
        path = os.path.join(here, "runtime_2node_packed_v1.npz")
        np.savez_compressed(path, **build_two_node_packed())
        print(f"wrote {path}")
    else:
        path = os.path.join(here, "engine_v1.npz")
        np.savez_compressed(path, **build())
        print(f"wrote {path}")
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        for flag in ("--two-node", "--two-node-packed"):
            subprocess.run(
                [sys.executable, os.path.abspath(__file__), flag],
                env=env, check=True,
            )
