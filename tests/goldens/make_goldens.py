"""Regenerate the engine golden outputs (`engine_v1.npz`).

The goldens were captured from the PRE-runtime-refactor `LshEngine`
(PR 3 tree) and pin its exact search/contains outputs: the refactored
engine façade and the 1-node `IndexRuntime` must keep returning
bit-identical ids (tests/test_runtime.py).  Regenerating is therefore
ONLY legitimate when the reference semantics intentionally change —
never to make a failing equivalence test pass.

    PYTHONPATH=src python tests/goldens/make_goldens.py
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BucketStore, DenseCorpus, EngineConfig, LshEngine, LshParams,
    make_hyperplanes,
)
from repro.core.hashing import sketch_codes_batched
from repro.core.store import build_store_host

N, D, K, L, M, NQ = 1200, 32, 5, 3, 10, 48

PROBE_CELLS = [
    ("full", dict()),
    ("p2", dict(num_probes=2)),
    ("ranked3", dict(num_probes=3, ranked_probes=True)),
]


def build():
    rng = np.random.default_rng(17)
    vecs = rng.standard_normal((N, D)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    params = LshParams(d=D, k=K, L=L, seed=23)
    h = make_hyperplanes(params)
    codes = sketch_codes_batched(jnp.asarray(vecs), h)
    store = build_store_host(codes, params.num_buckets, capacity=64,
                             payload=vecs)
    ids_only = BucketStore(store.ids, store.timestamps, store.write_ptr, None)
    corpus = DenseCorpus(jnp.asarray(vecs))
    q = jnp.asarray(vecs[:NQ])
    exclude = np.arange(NQ, dtype=np.int32)
    targets = rng.integers(0, N, size=NQ).astype(np.int32)

    out = {}
    for variant in ("lsh", "nb", "cnb"):
        for cell, pkw in PROBE_CELLS:
            eng = LshEngine(params, h, ids_only, corpus, None,
                            EngineConfig(variant=variant, **pkw))
            r = eng.search(q, m=M, exclude=exclude)
            out[f"search_ids_{variant}_{cell}"] = r.ids
            out[f"search_scores_{variant}_{cell}"] = r.scores
            out[f"contains_{variant}_{cell}"] = eng.contains(q, targets)
    out["targets"] = targets
    return out


if __name__ == "__main__":
    path = os.path.join(os.path.dirname(__file__), "engine_v1.npz")
    np.savez_compressed(path, **build())
    print(f"wrote {path}")
