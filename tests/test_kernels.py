"""Pallas kernels vs pure-jnp oracles (interpret mode shape/dtype sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize(
    "n,d,L,k",
    [(100, 512, 4, 12), (7, 64, 2, 6), (300, 1000, 3, 30),
     (256, 2048, 8, 15), (1, 128, 1, 1), (33, 96, 5, 10)],
)
def test_simhash_matches_ref(rng, n, d, L, k):
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    h = jnp.asarray(rng.standard_normal((L, k, d)), jnp.float32)
    got = ops.simhash(x, h)
    want = ref.simhash_ref(x, h)
    assert got.shape == want.shape and got.dtype == want.dtype
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_simhash_dtypes(rng, dtype):
    x = jnp.asarray(rng.standard_normal((64, 256)), dtype)
    h = jnp.asarray(rng.standard_normal((2, 8, 256)), dtype)
    got = ops.simhash(x, h)
    want = ref.simhash_ref(x, h)
    # bf16 rounding can flip signs on near-zero projections; codes must
    # still agree on ~all entries (discrete_boundary tolerance)
    frac = np.mean(np.asarray(got) == np.asarray(want))
    assert frac > 0.97


@pytest.mark.parametrize(
    "b,kc,d,m",
    [(16, 200, 64, 10), (3, 50, 32, 5), (8, 128, 256, 10),
     (1, 7, 16, 3), (40, 333, 48, 10)],
)
def test_bucket_topk_matches_ref(rng, b, kc, d, m):
    q = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    cand = jnp.asarray(rng.standard_normal((b, kc, d)), jnp.float32)
    valid = jnp.asarray(rng.random((b, kc)) > 0.3)
    gs, gi = ops.bucket_topk(q, cand, valid, m)
    ws, wi = ref.bucket_topk_ref(q, cand, valid, m)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws),
                               rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(gi), np.asarray(wi))


def test_bucket_topk_all_invalid(rng):
    q = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    cand = jnp.asarray(rng.standard_normal((4, 20, 32)), jnp.float32)
    valid = jnp.zeros((4, 20), bool)
    gs, gi = ops.bucket_topk(q, cand, valid, 5)
    assert np.all(np.asarray(gi) == -1)
    assert np.all(np.isneginf(np.asarray(gs)))


def test_bucket_topk_duplicate_scores_tiebreak(rng):
    """Ties break to the lowest candidate index in both kernel and ref."""
    q = jnp.ones((2, 16), jnp.float32)
    cand = jnp.ones((2, 30, 16), jnp.float32)  # all identical scores
    valid = jnp.ones((2, 30), bool)
    gs, gi = ops.bucket_topk(q, cand, valid, 4)
    ws, wi = ref.bucket_topk_ref(q, cand, valid, 4)
    assert np.array_equal(np.asarray(gi), np.asarray(wi))
    assert np.asarray(gi).tolist() == [[0, 1, 2, 3]] * 2


@pytest.mark.parametrize("n,kc", [(100, 50), (7, 200), (256, 128), (1, 1)])
def test_hamming_matches_ref(rng, n, kc):
    c = jnp.asarray(rng.integers(0, 2**31, (n,)), jnp.uint32)
    cc = jnp.asarray(rng.integers(0, 2**31, (n, kc)), jnp.uint32)
    got = ops.hamming(c, cc)
    want = ref.hamming_ref(c, cc)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_simhash_agrees_with_core_hashing(rng):
    """The kernel and repro.core.hashing must produce identical codes."""
    from repro.core import hashing
    from repro.core.hashing import LshParams

    params = LshParams(d=128, k=14, L=3, seed=5)
    h = hashing.make_hyperplanes(params)
    x = jnp.asarray(rng.standard_normal((50, 128)), jnp.float32)
    core = hashing.sketch_codes(x, h)
    kern = ops.simhash(x, h)
    assert np.array_equal(np.asarray(core), np.asarray(kern))
