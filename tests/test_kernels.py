"""Pallas kernels vs pure-jnp oracles (interpret mode shape/dtype sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize(
    "n,d,L,k",
    [(100, 512, 4, 12), (7, 64, 2, 6), (300, 1000, 3, 30),
     (256, 2048, 8, 15), (1, 128, 1, 1), (33, 96, 5, 10)],
)
def test_simhash_matches_ref(rng, n, d, L, k):
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    h = jnp.asarray(rng.standard_normal((L, k, d)), jnp.float32)
    got = ops.simhash(x, h)
    want = ref.simhash_ref(x, h)
    assert got.shape == want.shape and got.dtype == want.dtype
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_simhash_dtypes(rng, dtype):
    x = jnp.asarray(rng.standard_normal((64, 256)), dtype)
    h = jnp.asarray(rng.standard_normal((2, 8, 256)), dtype)
    got = ops.simhash(x, h)
    want = ref.simhash_ref(x, h)
    # bf16 rounding can flip signs on near-zero projections; codes must
    # still agree on ~all entries (discrete_boundary tolerance)
    frac = np.mean(np.asarray(got) == np.asarray(want))
    assert frac > 0.97


@pytest.mark.parametrize(
    "b,kc,d,m",
    [(16, 200, 64, 10), (3, 50, 32, 5), (8, 128, 256, 10),
     (1, 7, 16, 3), (40, 333, 48, 10)],
)
def test_bucket_topk_matches_ref(rng, b, kc, d, m):
    q = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    cand = jnp.asarray(rng.standard_normal((b, kc, d)), jnp.float32)
    valid = jnp.asarray(rng.random((b, kc)) > 0.3)
    gs, gi = ops.bucket_topk(q, cand, valid, m)
    ws, wi = ref.bucket_topk_ref(q, cand, valid, m)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws),
                               rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(gi), np.asarray(wi))


def test_bucket_topk_all_invalid(rng):
    q = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    cand = jnp.asarray(rng.standard_normal((4, 20, 32)), jnp.float32)
    valid = jnp.zeros((4, 20), bool)
    gs, gi = ops.bucket_topk(q, cand, valid, 5)
    assert np.all(np.asarray(gi) == -1)
    assert np.all(np.isneginf(np.asarray(gs)))


def test_bucket_topk_duplicate_scores_tiebreak(rng):
    """Ties break to the lowest candidate index in both kernel and ref."""
    q = jnp.ones((2, 16), jnp.float32)
    cand = jnp.ones((2, 30, 16), jnp.float32)  # all identical scores
    valid = jnp.ones((2, 30), bool)
    gs, gi = ops.bucket_topk(q, cand, valid, 4)
    ws, wi = ref.bucket_topk_ref(q, cand, valid, 4)
    assert np.array_equal(np.asarray(gi), np.asarray(wi))
    assert np.asarray(gi).tolist() == [[0, 1, 2, 3]] * 2


@pytest.mark.parametrize("n,kc", [(100, 50), (7, 200), (256, 128), (1, 1)])
def test_hamming_matches_ref(rng, n, kc):
    c = jnp.asarray(rng.integers(0, 2**31, (n,)), jnp.uint32)
    cc = jnp.asarray(rng.integers(0, 2**31, (n, kc)), jnp.uint32)
    got = ops.hamming(c, cc)
    want = ref.hamming_ref(c, cc)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_simhash_agrees_with_core_hashing(rng):
    """The kernel and repro.core.hashing must produce identical codes."""
    from repro.core import hashing
    from repro.core.hashing import LshParams

    params = LshParams(d=128, k=14, L=3, seed=5)
    h = hashing.make_hyperplanes(params)
    x = jnp.asarray(rng.standard_normal((50, 128)), jnp.float32)
    core = hashing.sketch_codes(x, h)
    kern = ops.simhash(x, h)
    assert np.array_equal(np.asarray(core), np.asarray(kern))


@pytest.mark.parametrize("n,kc,w", [(100, 50, 1), (7, 33, 2), (64, 128, 5)])
def test_hamming_words_matches_ref(rng, n, kc, w):
    """Multi-word packed rows (the core.packed layout)."""
    c = jnp.asarray(rng.integers(0, 2**31, (n, w)), jnp.uint32)
    cc = jnp.asarray(rng.integers(0, 2**31, (n, kc, w)), jnp.uint32)
    got = ops.hamming(c, cc)
    want = ref.hamming_words_ref(c, cc)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,d,L,k", [(40, 96, 3, 11), (9, 64, 5, 7),
                                     (33, 128, 1, 30)])
def test_simhash_packed_matches_pack_codes(rng, n, d, L, k):
    """In-kernel packed-word emit == pack_codes over the unpacked codes."""
    from repro.core import packed

    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    h = jnp.asarray(rng.standard_normal((L, k, d)), jnp.float32)
    words = ops.simhash(x, h, packed=True)
    want = packed.pack_codes(ops.simhash(x, h), k)
    assert words.shape == (n, packed.num_words(k, L))
    assert np.array_equal(np.asarray(words), np.asarray(want))


def _fused_inputs(rng, t, nb, c, d, r, p, id_max=60):
    ids_flat = np.full((t * nb, c), -1, np.int32)
    pay_flat = np.zeros((t * nb, c, d), np.float32)
    for row in range(t * nb):
        live = rng.integers(0, c + 1)
        ids_flat[row, :live] = rng.integers(0, id_max, size=live)
        pay_flat[row, :live] = rng.standard_normal((live, d))
    fb = rng.integers(0, t * nb, size=(r, p)).astype(np.int32)
    pword = rng.integers(0, 2**p, size=(r,)).astype(np.int32)
    excl = np.where(rng.random(r) < 0.5,
                    rng.integers(0, id_max, size=r), -1).astype(np.int32)
    meta = np.stack([pword, excl], axis=1).astype(np.int32)
    q = rng.standard_normal((r, d)).astype(np.float32)
    return (jnp.asarray(ids_flat), jnp.asarray(pay_flat), jnp.asarray(q),
            jnp.asarray(fb), jnp.asarray(meta))


@pytest.mark.parametrize(
    "t,nb,c,d,r,p,m",
    [(3, 8, 6, 16, 14, 5, 4), (1, 4, 3, 8, 5, 1, 2),
     (2, 16, 10, 32, 30, 6, 10), (4, 4, 1, 8, 8, 3, 1)],
)
def test_fused_query_matches_ref(rng, t, nb, c, d, r, p, m):
    ids_flat, pay_flat, q, fb, meta = _fused_inputs(rng, t, nb, c, d, r, p)
    gi, gs = ops.fused_query(ids_flat, pay_flat, q, fb, meta, m=m)
    wi, ws = ref.fused_query_ref(ids_flat, pay_flat, q, fb, meta, m=m)
    assert np.array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("w", [1, 2, 4])
def test_fused_query_hamming_matches_ref_bitexact(rng, w):
    t, nb, c, r, p, m = 2, 8, 5, 12, 4, 6
    ids_flat, _, _, fb, meta = _fused_inputs(rng, t, nb, c, 8, r, p)
    pay = rng.integers(0, 2**32, size=(t * nb, c, w), dtype=np.uint32)
    pay[np.asarray(ids_flat) < 0] = 0
    qw = jnp.asarray(
        rng.integers(0, 2**32, size=(r, w), dtype=np.uint32))
    pay = jnp.asarray(pay)
    gi, gs = ops.fused_query(ids_flat, pay, qw, fb, meta, m=m,
                             score="hamming")
    wi, ws = ref.fused_query_ref(ids_flat, pay, qw, fb, meta, m=m,
                                 score="hamming")
    # integer scores: ids AND scores bit-equal
    assert np.array_equal(np.asarray(gi), np.asarray(wi))
    assert np.array_equal(np.asarray(gs), np.asarray(ws))


@pytest.mark.parametrize("tb,kc", [(2, 4), (8, 8), (16, 32)])
def test_fused_query_block_shape_invariance(rng, tb, kc):
    """Autotuned block shapes must never change results, only speed."""
    ids_flat, pay_flat, q, fb, meta = _fused_inputs(rng, 2, 8, 6, 16, 13, 4)
    gi0, gs0 = ops.fused_query(ids_flat, pay_flat, q, fb, meta, m=5)
    gi, gs = ops.fused_query(ids_flat, pay_flat, q, fb, meta, m=5,
                             tb=tb, kc=kc)
    assert np.array_equal(np.asarray(gi), np.asarray(gi0))
    np.testing.assert_allclose(np.asarray(gs), np.asarray(gs0),
                               rtol=1e-5, atol=1e-6)


def test_fused_contains_matches_ref(rng):
    t, nb, c, r, p = 3, 8, 6, 20, 5
    ids_flat, _, _, fb, meta = _fused_inputs(rng, t, nb, c, 8, r, p)
    tgt = rng.integers(0, 60, size=r).astype(np.int32)
    meta = jnp.asarray(
        np.stack([np.asarray(meta)[:, 0], tgt], axis=1).astype(np.int32))
    got = ops.fused_contains(ids_flat, fb, meta)
    want = ref.fused_contains_ref(ids_flat, fb, meta)
    assert np.array_equal(np.asarray(got), np.asarray(want)[:, 0] > 0)
