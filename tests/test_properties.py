"""Property-based invariant suite (hypothesis, behind the conftest guard).

Two families of invariants that example tests can only spot-check:

  * CAN geometry — the traced (jnp) and host (np) coordinate backends of
    `CanTopology` agree everywhere, `code_of(node_of, local_of)` is the
    identity, zones tile the bucket space, and the elastic-membership
    closed form (`moved_buckets`) matches an exact owner-array count for
    every power-of-two join/leave round;
  * replica coverage — for any R-way placement (`replicas_of`) and ANY
    single fail-stop loss, every bucket keeps >= R-1 live replica owners
    and the quorum-readable id set is a superset of the survivor-only
    reference (complete at R >= 2);
  * routing conservation — every planned probe is either delivered to its
    destination buffer exactly once or counted in `dropped`, never both
    and never silently lost, over random destination plans and
    capacities (the counted-never-silent contract every distributed step
    builds on).

Each invariant lives in a plain `_check_*` helper so the suite degrades
gracefully: the hypothesis tests explore the space when the package is
installed (pinned deterministic profile, see conftest), and the
`*_examples` twins sweep a fixed seeded grid either way — the invariants
are always exercised in tier-1, hypothesis only widens the net.
"""

from __future__ import annotations

import numpy as np
from conftest import given, st  # hypothesis or skip-fallback

from repro.core.can import CanTopology, moved_buckets, survivor_of
from repro.core.routing import (
    build_send_buffer, plan_routes, return_to_origin,
)

# -----------------------------------------------------------------------------
# CAN geometry invariants
# -----------------------------------------------------------------------------


def _check_can_coordinates(k: int, a: int, codes: np.ndarray) -> None:
    """jnp/np backend agreement + node/local reconstruction round-trip."""
    topo = CanTopology(k=k, n_nodes=1 << a)
    codes = np.asarray(codes, dtype=np.uint32)

    n_np = topo.node_of_np(codes)
    l_np = topo.local_of_np(codes)
    # the traced backend computes the same coordinates
    assert np.array_equal(np.asarray(topo.node_of(codes)), n_np)
    assert np.array_equal(np.asarray(topo.local_of(codes)), l_np)
    # coordinates are in range and reconstruct the code exactly
    assert n_np.max(initial=0) < topo.n_nodes
    assert l_np.max(initial=0) < topo.buckets_per_node
    rebuilt = np.asarray(
        [topo.code_of(int(n), int(l)) for n, l in zip(n_np, l_np)],
        dtype=np.uint32,
    )
    assert np.array_equal(rebuilt, codes)
    # every code sits inside its owner's contiguous zone
    for c, n in zip(codes, n_np):
        start, end = topo.zone_range(int(n))
        assert start <= int(c) < end


def _check_zone_tiling(k: int, a: int) -> None:
    """Zones partition the bucket space: disjoint, contiguous, complete."""
    topo = CanTopology(k=k, n_nodes=1 << a)
    covered = []
    for node in range(topo.n_nodes):
        start, end = topo.zone_range(node)
        assert end - start == topo.buckets_per_node
        covered.extend(range(start, end))
    assert covered == list(range(1 << k))


def _check_moved_buckets(k: int, a_old: int, a_new: int) -> None:
    """The handoff closed form equals the exact owner-array count.

    A bucket survives in place iff its old owner is a survivor of the
    round (for a leave: the first node of its sibling group) AND its new
    owner is that survivor's image — everything else is handed off.
    """
    old = CanTopology(k=k, n_nodes=1 << a_old)
    new = CanTopology(k=k, n_nodes=1 << a_new)
    codes = np.arange(1 << k, dtype=np.uint32)
    own_old = old.node_of_np(codes)
    own_new = new.node_of_np(codes)
    if new.n_nodes >= old.n_nodes:
        survives = np.ones_like(own_old, dtype=bool)  # joins: all survive
    else:
        r = old.n_nodes // new.n_nodes
        survives = own_old % r == 0
    stays = survives & (own_new == survivor_of(old, new, own_old))
    moved_exact = int((~stays).sum())
    assert moved_buckets(old, new) == moved_exact
    # symmetry: a join and the leave that undoes it move the same rows
    assert moved_buckets(old, new) == moved_buckets(new, old)


@given(st.integers(1, 12), st.integers(0, 6), st.integers(0, 2**32 - 1))
def test_can_coordinates_property(k, a, seed):
    a = min(a, k)
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 1 << k, size=32, dtype=np.uint32)
    _check_can_coordinates(k, a, codes)


@given(st.integers(1, 10), st.integers(0, 5))
def test_zone_tiling_property(k, a):
    _check_zone_tiling(k, min(a, k))


@given(st.integers(1, 12), st.integers(0, 6), st.integers(0, 6))
def test_moved_buckets_property(k, a_old, a_new):
    _check_moved_buckets(k, min(a_old, k), min(a_new, k))


def test_can_invariants_examples():
    """Seeded sweep of the same invariants (runs with or without
    hypothesis — the property tests only widen the net)."""
    rng = np.random.default_rng(7)
    for k in (1, 3, 6, 9, 12):
        for a in range(0, min(k, 5) + 1):
            codes = rng.integers(0, 1 << k, size=48, dtype=np.uint32)
            _check_can_coordinates(k, a, codes)
            _check_zone_tiling(k, a)
            for a_new in range(0, min(k, 5) + 1):
                _check_moved_buckets(k, a, a_new)


# -----------------------------------------------------------------------------
# replica coverage invariants (DESIGN.md Sec. 10)
# -----------------------------------------------------------------------------


def _check_replica_coverage(k: int, a: int, R: int, dead: int) -> None:
    """R-way placement survives any single fail-stop loss.

    For every bucket: its R owners (`replicas_of`) are distinct nodes led
    by the primary, and after killing ANY one node at least R-1 of them
    are still alive — so with R >= 2 every bucket stays readable.  Under
    the read model (each live owner serves its full zone copy), the
    quorum-read id set (any live owner) is a SUPERSET of the
    survivor-only reference (primary alive), and at R >= 2 a single kill
    leaves it complete.
    """
    topo = CanTopology(k=k, n_nodes=1 << a)
    R = min(R, topo.n_nodes)
    codes = np.arange(1 << k, dtype=np.uint32)
    owners = np.asarray(topo.replicas_of(codes, R))          # [B, R]

    assert owners.shape == (codes.size, R)
    assert np.array_equal(owners[:, 0], topo.node_of_np(codes))
    assert owners.min() >= 0 and owners.max() < topo.n_nodes
    # the R owners of a bucket are R DISTINCT nodes (ring successors)
    assert all(len({int(x) for x in row}) == R for row in owners)

    live = np.ones(topo.n_nodes, dtype=bool)
    live[dead % topo.n_nodes] = False
    live_owners = live[owners]                               # [B, R]
    assert np.all(live_owners.sum(axis=1) >= R - 1)
    if R >= 2:
        assert np.all(live_owners.any(axis=1))               # readable

    # read model: bucket is servable by its primary alone (survivor-only
    # reference) vs by any live owner (what first/quorum reads reach)
    survivor_ids = set(codes[live[owners[:, 0]]].tolist())
    quorum_ids = set(codes[live_owners.any(axis=1)].tolist())
    assert survivor_ids <= quorum_ids
    if R >= 2:
        assert quorum_ids == set(codes.tolist())             # no hole


@given(st.integers(1, 10), st.integers(0, 5), st.integers(1, 8),
       st.integers(0, 2**31 - 1))
def test_replica_coverage_property(k, a, R, dead):
    _check_replica_coverage(k, min(a, k), R, dead)


def test_replica_coverage_examples():
    for k in (1, 3, 6, 9):
        for a in range(0, min(k, 4) + 1):
            n = 1 << a
            for R in (1, 2, 3, n):
                for dead in range(n):
                    _check_replica_coverage(k, a, R, dead)


# -----------------------------------------------------------------------------
# routing conservation invariants
# -----------------------------------------------------------------------------


def _check_routing_conservation(
    dest: np.ndarray, n_dests: int, cap: int
) -> None:
    """Exactly-once delivery or counted drop — never both, never silent."""
    dest = np.asarray(dest, dtype=np.int32)
    n = dest.shape[0]
    route = plan_routes(dest, n_dests, cap)
    ok = np.asarray(route.ok)
    dropped = int(route.dropped)

    # conservation: every planned item is delivered xor counted dropped
    assert int(ok.sum()) + dropped == n
    # the drop count is exactly the per-destination overflow
    counts = np.bincount(dest, minlength=n_dests)
    assert dropped == int(np.maximum(counts - cap, 0).sum())

    # payload values are distinct, so the buffer tells us WHO landed:
    # each surviving item appears exactly once, at its own destination,
    # and no dropped item's value appears anywhere.
    values = np.arange(10, 10 + n, dtype=np.int32)  # distinct, > fill
    buf = np.asarray(
        build_send_buffer(route, n_dests, cap, values, fill=-1)
    )
    assert buf.shape == (n_dests, cap)
    landed = buf[buf >= 0]
    order = np.asarray(route.order)
    ok_orig = np.zeros(n, dtype=bool)
    ok_orig[order] = ok  # ok is in destination-sorted order
    assert sorted(landed.tolist()) == sorted(values[ok_orig].tolist())
    for d in range(n_dests):
        row = buf[d][buf[d] >= 0]
        assert np.all(dest[row - 10] == d)  # landed at the planned dest

    # the origin-side gather returns each survivor's own result and the
    # fill sentinel (never another item's slot) for every dropped item
    back = return_to_origin(route, buf, fill=-1)
    back = np.asarray(back)
    assert np.array_equal(back[ok_orig], values[ok_orig])
    assert np.all(back[~ok_orig] == -1)


@given(
    st.integers(1, 8),                 # n_dests
    st.integers(1, 16),                # cap
    st.lists(st.integers(0, 7), min_size=1, max_size=64),
)
def test_routing_conservation_property(n_dests, cap, dests):
    dest = np.asarray(dests, dtype=np.int32) % n_dests
    _check_routing_conservation(dest, n_dests, cap)


def test_routing_conservation_examples():
    rng = np.random.default_rng(11)
    for n_dests, cap, n in [
        (1, 1, 1), (2, 1, 8), (4, 3, 40), (8, 16, 64), (3, 2, 17),
        (5, 4, 64),
    ]:
        for _ in range(4):
            dest = rng.integers(0, n_dests, size=n).astype(np.int32)
            _check_routing_conservation(dest, n_dests, cap)
    # adversarial: everything to one destination (max overflow)
    _check_routing_conservation(np.zeros(32, np.int32), 4, 3)
    # no overflow possible
    _check_routing_conservation(np.arange(8, dtype=np.int32) % 8, 8, 8)
