"""Shared probe planner: one query discipline for both runtimes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LshParams, make_hyperplanes
from repro.core import hashing, plan
from repro.core.can import CanTopology


@pytest.fixture(scope="module")
def setup(rng):
    params = LshParams(d=16, k=6, L=3, seed=2)
    h = make_hyperplanes(params)
    q = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    return params, h, q


def test_spec_sizes(setup):
    params, _, _ = setup
    assert plan.ProbeSpec(params, "lsh").probes_per_table == 1
    assert plan.ProbeSpec(params, "cnb").probes_per_table == 7
    assert plan.ProbeSpec(params, "cnb", num_probes=2).probes_per_table == 3
    # budgets beyond k clamp (there are only k 1-near buckets)
    assert plan.ProbeSpec(params, "nb", num_probes=99).probes_per_table == 7
    with pytest.raises(ValueError):
        plan.ProbeSpec(params, "bogus")


def test_full_probe_plan(setup):
    params, h, q = setup
    p = plan.make_plan(plan.ProbeSpec(params, "cnb"), q, h)
    codes = hashing.sketch_codes(q, h)
    assert np.array_equal(np.asarray(p.codes), np.asarray(codes))
    assert p.probes.shape == (8, params.L, 1 + params.k)
    # entry 0 is the exact bucket; entry 1+j flips bit j
    assert np.array_equal(np.asarray(p.probes[..., 0]), np.asarray(codes))
    for j in range(params.k):
        assert np.array_equal(
            np.asarray(p.probes[..., 1 + j]),
            np.asarray(codes) ^ (1 << j))
    assert np.all(np.asarray(p.probe_mask) == (1 << params.k) - 1)


def test_lsh_plan_probes_nothing_near(setup):
    params, h, q = setup
    p = plan.make_plan(plan.ProbeSpec(params, "lsh"), q, h)
    assert p.probes.shape == (8, params.L, 1)
    assert np.all(np.asarray(p.probe_mask) == 0)


def test_unranked_budget_mask(setup):
    params, h, q = setup
    p = plan.make_plan(plan.ProbeSpec(params, "cnb", num_probes=2), q, h)
    assert p.probes.shape == (8, params.L, 3)
    # unranked budget takes the first p bits
    assert np.all(np.asarray(p.probe_mask) == 0b11)


def test_ranked_budget_mask_matches_margins(setup):
    params, h, q = setup
    spec = plan.ProbeSpec(params, "cnb", num_probes=2, ranked_probes=True)
    p = plan.make_plan(spec, q, h)
    margins = np.asarray(hashing.projection_margins(q, h))  # [8, L, k]
    mask = np.asarray(p.probe_mask)
    for i in range(8):
        for l in range(params.L):
            want_bits = set(np.argsort(margins[i, l])[:2].tolist())
            got_bits = {j for j in range(params.k) if (mask[i, l] >> j) & 1}
            assert got_bits == want_bits, (i, l)
    # the probe codes flip exactly the masked bits
    probes = np.asarray(p.probes)
    codes = np.asarray(p.codes)
    for i in range(8):
        for l in range(params.L):
            flips = {int(codes[i, l] ^ c) for c in probes[i, l, 1:]}
            want = {1 << j for j in range(params.k) if (mask[i, l] >> j) & 1}
            assert flips == want


def test_owner_local_split(setup):
    params, h, q = setup
    topo = CanTopology(params.k, 4)
    p = plan.make_plan(plan.ProbeSpec(params, "cnb"), q, h, topo)
    codes = np.asarray(p.codes)
    assert np.array_equal(np.asarray(p.owner), codes >> topo.local_bits)
    assert np.array_equal(
        np.asarray(p.local_idx), codes & ((1 << topo.local_bits) - 1))


def test_shard_local_probes_mask(setup):
    params, _, _ = setup
    topo = CanTopology(params.k, 4)  # local_bits = 4
    local = jnp.asarray([5, 9], jnp.int32)
    mask = jnp.asarray([0b0011, 0b1000], jnp.uint32)
    probes, valid = plan.shard_local_probes(topo, local, mask,
                                            include_near=True)
    assert probes.shape == (2, 1 + topo.local_bits)
    assert np.array_equal(np.asarray(probes[0]),
                          [5, 5 ^ 1, 5 ^ 2, 5 ^ 4, 5 ^ 8])
    # exact always valid; near entries follow the mask bits
    assert np.asarray(valid).tolist() == [
        [True, True, True, False, False],
        [True, False, False, False, True],
    ]
    exact, always = plan.shard_local_probes(topo, local, mask,
                                            include_near=False)
    assert exact.shape == (2, 1) and bool(np.all(np.asarray(always)))


def test_node_bit_probe_valid(setup):
    params, _, _ = setup
    topo = CanTopology(params.k, 4)  # local_bits=4, node_bits=2
    mask = jnp.asarray([0b110000, 0b010000, 0], jnp.uint32)
    got = np.stack([
        np.asarray(plan.node_bit_probe_valid(topo, mask, b))
        for b in range(topo.node_bits)
    ], axis=-1)
    assert got.tolist() == [[True, True], [True, False], [False, False]]
