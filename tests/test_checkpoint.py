"""Checkpointing: atomic writes, checksums, elastic re-sharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt


def _tree(rng):
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
                   "b": jnp.zeros((16,), jnp.bfloat16)},
        "opt": {"count": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path, rng):
    tree = _tree(rng)
    d = ckpt.save(str(tmp_path), 10, tree, extra={"arch": "x"})
    assert ckpt.verify(d)
    restored = ckpt.restore(d, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    meta = ckpt.load_meta(d)
    assert meta["step"] == 10 and meta["arch"] == "x"


def test_latest_and_gc(tmp_path, rng):
    tree = _tree(rng)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep_last=3)
    latest = ckpt.latest_step_dir(str(tmp_path))
    assert latest.endswith("step_00000005")
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004", "step_00000005"]


def test_corruption_detected(tmp_path, rng):
    tree = _tree(rng)
    d = ckpt.save(str(tmp_path), 1, tree)
    with open(os.path.join(d, "arrays.npz"), "r+b") as f:
        f.seek(50)
        f.write(b"\xde\xad")
    assert not ckpt.verify(d)
    with pytest.raises(IOError):
        ckpt.restore(d, tree)


def test_shape_mismatch_rejected(tmp_path, rng):
    tree = _tree(rng)
    d = ckpt.save(str(tmp_path), 1, tree)
    bad = dict(tree)
    bad["params"] = {"w": jnp.zeros((4, 4)), "b": tree["params"]["b"]}
    with pytest.raises(ValueError):
        ckpt.restore(d, bad)


ELASTIC = r"""
import numpy as np, jax, jax.numpy as jnp, sys, os
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import checkpoint as ckpt
from repro.compat import make_mesh

tmp = sys.argv[1]
rng = np.random.default_rng(0)
tree = {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)}

# write under a (4, 2) mesh sharding
mesh_a = make_mesh((4, 2), ("data", "model"))
sharded = jax.device_put(tree["w"], NamedSharding(mesh_a, P("data", "model")))
d = ckpt.save(tmp, 1, {"w": sharded})

# restore under a DIFFERENT mesh shape (2, 4) — elastic re-sharding
mesh_b = make_mesh((2, 4), ("data", "model"))
target = NamedSharding(mesh_b, P("data", "model"))
restored = ckpt.restore(d, {"w": tree["w"]}, shardings={"w": target})
assert restored["w"].sharding == target
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
print("ELASTIC-OK")
"""


@pytest.mark.slow
def test_elastic_resharding(tmp_path):
    import subprocess
    import sys
    import textwrap

    from conftest import SRC

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(ELASTIC), str(tmp_path)],
        capture_output=True, text=True, timeout=300, env=env,
    )
    assert proc.returncode == 0, proc.stderr
    assert "ELASTIC-OK" in proc.stdout
