"""IndexRuntime consolidation contracts (DESIGN.md Sec. 8).

The engine/distributed split collapsed into one topology-parameterized
execution layer; these tests pin the consolidation down:

  * the refactored `LshEngine` façade returns BIT-IDENTICAL ids to the
    pre-refactor engine (checked-in goldens, tests/goldens/engine_v1.npz);
  * a 1-node `IndexRuntime` reproduces the engine on both payload models
    (id-keyed corpus and embedded bucket-slot payloads);
  * the mesh-mode runtime (shard_map, 1 shard — tier-1 single device)
    matches the 1-node runtime exactly;
  * the runtime's insert/expire/payload-sync steps reproduce the
    single-host store semantics on the degenerate topology;
  * the unified churn driver reports the same dict surface on every
    topology (drops counted, staleness tracked).

(The >= 2-shard equivalences run in the slow subprocess suites:
tests/test_distributed.py and tests/test_churn.py.)
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BucketStore, DenseCorpus, EngineConfig, LshEngine, LshParams,
    make_hyperplanes,
)
from repro.core.hashing import sketch_codes, sketch_codes_batched
from repro.core.runtime import IndexRuntime, RuntimeConfig
from repro.core.store import build_store_host, insert_batch, make_store

GOLDENS = os.path.join(os.path.dirname(__file__), "goldens", "engine_v1.npz")

# must mirror tests/goldens/make_goldens.py exactly
N, D, K, L, M, NQ = 1200, 32, 5, 3, 10, 48
PROBE_CELLS = [
    ("full", dict()),
    ("p2", dict(num_probes=2)),
    ("ranked3", dict(num_probes=3, ranked_probes=True)),
]


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(17)
    vecs = rng.standard_normal((N, D)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    params = LshParams(d=D, k=K, L=L, seed=23)
    h = make_hyperplanes(params)
    codes = sketch_codes_batched(jnp.asarray(vecs), h)
    store = build_store_host(codes, params.num_buckets, capacity=64,
                             payload=vecs)
    ids_only = BucketStore(store.ids, store.timestamps, store.write_ptr, None)
    corpus = DenseCorpus(jnp.asarray(vecs))
    golden = dict(np.load(GOLDENS))
    return params, h, store, ids_only, corpus, vecs, golden


def _cells():
    return [(v, name, pkw) for v in ("lsh", "nb", "cnb")
            for name, pkw in PROBE_CELLS]


@pytest.mark.parametrize("variant,cell,pkw", _cells(),
                         ids=[f"{v}-{c}" for v, c, _ in _cells()])
def test_engine_matches_prerefactor_goldens(setup, variant, cell, pkw):
    """The façade keeps the pre-refactor engine's exact outputs."""
    params, h, store, ids_only, corpus, vecs, golden = setup
    eng = LshEngine(params, h, ids_only, corpus, None,
                    EngineConfig(variant=variant, **pkw))
    q = jnp.asarray(vecs[:NQ])
    r = eng.search(q, m=M, exclude=np.arange(NQ))
    np.testing.assert_array_equal(
        r.ids, golden[f"search_ids_{variant}_{cell}"])
    np.testing.assert_allclose(
        r.scores, golden[f"search_scores_{variant}_{cell}"], atol=1e-6)
    hits = eng.contains(q, golden["targets"])
    np.testing.assert_array_equal(hits, golden[f"contains_{variant}_{cell}"])


def test_runtime_local_corpus_matches_goldens(setup):
    """The 1-node runtime drives the same kernel the engine wraps —
    calling it directly (host API) returns the same golden ids."""
    params, h, store, ids_only, corpus, vecs, golden = setup
    rt = IndexRuntime(RuntimeConfig(params=params, variant="cnb", m=M))
    q = vecs[:NQ]
    ids, scores, dropped = rt.search(
        h, ids_only, q, corpus=corpus, exclude=np.arange(NQ))
    assert int(dropped) == 0
    np.testing.assert_array_equal(
        np.asarray(ids), golden["search_ids_cnb_full"])
    hits, cdrop = rt.contains(h, ids_only, q, golden["targets"])
    assert int(cdrop) == 0
    np.testing.assert_array_equal(
        np.asarray(hits), golden["contains_cnb_full"])


def test_runtime_local_payload_matches_corpus(setup):
    """Embedded slot payloads (the sharded data model) and the id-keyed
    corpus (the reference data model) score identically when in sync."""
    params, h, store, ids_only, corpus, vecs, golden = setup
    rt = IndexRuntime(RuntimeConfig(params=params, variant="cnb", m=M))
    q = vecs[:NQ]
    ids_p, sc_p, _ = rt.search(h, store, q)
    ids_c, sc_c, _ = rt.search(h, ids_only, q, corpus=corpus)
    np.testing.assert_array_equal(np.asarray(ids_p), np.asarray(ids_c))
    np.testing.assert_allclose(np.asarray(sc_p), np.asarray(sc_c), atol=1e-6)


def test_mesh_runtime_matches_local(setup, single_mesh):
    """shard_map mode on the (1, 1) mesh is the same computation as the
    mesh-free 1-node mode — the adapter adds only placement."""
    params, h, store, ids_only, corpus, vecs, golden = setup
    q = vecs[:32]
    local = IndexRuntime(RuntimeConfig(params=params, variant="cnb", m=M))
    mesh_rt = IndexRuntime(
        RuntimeConfig(params=params, variant="cnb", m=M,
                      cap_factor=float(L)),
        mesh=single_mesh,
    )
    store_sh = mesh_rt.shard_store(store)
    ids_l, _, _ = local.search(h, store, q)
    ids_m, _, drop = mesh_rt.search(h, store_sh, q)
    assert int(drop) == 0
    np.testing.assert_array_equal(np.asarray(ids_l), np.asarray(ids_m))
    targets = np.arange(32, dtype=np.int32)
    hits_l, _ = local.contains(h, store, q, targets)
    hits_m, _ = mesh_rt.contains(h, store_sh, q, targets)
    np.testing.assert_array_equal(np.asarray(hits_l), np.asarray(hits_m))


def test_runtime_insert_matches_insert_batch(setup):
    """The topology-generic insert step at n_nodes=1 reproduces the
    single-host `insert_batch` store exactly (same codes, same slots)."""
    params, h, _, _, _, vecs, _ = setup
    nv = 200
    codes = sketch_codes(jnp.asarray(vecs[:nv]), h)
    ref = insert_batch(
        make_store(L, params.num_buckets, 16, payload_dim=D),
        jnp.arange(nv, dtype=jnp.int32), codes, jnp.int32(3),
        jnp.asarray(vecs[:nv]),
    )
    rt = IndexRuntime(RuntimeConfig(params=params, variant="cnb", m=M))
    st = rt.insert(h, make_store(L, params.num_buckets, 16, payload_dim=D),
                   vecs[:nv], np.arange(nv, dtype=np.int32), 3)
    np.testing.assert_array_equal(np.asarray(st.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(
        np.asarray(st.timestamps), np.asarray(ref.timestamps))
    np.testing.assert_allclose(
        np.asarray(st.payload), np.asarray(ref.payload))
    assert int(st.generation) == int(ref.generation) == L


def test_runtime_expire_and_payload_sync(setup):
    params, h, _, _, _, vecs, _ = setup
    nv = 64
    rt = IndexRuntime(RuntimeConfig(params=params, variant="cnb", m=M))
    st = rt.insert(h, make_store(L, params.num_buckets, 16, payload_dim=D),
                   vecs[:nv], np.arange(nv, dtype=np.int32), 1)
    gen0 = int(st.generation)
    # payload sync repoints live entries at the LATEST announced vectors
    # (and donates the old store — its buffers are dead afterwards)
    moved = np.roll(vecs[:nv], 1, axis=0)
    st2 = rt.payload_sync(st, moved)
    ids0 = np.asarray(st2.ids[0])
    live = np.argwhere(ids0 >= 0)
    b, c = live[0]
    np.testing.assert_allclose(
        np.asarray(st2.payload[0, b, c]), moved[ids0[b, c]], atol=0)
    assert int(st2.generation) == gen0 + 1
    # expire GCs everything older than the TTL
    st3 = rt.expire(st2, now=10, ttl=2)
    assert int(np.asarray(st3.ids).max()) == -1


def test_runtime_requires_mesh_for_multinode():
    params = LshParams(d=8, k=4, L=2, seed=0)
    with pytest.raises(ValueError, match="needs a mesh"):
        IndexRuntime(RuntimeConfig(params=params, n_nodes=2))


def test_runtime_mesh_axis_must_match(single_mesh):
    params = LshParams(d=8, k=4, L=2, seed=0)
    with pytest.raises(ValueError, match="model axis"):
        IndexRuntime(RuntimeConfig(params=params, n_nodes=2),
                     mesh=single_mesh)


def test_churn_driver_dict_surface():
    """The unified driver reports the full surface (drops counted,
    staleness tracked) on the 1-node topology too."""
    from repro.core.churn import ChurnConfig, run_churn

    out = run_churn(ChurnConfig(
        num_users=300, dim=16, k=4, L=2, capacity=32, epochs=3,
        num_queries=24, m=5, refresh_every=2, seed=1,
    ))
    assert out["recalls"].shape == (3,)
    assert np.all(out["dropped_probes"] == 0)
    assert out["cache_staleness"].min() == 0
    assert out["staleness"].max() >= 1
    assert out["store_generation"] > 0


def test_reshard_roundtrip_local_mesh_local(setup, single_mesh):
    """Elastic membership on one device: 1-node mesh-free -> 1-shard
    shard_map context -> back.  The degenerate round (no zone moves, zero
    handoff bytes) still exercises the full swap machinery — runtime
    rebuild, store migration, generation bump — and the round trip is
    bit-identical."""
    from repro.core.runtime import gather_store, reshard

    params, h, store, ids_only, corpus, vecs, golden = setup
    rt = IndexRuntime(RuntimeConfig(params=params, variant="cnb", m=M,
                                    cap_factor=float(L)))
    q = vecs[:NQ]
    ids0, sc0, _ = rt.search(h, store, q)
    gen0 = int(store.generation)

    rt2, store2, ev = reshard(rt, store, 1, mesh=single_mesh)
    assert rt2.is_distributed and ev.old_n == ev.new_n == 1
    assert ev.moved_buckets == 0 and ev.handoff_bytes == 0
    assert int(store2.generation) == gen0 + 1  # membership = state event
    ids1, _, drop = rt2.search(h, store2, q)
    assert int(drop) == 0
    np.testing.assert_array_equal(np.asarray(ids0), np.asarray(ids1))

    rt3, store3, _ = reshard(rt2, store2, 1)
    assert not rt3.is_distributed
    ids2, sc2, _ = rt3.search(h, store3, q)
    np.testing.assert_array_equal(np.asarray(ids0), np.asarray(ids2))
    np.testing.assert_array_equal(np.asarray(sc0), np.asarray(sc2))
    # the global bucket array is invariant under the round trip
    g0, g3 = gather_store(store), gather_store(store3)
    np.testing.assert_array_equal(np.asarray(g0.ids), np.asarray(g3.ids))
    np.testing.assert_array_equal(np.asarray(g0.payload),
                                  np.asarray(g3.payload))
    assert int(store3.generation) == gen0 + 2


def test_reshard_validates_arguments(setup, single_mesh):
    from repro.core.runtime import reshard

    params, h, store, ids_only, corpus, vecs, golden = setup
    rt = IndexRuntime(RuntimeConfig(params=params, variant="cnb", m=M))
    with pytest.raises(ValueError, match="new_n_nodes or a prebuilt"):
        reshard(rt, store)
    with pytest.raises(ValueError, match="needs a mesh"):
        reshard(rt, store, 2)  # multi-node without a mesh
    other = IndexRuntime(
        RuntimeConfig(params=params, variant="cnb", m=M), mesh=single_mesh)
    with pytest.raises(ValueError, match="n_nodes"):
        reshard(rt, store, 2, runtime=other)  # runtime/count mismatch


def test_reshard_keeps_config_and_scales_caps(setup, single_mesh):
    """A membership round replaces ONLY the topology knobs: the probe
    discipline and m survive, cap_factor rescales when asked (the
    DistConfig legacy factory's captured n_shards does NOT track this —
    always re-read runtime.cfg, see DESIGN.md Sec. 9)."""
    from repro.core.runtime import reshard

    params, h, store, ids_only, corpus, vecs, golden = setup
    rt = IndexRuntime(RuntimeConfig(
        params=params, variant="cnb", m=M, num_probes=2, cap_factor=2.0))
    rt2, _, _ = reshard(rt, store, 1, mesh=single_mesh, cap_factor=4.0)
    assert rt2.cfg.num_probes == 2 and rt2.cfg.m == M
    assert rt2.cfg.cap_factor == 4.0
    rt3, _, _ = reshard(rt2, store, 1)
    assert rt3.cfg.cap_factor == 4.0  # unchanged unless asked


@pytest.mark.slow
def test_runtime_two_node_matches_golden():
    """The 2-node mesh runtime reproduces its checked-in golden
    (tests/goldens/runtime_2node_v1.npz) bit-exactly."""
    from conftest import run_in_subprocess

    out = run_in_subprocess(
        """
        import os
        import numpy as np
        import tests.goldens.make_goldens as mg

        golden = dict(np.load(os.path.join(
            os.path.dirname(mg.__file__), "runtime_2node_v1.npz")))
        got = mg.build_two_node()
        for key, want in golden.items():
            if key.startswith("search_scores"):
                np.testing.assert_allclose(got[key], want, atol=1e-6)
            else:
                np.testing.assert_array_equal(got[key], want, err_msg=key)
        print("TWO-NODE-GOLDEN-OK")
        """,
        devices=2,
    )
    assert "TWO-NODE-GOLDEN-OK" in out


@pytest.mark.slow
def test_reshard_1_2_1_roundtrip_pins_goldens():
    """The acceptance gate: a real 1 -> 2 -> 1 membership round trip is
    bit-identical to the pre-reshard golden (engine_v1.npz), the 2-node
    midpoint matches ITS golden, and the handoff is charged."""
    from conftest import run_in_subprocess

    out = run_in_subprocess(
        """
        import os
        import numpy as np
        import tests.goldens.make_goldens as mg
        from repro.core import costmodel
        from repro.core.runtime import IndexRuntime, RuntimeConfig, reshard
        from repro.launch.mesh import make_zone_mesh

        here = os.path.dirname(mg.__file__)
        eng_g = dict(np.load(os.path.join(here, "engine_v1.npz")))
        two_g = dict(np.load(os.path.join(here, "runtime_2node_v1.npz")))
        params, h, store, vecs, targets = mg._build_setup()
        q = vecs[:mg.NQ]
        ex = np.arange(mg.NQ, dtype=np.int32)

        rt = IndexRuntime(RuntimeConfig(
            params=params, variant="cnb", m=mg.M, cap_factor=float(mg.L)))
        ids0, sc0, _ = rt.search(h, store, q, exclude=ex)
        np.testing.assert_array_equal(
            np.asarray(ids0), eng_g["search_ids_cnb_full"])

        # -- join: 1 -> 2 nodes (zone split + handoff) -------------------
        rt2, store2, ev = reshard(rt, store, 2, mesh=make_zone_mesh(2))
        assert ev.handoff_bytes == costmodel.estimate_handoff_bytes(
            mg.L, params.num_buckets, 64, mg.D, 1, 2) > 0
        cache = rt2.refresh_cache(store2)
        ids_mid, _, drop = rt2.search(h, store2, q, cache=cache)
        assert int(drop) == 0
        np.testing.assert_array_equal(
            np.asarray(ids_mid), two_g["search_ids_cnb"])

        # -- leave: 2 -> 1 nodes (zone merge) ----------------------------
        rt1, store1, ev2 = reshard(rt2, store2, 1)
        assert ev2.handoff_bytes == ev.handoff_bytes
        ids1, sc1, _ = rt1.search(h, store1, q, exclude=ex)
        np.testing.assert_array_equal(np.asarray(ids1), np.asarray(ids0))
        np.testing.assert_array_equal(np.asarray(sc1), np.asarray(sc0))
        np.testing.assert_array_equal(
            np.asarray(ids1), eng_g["search_ids_cnb_full"])
        print("RESHARD-121-OK")
        """,
        devices=2,
    )
    assert "RESHARD-121-OK" in out


@pytest.mark.slow
def test_runtime_two_shards_matches_engine():
    """The runtime-level host API on a REAL >= 2-shard mesh returns the
    engine's exact result sets (the step-level equivalences run in
    tests/test_distributed.py)."""
    from conftest import run_in_subprocess

    out = run_in_subprocess(
        """
        import numpy as np, jax.numpy as jnp
        from repro.core import (
            BucketStore, DenseCorpus, EngineConfig, LshEngine, LshParams,
            make_hyperplanes,
        )
        from repro.core.hashing import sketch_codes_batched
        from repro.core.runtime import IndexRuntime, RuntimeConfig
        from repro.core.store import build_store_host
        from repro.launch.mesh import make_host_mesh

        rng = np.random.default_rng(5)
        N, D, k, L, m = 1500, 32, 5, 3, 8
        params = LshParams(d=D, k=k, L=L, seed=7)
        h = make_hyperplanes(params)
        vecs = rng.standard_normal((N, D)).astype(np.float32)
        vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
        codes = sketch_codes_batched(jnp.asarray(vecs), h)
        store = build_store_host(codes, params.num_buckets, capacity=128,
                                 payload=vecs)
        ids_only = BucketStore(store.ids, store.timestamps,
                               store.write_ptr, None)
        eng = LshEngine(params, h, ids_only, DenseCorpus(jnp.asarray(vecs)),
                        None, EngineConfig(variant="cnb"))
        q = vecs[:32]
        want = eng.search(jnp.asarray(q), m=m)

        mesh = make_host_mesh(data=1, model=2)
        rt = IndexRuntime(
            RuntimeConfig(params=params, variant="cnb", m=m, n_nodes=2,
                          cap_factor=float(L)),
            mesh=mesh,
        )
        store_sh = rt.shard_store(store)
        cache = rt.refresh_cache(store_sh)
        ids, _, drop = rt.search(h, store_sh, q, cache=cache)
        assert int(drop) == 0
        ids = np.asarray(ids)
        for i in range(32):
            assert set(ids[i][ids[i] >= 0]) == set(
                want.ids[i][want.ids[i] >= 0]), i
        hits, _ = rt.contains(h, store_sh, q,
                              np.arange(32, dtype=np.int32), cache=cache)
        want_h = eng.contains(jnp.asarray(q), np.arange(32))
        assert np.array_equal(np.asarray(hits), want_h)
        print("RUNTIME-2SHARD-OK")
        """,
        devices=2,
    )
    assert "RUNTIME-2SHARD-OK" in out
