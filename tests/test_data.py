"""Data pipeline: determinism (the elastic/straggler recovery property)."""

import numpy as np

from repro.configs import get_config
from repro.data import osn, tokens


def test_batches_deterministic_by_step():
    cfg = get_config("starcoder2-7b", smoke=True)
    dcfg = tokens.DataConfig(seed=11)
    a = tokens.make_batch(cfg, dcfg, step=3, batch=4, seq=32)
    b = tokens.make_batch(cfg, dcfg, step=3, batch=4, seq=32)
    c = tokens.make_batch(cfg, dcfg, step=4, batch=4, seq=32)
    assert np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_batch_shapes_per_modality():
    for arch in ("phi-3-vision-4.2b", "seamless-m4t-medium"):
        cfg = get_config(arch, smoke=True)
        b = tokens.make_batch(cfg, tokens.DataConfig(), 0, 2, 32)
        assert b["labels"].shape[0] == 2
        if cfg.modality == "vision_patches":
            assert b["prefix_embeds"].shape == (2, cfg.num_prefix_embeds, cfg.d_model)
            assert b["labels"].shape[1] == 32
            assert np.all(np.asarray(b["labels"][:, :cfg.num_prefix_embeds]) == -1)
        if cfg.encoder_layers:
            assert b["frames"].shape == (2, 32, cfg.d_model)


def test_input_specs_match_batches():
    for arch in ("gemma2-2b", "phi-3-vision-4.2b", "seamless-m4t-medium"):
        cfg = get_config(arch, smoke=True)
        specs = tokens.input_specs(cfg, 2, 32, kind="train")
        batch = tokens.make_batch(cfg, tokens.DataConfig(), 0, 2, 32)
        assert set(specs) == set(batch)
        for k in specs:
            assert tuple(specs[k].shape) == tuple(batch[k].shape), (arch, k)


def test_osn_generator_statistics():
    spec = osn.tiny_spec()
    corpus = osn.generate(spec)
    assert corpus.n == spec.num_users
    ids = np.asarray(corpus.nnz_ids)
    vals = np.asarray(corpus.nnz_vals)
    # rows unit-norm over valid entries
    norms = np.sqrt((vals ** 2).sum(1))
    assert np.allclose(norms[norms > 0], 1.0, atol=1e-5)
    # every user has >= 2 interests (generator contract)
    assert ((ids >= 0).sum(1) >= 2).all()
    # determinism
    corpus2 = osn.generate(spec)
    assert np.array_equal(ids, np.asarray(corpus2.nnz_ids))
