"""Distributed LSH runtime == reference engine (8 host devices, subprocess)."""

import pytest

from conftest import run_in_subprocess

EQUIV = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import *
from repro.core import distributed as dist
from repro.core.store import build_store_host
from repro.core.hashing import sketch_codes_batched
from repro.compat import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
N, D, k, L, m = 3000, 64, 5, 3, 10
params = LshParams(d=D, k=k, L=L, seed=3)
H = make_hyperplanes(params)
vecs = np.abs(rng.standard_normal((N, D))).astype(np.float32)
vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
codes = sketch_codes_batched(jnp.asarray(vecs), H)
store_host = build_store_host(codes, params.num_buckets, capacity=512,
                              payload=vecs)
B = 64
q = vecs[rng.choice(N, B, replace=False)]
ids_only = BucketStore(store_host.ids, store_host.timestamps,
                       store_host.write_ptr, None)
corpus = DenseCorpus(jnp.asarray(vecs))
ref = {}
for variant in ("lsh", "nb", "cnb"):
    e = LshEngine(params, H, ids_only, corpus, None,
                  EngineConfig(variant=variant))
    ref[variant] = e.search(jnp.asarray(q), m=m)

store_sh = dist.shard_store(mesh, store_host)
for variant in ("lsh", "nb", "cnb"):
    for routing, use_kernels in (("alltoall", False), ("allgather", False),
                                 ("alltoall", True)):
        cfg = dist.DistConfig(params=params, n_shards=4, variant=variant,
                              m=m, routing=routing, cap_factor=3.0,
                              use_kernels=use_kernels)
        args = [H, store_sh.ids, store_sh.payload]
        if variant == "cnb" and cfg.node_bits > 0:
            refresh = dist.make_refresh_cache(cfg, mesh)
            ci, cp = refresh(store_sh.ids, store_sh.payload)
            args += [ci, cp]
        step = dist.make_search_step(cfg, mesh)
        qd = jax.device_put(jnp.asarray(q),
                            NamedSharding(mesh, P(("data", "model"), None)))
        ids, sc = step(*args, qd)
        ids = np.asarray(ids)
        want = ref[variant]
        for i in range(B):
            assert set(ids[i][ids[i] >= 0]) == set(
                want.ids[i][want.ids[i] >= 0]), (variant, routing, use_kernels, i)
print("EQUIV-OK")
"""

INSERT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import *
from repro.core import distributed as dist
from repro.core.store import make_store
from repro.core import hashing
from repro.compat import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(1)
N, D, k, L = 256, 32, 5, 2
params = LshParams(d=D, k=k, L=L, seed=9)
H = make_hyperplanes(params)
cfg = dist.DistConfig(params=params, n_shards=4, variant="cnb", m=5)
store = make_store(L, params.num_buckets, 512, payload_dim=D)
store = dist.shard_store(mesh, store)
vecs = np.abs(rng.standard_normal((N, D))).astype(np.float32)
vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
insert = dist.make_insert_step(cfg, mesh)
vd = jax.device_put(jnp.asarray(vecs),
                    NamedSharding(mesh, P(("data", "model"), None)))
vid = jax.device_put(jnp.arange(N, dtype=jnp.int32),
                     NamedSharding(mesh, P(("data", "model"))))
store = insert(H, store, vd, vid, jnp.int32(1))
# every vector must be present in its bucket in every table
codes = np.asarray(hashing.sketch_codes(jnp.asarray(vecs), H))
ids = np.asarray(store.ids)
ok = 0
for i in range(N):
    for l in range(L):
        b = int(codes[i, l])
        ok += int(i in set(ids[l, b][ids[l, b] >= 0]))
assert ok == N * L, (ok, N * L)
# payload integrity: stored vector equals the original
payload = np.asarray(store.payload)
b0 = int(codes[0, 0])
slot = int(np.where(ids[0, b0] == 0)[0][0])
assert np.allclose(payload[0, b0, slot], vecs[0], atol=1e-6)
print("INSERT-OK")
"""


@pytest.mark.slow
def test_distributed_equals_reference():
    out = run_in_subprocess(EQUIV, devices=8)
    assert "EQUIV-OK" in out


@pytest.mark.slow
def test_distributed_insert_then_search():
    out = run_in_subprocess(INSERT, devices=8)
    assert "INSERT-OK" in out


def test_byte_estimates():
    from repro.core import LshParams
    from repro.core.distributed import DistConfig, estimate_query_bytes

    params = LshParams(d=128, k=12, L=4)
    a2a = estimate_query_bytes(
        DistConfig(params=params, n_shards=16, variant="cnb",
                   routing="alltoall"), batch=4096, d=128, n_total=256)
    ag = estimate_query_bytes(
        DistConfig(params=params, n_shards=16, variant="cnb",
                   routing="allgather"), batch=4096, d=128, n_total=256)
    # routed all_to_all must move fewer query bytes than all_gather
    assert a2a["query_routing"] < ag["query_routing"]
    # nb pays neighbor traffic, cnb doesn't
    nb = estimate_query_bytes(
        DistConfig(params=params, n_shards=16, variant="nb",
                   routing="alltoall"), batch=4096, d=128, n_total=256)
    assert nb["neighbor"] > 0
    assert a2a["neighbor"] == 0
