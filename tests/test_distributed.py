"""Distributed LSH runtime == reference engine (8 host devices, subprocess),
plus single-device (tier-1) coverage of the shared planner/router plumbing:
overflow drop accounting, distributed `contains`, and the byte estimator.
"""

import numpy as np
import pytest

from conftest import run_in_subprocess

EQUIV = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import *
from repro.core import distributed as dist
from repro.core.store import build_store_host
from repro.core.hashing import sketch_codes_batched
from repro.compat import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
N, D, k, L, m = 3000, 64, 5, 3, 10
params = LshParams(d=D, k=k, L=L, seed=3)
H = make_hyperplanes(params)
vecs = np.abs(rng.standard_normal((N, D))).astype(np.float32)
vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
codes = sketch_codes_batched(jnp.asarray(vecs), H)
store_host = build_store_host(codes, params.num_buckets, capacity=512,
                              payload=vecs)
B = 64
q = vecs[rng.choice(N, B, replace=False)]
targets = rng.integers(0, N, size=B).astype(np.int32)
ids_only = BucketStore(store_host.ids, store_host.timestamps,
                       store_host.write_ptr, None)
corpus = DenseCorpus(jnp.asarray(vecs))

# (variant, probe kwargs) cells: the paper's full-probe discipline plus the
# beyond-paper budgeted/ranked modes the planner must keep identical across
# the two runtimes.
probe_cells = [
    dict(),
    dict(num_probes=2),
    dict(num_probes=3, ranked_probes=True),
]
ref, ref_contains = {}, {}
for variant in ("lsh", "nb", "cnb"):
    for pi, pkw in enumerate(probe_cells):
        e = LshEngine(params, H, ids_only, corpus, None,
                      EngineConfig(variant=variant, **pkw))
        ref[variant, pi] = e.search(jnp.asarray(q), m=m)
        ref_contains[variant, pi] = e.contains(jnp.asarray(q), targets)

store_sh = dist.shard_store(mesh, store_host)
qspec = NamedSharding(mesh, P(("data", "model"), None))
tspec = NamedSharding(mesh, P(("data", "model")))
qd = jax.device_put(jnp.asarray(q), qspec)
td = jax.device_put(jnp.asarray(targets), tspec)
# (routing, use_kernels, probe-cell indices to search, cells to contains):
# full probe matrix on the routed path; spot checks elsewhere to bound the
# compile count of this subprocess.
runs = [
    ("alltoall", False, (0, 1, 2), (0, 2)),
    ("allgather", False, (0, 2), (0,)),
    ("alltoall", True, (0,), ()),
]
for variant in ("lsh", "nb", "cnb"):
    for routing, use_kernels, search_cells, contains_cells in runs:
        for pi in sorted(set(search_cells) | set(contains_cells)):
            pkw = probe_cells[pi]
            cfg = dist.DistConfig(params=params, n_shards=4, variant=variant,
                                  m=m, routing=routing, cap_factor=3.0,
                                  use_kernels=use_kernels, **pkw)
            args = [H, store_sh.ids, store_sh.payload]
            cargs = [H, store_sh.ids]
            if variant == "cnb" and cfg.node_bits > 0:
                refresh = dist.make_refresh_cache(cfg, mesh)
                ci, cp = refresh(store_sh.ids, store_sh.payload)
                args += [ci, cp]
                cargs += [ci]
            if pi in search_cells:
                step = dist.make_search_step(cfg, mesh)
                ids, sc, dropped = step(*args, qd)
                ids = np.asarray(ids)
                assert int(dropped) == 0, (variant, routing, pi, int(dropped))
                want = ref[variant, pi]
                for i in range(B):
                    assert set(ids[i][ids[i] >= 0]) == set(
                        want.ids[i][want.ids[i] >= 0]), (
                            variant, routing, use_kernels, pi, i)
            if pi in contains_cells:
                cstep = dist.make_contains_step(cfg, mesh)
                hits, cdropped = cstep(*cargs, qd, td)
                assert int(cdropped) == 0
                assert np.array_equal(np.asarray(hits),
                                      ref_contains[variant, pi]), (
                    variant, routing, pi)
print("EQUIV-OK")
"""

CAP_SWEEP = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import *
from repro.core import distributed as dist
from repro.core.store import build_store_host
from repro.core.hashing import sketch_codes_batched
from repro.compat import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(2)
N, D, k, L, m = 2000, 32, 5, 3, 5
params = LshParams(d=D, k=k, L=L, seed=3)
H = make_hyperplanes(params)
vecs = rng.standard_normal((N, D)).astype(np.float32)
vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
codes = sketch_codes_batched(jnp.asarray(vecs), H)
store = dist.shard_store(
    mesh, build_store_host(codes, params.num_buckets, 128, payload=vecs))
B = 64
qd = jax.device_put(jnp.asarray(vecs[:B]),
                    NamedSharding(mesh, P(("data", "model"), None)))
drops = {}
for cap_factor in (0.25, float(L)):
    cfg = dist.DistConfig(params=params, n_shards=4, variant="cnb", m=m,
                          routing="alltoall", cap_factor=cap_factor)
    refresh = dist.make_refresh_cache(cfg, mesh)
    ci, cp = refresh(store.ids, store.payload)
    step = dist.make_search_step(cfg, mesh)
    _, _, dropped = step(H, store.ids, store.payload, ci, cp, qd)
    drops[cap_factor] = int(dropped)
# generous buffers (cap_factor >= L) lose nothing; a deliberately tiny cap
# must REPORT its losses instead of silently eating them.
assert drops[float(L)] == 0, drops
assert drops[0.25] > 0, drops
print("CAP-OK", drops)
"""

INSERT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import *
from repro.core import distributed as dist
from repro.core.store import make_store
from repro.core import hashing
from repro.compat import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(1)
N, D, k, L = 256, 32, 5, 2
params = LshParams(d=D, k=k, L=L, seed=9)
H = make_hyperplanes(params)
cfg = dist.DistConfig(params=params, n_shards=4, variant="cnb", m=5)
store = make_store(L, params.num_buckets, 512, payload_dim=D)
store = dist.shard_store(mesh, store)
vecs = np.abs(rng.standard_normal((N, D))).astype(np.float32)
vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
insert = dist.make_insert_step(cfg, mesh)
vd = jax.device_put(jnp.asarray(vecs),
                    NamedSharding(mesh, P(("data", "model"), None)))
vid = jax.device_put(jnp.arange(N, dtype=jnp.int32),
                     NamedSharding(mesh, P(("data", "model"))))
store = insert(H, store, vd, vid, jnp.int32(1))
# every vector must be present in its bucket in every table
codes = np.asarray(hashing.sketch_codes(jnp.asarray(vecs), H))
ids = np.asarray(store.ids)
ok = 0
for i in range(N):
    for l in range(L):
        b = int(codes[i, l])
        ok += int(i in set(ids[l, b][ids[l, b] >= 0]))
assert ok == N * L, (ok, N * L)
# payload integrity: stored vector equals the original
payload = np.asarray(store.payload)
b0 = int(codes[0, 0])
slot = int(np.where(ids[0, b0] == 0)[0][0])
assert np.allclose(payload[0, b0, slot], vecs[0], atol=1e-6)
print("INSERT-OK")
"""


@pytest.mark.slow
def test_distributed_equals_reference():
    out = run_in_subprocess(EQUIV, devices=8)
    assert "EQUIV-OK" in out


@pytest.mark.slow
def test_cap_factor_sweep_drop_accounting():
    out = run_in_subprocess(CAP_SWEEP, devices=8)
    assert "CAP-OK" in out


@pytest.mark.slow
def test_distributed_insert_then_search():
    out = run_in_subprocess(INSERT, devices=8)
    assert "INSERT-OK" in out


# -----------------------------------------------------------------------------
# tier-1 coverage (single device, mesh (1, 1)): the planner/router plumbing
# runs identically through shard_map; n_shards=1 makes every near bucket a
# local-bit probe, so the full engine equivalence is checkable without
# subprocesses.
# -----------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_setup(single_mesh):
    import jax.numpy as jnp

    from repro.core import (
        BucketStore, DenseCorpus, LshParams, make_hyperplanes,
    )
    from repro.core.hashing import sketch_codes_batched
    from repro.core.store import build_store_host

    rng = np.random.default_rng(4)
    N, D, k, L = 800, 24, 5, 3
    params = LshParams(d=D, k=k, L=L, seed=5)
    H = make_hyperplanes(params)
    vecs = rng.standard_normal((N, D)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    codes = sketch_codes_batched(jnp.asarray(vecs), H)
    store = build_store_host(codes, params.num_buckets, capacity=128,
                             payload=vecs)
    ids_only = BucketStore(store.ids, store.timestamps, store.write_ptr, None)
    corpus = DenseCorpus(jnp.asarray(vecs))
    q = jnp.asarray(vecs[:32])
    return single_mesh, params, H, store, ids_only, corpus, q, vecs


@pytest.mark.parametrize(
    "probe_kw",
    [dict(), dict(num_probes=2), dict(num_probes=2, ranked_probes=True)],
    ids=["all-probes", "p2", "ranked-p2"],
)
def test_single_shard_equals_engine(small_setup, probe_kw):
    from repro.core import EngineConfig, LshEngine
    from repro.core import distributed as dist

    mesh, params, H, store, ids_only, corpus, q, vecs = small_setup
    eng = LshEngine(params, H, ids_only, corpus, None,
                    EngineConfig(variant="cnb", **probe_kw))
    want = eng.search(q, m=8)
    cfg = dist.DistConfig(params=params, n_shards=1, variant="cnb", m=8,
                          cap_factor=float(params.L), **probe_kw)
    step = dist.make_search_step(cfg, mesh)
    ids, sc, dropped = step(H, store.ids, store.payload, q)
    assert int(dropped) == 0
    ids = np.asarray(ids)
    for i in range(ids.shape[0]):
        assert set(ids[i][ids[i] >= 0]) == set(
            want.ids[i][want.ids[i] >= 0]), (probe_kw, i)


def test_single_shard_contains_equals_engine(small_setup):
    import jax.numpy as jnp

    from repro.core import EngineConfig, LshEngine
    from repro.core import distributed as dist

    mesh, params, H, store, ids_only, corpus, q, vecs = small_setup
    rng = np.random.default_rng(9)
    targets = rng.integers(0, vecs.shape[0], size=q.shape[0]).astype(np.int32)
    for variant in ("lsh", "cnb"):
        eng = LshEngine(params, H, ids_only, corpus, None,
                        EngineConfig(variant=variant))
        want = eng.contains(q, targets)
        cfg = dist.DistConfig(params=params, n_shards=1, variant=variant,
                              m=8, cap_factor=float(params.L))
        cstep = dist.make_contains_step(cfg, mesh)
        hits, dropped = cstep(H, store.ids, q, jnp.asarray(targets))
        assert int(dropped) == 0
        assert np.array_equal(np.asarray(hits), want), variant
    assert want.any()  # the metric is non-degenerate on this data


def test_tiny_cap_reports_drops(small_setup):
    from repro.core import distributed as dist

    mesh, params, H, store, ids_only, corpus, q, vecs = small_setup
    cfg = dist.DistConfig(params=params, n_shards=1, variant="cnb", m=8,
                          cap_factor=0.1)
    step = dist.make_search_step(cfg, mesh)
    ids, sc, dropped = step(H, store.ids, store.payload, q)
    # 32 queries * 3 tables = 96 probes into ceil(96*0.1)=10 slots
    assert int(dropped) == 96 - 10


def test_byte_estimates():
    from repro.core import LshParams
    from repro.core.distributed import DistConfig, estimate_query_bytes

    params = LshParams(d=128, k=12, L=4)
    a2a = estimate_query_bytes(
        DistConfig(params=params, n_shards=16, variant="cnb",
                   routing="alltoall"), batch=4096, d=128, n_total=256)
    ag = estimate_query_bytes(
        DistConfig(params=params, n_shards=16, variant="cnb",
                   routing="allgather"), batch=4096, d=128, n_total=256)
    # routed all_to_all must move fewer query bytes than all_gather
    assert a2a["query_routing"] < ag["query_routing"]
    # nb pays neighbor traffic, cnb doesn't
    nb = estimate_query_bytes(
        DistConfig(params=params, n_shards=16, variant="nb",
                   routing="alltoall"), batch=4096, d=128, n_total=256)
    assert nb["neighbor"] > 0
    assert a2a["neighbor"] == 0


def test_byte_estimates_nb_allgather():
    """The nb + allgather branch (neighbor traffic on replicated queries)
    must produce finite, larger-than-cnb neighbor bytes."""
    from repro.core import LshParams
    from repro.core.distributed import DistConfig, estimate_query_bytes

    params = LshParams(d=128, k=12, L=4)
    nb_ag = estimate_query_bytes(
        DistConfig(params=params, n_shards=16, variant="nb",
                   routing="allgather"), batch=4096, d=128, n_total=256)
    cnb_ag = estimate_query_bytes(
        DistConfig(params=params, n_shards=16, variant="cnb",
                   routing="allgather"), batch=4096, d=128, n_total=256)
    assert nb_ag["neighbor"] > 0
    assert cnb_ag["neighbor"] == 0
    assert nb_ag["total"] > cnb_ag["total"]
    # replicated-query neighbor traffic dominates the routed-buffer version
    nb_a2a = estimate_query_bytes(
        DistConfig(params=params, n_shards=16, variant="nb",
                   routing="alltoall"), batch=4096, d=128, n_total=256)
    assert nb_ag["neighbor"] > nb_a2a["neighbor"]
