"""Training runtime: optimizer (incl. int8 states), loss, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or skip-fallback

from conftest import run_in_subprocess
from repro.train import optimizer as opt


def test_lr_schedule():
    cfg = opt.OptConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100,
                        min_lr_ratio=0.1)
    assert float(opt.lr_at(cfg, jnp.int32(0))) == 0.0
    assert abs(float(opt.lr_at(cfg, jnp.int32(10))) - 1e-3) < 1e-9
    end = float(opt.lr_at(cfg, jnp.int32(200)))
    assert abs(end - 1e-4) < 1e-9


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3))
def test_quantize_roundtrip_error_bounded(seed, ndim):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 40, ndim))
    x = rng.standard_normal(shape).astype(np.float32) * 10.0 ** rng.integers(-4, 3)
    q, s = opt.quantize_blockwise(jnp.asarray(x), 64)
    back = np.asarray(opt.dequantize_blockwise(q, s, shape))
    # absmax int8: error bounded by scale/2 = absmax/254 per block
    blocks = opt._blocked(jnp.asarray(x), 64)
    bound = np.asarray(jnp.max(jnp.abs(blocks), -1) / 127.0)
    err = np.abs(back - x)
    err_b = np.asarray(opt._blocked(jnp.asarray(err), 64)).max(-1)
    assert np.all(err_b <= bound * 0.51 + 1e-12)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_v_log_quant_relative_error(seed):
    """Log-codebook v quantization: <=6% relative error over 10 decades."""
    rng = np.random.default_rng(seed)
    x = (10.0 ** rng.uniform(-9, 0, size=(8, 64))).astype(np.float32)
    q, s = opt.quantize_v_log(jnp.asarray(x), 64)
    back = np.asarray(opt.dequantize_v_log(q, s, x.shape))
    rel = np.abs(back - x) / x
    assert np.max(rel) < 0.066, np.max(rel)


def test_adamw_matches_reference():
    """One fp32 AdamW step vs a hand-rolled numpy reference."""
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32) * 0.1}
    cfg = opt.OptConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10**9,
                        weight_decay=0.01, grad_clip=1e9)
    state = opt.init_opt_state(p, cfg)
    new_p, state, _ = opt.apply_updates(p, g, state, cfg)
    m = 0.1 * np.asarray(g["w"])
    v = 0.05 * np.asarray(g["w"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    want = (np.asarray(p["w"])
            - 1e-2 * (mhat / (np.sqrt(vhat) + 1e-8)
                      + 0.01 * np.asarray(p["w"])))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)


def test_int8_matches_fp32_trajectory():
    """int8 states track fp32 within float noise over several steps."""
    rng = np.random.default_rng(1)
    p0 = {"w": jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)}
    cfgs = {
        sd: opt.OptConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10**9,
                          weight_decay=0.0, state_dtype=sd)
        for sd in ("fp32", "int8")
    }
    ps = {sd: p0 for sd in cfgs}
    states = {sd: opt.init_opt_state(p0, c) for sd, c in cfgs.items()}
    for step in range(10):
        g = {"w": jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)}
        for sd, c in cfgs.items():
            ps[sd], states[sd], _ = opt.apply_updates(ps[sd], g, states[sd], c)
    diff = float(jnp.max(jnp.abs(ps["fp32"]["w"] - ps["int8"]["w"])))
    scale = float(jnp.max(jnp.abs(ps["fp32"]["w"] - p0["w"])))
    assert diff < 0.12 * scale, (diff, scale)


def test_grad_accum_equivalence(single_mesh):
    """Micro-batched gradient accumulation == single big batch."""
    from repro.configs import get_config
    from repro.data import tokens as dt
    from repro.models import model as M, sharding as sh
    from repro.train import train_step as ts

    cfg = get_config("starcoder2-7b", smoke=True)
    params, _ = M.init_model(cfg, 0)
    hp = ts.TrainHParams(loss_chunk=64)
    batch = dt.make_batch(cfg, dt.DataConfig(), 0, 4, 32)
    with sh.use_mesh(single_mesh):
        loss_fn = ts.make_loss_fn(cfg, hp)
        (l_full, _), g_full = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        micro = {k: v.reshape(2, 2, *v.shape[1:]) for k, v in batch.items()}
        # accumulate in fp32 — exactly what make_grad_accum_train_step does
        g_sum = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), g_full)
        l_sum = 0.0
        for i in range(2):
            mb = {k: v[i] for k, v in micro.items()}
            (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            l_sum += float(l)
            g_sum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_sum, g)
        g_avg = jax.tree.map(lambda x: x / 2, g_sum)
    assert abs(l_sum / 2 - float(l_full)) < 1e-4
    flat_a = jnp.concatenate(
        [x.ravel().astype(jnp.float32) for x in jax.tree.leaves(g_full)])
    flat_b = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g_avg)])
    cos = float(jnp.vdot(flat_a, flat_b) /
                (jnp.linalg.norm(flat_a) * jnp.linalg.norm(flat_b)))
    assert cos > 0.999, cos


COMPRESSION = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.train import compression as C

mesh = make_mesh((4,), ("pod",))
rng = np.random.default_rng(0)
g_global = rng.standard_normal((4, 64, 33)).astype(np.float32)

def body(g_local, err):
    red, new_err = C.compressed_psum({"w": g_local}, {"w": err}, "pod")
    return red["w"], new_err["w"]

fn = shard_map(body, mesh=mesh,
               in_specs=(P("pod", None, None), P("pod", None, None)),
               out_specs=(P("pod", None, None), P("pod", None, None)))

want = g_global.sum(0)
err = jnp.zeros_like(jnp.asarray(g_global))
red, err = fn(jnp.asarray(g_global), err)
red = np.asarray(red)[0]
rel = np.abs(red - want).max() / np.abs(want).max()
assert rel < 0.1, rel
# error feedback: summed over repeated steps the bias vanishes
acc = np.zeros_like(want)
err = jnp.zeros_like(jnp.asarray(g_global))
for _ in range(20):
    red, err = fn(jnp.asarray(g_global), err)
    acc += np.asarray(red)[0]
rel20 = np.abs(acc / 20 - want).max() / np.abs(want).max()
assert rel20 < 0.02, rel20
print("COMPRESS-OK", rel, rel20)
"""


@pytest.mark.slow
def test_compressed_psum_error_feedback():
    out = run_in_subprocess(COMPRESSION, devices=4)
    assert "COMPRESS-OK" in out


PIPELINE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import model as M
from repro.models import sharding as sh
from repro.train.pipeline import pipeline_forward

cfg = get_config("starcoder2-7b", smoke=True)  # 3 layers -> pad to 4 periods? 3 % 2 != 0
import dataclasses
cfg = dataclasses.replace(cfg, num_layers=4)
params, _ = M.init_model(cfg, 0)
from repro.compat import make_mesh
mesh = make_mesh((2,), ("stage",))
rng = np.random.default_rng(0)
B, S = 4, 16
x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32) * 0.1
positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

# reference: plain scan over all periods
def ref_fn(blocks, xx):
    def body(xc, pp):
        y, _, _ = M._period_forward(cfg, pp, xc, positions, mode="train")
        return y, None
    out, _ = jax.lax.scan(body, xx, blocks)
    return out

ref = ref_fn(params["blocks"], x)
out = pipeline_forward(cfg, mesh, params["blocks"], x, positions,
                       num_microbatches=2)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=2e-4, atol=2e-4)

# gradients must match too (GPipe backward via AD)
def loss_pipe(blocks):
    return jnp.sum(pipeline_forward(cfg, mesh, blocks, x, positions, 2) ** 2)
def loss_ref(blocks):
    return jnp.sum(ref_fn(blocks, x) ** 2)
g_pipe = jax.grad(loss_pipe)(params["blocks"])
g_ref = jax.grad(loss_ref)(params["blocks"])
# bf16 params => bf16 cotangents; different reduction orders round
# differently, so compare direction + magnitude, not elementwise bits.
for a, b in zip(jax.tree.leaves(g_pipe), jax.tree.leaves(g_ref)):
    a = np.asarray(a, np.float32).ravel()
    b = np.asarray(b, np.float32).ravel()
    na, nb = np.linalg.norm(a), np.linalg.norm(b)
    if nb < 1e-6:
        assert na < 1e-4
        continue
    cos = float(a @ b / (na * nb))
    assert cos > 0.999, cos
    assert abs(na - nb) / nb < 0.02, (na, nb)
print("PIPELINE-OK")
"""


@pytest.mark.slow
def test_pipeline_parallel_equivalence():
    out = run_in_subprocess(PIPELINE, devices=2)
    assert "PIPELINE-OK" in out
