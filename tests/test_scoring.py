"""`core.scoring.dedupe_topk` tie/edge semantics (DESIGN.md Sec. 11).

These are THE semantics the fused mega-kernel's in-register reduce must
reproduce, so every edge case is pinned twice: once on `dedupe_topk`
itself, and once as a staged-vs-fused agreement check through the
kernel wrappers (`ops.fused_query` vs `ref.fused_query_ref` — the ref
calls `dedupe_topk`, so agreement there IS agreement with the staged
path).

Covered edges: all-EMPTY candidate rows, duplicate ids straddling a
probe-block boundary in the fused scratch, m larger than the live
candidate count, and m larger than K itself (which used to crash
`lax.top_k`).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scoring import NEG_INF, dedupe_topk, score_topk

NEG = float("-inf")


def test_all_empty_rows():
    """A row with no live candidate returns all id -1 / score -inf."""
    ids = jnp.full((3, 8), -1, jnp.int32)
    scores = jnp.full((3, 8), NEG_INF)
    top_i, top_s = dedupe_topk(ids, scores, 4)
    np.testing.assert_array_equal(np.asarray(top_i), -1)
    assert np.all(np.isneginf(np.asarray(top_s)))


def test_m_larger_than_k():
    """m > K used to crash lax.top_k; now the tail pads with -1/-inf."""
    ids = jnp.asarray([[3, 7, 3]], jnp.int32)
    scores = jnp.asarray([[1.0, 2.0, 0.5]])
    top_i, top_s = dedupe_topk(ids, scores, 6)
    np.testing.assert_array_equal(np.asarray(top_i)[0], [7, 3, -1, -1, -1, -1])
    np.testing.assert_array_equal(
        np.asarray(top_s)[0], [2.0, 1.0, NEG, NEG, NEG, NEG])


def test_m_larger_than_live_count():
    """More requested results than live candidates: the dead tail is
    id -1 / -inf, and every live id appears exactly once."""
    ids = jnp.asarray([[5, -1, 5, 2, -1, -1]], jnp.int32)
    scores = jnp.asarray([[1.0, NEG, 9.0, 0.5, NEG, NEG]])
    top_i, top_s = dedupe_topk(ids, scores, 5)
    # first occurrence of id 5 (score 1.0) wins over the later 9.0 copy
    np.testing.assert_array_equal(np.asarray(top_i)[0], [5, 2, -1, -1, -1])
    np.testing.assert_array_equal(
        np.asarray(top_s)[0], [1.0, 0.5, NEG, NEG, NEG])


def test_first_occurrence_keeps_its_score():
    """Duplicate ids collapse to the FIRST flat occurrence's score, even
    when a later copy scores higher (stale-copy semantics: the probe
    order is the freshness order)."""
    ids = jnp.asarray([[9, 4, 9, 4]], jnp.int32)
    scores = jnp.asarray([[1.0, 3.0, 8.0, 7.0]])
    top_i, top_s = dedupe_topk(ids, scores, 2)
    np.testing.assert_array_equal(np.asarray(top_i)[0], [4, 9])
    np.testing.assert_array_equal(np.asarray(top_s)[0], [3.0, 1.0])


def test_score_ties_break_to_lowest_id():
    ids = jnp.asarray([[30, 10, 20]], jnp.int32)
    scores = jnp.asarray([[2.0, 2.0, 2.0]])
    top_i, _ = dedupe_topk(ids, scores, 3)
    np.testing.assert_array_equal(np.asarray(top_i)[0], [10, 20, 30])


def test_score_topk_m_larger_than_k_kernel_parity():
    """The m > K pad must hold on the kernel path too (sorted id lanes
    feed `bucket_topk`, whose KC is lane-padded past m anyway)."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((4, 16)).astype(np.float32))
    vecs = jnp.asarray(rng.standard_normal((4, 3, 16)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, 5, size=(4, 3)), jnp.int32)
    ref_i, ref_s = score_topk(q, ids, vecs, 7)
    ker_i, ker_s = score_topk(q, ids, vecs, 7, use_kernels=True)
    np.testing.assert_array_equal(np.asarray(ker_i), np.asarray(ref_i))
    np.testing.assert_allclose(np.asarray(ker_s), np.asarray(ref_s),
                               atol=1e-5)


def test_score_topk_hamming_kernel_parity():
    """Hamming mode: the jnp path (packed.hamming_words) and the Pallas
    path (ops.hamming multi-word) return bit-equal integer scores."""
    rng = np.random.default_rng(1)
    b, kk, w = 6, 9, 2
    q = jnp.asarray(rng.integers(0, 2**32, size=(b, w), dtype=np.uint32))
    cand = jnp.asarray(
        rng.integers(0, 2**32, size=(b, kk, w), dtype=np.uint32))
    ids = jnp.asarray(rng.integers(-1, 20, size=(b, kk)), jnp.int32)
    ref_i, ref_s = score_topk(q, ids, cand, 4, score="hamming")
    ker_i, ker_s = score_topk(q, ids, cand, 4, score="hamming",
                              use_kernels=True)
    np.testing.assert_array_equal(np.asarray(ker_i), np.asarray(ref_i))
    np.testing.assert_array_equal(np.asarray(ker_s), np.asarray(ref_s))


# ---------------------------------------------------------------------------
# staged-vs-fused agreement on the same edges, through the kernel wrappers
# ---------------------------------------------------------------------------


def _fused_case(ids_flat, pay_flat, q, fb, meta, m, score="dot"):
    from repro.kernels import ops, ref

    got_i, got_s = ops.fused_query(
        jnp.asarray(ids_flat), jnp.asarray(pay_flat), jnp.asarray(q),
        jnp.asarray(fb), jnp.asarray(meta), m=m, score=score)
    want_i, want_s = ref.fused_query_ref(
        jnp.asarray(ids_flat), jnp.asarray(pay_flat), jnp.asarray(q),
        jnp.asarray(fb), jnp.asarray(meta), m=m, score=score)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    return np.asarray(got_i), np.asarray(got_s), np.asarray(want_s)


def test_fused_all_empty_rows():
    """Rows whose every probe is invalid (probe word 0) or whose buckets
    are all EMPTY come back as -1/-inf from the fused kernel."""
    c, d = 4, 8
    ids_flat = np.full((6, c), -1, np.int32)
    pay_flat = np.zeros((6, c, d), np.float32)
    q = np.ones((3, d), np.float32)
    fb = np.zeros((3, 2), np.int32)
    meta = np.asarray([[0, -1], [3, -1], [0, -1]], np.int32)
    got_i, got_s, want_s = _fused_case(ids_flat, pay_flat, q, fb, meta, 3)
    np.testing.assert_array_equal(got_i, -1)
    assert np.all(np.isneginf(got_s))


def test_fused_duplicate_across_probe_blocks():
    """The same id in two DIFFERENT probed buckets lands in two different
    KC blocks of the fused scratch; the first probe's copy must win with
    its own score — exactly `dedupe_topk`'s stable first-occurrence rule."""
    c, d = 4, 8
    rng = np.random.default_rng(2)
    ids_flat = np.full((6, c), -1, np.int32)
    pay_flat = np.zeros((6, c, d), np.float32)
    # id 7 lives in bucket row 0 (weak vector) AND row 3 (strong vector)
    ids_flat[0, :3] = [7, 1, 2]
    ids_flat[3, :2] = [7, 5]
    pay_flat[0, :3] = rng.standard_normal((3, d)) * 0.1
    pay_flat[3, 0] = 10.0  # stale duplicate scores much higher
    pay_flat[3, 1] = rng.standard_normal(d)
    q = np.ones((1, d), np.float32)
    fb = np.asarray([[0, 3]], np.int32)
    meta = np.asarray([[0b11, -1]], np.int32)
    got_i, got_s, want_s = _fused_case(ids_flat, pay_flat, q, fb, meta, 4)
    assert list(got_i[0]).count(7) == 1  # deduped
    # id 7's surviving score is the FIRST (probe-0, weak) copy's
    pos = list(got_i[0]).index(7)
    assert got_s[0][pos] == want_s[0][pos]
    assert got_s[0][pos] < 1.0


def test_fused_m_larger_than_live():
    c, d = 4, 8
    ids_flat = np.full((6, c), -1, np.int32)
    pay_flat = np.zeros((6, c, d), np.float32)
    ids_flat[1, 0] = 3
    pay_flat[1, 0] = 1.0
    q = np.ones((2, d), np.float32)
    fb = np.asarray([[1, 2], [2, 2]], np.int32)
    meta = np.asarray([[0b11, -1], [0b11, -1]], np.int32)
    got_i, got_s, _ = _fused_case(ids_flat, pay_flat, q, fb, meta, 5)
    np.testing.assert_array_equal(got_i[0], [3, -1, -1, -1, -1])
    np.testing.assert_array_equal(got_i[1], -1)


def test_fused_exclude_sentinel():
    """exclude=-1 means no exclusion (only matches EMPTY slots); a real
    exclude id drops exactly that id."""
    c, d = 4, 8
    ids_flat = np.full((2, c), -1, np.int32)
    pay_flat = np.zeros((2, c, d), np.float32)
    ids_flat[0, :2] = [11, 12]
    pay_flat[0, :2] = 1.0
    q = np.ones((2, d), np.float32)
    fb = np.asarray([[0], [0]], np.int32)
    meta = np.asarray([[1, 11], [1, -1]], np.int32)
    got_i, _, _ = _fused_case(ids_flat, pay_flat, q, fb, meta, 2)
    assert 11 not in got_i[0] and 12 in got_i[0]
    assert set(got_i[1]) == {11, 12}
