"""Capacitated routing layer: run ranks, send buffers, drop accounting."""

import jax.numpy as jnp
import numpy as np

from repro.core import routing


def test_run_ranks():
    keys = jnp.asarray([0, 0, 0, 2, 2, 5], jnp.int32)
    assert np.asarray(routing.run_ranks(keys)).tolist() == [0, 1, 2, 0, 1, 0]


def test_plan_routes_no_overflow(rng):
    dest = jnp.asarray(rng.integers(0, 4, 32), jnp.int32)
    route = routing.plan_routes(dest, n_dests=4, cap=32)
    assert int(route.dropped) == 0
    assert bool(np.all(np.asarray(route.ok)))
    # (dest, slot) pairs are unique -> a collision-free buffer layout
    d, s = np.asarray(route.dest), np.asarray(route.slot)
    assert len({(int(a), int(b)) for a, b in zip(d, s)}) == 32


def test_plan_routes_counts_drops():
    # 6 items to dest 0, 2 to dest 1, cap 3: exactly 3 of dest-0 drop
    dest = jnp.asarray([0, 0, 0, 0, 0, 0, 1, 1], jnp.int32)
    route = routing.plan_routes(dest, n_dests=2, cap=3)
    assert int(route.dropped) == 3
    assert int(np.sum(~np.asarray(route.ok))) == 3


def test_send_buffer_roundtrip(rng):
    """build_send_buffer + return_to_origin is the identity for surviving
    items and the fill sentinel for dropped ones."""
    n, n_dests, cap = 40, 4, 8
    dest = jnp.asarray(rng.integers(0, n_dests, n), jnp.int32)
    vals = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    route = routing.plan_routes(dest, n_dests, cap)
    buf = routing.build_send_buffer(route, n_dests, cap, vals, 0.0)
    assert buf.shape == (n_dests, cap, 3)
    back = routing.return_to_origin(route, buf, -7.0)
    ok = np.asarray(route.ok)[np.argsort(np.asarray(route.order))]
    got, want = np.asarray(back), np.asarray(vals)
    assert np.allclose(got[ok], want[ok])
    assert np.all(got[~ok] == -7.0)
    # drop accounting is consistent with the buffer capacity
    assert int(route.dropped) == int(np.sum(~ok))


def test_overflow_never_clobbers_survivors():
    """Overflowed items must not overwrite any surviving item's slot
    (they scatter out of bounds, not onto clamped coordinates)."""
    # every item to dest 0; cap 2 -> items rank 2.. drop
    n = 6
    dest = jnp.zeros((n,), jnp.int32)
    vals = jnp.arange(n, dtype=jnp.float32)[:, None]
    route = routing.plan_routes(dest, n_dests=2, cap=2)
    buf = routing.build_send_buffer(route, 2, 2, vals, -1.0)
    kept = sorted(np.asarray(buf[0]).ravel().tolist())
    # exactly two survivors, from the original items, nothing synthesized
    assert len(kept) == 2 and set(kept) <= set(range(n))
    assert np.all(np.asarray(buf[1]) == -1.0)
    assert int(route.dropped) == 4


def test_metadata_sentinel_detection(rng):
    """Receivers detect empty slots by the -1 fill of the meta channel."""
    dest = jnp.asarray([1, 1, 3], jnp.int32)
    meta = jnp.asarray([[0, 7], [1, 8], [2, 9]], jnp.int32)
    route = routing.plan_routes(dest, n_dests=4, cap=2)
    buf = routing.build_send_buffer(route, 4, 2, meta, -1)
    b = np.asarray(buf)
    assert np.all(b[0] == -1) and np.all(b[2] == -1)
    assert set(b[1, :, 1].tolist()) == {7, 8}
    assert set(b[3, :, 1].tolist()) == {9, -1}


def test_run_ranks_and_plan_routes_empty():
    """n = 0 must be total: run_ranks once built a shape-(1,) is_start
    against a shape-(0,) pos and failed to broadcast (PR 10 bugfix)."""
    r = routing.run_ranks(jnp.zeros((0,), jnp.int32))
    assert r.shape == (0,) and r.dtype == jnp.int32
    route = routing.plan_routes(jnp.zeros((0,), jnp.int32), 4, 3)
    assert int(route.dropped) == 0
    vals = jnp.zeros((0, 2), jnp.float32)
    buf = routing.build_send_buffer(route, 4, 3, vals, 5.0)
    assert buf.shape == (4, 3, 2)
    assert np.all(np.asarray(buf) == 5.0)  # nothing scattered, all fill
    back = routing.return_to_origin(route, buf, -1.0)
    assert back.shape == (0, 2)


def test_plan_routes_cap_zero_drops_everything():
    """cap = 0: every item overflows (counted, clamps stay in bounds) and
    the origin-side gather returns pure fill instead of crashing on the
    size-0 slot axis."""
    dest = jnp.asarray([0, 1, 1], jnp.int32)
    route = routing.plan_routes(dest, 2, 0)
    assert int(route.dropped) == 3
    assert not np.any(np.asarray(route.ok))
    vals = jnp.asarray([[1.0], [2.0], [3.0]], jnp.float32)
    buf = routing.build_send_buffer(route, 2, 0, vals, 0.0)
    assert buf.shape == (2, 0, 1)
    back = routing.return_to_origin(route, buf, -9.0)
    assert back.shape == (3, 1)
    assert np.all(np.asarray(back) == -9.0)
