"""Soft-state freshness under churn (paper Sec. 4.1 maintenance claims)."""

import dataclasses

import numpy as np

from repro.core.churn import ChurnConfig, run_churn


def test_refresh_recovers_recall():
    """Frequent refresh must beat infrequent refresh under the same churn:
    the paper's soft-state design depends on this monotonicity."""
    base = ChurnConfig(num_users=1500, epochs=8, num_queries=64, seed=3)
    fast = run_churn(dataclasses.replace(base, refresh_every=1))
    slow = run_churn(dataclasses.replace(base, refresh_every=8))
    assert fast["mean_recall"] > slow["mean_recall"] + 0.03, (
        fast["mean_recall"], slow["mean_recall"])


def test_recall_dips_then_recovers_on_refresh():
    """Between refreshes recall decays (stale buckets); the refresh epoch
    restores it — the sawtooth the soft-state protocol produces."""
    cfg = ChurnConfig(num_users=1500, epochs=9, refresh_every=3,
                      update_rate=0.15, churn_rate=0.05,
                      num_queries=64, seed=5)
    out = run_churn(cfg)
    rec = out["recalls"]
    # epochs 3, 6, 9 are refresh epochs (index 2, 5, 8)
    refreshed = rec[[2, 5, 8]].mean()
    stale = rec[[1, 4, 7]].mean()  # just before refresh
    assert refreshed > stale, (refreshed, stale)


def test_no_refresh_degrades():
    cfg = ChurnConfig(num_users=1500, epochs=6, refresh_every=100,
                      update_rate=0.2, churn_rate=0.1,
                      num_queries=64, seed=7)
    out = run_churn(cfg)
    assert out["recalls"][-1] < out["recalls"][0]
