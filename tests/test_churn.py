"""Soft-state freshness under churn (paper Sec. 4.1 maintenance claims),
plus elastic node membership (node join/leave, DESIGN.md Sec. 9)."""

import dataclasses

import numpy as np
import pytest

from conftest import run_in_subprocess
from repro.core.churn import (
    ChurnConfig, NodeChurnConfig, run_churn, run_node_churn,
)


def test_refresh_recovers_recall():
    """Frequent refresh must beat infrequent refresh under the same churn:
    the paper's soft-state design depends on this monotonicity."""
    base = ChurnConfig(num_users=1500, epochs=8, num_queries=64, seed=3)
    fast = run_churn(dataclasses.replace(base, refresh_every=1))
    slow = run_churn(dataclasses.replace(base, refresh_every=8))
    assert fast["mean_recall"] > slow["mean_recall"] + 0.03, (
        fast["mean_recall"], slow["mean_recall"])


def test_recall_dips_then_recovers_on_refresh():
    """Between refreshes recall decays (stale buckets); the refresh epoch
    restores it — the sawtooth the soft-state protocol produces."""
    cfg = ChurnConfig(num_users=1500, epochs=9, refresh_every=3,
                      update_rate=0.15, churn_rate=0.05,
                      num_queries=64, seed=5)
    out = run_churn(cfg)
    rec = out["recalls"]
    # epochs 3, 6, 9 are refresh epochs (index 2, 5, 8)
    refreshed = rec[[2, 5, 8]].mean()
    stale = rec[[1, 4, 7]].mean()  # just before refresh
    assert refreshed > stale, (refreshed, stale)


def test_no_refresh_degrades():
    cfg = ChurnConfig(num_users=1500, epochs=6, refresh_every=100,
                      update_rate=0.2, churn_rate=0.1,
                      num_queries=64, seed=7)
    out = run_churn(cfg)
    assert out["recalls"][-1] < out["recalls"][0]


CHURN_DIST = r"""
import numpy as np
from repro.core.churn import ChurnConfig, run_churn, run_churn_distributed

cfg = ChurnConfig(num_users=1200, epochs=6, num_queries=64, update_rate=0.1,
                  churn_rate=0.03, refresh_every=2, seed=3)
single = run_churn(cfg)
d = run_churn_distributed(cfg, n_shards=2)
diff = float(np.abs(d["recalls"] - single["recalls"]).max())
# the sharded runtime must track the single-host trajectory at the same
# refresh period (acceptance: within 0.02; in practice it is exact)
assert diff <= 0.02, (diff, single["recalls"].tolist(), d["recalls"].tolist())
assert int(d["dropped_probes"].sum()) == 0
assert int(d["cache_staleness"].max()) >= 1   # cache goes stale between refreshes
assert int(d["cache_staleness"].min()) == 0   # and is rebuilt at each refresh
print("CHURN-DIST-OK", diff)
"""


@pytest.mark.slow
def test_distributed_churn_matches_single_host():
    out = run_in_subprocess(CHURN_DIST, devices=2)
    assert "CHURN-DIST-OK" in out


# -----------------------------------------------------------------------------
# elastic node membership (node join/leave during the trajectory)
# -----------------------------------------------------------------------------


def test_node_churn_static_schedule_is_run_churn():
    """A constant all-1 schedule must leave the trajectory untouched —
    the membership machinery in the unified loop cannot perturb the
    static reference it is compared against."""
    cfg = ChurnConfig(num_users=300, dim=16, k=4, L=2, capacity=32,
                      epochs=3, num_queries=24, m=5, refresh_every=2,
                      seed=1)
    static = run_churn(cfg)
    elastic = run_node_churn(NodeChurnConfig(churn=cfg, schedule=(1,)))
    np.testing.assert_array_equal(elastic["recalls"], static["recalls"])
    # no rounds fired: nothing moved, nothing charged
    assert elastic["reshard_events"] == []
    assert int(elastic["handoff_bytes"].sum()) == 0
    assert np.all(elastic["n_nodes"] == 1)
    # the static driver reports the same (all-zero) membership surface
    assert int(static["total_handoff_bytes"]) == 0
    assert static["handoff_bytes"].shape == static["recalls"].shape


def test_node_churn_schedule_validation():
    from repro.core.churn import _expand_schedule

    assert _expand_schedule((1, 2), 4) == [1, 2, 2, 2, 2]
    assert _expand_schedule((1, 2, 4, 2, 1, 2, 1, 4), 3) == [1, 2, 4, 2]
    with pytest.raises(ValueError, match="powers of two"):
        _expand_schedule((1, 3), 4)
    with pytest.raises(ValueError, match="empty"):
        _expand_schedule((), 4)
    cfg = ChurnConfig(num_users=64, epochs=2, num_queries=8)
    with pytest.raises(ValueError, match="powers of two"):
        run_node_churn(NodeChurnConfig(churn=cfg, schedule=(6,)))


NODE_CHURN = r"""
import numpy as np
from repro.core.churn import (
    ChurnConfig, NodeChurnConfig, run_churn, run_node_churn,
)
from repro.core import costmodel

cfg = ChurnConfig(num_users=1200, dim=32, k=5, L=2, capacity=64, epochs=6,
                  num_queries=64, update_rate=0.1, churn_rate=0.03,
                  refresh_every=2, seed=3)
static = run_churn(cfg)
# joins up to 4 nodes, leaves back down, rejoin — every transition kind
elastic = run_node_churn(
    NodeChurnConfig(churn=cfg, schedule=(1, 2, 4, 2, 1, 2, 1)))

diff = float(np.abs(elastic["recalls"] - static["recalls"]).max())
# acceptance: within 0.02 of the static-topology reference on the same
# RNG trajectory (in practice exact: the bucket array is round-invariant)
assert diff <= 0.02, (diff, static["recalls"].tolist(),
                      elastic["recalls"].tolist())
assert int(elastic["dropped_probes"].sum()) == 0

# handoff charged on EVERY membership epoch, never silently uncharged,
# and each event matches the closed form
n = elastic["n_nodes"]
changed = np.concatenate([[n[0] != 1], n[1:] != n[:-1]])
assert np.array_equal(elastic["handoff_bytes"] > 0, changed), (
    elastic["handoff_bytes"].tolist(), n.tolist())
assert len(elastic["reshard_events"]) == int(changed.sum())
for ev in elastic["reshard_events"]:
    want = costmodel.estimate_handoff_bytes(
        cfg.L, 1 << cfg.k, cfg.capacity, cfg.dim, ev.old_n, ev.new_n)
    assert ev.handoff_bytes == want > 0, ev
# mesh epochs also charge cache-rewarm refresh bytes; 1-node epochs don't
assert np.all((elastic["refresh_bytes"] > 0) == (n > 1)), (
    elastic["refresh_bytes"].tolist(), n.tolist())
print("NODE-CHURN-OK", diff)
"""


@pytest.mark.slow
def test_node_churn_matches_static_reference():
    """The weekly equivalence gate: interleaved join/leave epochs (1 ->
    2 -> 4 -> 2 -> 1 nodes) + content churn + queries track the static
    run_churn trajectory, with handoff bytes reported per round."""
    out = run_in_subprocess(NODE_CHURN, devices=4)
    assert "NODE-CHURN-OK" in out
