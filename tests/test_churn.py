"""Soft-state freshness under churn (paper Sec. 4.1 maintenance claims)."""

import dataclasses

import pytest

from conftest import run_in_subprocess
from repro.core.churn import ChurnConfig, run_churn


def test_refresh_recovers_recall():
    """Frequent refresh must beat infrequent refresh under the same churn:
    the paper's soft-state design depends on this monotonicity."""
    base = ChurnConfig(num_users=1500, epochs=8, num_queries=64, seed=3)
    fast = run_churn(dataclasses.replace(base, refresh_every=1))
    slow = run_churn(dataclasses.replace(base, refresh_every=8))
    assert fast["mean_recall"] > slow["mean_recall"] + 0.03, (
        fast["mean_recall"], slow["mean_recall"])


def test_recall_dips_then_recovers_on_refresh():
    """Between refreshes recall decays (stale buckets); the refresh epoch
    restores it — the sawtooth the soft-state protocol produces."""
    cfg = ChurnConfig(num_users=1500, epochs=9, refresh_every=3,
                      update_rate=0.15, churn_rate=0.05,
                      num_queries=64, seed=5)
    out = run_churn(cfg)
    rec = out["recalls"]
    # epochs 3, 6, 9 are refresh epochs (index 2, 5, 8)
    refreshed = rec[[2, 5, 8]].mean()
    stale = rec[[1, 4, 7]].mean()  # just before refresh
    assert refreshed > stale, (refreshed, stale)


def test_no_refresh_degrades():
    cfg = ChurnConfig(num_users=1500, epochs=6, refresh_every=100,
                      update_rate=0.2, churn_rate=0.1,
                      num_queries=64, seed=7)
    out = run_churn(cfg)
    assert out["recalls"][-1] < out["recalls"][0]


CHURN_DIST = r"""
import numpy as np
from repro.core.churn import ChurnConfig, run_churn, run_churn_distributed

cfg = ChurnConfig(num_users=1200, epochs=6, num_queries=64, update_rate=0.1,
                  churn_rate=0.03, refresh_every=2, seed=3)
single = run_churn(cfg)
d = run_churn_distributed(cfg, n_shards=2)
diff = float(np.abs(d["recalls"] - single["recalls"]).max())
# the sharded runtime must track the single-host trajectory at the same
# refresh period (acceptance: within 0.02; in practice it is exact)
assert diff <= 0.02, (diff, single["recalls"].tolist(), d["recalls"].tolist())
assert int(d["dropped_probes"].sum()) == 0
assert int(d["cache_staleness"].max()) >= 1   # cache goes stale between refreshes
assert int(d["cache_staleness"].min()) == 0   # and is rebuilt at each refresh
print("CHURN-DIST-OK", diff)
"""


@pytest.mark.slow
def test_distributed_churn_matches_single_host():
    out = run_in_subprocess(CHURN_DIST, devices=2)
    assert "CHURN-DIST-OK" in out
