"""Bucket store: insert/refresh/GC lifecycle (paper Sec. 4.1)."""

import jax.numpy as jnp
import numpy as np
from conftest import given, settings, st  # hypothesis or skip-fallback

from repro.core import store as st_mod
from repro.core.store import build_store_host, expire, insert_batch, make_store


def _occupied(store, l, b):
    ids = np.asarray(store.ids[l, b])
    return set(int(x) for x in ids if x >= 0)


def test_insert_batch_basic(rng):
    store = make_store(num_tables=2, num_buckets=8, capacity=4)
    ids = jnp.arange(6, dtype=jnp.int32)
    codes = jnp.asarray(rng.integers(0, 8, (6, 2)), jnp.uint32)
    store = insert_batch(store, ids, codes, jnp.int32(1))
    for l in range(2):
        for i in range(6):
            b = int(codes[i, l])
            assert int(ids[i]) in _occupied(store, l, b)


def test_ring_buffer_eviction():
    store = make_store(num_tables=1, num_buckets=2, capacity=3)
    # 5 entries into one bucket of capacity 3: keeps the last 3
    ids = jnp.arange(5, dtype=jnp.int32)
    codes = jnp.zeros((5, 1), jnp.uint32)
    store = insert_batch(store, ids, codes, jnp.int32(0))
    assert _occupied(store, 0, 0) == {2, 3, 4}


def test_refresh_overwrites_slots():
    store = make_store(num_tables=1, num_buckets=4, capacity=8)
    ids = jnp.arange(4, dtype=jnp.int32)
    codes = jnp.ones((4, 1), jnp.uint32)
    store = insert_batch(store, ids, codes, jnp.int32(0))
    store = insert_batch(store, ids, codes, jnp.int32(5))
    # same ids re-announced: occupancy can't exceed capacity, ts refreshed
    assert int(jnp.max(store.timestamps[0, 1])) == 5


def test_expire_gc():
    store = make_store(num_tables=1, num_buckets=4, capacity=4)
    store = insert_batch(
        store, jnp.arange(3, dtype=jnp.int32),
        jnp.zeros((3, 1), jnp.uint32), jnp.int32(0),
    )
    store = insert_batch(
        store, jnp.arange(3, 4, dtype=jnp.int32),
        jnp.zeros((1, 1), jnp.uint32), jnp.int32(10),
    )
    store = expire(store, jnp.int32(12), ttl=5)
    assert _occupied(store, 0, 0) == {3}


def test_insert_masked_drops_invalid():
    store = make_store(num_tables=1, num_buckets=4, capacity=4)
    ids = jnp.asarray([5, -1, 7], jnp.int32)
    buckets = jnp.asarray([1, 2, 1], jnp.uint32)
    store = st_mod.insert_masked(store, 0, ids, buckets, jnp.int32(0))
    assert _occupied(store, 0, 1) == {5, 7}
    assert _occupied(store, 0, 2) == set()


def test_build_host_matches_streaming(rng):
    n, nb, cap, T = 60, 8, 16, 3
    codes = rng.integers(0, nb, (n, T)).astype(np.uint32)
    built = build_store_host(codes, nb, cap)
    streamed = make_store(T, nb, cap)
    streamed = insert_batch(
        streamed, jnp.arange(n, dtype=jnp.int32), jnp.asarray(codes),
        jnp.int32(0),
    )
    for l in range(T):
        for b in range(nb):
            assert _occupied(built, l, b) == _occupied(streamed, l, b), (l, b)


def test_payload_store(rng):
    store = make_store(1, 4, 4, payload_dim=8)
    vecs = jnp.asarray(rng.standard_normal((3, 8)), jnp.float32)
    store = insert_batch(
        store, jnp.arange(3, dtype=jnp.int32),
        jnp.asarray([[0], [0], [1]], jnp.uint32), jnp.int32(0), vecs,
    )
    ids0 = np.asarray(store.ids[0, 0])
    slot = int(np.where(ids0 == 1)[0][0])
    assert np.allclose(np.asarray(store.payload[0, 0, slot]), np.asarray(vecs[1]))


def test_reannounce_refreshes_in_place():
    """Soft-state semantics: re-announcing an id UPDATES its entry (slot,
    timestamp, payload) instead of appending a second copy."""
    store = make_store(1, 4, 8, payload_dim=2)
    ids = jnp.arange(3, dtype=jnp.int32)
    codes = jnp.zeros((3, 1), jnp.uint32)
    v0 = jnp.asarray([[0., 0.], [1., 1.], [2., 2.]], jnp.float32)
    store = insert_batch(store, ids, codes, jnp.int32(0), v0)
    v1 = v0 + 10.0
    store = insert_batch(store, ids, codes, jnp.int32(5), v1)
    assert _occupied(store, 0, 0) == {0, 1, 2}          # no duplicates
    assert int(jnp.sum(store.ids[0, 0] >= 0)) == 3
    slot = int(np.where(np.asarray(store.ids[0, 0]) == 1)[0][0])
    assert int(store.timestamps[0, 0, slot]) == 5
    assert np.allclose(np.asarray(store.payload[0, 0, slot]), [11., 11.])


def test_wraparound_expire_reannounce_never_resurrects():
    """Ring wraparound x expire interplay: insert past capacity, GC, then
    re-announce different ids — evicted/expired ids must never reappear."""
    cap = 4
    store = make_store(1, 2, cap)
    # 6 distinct ids into bucket 0 of capacity 4: ring wraps, keeps 2..5
    store = insert_batch(
        store, jnp.arange(6, dtype=jnp.int32),
        jnp.zeros((6, 1), jnp.uint32), jnp.int32(0),
    )
    assert _occupied(store, 0, 0) == {2, 3, 4, 5}
    assert int(store.write_ptr[0, 0]) == 6 % cap
    # everything is stale at t=10: GC empties the bucket, ptr keeps moving
    store = expire(store, jnp.int32(10), ttl=5)
    assert _occupied(store, 0, 0) == set()
    # re-announce two FRESH ids; the expired ones must not resurrect
    store = insert_batch(
        store, jnp.asarray([7, 8], jnp.int32),
        jnp.zeros((2, 1), jnp.uint32), jnp.int32(10),
    )
    assert _occupied(store, 0, 0) == {7, 8}
    # and a later expire pass cannot bring anything back either
    store = expire(store, jnp.int32(11), ttl=5)
    assert _occupied(store, 0, 0) == {7, 8}


def test_wraparound_then_refresh_keeps_single_copy():
    """An id that survived a wraparound refreshes in place on re-announce
    even when the write pointer has lapped its slot."""
    store = make_store(1, 2, 4)
    store = insert_batch(
        store, jnp.arange(6, dtype=jnp.int32),
        jnp.zeros((6, 1), jnp.uint32), jnp.int32(0),
    )  # bucket holds {2,3,4,5}, ptr=2
    store = insert_batch(
        store, jnp.asarray([4], jnp.int32),
        jnp.zeros((1, 1), jnp.uint32), jnp.int32(3),
    )
    assert _occupied(store, 0, 0) == {2, 3, 4, 5}       # still one copy of 4
    slot = int(np.where(np.asarray(store.ids[0, 0]) == 4)[0][0])
    assert int(store.timestamps[0, 0, slot]) == 3
    assert int(store.write_ptr[0, 0]) == 2              # no append happened


def test_duplicate_ids_in_one_batch_keep_last():
    """Regression: two rows with the same NEW id in one batch used to both
    miss the refresh-in-place match and both ring-append — two live copies
    of one user.  In-batch dedupe keeps exactly one, with the LAST row's
    timestamp/payload (the current announcement wins, matching
    `build_store_host`'s keep-last bulk semantics)."""
    store = make_store(1, 8, 4, payload_dim=2)
    ids = jnp.asarray([5, 5, 7], jnp.int32)
    codes = jnp.asarray([[3], [3], [3]], jnp.uint32)
    pay = jnp.asarray([[1., 0.], [0., 1.], [.5, .5]], jnp.float32)
    store = insert_batch(store, ids, codes, jnp.int32(1), pay)
    bucket = np.asarray(store.ids[0, 3])
    assert int((bucket == 5).sum()) == 1          # ONE copy, not two
    assert int((bucket == 7).sum()) == 1
    slot = int(np.argmax(bucket == 5))
    assert np.allclose(np.asarray(store.payload[0, 3, slot]), [0., 1.])
    # dedup-equivalence with the host bulk build: duplicates resolved
    # keep-last stream identically to a batch that never had them
    dedup = make_store(1, 8, 4, payload_dim=2)
    dedup = insert_batch(
        dedup, jnp.asarray([5, 7], jnp.int32),
        jnp.asarray([[3], [3]], jnp.uint32), jnp.int32(1), pay[1:],
    )
    assert _occupied(store, 0, 3) == _occupied(dedup, 0, 3)


def test_duplicate_ids_dont_inflate_write_ptr():
    """The dropped duplicate must not advance the ring pointer either —
    a phantom advance would evict a live slot on the next append."""
    store = make_store(1, 2, 4)
    store = insert_batch(
        store, jnp.asarray([1, 1, 1, 2], jnp.int32),
        jnp.zeros((4, 1), jnp.uint32), jnp.int32(0),
    )
    assert _occupied(store, 0, 0) == {1, 2}
    assert int(store.write_ptr[0, 0]) == 2        # two appends, not four


def test_expire_noop_keeps_generation():
    """Regression: a GC pass that collects NOTHING used to bump
    `generation` anyway, evicting every sketch-keyed query-cache entry
    for free.  Now the bump is conditional on something actually being
    collected — and empty slots (timestamp 0) never count as stale."""
    store = make_store(1, 4, 4)
    store = insert_batch(
        store, jnp.arange(3, dtype=jnp.int32),
        jnp.zeros((3, 1), jnp.uint32), jnp.int32(10),
    )
    g0 = int(store.generation)
    store = expire(store, jnp.int32(11), ttl=5)   # nothing is stale
    assert int(store.generation) == g0
    assert _occupied(store, 0, 0) == {0, 1, 2}
    store = expire(store, jnp.int32(12), ttl=5)   # still nothing
    assert int(store.generation) == g0
    store = expire(store, jnp.int32(20), ttl=5)   # everything is
    assert int(store.generation) == g0 + 1
    assert _occupied(store, 0, 0) == set()
    # an ALL-EMPTY store is the sharp edge: ts==0 everywhere, every slot
    # 'stale' by timestamp — but there is nothing to collect
    store = expire(store, jnp.int32(30), ttl=5)
    assert int(store.generation) == g0 + 1


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 40), st.integers(1, 4), st.integers(2, 8),
    st.integers(2, 8), st.integers(0, 2**31 - 1),
)
def test_insert_never_loses_recent_entries(n, T, nb_pow, cap, seed):
    """Property: after inserting a batch, every bucket holds the LAST
    min(cap, count) ids routed to it, in insertion order."""
    nb = 1 << (nb_pow - 1)
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, nb, (n, T)).astype(np.uint32)
    store = make_store(T, nb, cap)
    store = insert_batch(
        store, jnp.arange(n, dtype=jnp.int32), jnp.asarray(codes), jnp.int32(0)
    )
    for l in range(T):
        for b in range(nb):
            routed = [i for i in range(n) if codes[i, l] == b]
            expect = set(routed[-cap:])
            assert _occupied(store, l, b) == expect
