"""Fail-stop node loss: R-way replication, liveness-masked reads, and
the failure-injection harness (DESIGN.md Sec. 10).

Tier-1 covers the host-side machinery — replica placement geometry, the
Sec. 10 byte closed forms, config/schedule validation, and `kill_node`'s
blanking semantics.  The `slow` subprocess tests run the real thing on a
4-device host mesh: a kill with NO handoff degrades recall within the
acceptance bound, the next re-announce recovers to parity with every
replication/recovery byte charged, quorum reads match, and one long-lived
serving frontend survives the same kill live.
"""

import dataclasses
import types

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess
from repro.core import costmodel
from repro.core.can import CanTopology
from repro.core.churn import (
    ChurnConfig, FailureChurnConfig, _expand_kills, run_churn_runtime,
)
from repro.core.hashing import LshParams
from repro.core.runtime import IndexRuntime, RuntimeConfig, kill_node
from repro.core.store import make_store


# -----------------------------------------------------------------------------
# replica placement geometry
# -----------------------------------------------------------------------------


def test_replicas_of_ring_successors():
    topo = CanTopology(k=4, n_nodes=4)
    codes = np.arange(16, dtype=np.uint32)
    owners = np.asarray(topo.replicas_of(codes, 3))
    assert owners.shape == (16, 3)
    # column 0 is the primary; column r is the r-th zone-adjacent successor
    np.testing.assert_array_equal(owners[:, 0], topo.node_of_np(codes))
    for r in (1, 2):
        np.testing.assert_array_equal(
            owners[:, r], (owners[:, 0] + r) % topo.n_nodes)
    # R=1 degenerates to plain ownership
    np.testing.assert_array_equal(
        np.asarray(topo.replicas_of(codes, 1))[:, 0], topo.node_of_np(codes))


def test_replicas_of_validation():
    topo = CanTopology(k=4, n_nodes=4)
    codes = np.arange(4, dtype=np.uint32)
    with pytest.raises(ValueError, match="R"):
        topo.replicas_of(codes, 0)
    with pytest.raises(ValueError, match="R"):
        topo.replicas_of(codes, 5)  # more replicas than nodes


# -----------------------------------------------------------------------------
# Sec. 10 byte closed forms
# -----------------------------------------------------------------------------


def test_replication_bytes_closed_form():
    # (R-1) extra copies of L tables x n vectors x (8-byte id+ts, 4d payload)
    assert costmodel.estimate_replication_bytes(2, 100, 16, 3) == (
        2 * 2 * 100 * (8 + 4 * 16))
    assert costmodel.estimate_replication_bytes(4, 1000, 32, 1) == 0
    with pytest.raises(ValueError):
        costmodel.estimate_replication_bytes(2, 100, 16, 0)


def test_recovery_bytes_closed_form():
    # one full zone: L x buckets_per_node x (capacity slots + ring ptr)
    assert costmodel.estimate_recovery_bytes(2, 8, 4, 16) == (
        2 * 8 * (4 * (8 + 4 * 16) + 4))


# -----------------------------------------------------------------------------
# config / schedule validation
# -----------------------------------------------------------------------------


def _params():
    return LshParams(d=16, k=4, L=2, seed=0)


def test_runtime_config_replication_validation():
    ok = RuntimeConfig(params=_params(), n_nodes=4, routing="alltoall",
                       replication=2, read_mode="quorum")
    assert ok.replication == 2
    with pytest.raises(ValueError, match="replication"):
        RuntimeConfig(params=_params(), n_nodes=2, replication=3)
    with pytest.raises(ValueError, match="alltoall"):
        RuntimeConfig(params=_params(), n_nodes=4, routing="pairwise",
                      replication=2)
    with pytest.raises(ValueError, match="read_mode"):
        RuntimeConfig(params=_params(), n_nodes=4, routing="alltoall",
                      replication=2, read_mode="all")
    with pytest.raises(ValueError, match="nb"):
        RuntimeConfig(params=_params(), n_nodes=4, routing="alltoall",
                      variant="nb", replication=2)


def test_expand_kills_validation():
    assert _expand_kills(((3, 1), (3, 2), (5, 0)), 6, 4) == {
        3: [1, 2], 5: [0]}
    with pytest.raises(ValueError, match="epoch"):
        _expand_kills(((9, 0),), 6, 4)
    with pytest.raises(ValueError, match="node"):
        _expand_kills(((2, 4),), 6, 4)
    assert _expand_kills((), 6, 4) == {}


def test_kills_require_replication():
    cfg = ChurnConfig(num_users=64, epochs=2, num_queries=8)
    rt = IndexRuntime(RuntimeConfig(params=_params(), n_nodes=1))
    with pytest.raises(ValueError, match="replication"):
        run_churn_runtime(cfg, rt, kills=((1, 0),))


def test_failure_config_defaults():
    cfg = FailureChurnConfig()
    assert cfg.replication >= 2
    assert cfg.read_mode in ("first", "quorum")
    assert all(0 <= n < cfg.n_nodes for _e, n in cfg.kills)


# -----------------------------------------------------------------------------
# kill_node blanking semantics (host-side; only the topology is consulted)
# -----------------------------------------------------------------------------


def test_kill_node_blanks_zone_and_held_replicas():
    topo = CanTopology(k=4, n_nodes=4)
    rt = types.SimpleNamespace(topology=topo)
    L, nb, cap, d, R = 2, 16, 4, 8, 2
    store = make_store(L, nb, cap, payload_dim=d)
    store = dataclasses.replace(
        store,
        ids=jnp.zeros((L, nb, cap), jnp.int32),       # all slots "live"
        timestamps=jnp.ones((L, nb, cap), jnp.int32),
        write_ptr=jnp.ones((L, nb), jnp.int32),
        payload=jnp.ones((L, nb, cap, d), jnp.float32),
    )
    reps = (jnp.zeros((L, R - 1, nb, cap), jnp.int32),
            jnp.ones((L, R - 1, nb, cap, d), jnp.float32))
    g0 = int(store.generation)
    store2, reps2 = kill_node(rt, store, reps, 1)

    s, e = topo.zone_range(1)
    zone = np.zeros(nb, bool)
    zone[s:e] = True
    # the victim's zone is gone from the primary store...
    assert np.all(np.asarray(store2.ids)[:, zone] == -1)
    assert np.all(np.asarray(store2.timestamps)[:, zone] == 0)
    assert np.all(np.asarray(store2.write_ptr)[:, zone] == 0)
    assert np.all(np.asarray(store2.payload)[:, zone] == 0.0)
    # ...and from the replica slices it was holding for its predecessors
    assert np.all(np.asarray(reps2[0])[:, :, zone] == -1)
    assert np.all(np.asarray(reps2[1])[:, :, zone] == 0.0)
    # everything outside the zone is untouched (replicas OF the zone that
    # live on the successors are in the survivors' slices — not blanked)
    assert np.all(np.asarray(store2.ids)[:, ~zone] == 0)
    assert np.all(np.asarray(reps2[0])[:, :, ~zone] == 0)
    # serve caches must drop anything computed pre-kill
    assert int(store2.generation) == g0 + 1
    # replicas=None (an R=1 caller) passes through
    store3, none_reps = kill_node(rt, store, None, 0)
    assert none_reps is None
    s0, e0 = topo.zone_range(0)
    assert np.all(np.asarray(store3.ids)[:, s0:e0] == -1)


# -----------------------------------------------------------------------------
# the real thing: 4-device failure runs (slow, subprocess)
# -----------------------------------------------------------------------------


FAILURE_CHURN = r"""
import numpy as np
from repro.core import costmodel
from repro.core.churn import (
    ChurnConfig, FailureChurnConfig, run_failure_churn,
)

cfg = ChurnConfig(num_users=1200, dim=32, k=5, L=2, capacity=64, epochs=6,
                  num_queries=64, update_rate=0.1, churn_rate=0.03,
                  refresh_every=2, seed=3)

for read_mode in ("first", "quorum"):
    out = run_failure_churn(FailureChurnConfig(
        churn=cfg, n_nodes=4, replication=2, read_mode=read_mode,
        kills=((3, 1),),
    ))
    # the kill degrades liveness for exactly the epochs before the next
    # announce, recall stays within the acceptance bound, and the revival
    # restores parity with the no-failure reference
    assert out["degraded"].any() and not out["degraded"][-1]
    assert out["degraded_gap"] <= 0.05, (read_mode, out["degraded_gap"])
    assert out["recovered_gap"] <= 0.02, (read_mode, out["recovered_gap"])
    assert out["recovery_epochs"] <= cfg.refresh_every
    assert int(out["dropped_probes"].sum()) == 0
    # before the kill the replica layer is invisible: reference == failure
    # bit-exactly (post-recovery epochs are parity-bounded, not exact —
    # the rebuilt zone lacks the reference's not-yet-expired stale rows)
    pre = np.arange(out["recalls"].size) < int(np.argmax(out["degraded"]))
    assert pre.any()
    assert np.array_equal(out["recalls"][pre], out["reference_recalls"][pre])
    # every byte charged, never silent, matching the closed forms
    per_rep = costmodel.estimate_replication_bytes(cfg.L, cfg.num_users,
                                                   cfg.dim, 2)
    announced = out["replication_bytes"] > 0
    assert announced.any()
    assert np.all(out["replication_bytes"][announced] == per_rep)
    per_zone = costmodel.estimate_recovery_bytes(
        cfg.L, (1 << cfg.k) // 4, cfg.capacity, cfg.dim)
    recovered = out["recovery_bytes"] > 0
    assert recovered.any()
    assert np.all(out["recovery_bytes"][recovered] == per_zone)
    assert out["total_recovery_bytes"] == sum(
        b for _e, _n, b in out["recoveries"])
    print(f"FAILURE-{read_mode}-OK", out["degraded_gap"])
"""


@pytest.mark.slow
def test_failure_churn_degrades_and_recovers():
    out = run_in_subprocess(FAILURE_CHURN, devices=4)
    assert "FAILURE-first-OK" in out
    assert "FAILURE-quorum-OK" in out


SERVE_FAILURE = r"""
import numpy as np
from repro.core.churn import ChurnConfig
from repro.serve.lifecycle import ServeFailureConfig, run_serve_failure

cfg = ServeFailureConfig(
    churn=ChurnConfig(num_users=1200, dim=32, k=5, L=2, capacity=64,
                      epochs=6, num_queries=64, update_rate=0.1,
                      churn_rate=0.03, refresh_every=2, seed=3),
    n_nodes=4, replication=2, read_mode="first", kill_epoch=3, kill_node=1,
)
out = run_serve_failure(cfg)
# serving never stops: every read epoch (including the kill epoch, twice)
# produced results, repeats within a generation are bit-identical, and
# the kill epoch is the only degraded one
assert out["repeat_mismatches"] == 0
assert out["degraded"][cfg.kill_epoch - 1] and not out["degraded"][-1]
assert out["recall_after_kill"] >= out["recall_before_kill"] - 0.05
# the kill bumps the backend generation mid-epoch (pre-kill cache entries
# die) and the cache still works on both sides of it
g = out["generations"]
assert g[cfg.kill_epoch - 1] > g[cfg.kill_epoch - 2]
assert out["stale_evictions"] > 0 and out["cache_hits"] > 0
assert out["replication_bytes"] > 0 and out["recovery_bytes"] > 0
assert out["stats"].dropped_probes == 0
print("SERVE-FAILURE-OK", out["recall_before_kill"], out["recall_after_kill"])
"""


@pytest.mark.slow
def test_serving_survives_kill():
    out = run_in_subprocess(SERVE_FAILURE, devices=4)
    assert "SERVE-FAILURE-OK" in out
