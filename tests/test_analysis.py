"""Propositions 1-4 closed forms (paper Sec. 5) as executable properties."""

import numpy as np
from conftest import given, settings, st  # hypothesis or skip-fallback

from repro.core import analysis, costmodel

sim = st.floats(0.5, 1.0)  # angular similarity range for non-negative vectors
ks = st.integers(2, 20)
Ls = st.integers(1, 30)


@settings(max_examples=60, deadline=None)
@given(sim, ks, Ls)
def test_sp_bounds(s, k, L):
    for f in (analysis.sp_lsh, analysis.sp_nearbucket):
        v = f(s, k, L)
        assert 0.0 <= v <= 1.0 + 1e-12


@settings(max_examples=60, deadline=None)
@given(sim, ks, Ls)
def test_prop2_exact_dominates_near(s, k, L):
    """Prop. 2: SP(exact) >= SP(1-near bucket) for s in [0.5, 1]."""
    assert analysis.sp_exact_bucket(s, k) >= analysis.sp_b_near_bucket(s, k, 1) - 1e-12


@settings(max_examples=60, deadline=None)
@given(sim, st.integers(3, 20), st.integers(0, 3))
def test_prop3_b_monotonicity(s, k, b):
    """Prop. 3: b1 < b2 => SP(b1-near) >= SP(b2-near)."""
    assert analysis.sp_b_near_bucket(s, k, b) >= analysis.sp_b_near_bucket(
        s, k, b + 1
    ) - 1e-12


@settings(max_examples=60, deadline=None)
@given(sim, ks, Ls)
def test_nearbucket_dominates_lsh_at_equal_L(s, k, L):
    """Fig. 2: SP(NB(k,L)) >= SP(LSH(k,L))."""
    assert analysis.sp_nearbucket(s, k, L) >= analysis.sp_lsh(s, k, L) - 1e-12


@settings(max_examples=60, deadline=None)
@given(sim, ks, Ls)
def test_lsh_monotone_in_L(s, k, L):
    assert analysis.sp_lsh(s, k, L + 1) >= analysis.sp_lsh(s, k, L) - 1e-12


def test_fig1_lsh_dominates_at_equal_buckets():
    """Fig. 1: at equal searched-bucket budget, LSH >= NB (exact buckets
    are individually better; k=12, budget = L_nb * 13 buckets).

    Note: this is the paper's *plotted* claim, not a pointwise theorem — at
    s ~ 0.5 NB's near buckets (disjoint within a table) edge out LSH's
    overlapping independent tables by O(1e-4); we assert dominance up to
    that tail tolerance, and strictly for s >= 0.65.
    """
    k = 12
    for L_nb in (1, 10, 100):
        budget = L_nb * (1 + k)
        s = np.linspace(0.5, 1.0, 101)
        lsh = analysis.sp_lsh(s, k, budget)
        nb = analysis.sp_nearbucket(s, k, L_nb)
        assert np.all(lsh >= nb - 5e-4)
        hi = s >= 0.65
        assert np.all(lsh[hi] >= nb[hi] - 1e-12)


def test_fig3_cnb_dominates_at_equal_messages():
    """Fig. 3: at equal message budget, CNB >= LSH and CNB >= NB."""
    k = 12
    for budget in (18, 180, 1800):
        s = np.linspace(0.5, 1.0, 101)
        L_cnb = costmodel.lsh_L_for_budget("cnb", k, budget)
        L_lsh = costmodel.lsh_L_for_budget("lsh", k, budget)
        L_nb = costmodel.lsh_L_for_budget("nb", k, budget)
        cnb = analysis.sp_nearbucket(s, k, L_cnb)
        lsh = analysis.sp_lsh(s, k, L_lsh)
        nb = analysis.sp_nearbucket(s, k, max(L_nb, 0))
        assert np.all(cnb >= lsh - 1e-12)
        assert np.all(cnb >= nb - 1e-12)


def test_angular_cosine_roundtrip():
    t = np.linspace(0, 1, 51)
    s = analysis.angular_from_cosine(t)
    assert np.all((s >= 0.5) & (s <= 1.0))
    back = analysis.cosine_from_angular(s)
    assert np.allclose(back, t, atol=1e-9)


def test_layered_equals_lsh():
    s = np.linspace(0.5, 1, 11)
    assert np.allclose(
        analysis.sp_layered(s, 12, 4), analysis.sp_lsh(s, 12, 4)
    )


def test_table1_closed_forms():
    qc = costmodel.table1("lsh", k=12, L=4, bucket_size=100)
    assert (qc.nodes_contacted, qc.messages) == (4, 24.0)
    assert (qc.vectors_stored_per_node, qc.vectors_searched) == (100, 400)
    qc = costmodel.table1("nb", k=12, L=4, bucket_size=100)
    assert (qc.nodes_contacted, qc.messages) == (52, 72.0)
    assert qc.vectors_searched == 4 * 13 * 100
    qc = costmodel.table1("cnb", k=12, L=4, bucket_size=100)
    assert (qc.nodes_contacted, qc.messages) == (4, 24.0)
    assert qc.vectors_stored_per_node == 13 * 100
    assert qc.vectors_searched == 4 * 13 * 100
    qc_layered = costmodel.table1("layered", k=12, L=4, bucket_size=100)
    assert qc_layered == costmodel.table1("lsh", k=12, L=4, bucket_size=100)
