"""Pipelined serving (DESIGN.md Sec. 13): depth-K dispatch queue,
out-of-order reap, background churn writer, open-loop load.

The load-bearing invariant: pipelining changes WHEN work happens, never
WHAT is computed.  Batch composition is a function of the submit/step
call schedule alone (FIFO intake of min(pending, max_batch) rows at
every stage point), per-row results are independent of batch
composition, and in-flight batches hold the store pytree they were
dispatched with — so served ids are bit-identical across pipeline
depths under any deterministic schedule, with or without the cache,
with churn updates interleaved mid-flight.  These tests pin that down,
plus the writer-vs-reader generation contract and the open-loop
generator's accounting.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    DenseCorpus, EngineConfig, LshEngine, LshParams, make_hyperplanes,
)
from repro.core.churn import ChurnConfig, run_churn
from repro.core.hashing import sketch_codes_batched
from repro.core.store import build_store_host, insert_batch
from repro.serve import (
    ChurnWriter, FrontendConfig, RetrievalFrontend, RuntimeBackend,
    ServeChurnConfig, SubmitReject, poisson_arrivals, run_open_loop,
    run_serve_churn,
)

K, L, D, M = 5, 3, 16, 8


def _make_engine(n=400, seed=0, capacity=32):
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((n, D)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    params = LshParams(d=D, k=K, L=L, seed=seed + 1)
    h = make_hyperplanes(params)
    codes = sketch_codes_batched(jnp.asarray(emb), h)
    store = build_store_host(codes, params.num_buckets, capacity=capacity)
    engine = LshEngine(params, h, store, DenseCorpus(jnp.asarray(emb)), None,
                       EngineConfig(variant="cnb"))
    return emb, engine, h


def _new_store_update(emb, h, seed, epoch):
    """One churn write epoch's update kwargs: fresh vectors, rebuilt
    store — applied via `apply_update` mid-schedule."""
    rng = np.random.default_rng(seed)
    vecs = (emb + 0.05 * rng.standard_normal(emb.shape)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    codes = sketch_codes_batched(jnp.asarray(vecs), h)
    store = build_store_host(codes, 1 << K, capacity=32)
    store = insert_batch(
        store, jnp.arange(0, dtype=jnp.int32),
        jnp.zeros((0, L), jnp.uint32), jnp.int32(epoch),
    )  # no-op insert: bumps generation past any previous store's
    return dict(store=store, corpus=DenseCorpus(jnp.asarray(vecs))), vecs


def _drive_schedule(fe, emb, h, *, churn):
    """One fixed deterministic schedule: submit bursts of varied sizes,
    interleaved step() calls, optional mid-flight churn updates — the
    SAME call sequence regardless of the frontend's pipeline depth.
    Returns ids keyed by submission order."""
    tickets = []
    rng = np.random.default_rng(7)
    rows = rng.integers(0, emb.shape[0], size=60)
    rows[45:] = rows[:15]   # the last burst repeats served rows (hits)
    qsrc = emb

    def sub(a, b):
        for r in rows[a:b]:
            t = fe.submit(qsrc[r], int(r))
            assert not isinstance(t, SubmitReject)
            tickets.append(t)

    sub(0, 5)
    fe.step()
    sub(5, 20)          # includes repeats of earlier rows (cache fodder)
    fe.step()
    fe.step()
    if churn:
        kw, qsrc = _new_store_update(emb, h, seed=11, epoch=2)
        fe.apply_update(**kw)
    sub(20, 41)
    fe.step()
    if churn:
        kw, qsrc = _new_store_update(emb, h, seed=12, epoch=3)
        fe.apply_update(**kw)
    sub(41, 45)
    fe.flush()          # part of the schedule: rows 0..44 all reaped here
    sub(45, 60)         # repeats of rows 0..14 — cache hits at ANY depth
    fe.flush()
    return np.stack([fe.poll(t)[0] for t in tickets])


@pytest.mark.parametrize("cache", [False, True])
@pytest.mark.parametrize("churn", [False, True])
def test_pipelined_ids_bit_identical_to_sync(cache, churn):
    """THE equivalence invariant: under one deterministic schedule the
    pipelined frontend serves ids bit-identical to the synchronous
    (depth-1) path — cache on or off, churn updates installed mid-flight
    or not.  (With churn the schedule queries the post-update vectors,
    so every row is a fresh exact-mode key: hit/miss timing cannot
    diverge between depths across a generation bump.)"""
    emb, engine, h = _make_engine()
    ref = None
    for depth in (1, 3):
        fe = RetrievalFrontend(
            RuntimeBackend(engine),
            FrontendConfig(m=M, max_batch=8, queue_capacity=256,
                           cache=cache, pipeline_depth=depth),
        )
        ids = _drive_schedule(fe, emb, h, churn=churn)
        if cache and not churn:
            assert fe.stats.cache_hits > 0  # repeats really hit
        if ref is None:
            ref = ids
        else:
            np.testing.assert_array_equal(ids, ref)


def test_deep_pipeline_really_overlaps():
    """Sanity on the machine itself: with depth 3 and pending rows, step
    stages WITHOUT reaping until the pipeline fills, so multiple batches
    are genuinely in flight at once."""
    emb, engine, _ = _make_engine()
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=4, queue_capacity=64, cache=False,
                       pipeline_depth=3),
    )
    for i in range(12):
        fe.submit(emb[i])
    fe.step()
    fe.step()
    assert fe.inflight == 2 and fe.inflight_rows == 8
    fe.step()   # stages the 3rd AND block-reaps the oldest (pipeline full)
    assert fe.inflight == 2
    fe.flush()
    assert fe.inflight == 0 and fe.stats.completed == 12


def test_out_of_order_reap_by_ticket():
    """`wait(ticket)` reaps exactly the batch carrying the ticket; a
    batch dispatched EARLIER stays on the device queue, and its results
    stay pending until their own reap."""
    emb, engine, _ = _make_engine()
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=4, queue_capacity=64, cache=False,
                       pipeline_depth=3),
    )
    ta = [fe.submit(emb[i]) for i in range(4)]
    fe.step()                       # stage batch A
    tb = [fe.submit(emb[i]) for i in range(4, 8)]
    fe.step()                       # stage batch B
    assert fe.inflight == 2
    got = fe.wait(tb[2])            # newest batch first
    assert got[0].shape == (M,)
    assert fe.inflight == 1         # batch A still in flight
    # B's wait reaped ONLY B: A's results are not scattered yet
    assert all(t not in fe._results for t in ta)
    assert all(fe.poll(t) is not None for t in tb if t != tb[2])
    assert all(fe.wait(t) is not None for t in ta)
    assert fe.inflight == 0
    # unknown tickets raise once nothing is pending
    with pytest.raises(KeyError):
        fe.wait(10_000)


def test_writer_generation_vs_reader():
    """Writer-vs-reader contract: a result computed by a batch that was
    in flight when a churn update installed is cached at its STAGE-TIME
    generation — after the install, lookups evict it as stale and
    recompute against the new store.  Nothing pre-update is ever served
    post-update."""
    emb, engine, h = _make_engine()
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=4, queue_capacity=64, cache=True,
                       pipeline_depth=2),
    )
    q = emb[:4]
    for r in q:
        fe.submit(r)
    fe.step()                               # batch in flight at gen g0
    assert fe.inflight == 1
    kw, _ = _new_store_update(emb, h, seed=21, epoch=2)
    fe.apply_update(**kw)                   # installs mid-flight: gen g1
    g1 = fe.backend.generation
    fe.flush()                              # reap: cache fill at g0 < g1
    evict0 = fe.cache.stale_evictions
    ids2, _ = fe.search(q)                  # post-update serving
    assert fe.cache.stale_evictions == evict0 + 4  # born-stale entries died
    assert fe.stats.cache_hits == 0
    # and the recompute really used the new store: it matches a fresh
    # synchronous frontend over the same updated backend state
    fe2 = RetrievalFrontend(
        fe.backend, FrontendConfig(m=M, max_batch=4, queue_capacity=64,
                                   cache=False),
    )
    np.testing.assert_array_equal(ids2, fe2.search(q)[0])


@pytest.mark.parametrize("inline", [True, False])
def test_churn_writer_prepare_install_split(inline):
    """`ChurnWriter`: prep runs off the serving path (worker thread, or
    inline for determinism), the prepared update installs at the next
    stage boundary, and `drain` is a full barrier."""
    emb, engine, h = _make_engine()
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=4, queue_capacity=64, cache=True,
                       pipeline_depth=2),
    )
    with ChurnWriter(fe, inline=inline) as w:
        assert fe.writer is w
        g0 = fe.backend.generation
        kw, vecs = _new_store_update(emb, h, seed=31, epoch=2)
        w.submit(lambda: kw)
        if inline:
            assert w.prepared == 1 and w.installed == 0
            assert fe.backend.generation == g0  # prepared != installed
        # the next stage boundary installs it before dispatching
        for r in vecs[:4]:
            fe.submit(r)
        if not inline:
            w.drain()                        # thread barrier, then install
        else:
            fe.step()                        # stage boundary installs
        assert w.installed == 1
        assert fe.backend.generation > g0
        fe.flush()
        # served against the NEW store: match a clean frontend on it
        ids, _ = fe.search(vecs[:4])
        fe_ref = RetrievalFrontend(
            fe.backend, FrontendConfig(m=M, max_batch=4,
                                       queue_capacity=64, cache=False),
        )
        np.testing.assert_array_equal(ids, fe_ref.search(vecs[:4])[0])
    assert fe.writer is None                 # close() detached


def test_writer_refuses_topology_swaps():
    emb, engine, _ = _make_engine()
    fe = RetrievalFrontend(RuntimeBackend(engine), FrontendConfig(m=M))
    with ChurnWriter(fe, inline=True) as w:
        w.submit(lambda: dict(runtime=object()))
        with pytest.raises(ValueError, match="update_backend"):
            w.install()


def test_serve_churn_writer_and_depth_track_reference():
    """The lifecycle driver through the writer path at depth 2 still
    tracks the run_churn reference trajectory bit-exactly."""
    churn = ChurnConfig(
        num_users=400, dim=D, k=K, L=L, capacity=32, epochs=4,
        num_queries=32, m=M, refresh_every=2, ttl_epochs=3, seed=5,
    )
    ref = run_churn(churn)
    out = run_serve_churn(ServeChurnConfig(
        churn=churn, query_repeats=2, max_batch=16, queue_capacity=64,
        pipeline_depth=2, use_writer=True,
    ))
    np.testing.assert_allclose(out["recalls"], ref["recalls"])
    assert out["repeat_mismatches"] == 0
    assert out["writer_installed"] >= 2      # every write epoch installed
    assert out["summary"]["hit_rate"] > 0.3


def test_zero_retrace_with_pipeline_and_obs():
    """The pow-2 shape budget survives pipelining, and obs adds ZERO
    retraces at depth > 1 (instrumentation is host-side only)."""
    from repro.obs import Observability

    emb, engine, _ = _make_engine()
    traces = {}
    for tag, obs in (("off", None), ("on", Observability())):
        backend = RuntimeBackend(engine)
        fe = RetrievalFrontend(
            backend,
            FrontendConfig(m=M, max_batch=16, queue_capacity=256,
                           cache=True, pipeline_depth=3),
            obs=obs,
        )
        rng = np.random.default_rng(3)
        for n in [1, 2, 3, 5, 7, 11, 13, 17, 23, 31, 43, 16, 6]:
            rows = rng.integers(0, emb.shape[0], size=n)
            fe.search(emb[rows])
        assert backend.traces <= 7
        traces[tag] = backend.traces
    assert traces["on"] == traces["off"]


def test_queue_depth_and_time_in_queue_metrics():
    """The pipeline's obs surface: `serve_queue_depth` gauge tracks the
    ring, `serve_time_in_queue_us` histogram sees one observation per
    staged row, and the stats summary carries queue percentiles."""
    from repro.obs import Observability

    emb, engine, _ = _make_engine()
    obs = Observability()
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=4, queue_capacity=64, cache=False,
                       pipeline_depth=2),
        obs=obs,
    )
    for i in range(6):
        fe.submit(emb[i])
    assert obs.registry.value("serve_queue_depth") == 6
    fe.step()
    assert obs.registry.value("serve_queue_depth") == 2
    fe.flush()
    assert obs.registry.value("serve_queue_depth") == 0
    assert obs.registry.value("serve_time_in_queue_us") == 6  # obs count
    s = fe.stats.summary()
    assert fe.stats.staged == 6
    assert s["p99_queue_us"] >= s["p50_queue_us"] >= 0.0


def test_poisson_arrivals_shape():
    arr = poisson_arrivals(1000.0, 500, seed=3)
    assert arr.shape == (500,) and np.all(np.diff(arr) > 0)
    assert 0.3 < arr[-1] < 1.2   # ~0.5 s of offered load at 1k qps
    det = poisson_arrivals(100.0, 10, deterministic=True)
    np.testing.assert_allclose(np.diff(det), 0.01)
    with pytest.raises(ValueError):
        poisson_arrivals(0.0, 10)


@pytest.mark.parametrize("depth", [1, 2])
def test_open_loop_accounting_and_identity(depth):
    """`run_open_loop` serves every arrival (no shed at a feasible
    rate), measures latency from the SCHEDULE, and the served ids are
    bit-identical to a direct synchronous search of the same rows."""
    emb, engine, _ = _make_engine()
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=8, queue_capacity=256, cache=False,
                       pipeline_depth=depth),
    )
    n = 64
    rows = np.random.default_rng(5).integers(0, emb.shape[0], size=n)
    arr = poisson_arrivals(2000.0, n, deterministic=True)
    res = run_open_loop(fe, emb[rows], arr)
    assert res.completed == n and res.shed == 0
    assert set(res.ids) == set(range(n))
    assert res.latencies_ms.shape == (n,)
    assert res.p99_ms >= res.p50_ms > 0
    assert res.slo_ok(p99_slo_ms=1e9) and not res.slo_ok(p99_slo_ms=0.0)
    assert res.summary["completed"] == n
    ref = RetrievalFrontend(
        fe.backend, FrontendConfig(m=M, max_batch=8, queue_capacity=256,
                                   cache=False),
    )
    ref_ids, _ = ref.search(emb[rows])
    got = np.stack([res.ids[i] for i in range(n)])
    np.testing.assert_array_equal(got, ref_ids)
