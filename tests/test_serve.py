"""Online retrieval serving subsystem (DESIGN.md Sec. 7).

Pins the serving contracts:
  * frontend results are BIT-IDENTICAL to direct `engine.search`, cache
    on and off (the no-serving-only-query-path rule);
  * pow-2 batch padding bounds the set of compiled shapes (trace count);
  * admission control rejects over-capacity arrivals, counted;
  * the sketch-keyed cache never serves a stale-generation entry across
    insert/expire churn;
  * telemetry aggregates QueryCost and dropped_probes at the summary;
  * the mesh-step backend (1-shard, single device) matches the engine;
  * read/write-epoch serving tracks the churn reference trajectory
    exactly.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    DenseCorpus, EngineConfig, LshEngine, LshParams, make_hyperplanes,
)
from repro.core import costmodel
from repro.core.churn import ChurnConfig, run_churn
from repro.core.hashing import sketch_codes_batched
from repro.core.store import build_store_host, expire, insert_batch, make_store
from repro.serve import (
    ADMIT_REJECT, RING_FULL, FrontendConfig, QueryCache, RetrievalFrontend,
    RuntimeBackend, ServeStats, ServeChurnConfig, SubmitReject, dispatch_pad,
    pow2_pad, run_serve_churn,
)

K, L, D, M = 5, 3, 16, 8


def _make_engine(n=400, seed=0, capacity=32, variant="cnb", payload=False):
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((n, D)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    params = LshParams(d=D, k=K, L=L, seed=seed + 1)
    h = make_hyperplanes(params)
    codes = sketch_codes_batched(jnp.asarray(emb), h)
    store = build_store_host(
        codes, params.num_buckets, capacity=capacity,
        payload=emb if payload else None,
    )
    engine = LshEngine(params, h, store, DenseCorpus(jnp.asarray(emb)), None,
                       EngineConfig(variant=variant))
    return emb, engine, codes


# -----------------------------------------------------------------------------
# bit-identity with the reference engine
# -----------------------------------------------------------------------------


@pytest.mark.parametrize("cache", [False, True])
def test_frontend_matches_engine_search(cache):
    emb, engine, _ = _make_engine()
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=16, queue_capacity=64, cache=cache),
    )
    q = emb[:50]
    ex = np.arange(50)
    ids, scores = fe.search(q, exclude=ex)
    ref = engine.search(jnp.asarray(q), m=M, exclude=ex)
    np.testing.assert_array_equal(ids, ref.ids)
    np.testing.assert_allclose(scores, ref.scores)


def test_repeat_queries_hit_cache_and_stay_identical():
    emb, engine, _ = _make_engine()
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=16, queue_capacity=64, cache=True),
    )
    q = emb[:24]
    ids1, sc1 = fe.search(q)
    ids2, sc2 = fe.search(q)
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_array_equal(sc1, sc2)
    assert fe.stats.cache_hits == 24
    assert fe.stats.completed == 48
    # a cache hit costs zero overlay messages: the measured average halves
    full = fe.backend.cost().messages
    assert fe.stats.messages_per_query == pytest.approx(full / 2)


# -----------------------------------------------------------------------------
# pow-2 padding: bounded compiled-shape set
# -----------------------------------------------------------------------------


def test_pow2_pad():
    assert [pow2_pad(n) for n in (1, 2, 3, 5, 8, 9, 64)] == [
        1, 2, 4, 8, 8, 16, 64]
    assert pow2_pad(3, floor=8) == 8


def test_dispatch_pad_divides_over_non_pow2_meshes():
    # a sharded backend's batch must divide over the device count: the
    # pow-2 grid rounds UP to a multiple (3 devices: 2 live rows -> 3)
    assert dispatch_pad(2, multiple=3) == 3
    assert [dispatch_pad(n, 3) for n in (1, 3, 4, 7)] == [3, 6, 6, 9]
    for n in range(1, 70):
        assert dispatch_pad(n, 3) % 3 == 0 and dispatch_pad(n, 3) >= n
    # degenerate multiples keep the plain pow-2 grid
    assert [dispatch_pad(n, 1) for n in (1, 5, 9)] == [1, 8, 16]
    # the shape set stays bounded: one padded size per pow-2 value
    assert len({dispatch_pad(n, 3) for n in range(1, 65)}) <= 7


def test_pow2_padding_bounds_trace_count():
    emb, engine, _ = _make_engine()
    backend = RuntimeBackend(engine)
    fe = RetrievalFrontend(
        backend,
        FrontendConfig(m=M, max_batch=64, queue_capacity=128, cache=True),
    )
    rng = np.random.default_rng(3)
    sizes = [1, 2, 3, 5, 7, 11, 13, 17, 23, 31, 43, 57, 64, 6, 29]
    for n in sizes:
        rows = rng.integers(0, emb.shape[0], size=n)
        fe.search(emb[rows])
    # every dispatch shape is a power of two <= 64: at most 7 distinct
    # shapes regardless of the arrival-size mix (and of cache hit layout)
    assert backend.traces <= 7
    assert backend.sketch_traces <= 7
    assert fe.stats.batches >= 1


# -----------------------------------------------------------------------------
# admission control
# -----------------------------------------------------------------------------


def test_ring_full_pushback_is_retryable():
    """A full ring pushes back with the RETRYABLE `RING_FULL` sentinel —
    counted in `stats.ring_full`, NOT in `rejected` (an admission shed):
    the two failure modes used to collapse into one None + reject count.
    A retry after one `step` (which drains a batch) must succeed."""
    emb, engine, _ = _make_engine()
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=4, queue_capacity=8, cache=False),
    )
    tickets = [fe.submit(emb[i]) for i in range(12)]
    ok = [t for t in tickets if not isinstance(t, SubmitReject)]
    assert len(ok) == 8
    assert all(t is RING_FULL and t.retryable for t in tickets[8:])
    assert not any(tickets[8:])  # falsy, so `if not ticket` still works
    assert fe.stats.ring_full == 4
    assert fe.stats.rejected == 0 and fe.stats.accepted == 8
    # transient: one step drains max_batch=4 rows, the retry is admitted
    fe.step()
    t = fe.submit(emb[8])
    assert not isinstance(t, SubmitReject)
    fe.flush()
    assert fe.stats.completed == 9
    got = [fe.poll(k) for k in ok + [t]]
    assert all(g is not None for g in got)


def test_admission_limit_sheds_with_admit_reject():
    """`admit_limit` counts ring + in-flight rows; beyond it `submit`
    sheds with the NON-retryable `ADMIT_REJECT` sentinel, counted in
    `stats.rejected` (kept apart from ring_full pushback)."""
    emb, engine, _ = _make_engine()
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=4, queue_capacity=16, cache=False,
                       admit_limit=6),
    )
    tickets = [fe.submit(emb[i]) for i in range(9)]
    ok = [t for t in tickets if not isinstance(t, SubmitReject)]
    assert len(ok) == 6
    assert all(t is ADMIT_REJECT and not t.retryable for t in tickets[6:])
    assert fe.stats.rejected == 3 and fe.stats.ring_full == 0
    fe.flush()
    assert fe.stats.completed == 6
    assert all(fe.poll(t) is not None for t in ok)


def test_cache_hit_bypasses_full_ring():
    """Intake-time cache lookup: a hit during a FULL ring still completes
    immediately — it never occupies a ring or dispatch-queue slot, so
    queued misses cannot backpressure hits (no priority inversion)."""
    emb, engine, _ = _make_engine()
    fe = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(m=M, max_batch=4, queue_capacity=4, cache=True),
    )
    # prime the cache with one served query
    ids0, sc0 = fe.search(emb[:1])
    # fill the ring to capacity with distinct misses
    fillers = [fe.submit(emb[10 + i]) for i in range(4)]
    assert all(not isinstance(t, SubmitReject) for t in fillers)
    assert isinstance(fe.submit(emb[30]), SubmitReject)  # ring really full
    # the primed query again: full ring, but it must be served NOW
    t_hit = fe.submit(emb[0])
    assert not isinstance(t_hit, SubmitReject)
    got = fe.poll(t_hit)
    assert got is not None
    np.testing.assert_array_equal(got[0], ids0[0])
    assert fe.stats.cache_hits == 1
    assert fe.pending == 4  # the queued misses are all still waiting
    fe.flush()


# -----------------------------------------------------------------------------
# the query cache: keying, LRU, generation invalidation
# -----------------------------------------------------------------------------


def test_qcache_lru_and_generation():
    c = QueryCache(capacity=2)
    q = np.ones((4,), np.float32)
    k1 = c.key([1, 2, 3], -2, q)
    k2 = c.key([1, 2, 4], -2, q)
    k3 = c.key([9, 9, 9], -2, q)
    ids = np.arange(3)
    c.put(k1, ids, ids, generation=5)
    c.put(k2, ids, ids, generation=5)
    assert c.get(k1, 5) is not None          # hit refreshes recency
    c.put(k3, ids, ids, generation=5)        # evicts k2 (LRU)
    assert c.get(k2, 5) is None and c.lru_evictions == 1
    # same key, older generation: evicted, never served
    assert c.get(k1, 6) is None
    assert c.stale_evictions == 1
    assert c.get(k1, 5) is None              # really gone
    # exclusion id and query bytes are part of the exact-mode key
    assert c.key([1, 2, 3], -2, q) != c.key([1, 2, 3], 7, q)
    q2 = q.copy(); q2[0] = 0.5
    assert c.key([1, 2, 3], -2, q) != c.key([1, 2, 3], -2, q2)
    # sketch-only mode shares entries across same-sketch queries
    c_approx = QueryCache(capacity=2, sketch_only=True)
    assert c_approx.key([1, 2, 3], -2, q) == c_approx.key([1, 2, 3], -2, q2)


def test_cache_key_includes_m():
    """Regression: the cache key used to omit the requested top-m, so an
    entry computed at a small m could serve a larger-m request TRUNCATED
    (correct prefix, silently missing tail).  m is now part of the key."""
    c = QueryCache()
    q = np.ones((4,), np.float32)
    assert c.key([1, 2, 3], -2, q, m=4) != c.key([1, 2, 3], -2, q, m=8)
    c_approx = QueryCache(sketch_only=True)
    assert c_approx.key([1, 2, 3], -2, m=4) != c_approx.key([1, 2, 3], -2,
                                                           m=8)
    # end-to-end: one shared cache behind two serving depths — the m=8
    # frontend must recompute, never serve the m=4 entry's prefix
    emb, engine, _ = _make_engine()
    backend = RuntimeBackend(engine)
    fe4 = RetrievalFrontend(
        backend, FrontendConfig(m=4, max_batch=16, queue_capacity=64,
                                cache=True),
    )
    q = emb[:8]
    ids4, _ = fe4.search(q)
    assert ids4.shape[1] == 4
    fe8 = RetrievalFrontend(
        backend, FrontendConfig(m=M, max_batch=16, queue_capacity=64,
                                cache=True),
    )
    fe8.cache = fe4.cache  # the two depths share one result cache
    ids8, _ = fe8.search(q)
    ref = engine.search(jnp.asarray(q), m=M)
    np.testing.assert_array_equal(ids8, ref.ids)
    # and the m=4 entries are still served at m=4 (distinct key spaces)
    ids4b, _ = fe4.search(q)
    np.testing.assert_array_equal(ids4b, ids4)


def test_store_generation_bumps():
    store = make_store(L, 1 << K, 8)
    assert int(store.generation) == 0
    ids = jnp.arange(4, dtype=jnp.int32)
    codes = jnp.zeros((4, L), jnp.uint32)
    store2 = insert_batch(store, ids, codes, jnp.int32(1))
    assert int(store2.generation) == L  # one bump per table insert
    store3 = expire(store2, jnp.int32(10), ttl=2)
    assert int(store3.generation) == L + 1


def test_cache_never_serves_stale_after_churn():
    emb, engine, codes = _make_engine(n=200)
    backend = RuntimeBackend(engine)
    fe = RetrievalFrontend(
        backend,
        FrontendConfig(m=M, max_batch=16, queue_capacity=64, cache=True),
    )
    q = emb[:16]
    ids1, _ = fe.search(q)
    assert fe.search(q)[0] is not None and fe.stats.cache_hits == 16

    # write epoch: insert near-duplicates of the queries under new ids
    n = emb.shape[0]
    store = engine.store
    dup = emb[:16]
    dup_codes = sketch_codes_batched(jnp.asarray(dup), engine.hyperplanes)
    new_ids = jnp.arange(n, n + 16, dtype=jnp.int32)
    store = insert_batch(store, new_ids, jnp.asarray(dup_codes), jnp.int32(1))
    corpus = DenseCorpus(jnp.asarray(np.concatenate([emb, dup])))
    backend.update(store, corpus)

    ids3, _ = fe.search(q)
    # the near-duplicate (cosine 1.0) MUST now appear in every result row
    # (right after the query's own id, which wins the equal-score tie by
    # lower id): a stale cache entry could not contain ids >= n
    assert np.all(ids3[:, 0] == np.arange(16))
    assert np.all(ids3[:, 1] == np.arange(n, n + 16))
    assert fe.cache.stale_evictions == 16

    # expire everything: served results must reflect the empty store
    store = expire(store, jnp.int32(100), ttl=1)
    backend.update(store)
    ids4, _ = fe.search(q)
    assert np.all(ids4 == -1)


def test_corpus_only_update_invalidates_cache():
    """A corpus swap changes scores even with the store untouched: the
    backend generation must bump on EVERY update, not only store bumps."""
    emb, engine, _ = _make_engine(n=100)
    backend = RuntimeBackend(engine)
    fe = RetrievalFrontend(
        backend,
        FrontendConfig(m=M, max_batch=16, queue_capacity=64, cache=True),
    )
    q = emb[:4]
    ids1, sc1 = fe.search(q)
    gen0 = backend.generation
    # same store object, new corpus: every indexed vector now equals
    # query 0, so all scores against q[0] become exactly 1.0
    emb2 = np.tile(emb[0], (emb.shape[0], 1)).astype(np.float32)
    backend.update(engine.store, DenseCorpus(jnp.asarray(emb2)))
    assert backend.generation > gen0
    ids2, sc2 = fe.search(q)
    assert fe.cache.stale_evictions == 4  # old entries died, none served
    live = ids2[0] >= 0
    np.testing.assert_allclose(sc2[0][live], 1.0, atol=1e-6)


# -----------------------------------------------------------------------------
# telemetry
# -----------------------------------------------------------------------------


def test_telemetry_aggregates_cost_and_drops():
    s = ServeStats()
    cost = costmodel.table1("cnb", k=6, L=4, bucket_size=2.0)
    s.record_submit(True)
    s.record_submit(True)
    s.record_submit(False)
    s.record_batch(2, 6, dropped_probes=3, cost=cost)
    s.record_done(100.0, hit=False)
    s.record_done(300.0, hit=False)
    out = s.summary()
    assert out["accepted"] == 2 and out["rejected"] == 1
    assert out["dropped_probes"] == 3
    assert out["padded"] == 6
    assert out["messages_per_query"] == pytest.approx(cost.messages)
    assert out["vectors_searched_per_query"] == pytest.approx(
        cost.vectors_searched)
    assert out["p50_us"] == pytest.approx(200.0)
    assert out["p99_us"] <= 300.0
    # format_summary is the driver's human surface — must not raise
    assert "dropped_probes=3" in s.format_summary()


def test_telemetry_empty_summary_is_finite():
    """Regression: before anything completes, qps/percentiles must be
    well-defined zeros, not nan (np.percentile of an empty array) — a
    crashed run's partial summary still has to print and aggregate."""
    s = ServeStats()
    out = s.summary()
    for key, v in out.items():
        if isinstance(v, float):
            assert np.isfinite(v), key
    assert out["qps"] == 0.0
    assert out["p50_us"] == 0.0 and out["p99_us"] == 0.0
    assert "nodes/query=" in s.format_summary()  # must not raise either


def test_telemetry_surfaces_nodes_contacted():
    """`nodes_contacted` was accumulated but never read out: Table 1's
    FIRST column (nodes contacted per query) now rides summary() and
    format_summary(), hit-rate discounted like messages_per_query."""
    s = ServeStats()
    cost = costmodel.table1("cnb", k=6, L=4, bucket_size=2.0)
    s.record_batch(2, 0, dropped_probes=0, cost=cost)
    s.record_done(10.0, hit=False)
    s.record_done(10.0, hit=False)
    s.record_done(5.0, hit=True)  # a cache hit contacts no node
    assert s.summary()["nodes_contacted_per_query"] == pytest.approx(
        cost.nodes_contacted * 2 / 3)
    assert f"nodes/query={cost.nodes_contacted * 2 / 3:.1f}" \
        in s.format_summary()


def test_telemetry_latency_window_is_bounded():
    s = ServeStats(latency_window=4)
    for i in range(10):
        s.record_done(float(i), hit=False)
    # only the last `latency_window` samples are retained (ring)
    assert s.latencies_us.size == 4
    assert sorted(s.latencies_us) == [6.0, 7.0, 8.0, 9.0]
    assert s.completed == 10
    assert s.percentile(50) == pytest.approx(7.5)


# -----------------------------------------------------------------------------
# mesh-step backend (single device, 1-shard — tier-1)
# -----------------------------------------------------------------------------


def test_mesh_backend_matches_engine(single_mesh):
    from repro.core import distributed as dist
    from repro.core.runtime import IndexRuntime

    emb, engine, codes = _make_engine(payload=True)
    store = dist.shard_store(single_mesh, engine.store)
    dcfg = dist.DistConfig(
        params=engine.params, n_shards=1, variant="cnb", m=M + 1,
        routing="alltoall", cap_factor=2.0,
    )
    backend = RuntimeBackend(
        IndexRuntime(dcfg, mesh=single_mesh),
        hyperplanes=engine.hyperplanes, store=store,
    )
    fe = RetrievalFrontend(
        backend, FrontendConfig(m=M, max_batch=16, queue_capacity=64,
                                cache=True),
    )
    q = emb[:20]
    ex = np.arange(20)
    ids, _ = fe.search(q, exclude=ex)
    ref = engine.search(jnp.asarray(q), m=M, exclude=ex)
    np.testing.assert_array_equal(ids, ref.ids)
    # repeats hit the cache and stay identical
    ids2, _ = fe.search(q, exclude=ex)
    np.testing.assert_array_equal(ids2, ids)
    assert fe.stats.cache_hits == 20
    assert fe.stats.dropped_probes == 0


def test_mesh_backend_surfaces_dropped_probes(single_mesh):
    from repro.core import distributed as dist
    from repro.core.runtime import IndexRuntime

    emb, engine, codes = _make_engine(payload=True)
    store = dist.shard_store(single_mesh, engine.store)
    # cap_factor < 1 under-provisions the send buffers on purpose: the
    # router MUST count the overflow, and the frontend MUST surface it
    dcfg = dist.DistConfig(
        params=engine.params, n_shards=1, variant="cnb", m=M + 1,
        routing="alltoall", cap_factor=0.25,
    )
    backend = RuntimeBackend(
        IndexRuntime(dcfg, mesh=single_mesh),
        hyperplanes=engine.hyperplanes, store=store,
    )
    fe = RetrievalFrontend(
        backend, FrontendConfig(m=M, max_batch=16, queue_capacity=64,
                                cache=False),
    )
    fe.search(emb[:16])
    assert fe.stats.dropped_probes > 0
    assert fe.stats.summary()["dropped_probes"] == fe.stats.dropped_probes


def test_backend_update_enforces_topology_guards(single_mesh):
    """update() keeps __init__'s topology rules: a corpus on a mesh
    backend (or a neighbor cache on a 1-node backend) must raise, never
    be silently ignored."""
    from repro.core import distributed as dist
    from repro.core.runtime import IndexRuntime

    emb, engine, _ = _make_engine(payload=True)
    store = dist.shard_store(single_mesh, engine.store)
    dcfg = dist.DistConfig(
        params=engine.params, n_shards=1, variant="cnb", m=M + 1,
        routing="alltoall", cap_factor=2.0,
    )
    mesh_backend = RuntimeBackend(
        IndexRuntime(dcfg, mesh=single_mesh),
        hyperplanes=engine.hyperplanes, store=store,
    )
    with pytest.raises(ValueError, match="1-node only"):
        mesh_backend.update(store, corpus=engine.corpus)
    local_backend = RuntimeBackend(engine)
    with pytest.raises(ValueError, match="mesh runtimes"):
        local_backend.update(engine.store, cache=(None, None))


# -----------------------------------------------------------------------------
# live topology swaps (elastic membership on the serving path, Sec. 9)
# -----------------------------------------------------------------------------


def _payload_backend(single_mesh):
    """1-node slot-payload backend + the two runtimes a single device can
    host (mesh-free and 1-shard shard_map), sharing one RuntimeConfig."""
    from repro.core.runtime import IndexRuntime, RuntimeConfig

    emb, engine, _ = _make_engine(payload=True)
    store = engine.store  # payload-carrying store
    rcfg = RuntimeConfig(params=engine.params, variant="cnb", m=M + 1,
                         cap_factor=2.0)
    rt_local = IndexRuntime(rcfg)
    rt_mesh = IndexRuntime(rcfg, mesh=single_mesh)
    backend = RuntimeBackend(rt_local, hyperplanes=engine.hyperplanes,
                             store=store)
    return emb, engine, store, backend, rt_local, rt_mesh


def test_topology_swap_bumps_generation_never_serves_stale(single_mesh):
    """Any topology swap through RuntimeBackend.update() bumps the
    backend generation, so no sketch-keyed cache entry computed on the
    old topology is ever served after a reshard — and the recomputed
    results are bit-identical (the reshard contract, live)."""
    from repro.core.runtime import reshard

    emb, engine, store, backend, rt_local, rt_mesh = _payload_backend(
        single_mesh)
    fe = RetrievalFrontend(
        backend, FrontendConfig(m=M, max_batch=16, queue_capacity=64,
                                cache=True),
    )
    q = emb[:20]
    ex = np.arange(20)
    ids_pre, sc_pre = fe.search(q, exclude=ex)
    ids_rep, _ = fe.search(q, exclude=ex)
    np.testing.assert_array_equal(ids_rep, ids_pre)
    assert fe.stats.cache_hits == 20  # warm within the generation
    gen0 = backend.generation

    # -- the membership round: 1-node -> 1-shard mesh ----------------------
    rt2, store2, _ = reshard(rt_local, store, runtime=rt_mesh)
    fe.update_backend(runtime=rt2, store=store2)
    assert backend.generation > gen0  # every swap bumps
    hits_before = fe.stats.cache_hits
    ids_post, _ = fe.search(q, exclude=ex)
    # nothing was served from the pre-swap cache...
    assert fe.stats.cache_hits == hits_before
    assert fe.cache.stale_evictions >= 20
    # ...and the new topology recomputed the SAME results
    np.testing.assert_array_equal(ids_post, ids_pre)
    # post-swap repeats hit again (the cache works within the new gen)
    ids_post2, _ = fe.search(q, exclude=ex)
    np.testing.assert_array_equal(ids_post2, ids_pre)
    assert fe.stats.cache_hits == hits_before + 20

    # -- and back: mesh -> 1-node (the cache dies again) -------------------
    gen1 = backend.generation
    rt3, store3, _ = reshard(rt2, store2, runtime=rt_local)
    fe.update_backend(runtime=rt3, store=store3)
    assert backend.generation > gen1
    ids_back, _ = fe.search(q, exclude=ex)
    np.testing.assert_array_equal(ids_back, ids_pre)


def test_topology_swap_argument_guards(single_mesh):
    """A swap without the migrated store, hyperplanes outside a swap, or
    a serving m over the new runtime's wire headroom must all raise."""
    from repro.core.runtime import IndexRuntime, RuntimeConfig

    emb, engine, store, backend, rt_local, rt_mesh = _payload_backend(
        single_mesh)
    with pytest.raises(ValueError, match="migrated store"):
        backend.update(runtime=rt_mesh)
    with pytest.raises(ValueError, match="runtime swap"):
        backend.update(store, hyperplanes=engine.hyperplanes)
    # an ids-only store cannot back a mesh dispatch (slot-payload scoring)
    # — must fail validation, not blow up at trace time half-mutated
    from repro.core import distributed as dist0
    ids_store = _make_engine(payload=False)[1].store
    with pytest.raises(ValueError, match="payload-carrying"):
        backend.update(runtime=rt_mesh,
                       store=dist0.shard_store(single_mesh, ids_store))

    fe = RetrievalFrontend(
        backend, FrontendConfig(m=M, max_batch=8, queue_capacity=32,
                                cache=True),
    )
    # a mesh runtime with NO headroom for host-side self-exclusion
    tight = IndexRuntime(
        RuntimeConfig(params=engine.params, variant="cnb", m=M,
                      cap_factor=2.0),
        mesh=single_mesh,
    )
    from repro.core import distributed as dist
    with pytest.raises(ValueError, match="headroom"):
        fe.update_backend(runtime=tight,
                          store=dist.shard_store(single_mesh, store))
    # the failed swap installed nothing: the backend still serves
    ids, _ = fe.search(emb[:4], exclude=np.arange(4))
    assert ids.shape == (4, M)


def test_serve_reshard_tracks_reference(single_mesh):
    """The lifecycle driver: live swaps at every read epoch track the
    run_churn reference exactly, repeats across swaps stay identical,
    and the swap count / stale evictions prove the cache died each
    time."""
    from repro.serve.lifecycle import run_serve_reshard

    churn = ChurnConfig(
        num_users=400, dim=D, k=K, L=L, capacity=32, epochs=4,
        num_queries=32, m=M, refresh_every=2, ttl_epochs=3, seed=5,
    )
    ref = run_churn(churn)
    out = run_serve_reshard(
        ServeChurnConfig(churn=churn, max_batch=16, queue_capacity=64),
        mesh=single_mesh,
    )
    np.testing.assert_allclose(out["recalls"], ref["recalls"])
    assert out["repeat_mismatches"] == 0
    assert out["swaps"] == 4  # one per read epoch
    # every swap invalidated that epoch's freshly-cached batch
    assert out["stale_evictions"] >= 4 * 32
    # the third serve of each epoch hit the post-swap cache
    assert out["cache_hits"] >= 4 * 32
    # degenerate 1 <-> 1-shard rounds move no zone state
    assert out["total_handoff_bytes"] == 0


# -----------------------------------------------------------------------------
# read/write epochs: serving under live churn
# -----------------------------------------------------------------------------


def test_serve_churn_tracks_reference_trajectory():
    churn = ChurnConfig(
        num_users=400, dim=D, k=K, L=L, capacity=32, epochs=4,
        num_queries=32, m=M, refresh_every=2, ttl_epochs=3, seed=5,
    )
    ref = run_churn(churn)
    out = run_serve_churn(ServeChurnConfig(
        churn=churn, query_repeats=2, max_batch=16, queue_capacity=64,
    ))
    # same trajectory, same store ops, same engine semantics -> recall
    # matches the fresh-engine-per-epoch reference EXACTLY
    np.testing.assert_allclose(out["recalls"], ref["recalls"])
    assert out["repeat_mismatches"] == 0
    # the repeats were served from the cache within each generation
    assert out["summary"]["hit_rate"] > 0.3
    # write epochs bumped the generation monotonically
    gens = out["generations"]
    assert np.all(np.diff(gens) >= 0) and gens[-1] > gens[0]
    assert out["store_generation"] == gens[-1]


def test_serve_churn_config_fields():
    cfg = ServeChurnConfig()
    assert dataclasses.is_dataclass(cfg) and cfg.query_repeats >= 1


@pytest.mark.slow
def test_mesh_backend_on_non_pow2_mesh():
    """Non-pow-2 DEVICE count (data=3 — the model axis must stay a power
    of two for the CAN geometry): dispatch sizes must round up to
    multiples of the device count, since a bare pow-2 pad would fail
    NamedSharding placement."""
    from conftest import run_in_subprocess

    out = run_in_subprocess(
        """
        import numpy as np, jax.numpy as jnp
        from repro.core import (
            DenseCorpus, EngineConfig, LshEngine, LshParams,
            make_hyperplanes,
        )
        from repro.core import distributed as dist
        from repro.core.hashing import sketch_codes_batched
        from repro.core.runtime import IndexRuntime
        from repro.core.store import build_store_host
        from repro.launch.mesh import make_host_mesh
        from repro.serve import FrontendConfig, RetrievalFrontend, RuntimeBackend

        M = 8
        rng = np.random.default_rng(0)
        emb = rng.standard_normal((300, 16)).astype(np.float32)
        emb /= np.linalg.norm(emb, axis=1, keepdims=True)
        params = LshParams(d=16, k=5, L=3, seed=1)
        h = make_hyperplanes(params)
        codes = sketch_codes_batched(jnp.asarray(emb), h)
        store_host = build_store_host(
            codes, params.num_buckets, capacity=32, payload=emb)
        engine = LshEngine(
            params, h, store_host, DenseCorpus(jnp.asarray(emb)), None,
            EngineConfig(variant="cnb"))

        mesh = make_host_mesh(data=3, model=1)
        store = dist.shard_store(mesh, store_host)
        dcfg = dist.DistConfig(
            params=params, n_shards=1, variant="cnb", m=M + 1,
            routing="alltoall", cap_factor=3.0)
        backend = RuntimeBackend(IndexRuntime(dcfg, mesh=mesh),
                                 hyperplanes=h, store=store)
        fe = RetrievalFrontend(backend, FrontendConfig(
            m=M, max_batch=16, queue_capacity=64, cache=True))
        # 2 pending rows on 3 devices: pad must be 6, not pow2(2)=4
        q, ex = emb[:2], np.arange(2)
        ids, _ = fe.search(q, exclude=ex)
        ref = engine.search(jnp.asarray(q), m=M, exclude=ex)
        assert np.array_equal(ids, ref.ids), (ids, ref.ids)
        ids20, _ = fe.search(emb[:20], exclude=np.arange(20))
        ref20 = engine.search(
            jnp.asarray(emb[:20]), m=M, exclude=np.arange(20))
        assert np.array_equal(ids20, ref20.ids)
        assert fe.stats.dropped_probes == 0
        print("OK", fe.stats.completed)
        """,
        devices=3,
    )
    assert "OK 22" in out
