"""MoE layer: dispatch correctness vs a dense per-token reference."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import moe as moe_mod
from repro.models import sharding as sh


def _dense_reference(p, x, cfg):
    """Per-token explicit top-k expert sum (no capacity, no dispatch)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    b, s, d = x.shape
    out = jnp.zeros_like(x)
    for e in range(cfg.moe_num_experts):
        h = jax.nn.silu(x @ p["w_gate"][e].astype(x.dtype)) * (
            x @ p["w_up"][e].astype(x.dtype))
        y_e = h @ p["w_down"][e].astype(x.dtype)
        sel = (idx == e).astype(x.dtype) * w.astype(x.dtype)  # [b,s,k]
        out = out + y_e * sel.sum(-1, keepdims=True)
    if "shared" in p:
        from repro.models.layers import mlp

        out = out + mlp(p["shared"], x, cfg)
    return out


def test_moe_matches_dense_reference(single_mesh, rng):
    cfg = get_config("deepseek-moe-16b", smoke=True)  # 8 experts top-3 + 2 shared
    cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)  # dropless
    p, _ = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)),
                    jnp.float32) * 0.3
    with sh.use_mesh(single_mesh):
        got, aux = moe_mod.moe(p, x, cfg)
    want = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    assert float(aux.load_balance_loss) > 0.0


def test_moe_capacity_drops_bounded(single_mesh, rng):
    """With tiny capacity the layer must still be finite & close-ish."""
    cfg = get_config("deepseek-moe-16b", smoke=True)
    cfg = dataclasses.replace(cfg, moe_capacity_factor=0.5)
    p, _ = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)),
                    jnp.float32) * 0.3
    with sh.use_mesh(single_mesh):
        got, _ = moe_mod.moe(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(got)))


def test_moe_grads_flow(single_mesh, rng):
    cfg = get_config("jamba-v0.1-52b", smoke=True)
    cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    p, _ = moe_mod.init_moe(cfg, jax.random.PRNGKey(1))
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)),
                    jnp.float32) * 0.3

    def loss(p):
        with sh.use_mesh(single_mesh):
            y, aux = moe_mod.moe(p, x, cfg)
        return jnp.sum(y**2) + 0.01 * aux.load_balance_loss

    g = jax.grad(loss)(p)
    leaves = jax.tree.leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)
    # router and at least one expert matrix get nonzero grads
    assert float(jnp.linalg.norm(g["router"])) > 0
    assert float(jnp.linalg.norm(g["w_gate"].astype(jnp.float32))) > 0


def test_moe_load_balance_loss_uniform_is_one(single_mesh):
    """Perfectly uniform routing gives lb_loss == 1 (Switch normalization)."""
    cfg = get_config("deepseek-moe-16b", smoke=True)
    p, _ = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])  # uniform probs
    x = jnp.ones((2, 16, cfg.d_model), jnp.float32)
    with sh.use_mesh(single_mesh):
        _, aux = moe_mod.moe(p, x, cfg)
    # density concentrates on top-k of a uniform distribution (ties), but
    # p_mean is uniform = 1/E; lb = E * sum(density * 1/E) = 1
    assert abs(float(aux.load_balance_loss) - 1.0) < 1e-5
