"""LSH hashing invariants (paper Eq. 3-5)."""

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or skip-fallback

from repro.core import hashing
from repro.core.hashing import LshParams


def test_pack_unpack_roundtrip(rng):
    bits = jnp.asarray(rng.random((50, 3, 17)) > 0.5)
    codes = hashing.pack_bits(bits)
    assert codes.dtype == jnp.uint32
    back = hashing.unpack_bits(codes, 17)
    assert np.array_equal(np.asarray(back), np.asarray(bits))


def test_sketch_codes_shape_and_range(rng):
    params = LshParams(d=32, k=8, L=5, seed=1)
    h = hashing.make_hyperplanes(params)
    x = jnp.asarray(rng.standard_normal((40, 32)), jnp.float32)
    codes = hashing.sketch_codes(x, h)
    assert codes.shape == (40, 5)
    assert int(codes.max()) < 2**8


def test_collision_probability_matches_similarity(rng):
    """Pr[h(u)=h(v)] == angular similarity — the defining LSH property,
    estimated over many independent hyperplanes (k*L bits)."""
    params = LshParams(d=64, k=20, L=100, seed=3)  # 2000 bits
    h = hashing.make_hyperplanes(params)
    for target_cos in (0.2, 0.5, 0.9):
        u = rng.standard_normal(64)
        # construct v at the desired cosine from u
        r = rng.standard_normal(64)
        r -= (r @ u) / (u @ u) * u
        v = target_cos * u / np.linalg.norm(u) + np.sqrt(1 - target_cos**2) * (
            r / np.linalg.norm(r)
        )
        bits_u = hashing.sketch_bits(jnp.asarray(u, jnp.float32), h)
        bits_v = hashing.sketch_bits(jnp.asarray(v, jnp.float32), h)
        match = float(np.mean(np.asarray(bits_u) == np.asarray(bits_v)))
        expected = float(
            hashing.collision_probability(
                jnp.asarray(u, jnp.float32), jnp.asarray(v, jnp.float32)
            )
        )
        assert abs(match - expected) < 0.03, (target_cos, match, expected)


def test_popcount_matches_python(rng):
    xs = rng.integers(0, 2**32, size=200, dtype=np.uint32)
    got = np.asarray(hashing.popcount32(jnp.asarray(xs)))
    want = np.array([bin(int(x)).count("1") for x in xs])
    assert np.array_equal(got, want)


def test_hamming_distance(rng):
    a = rng.integers(0, 2**32, size=100, dtype=np.uint32)
    b = rng.integers(0, 2**32, size=100, dtype=np.uint32)
    got = np.asarray(hashing.hamming_distance(jnp.asarray(a), jnp.asarray(b)))
    want = np.array([bin(int(x) ^ int(y)).count("1") for x, y in zip(a, b)])
    assert np.array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 30), st.integers(0, 2**30 - 1))
def test_pack_bits_inverse_property(k, value):
    value = value % (1 << k)
    bits = hashing.unpack_bits(jnp.uint32(value), k)
    assert int(hashing.pack_bits(bits)) == value


def test_normalize():
    x = jnp.asarray([[3.0, 4.0], [0.0, 0.0]])
    n = hashing.normalize(x)
    assert np.allclose(np.asarray(n[0]), [0.6, 0.8])
    assert np.all(np.isfinite(np.asarray(n)))


def test_params_validation():
    with pytest.raises(ValueError):
        LshParams(d=10, k=31, L=1)
    with pytest.raises(ValueError):
        LshParams(d=10, k=4, L=0)
