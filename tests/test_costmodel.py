"""Table-1 cost accounting: budget boundaries + simulator aggregation."""

import pytest

from repro.core.costmodel import (
    MessageCounter, QueryCost, lsh_L_for_budget, table1,
)


def test_budget_below_one_table_is_zero():
    # lsh/layered/cnb cost kL/2 = 3 messages per table at k=6; nb costs 9
    assert lsh_L_for_budget("lsh", 6, 2.9) == 0
    assert lsh_L_for_budget("layered", 6, 0.0) == 0
    assert lsh_L_for_budget("cnb", 6, 2.999) == 0
    assert lsh_L_for_budget("nb", 6, 8.99) == 0


def test_budget_exact_multiples():
    assert lsh_L_for_budget("lsh", 6, 3.0) == 1
    assert lsh_L_for_budget("lsh", 6, 6.0) == 2
    assert lsh_L_for_budget("cnb", 4, 100.0) == 50
    assert lsh_L_for_budget("nb", 6, 9.0) == 1
    # just past a multiple stays at the floor
    assert lsh_L_for_budget("lsh", 6, 8.9) == 2


def test_budget_is_consistent_with_table1():
    """The chosen L fits the budget, and L+1 would exceed it — for every
    variant (the Fig. 3 equal-budget comparison depends on this)."""
    for variant in ("lsh", "layered", "nb", "cnb"):
        for k in (4, 6, 10):
            for budget in (5.0, 12.0, 30.0, 31.5):
                L = lsh_L_for_budget(variant, k, budget)
                if L > 0:
                    assert table1(variant, k, L).messages <= budget
                assert table1(variant, k, L + 1).messages > budget


def test_unknown_variant_raises():
    with pytest.raises(KeyError):
        lsh_L_for_budget("bogus", 6, 10.0)
    with pytest.raises(ValueError):
        table1("bogus", 6, 2)


def test_message_counter_aggregation():
    c = MessageCounter()
    c.add_lookup(3)
    c.add_lookup(2)
    c.add_neighbor(4)
    c.add_result()
    c.add_result(4)
    assert c.dht_lookups == 2
    assert c.lookup_hops == 5
    assert c.neighbor_messages == 4
    assert c.result_messages == 5
    # Table-1 convention: routing hops + neighbor forwards count; result
    # returns are symmetric across variants and excluded
    assert c.total == 9


def test_message_counter_matches_closed_form_shape():
    """Counting k/2 expected hops per lookup over L tables reproduces the
    kL/2 closed form (the simulator's convergence target)."""
    k, L = 6, 4
    c = MessageCounter()
    for _ in range(L):
        c.add_lookup(k // 2)
        c.add_result()
    assert c.total == table1("cnb", k, L).messages
    assert isinstance(table1("cnb", k, L), QueryCost)


def test_handoff_bytes_closed_form():
    """Elastic membership (DESIGN.md Sec. 9): the handoff charge follows
    the moved-zone fraction and the per-bucket wire size exactly."""
    from repro.core.costmodel import estimate_handoff_bytes

    # n -> n is a no-op round: nothing moves, nothing is charged
    assert estimate_handoff_bytes(3, 32, 16, 8, 2, 2) == 0
    # 1 -> 2 moves half the bucket space; per moved bucket row:
    # capacity * (id 4B + ts 4B + payload 4B*d) + ring pointer 4B
    per_bucket = 16 * (8 + 4 * 8) + 4
    assert estimate_handoff_bytes(3, 32, 16, 8, 1, 2) == 3 * 16 * per_bucket
    # join and the leave that undoes it cost the same bytes
    assert estimate_handoff_bytes(3, 32, 16, 8, 4, 1) == \
        estimate_handoff_bytes(3, 32, 16, 8, 1, 4)
    # id-only stores (d = 0) still ship ids + timestamps + pointers
    assert estimate_handoff_bytes(1, 8, 4, 0, 1, 2) == 4 * (4 * 8 + 4)
    # the charge matches the geometry module's moved-bucket count
    from repro.core.can import CanTopology, moved_buckets

    old, new = CanTopology(5, 2), CanTopology(5, 8)
    moved = moved_buckets(old, new)
    assert estimate_handoff_bytes(2, 32, 16, 8, 2, 8) == \
        2 * moved * per_bucket
    with pytest.raises(ValueError):
        estimate_handoff_bytes(3, 32, 16, 8, 0, 2)
    # the ICI-side alias agrees with the overlay model (and thus with
    # the ReshardEvent charge, which uses the overlay form directly)
    from repro.core import distributed as dist
    from repro.core.hashing import LshParams

    cfg = dist.DistConfig(params=LshParams(d=8, k=5, L=2, seed=0),
                          n_shards=2)
    assert dist.estimate_reshard_bytes(cfg, 8, capacity=16, d=8) == \
        estimate_handoff_bytes(2, 32, 16, 8, 2, 8)
