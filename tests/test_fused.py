"""Fused query mega-kernel equivalence matrix (DESIGN.md Sec. 11).

The acceptance bar for the fused path: search ids BIT-IDENTICAL to the
staged path and to the checked-in goldens (tests/goldens/engine_v1.npz)
on every cell of the runtime equivalence matrix — variants (lsh, nb,
cnb) x probe budgets (full, p2, ranked3) — plus contains parity and the
hamming scoring mode, where the exact integer popcount scores make even
the SCORES bit-equal between staged and fused.

Since PR 10 the routed topologies fuse too: the post-route local stage
(the owner-side gather/score over all_to_all-delivered rows) dispatches
the same mega-kernel, with the collectives outside.  The routed matrix
here pins fused == staged bit-identity on the (1, 1) mesh (tier 1) and
on a real 2-node mesh against `runtime_2node_v1.npz` plus the packed
2-node golden `runtime_2node_packed_v1.npz` (slow).  Everything runs
with fused="on" to force the Pallas path through CPU interpret mode —
"auto" stays staged on CPU hosts (and TPU-gated on the mesh too).
"""

from __future__ import annotations

import dataclasses
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LshParams, make_hyperplanes, packed
from repro.core.hashing import sketch_codes_batched
from repro.core.runtime import IndexRuntime, RuntimeConfig
from repro.core.store import build_store_host, make_store

GOLDENS = os.path.join(os.path.dirname(__file__), "goldens", "engine_v1.npz")

# must mirror tests/goldens/make_goldens.py exactly
N, D, K, L, M, NQ = 1200, 32, 5, 3, 10, 48
PROBE_CELLS = [
    ("full", dict()),
    ("p2", dict(num_probes=2)),
    ("ranked3", dict(num_probes=3, ranked_probes=True)),
]


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(17)
    vecs = rng.standard_normal((N, D)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    params = LshParams(d=D, k=K, L=L, seed=23)
    h = make_hyperplanes(params)
    codes = sketch_codes_batched(jnp.asarray(vecs), h)
    store = build_store_host(codes, params.num_buckets, capacity=64,
                             payload=vecs)
    golden = dict(np.load(GOLDENS))
    return params, h, store, vecs, golden


def _cells():
    return [(v, name, pkw) for v in ("lsh", "nb", "cnb")
            for name, pkw in PROBE_CELLS]


def _pair(params, m, variant, pkw, **kw):
    staged = RuntimeConfig(params=params, variant=variant, m=m,
                           fused="off", **pkw, **kw)
    fused = dataclasses.replace(staged, fused="on")
    return IndexRuntime(staged), IndexRuntime(fused)


@pytest.mark.parametrize("variant,cell,pkw", _cells(),
                         ids=[f"{v}-{c}" for v, c, _ in _cells()])
def test_fused_search_matches_staged_and_goldens(setup, variant, cell, pkw):
    """Dot mode, embedded payloads: fused ids == staged ids == golden ids
    on every matrix cell; scores match to float tolerance."""
    params, h, store, vecs, golden = setup
    rt_s, rt_f = _pair(params, M, variant, pkw)
    q = vecs[:NQ]
    ex = np.arange(NQ)
    ids_s, sc_s, _ = rt_s.search(h, store, q, exclude=ex)
    ids_f, sc_f, _ = rt_f.search(h, store, q, exclude=ex)
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_s))
    np.testing.assert_allclose(np.asarray(sc_f), np.asarray(sc_s),
                               atol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(ids_f), golden[f"search_ids_{variant}_{cell}"])


@pytest.mark.parametrize("variant,cell,pkw", _cells(),
                         ids=[f"{v}-{c}" for v, c, _ in _cells()])
def test_fused_contains_matches_staged_and_goldens(setup, variant, cell,
                                                   pkw):
    """Metadata-only membership: the fused kernel needs no payload, so it
    runs on the ids-only store and must reproduce the golden hit mask."""
    params, h, store, vecs, golden = setup
    rt_s, rt_f = _pair(params, M, variant, pkw)
    q = vecs[:NQ]
    hits_s, _ = rt_s.contains(h, store, q, golden["targets"])
    hits_f, _ = rt_f.contains(h, store, q, golden["targets"])
    np.testing.assert_array_equal(np.asarray(hits_f), np.asarray(hits_s))
    np.testing.assert_array_equal(
        np.asarray(hits_f), golden[f"contains_{variant}_{cell}"])


@pytest.mark.parametrize("variant,cell,pkw", _cells(),
                         ids=[f"{v}-{c}" for v, c, _ in _cells()])
def test_fused_hamming_bit_exact(setup, variant, cell, pkw):
    """Hamming mode scores are exact integers, so staged and fused agree
    on SCORES bit-for-bit, not just on ids."""
    params, h, store, vecs, golden = setup
    rt_s, rt_f = _pair(params, M, variant, pkw, score="hamming")
    w = packed.num_words(K, L)
    sth = make_store(L, params.num_buckets, 64, payload_dim=w,
                     dtype=jnp.uint32)
    sth = rt_s.insert(h, sth, vecs, np.arange(N, dtype=np.int32), 0)
    q = vecs[:NQ]
    ex = np.arange(NQ)
    ids_s, sc_s, _ = rt_s.search(h, sth, q, exclude=ex)
    ids_f, sc_f, _ = rt_f.search(h, sth, q, exclude=ex)
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_s))
    np.testing.assert_array_equal(np.asarray(sc_f), np.asarray(sc_s))


def test_hamming_store_via_migration_shim(setup):
    """`pack_store_payload` on a dot store == building the hamming store
    from scratch, and both search identically (staged vs fused)."""
    params, h, store, vecs, golden = setup
    migrated = packed.pack_store_payload(store, h)
    rt_s, rt_f = _pair(params, M, "cnb", {}, score="hamming")
    q = vecs[:NQ]
    ids_s, sc_s, _ = rt_s.search(h, migrated, q)
    ids_f, sc_f, _ = rt_f.search(h, migrated, q)
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_s))
    np.testing.assert_array_equal(np.asarray(sc_f), np.asarray(sc_s))


def test_fused_on_raises_where_unsupported(setup):
    """fused='on' must refuse (not silently degrade) when the kernel
    cannot apply: id-keyed corpus scoring and ids-only search stores."""
    from repro.core import BucketStore, DenseCorpus

    params, h, store, vecs, golden = setup
    rt = IndexRuntime(
        RuntimeConfig(params=params, variant="cnb", m=M, fused="on"))
    ids_only = BucketStore(store.ids, store.timestamps, store.write_ptr,
                           None)
    q = vecs[:8]
    with pytest.raises(ValueError, match="corpus"):
        rt.search(h, ids_only, q, corpus=DenseCorpus(jnp.asarray(vecs)))
    with pytest.raises(ValueError, match="ids-only"):
        rt.search(h, ids_only, q)


def test_fused_auto_stays_staged_on_cpu(setup):
    """'auto' must not pick interpret-mode Pallas on CPU hosts — it is
    correct but slower than the jitted staged path."""
    import jax

    from repro.core import runtime as runtime_mod

    if jax.default_backend() != "cpu":
        pytest.skip("CPU-backend specific dispatch check")
    cfg = RuntimeConfig(params=LshParams(d=D, k=K, L=L), m=M)
    assert not runtime_mod._fused_on(
        cfg, runtime_mod.LOCAL, has_payload=True, has_corpus=False)
    assert runtime_mod._fused_on(
        dataclasses.replace(cfg, fused="on"), runtime_mod.LOCAL,
        has_payload=True, has_corpus=False)


def test_hamming_insert_rejects_unpacked_payload(setup):
    """score='hamming' insert must refuse an f32 dot-mode payload store —
    casting packed words into f32 slots silently drops bits above 2^24;
    `pack_store_payload` is the migration path."""
    params, h, store, vecs, golden = setup
    rt = IndexRuntime(RuntimeConfig(params=params, m=M, score="hamming"))
    with pytest.raises(ValueError, match="packed uint32"):
        rt.insert(h, store, vecs[:4], np.arange(4, dtype=np.int32), 0)


def test_hamming_mode_validation():
    """Config-level guards: bad knobs raise; hamming rides every topology
    since PR 10 (the packed words are what the mesh wire carries)."""
    params = LshParams(d=D, k=K, L=L)
    cfg = RuntimeConfig(params=params, n_nodes=2, score="hamming")
    assert cfg.score == "hamming" and cfg.n_nodes == 2
    with pytest.raises(ValueError, match="score"):
        RuntimeConfig(params=params, score="cosine")
    with pytest.raises(ValueError, match="fused"):
        RuntimeConfig(params=params, fused="maybe")


# -----------------------------------------------------------------------------
# routed topologies (PR 10): the mesh fuses the post-route local stage
# -----------------------------------------------------------------------------


def _hamming_store(params, h, store, vecs):
    return packed.pack_store_payload(store, h)


@pytest.mark.parametrize("score", ["dot", "hamming"])
@pytest.mark.parametrize("variant", ["nb", "cnb"])
def test_routed_fused_matches_staged(setup, single_mesh, score, variant):
    """(1, 1)-mesh shard_map (MeshCollectives — the real routed code path,
    one shard): fused == staged bit-identically for both scoring modes,
    and both match the mesh-free local run."""
    import dataclasses as dc

    params, h, store, vecs, golden = setup
    st = _hamming_store(params, h, store, vecs) if score == "hamming" \
        else store
    q = vecs[:NQ]
    targets = golden["targets"]
    local = IndexRuntime(
        RuntimeConfig(params=params, variant=variant, m=M, score=score))
    ids_l, sc_l, _ = local.search(h, st, q)
    hits_l, _ = local.contains(h, st, q, targets)
    base = RuntimeConfig(params=params, variant=variant, m=M, score=score,
                         cap_factor=float(L), fused="off")
    out = {}
    for fused in ("off", "on"):
        rt = IndexRuntime(dc.replace(base, fused=fused), mesh=single_mesh)
        st_sh = rt.shard_store(st)
        cache = rt.refresh_cache(st_sh) if variant == "cnb" else None
        ids, sc, drop = rt.search(h, st_sh, q, cache=cache)
        assert int(drop) == 0
        hits, _ = rt.contains(h, st_sh, q, targets, cache=cache)
        out[fused] = (np.asarray(ids), np.asarray(sc), np.asarray(hits))
    np.testing.assert_array_equal(out["on"][0], out["off"][0])
    np.testing.assert_array_equal(out["on"][2], out["off"][2])
    np.testing.assert_array_equal(out["off"][0], np.asarray(ids_l))
    np.testing.assert_array_equal(out["off"][2], np.asarray(hits_l))
    if score == "hamming":  # exact integer scores: bit-equal
        np.testing.assert_array_equal(out["on"][1], out["off"][1])
        np.testing.assert_array_equal(out["off"][1], np.asarray(sc_l))
    else:
        np.testing.assert_allclose(out["on"][1], out["off"][1], atol=1e-5)


def test_routed_drop_accounting_packed(setup, single_mesh):
    """Forced overflow (cap_factor such that cap < b*L) under packed
    hamming: `dropped_probes` is counted exactly, surviving queries match
    the uncapped run bit-for-bit, and a fully-dropped query returns only
    fill (ids -1) — fill-sentinel word rows are never scored as real
    candidates."""
    import dataclasses as dc

    params, h, store, vecs, golden = setup
    sth = _hamming_store(params, h, store, vecs)
    nq = 16
    q = vecs[:nq]
    base = RuntimeConfig(params=params, variant="cnb", m=M, score="hamming",
                         cap_factor=float(L))
    full = IndexRuntime(base, mesh=single_mesh)
    st_sh = full.shard_store(sth)
    ids_full, sc_full, drop0 = full.search(h, st_sh, q)
    assert int(drop0) == 0

    # one node, cap_factor = 1/L => cap = nq: exactly nq of the nq*L
    # (query, table) probes survive.  plan_routes is a stable argsort on
    # a single destination, so the survivors are the FIRST nq probes in
    # flat (query-major) order: queries 0 .. nq/L - 1 keep all L tables.
    capped = IndexRuntime(dc.replace(base, cap_factor=1.0 / L),
                          mesh=single_mesh)
    ids_cap, sc_cap, drop = capped.search(h, capped.shard_store(sth), q)
    assert int(drop) == nq * L - nq
    whole = nq // L  # queries whose every table probe survived
    np.testing.assert_array_equal(
        np.asarray(ids_cap[:whole]), np.asarray(ids_full[:whole]))
    np.testing.assert_array_equal(
        np.asarray(sc_cap[:whole]), np.asarray(sc_full[:whole]))
    # the last queries lost ALL their probes: nothing but fill comes back
    assert np.all(np.asarray(ids_cap[whole + 1:]) == -1)


TWO_NODE_PACKED = f"""
import numpy as np
import jax.numpy as jnp
import dataclasses as dc
from repro.core import LshParams, make_hyperplanes, packed
from repro.core.hashing import sketch_codes_batched
from repro.core.runtime import IndexRuntime, RuntimeConfig
from repro.core.store import build_store_host
from repro.launch.mesh import make_zone_mesh

N, D, K, L, M, NQ = {N}, {D}, {K}, {L}, {M}, {NQ}
rng = np.random.default_rng(17)
vecs = rng.standard_normal((N, D)).astype(np.float32)
vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
params = LshParams(d=D, k=K, L=L, seed=23)
h = make_hyperplanes(params)
codes = sketch_codes_batched(jnp.asarray(vecs), h)
store = build_store_host(codes, params.num_buckets, capacity=64,
                         payload=vecs)
sth = packed.pack_store_payload(store, h)
mesh = make_zone_mesh(2)
q = jnp.asarray(vecs[:NQ])
targets = rng.integers(0, N, size=NQ).astype(np.int32)
golden = dict(np.load("GOLDEN_2NODE"))
golden_p = dict(np.load("GOLDEN_PACKED"))

for score, st in (("dot", store), ("hamming", sth)):
    gold = golden if score == "dot" else golden_p
    for variant in ("nb", "cnb"):
        for R in (1, 2):
            if R > 1 and variant == "nb":
                continue  # nb x replication>1 is an invalid config
            base = RuntimeConfig(
                params=params, variant=variant, m=M, n_nodes=2,
                score=score, cap_factor=float(L), replication=R,
                fused="off")
            out = {{}}
            for fused in ("off", "on"):
                rt = IndexRuntime(dc.replace(base, fused=fused), mesh=mesh)
                st_sh = rt.shard_store(st)
                cache = rt.refresh_cache(st_sh) if variant == "cnb" else None
                reps = rt.replicate_store(st_sh) if R > 1 else None
                ids, sc, drop = rt.search(h, st_sh, q, cache=cache,
                                          replicas=reps)
                assert int(drop) == 0, (score, variant, R, fused)
                hits, _ = rt.contains(h, st_sh, q, targets, cache=cache,
                                      replicas=reps)
                out[fused] = (np.asarray(ids), np.asarray(sc),
                              np.asarray(hits))
            np.testing.assert_array_equal(out["on"][0], out["off"][0])
            np.testing.assert_array_equal(out["on"][2], out["off"][2])
            if score == "hamming":
                np.testing.assert_array_equal(out["on"][1], out["off"][1])
            else:
                np.testing.assert_allclose(out["on"][1], out["off"][1],
                                           atol=1e-5)
            np.testing.assert_array_equal(
                out["off"][0], gold[f"search_ids_{{variant}}"])
            np.testing.assert_array_equal(
                out["off"][2], gold[f"contains_{{variant}}"])
            print("OK", score, variant, "R=", R)
print("TWO-NODE-FUSED-OK")
"""


@pytest.mark.slow
def test_routed_fused_two_node_matrix():
    """Real 2-node mesh: routed x (dot, hamming) x (nb, cnb) x R in
    {1, 2}, fused == staged bit-identically and staged == the committed
    goldens (`runtime_2node_v1.npz` / `runtime_2node_packed_v1.npz`)."""
    from conftest import run_in_subprocess

    here = os.path.dirname(__file__)
    code = TWO_NODE_PACKED.replace(
        "GOLDEN_2NODE", os.path.join(here, "goldens", "runtime_2node_v1.npz")
    ).replace(
        "GOLDEN_PACKED",
        os.path.join(here, "goldens", "runtime_2node_packed_v1.npz"),
    )
    out = run_in_subprocess(code, devices=2)
    assert "TWO-NODE-FUSED-OK" in out
