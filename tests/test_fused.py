"""Fused query mega-kernel equivalence matrix (DESIGN.md Sec. 11).

The acceptance bar for the fused path: search ids BIT-IDENTICAL to the
staged path and to the checked-in goldens (tests/goldens/engine_v1.npz)
on every cell of the runtime equivalence matrix — variants (lsh, nb,
cnb) x probe budgets (full, p2, ranked3) — plus contains parity and the
hamming scoring mode, where the exact integer popcount scores make even
the SCORES bit-equal between staged and fused.

The routed topologies always run staged (the fused dispatch never
engages under collectives), so the 2-node golden
(runtime_2node_v1.npz, tests/test_runtime.py) is untouched by
construction; this module pins the 1-node side where the kernel lives.
Everything runs with fused="on" to force the Pallas path through CPU
interpret mode — "auto" stays staged on CPU hosts.
"""

from __future__ import annotations

import dataclasses
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LshParams, make_hyperplanes, packed
from repro.core.hashing import sketch_codes_batched
from repro.core.runtime import IndexRuntime, RuntimeConfig
from repro.core.store import build_store_host, make_store

GOLDENS = os.path.join(os.path.dirname(__file__), "goldens", "engine_v1.npz")

# must mirror tests/goldens/make_goldens.py exactly
N, D, K, L, M, NQ = 1200, 32, 5, 3, 10, 48
PROBE_CELLS = [
    ("full", dict()),
    ("p2", dict(num_probes=2)),
    ("ranked3", dict(num_probes=3, ranked_probes=True)),
]


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(17)
    vecs = rng.standard_normal((N, D)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    params = LshParams(d=D, k=K, L=L, seed=23)
    h = make_hyperplanes(params)
    codes = sketch_codes_batched(jnp.asarray(vecs), h)
    store = build_store_host(codes, params.num_buckets, capacity=64,
                             payload=vecs)
    golden = dict(np.load(GOLDENS))
    return params, h, store, vecs, golden


def _cells():
    return [(v, name, pkw) for v in ("lsh", "nb", "cnb")
            for name, pkw in PROBE_CELLS]


def _pair(params, m, variant, pkw, **kw):
    staged = RuntimeConfig(params=params, variant=variant, m=m,
                           fused="off", **pkw, **kw)
    fused = dataclasses.replace(staged, fused="on")
    return IndexRuntime(staged), IndexRuntime(fused)


@pytest.mark.parametrize("variant,cell,pkw", _cells(),
                         ids=[f"{v}-{c}" for v, c, _ in _cells()])
def test_fused_search_matches_staged_and_goldens(setup, variant, cell, pkw):
    """Dot mode, embedded payloads: fused ids == staged ids == golden ids
    on every matrix cell; scores match to float tolerance."""
    params, h, store, vecs, golden = setup
    rt_s, rt_f = _pair(params, M, variant, pkw)
    q = vecs[:NQ]
    ex = np.arange(NQ)
    ids_s, sc_s, _ = rt_s.search(h, store, q, exclude=ex)
    ids_f, sc_f, _ = rt_f.search(h, store, q, exclude=ex)
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_s))
    np.testing.assert_allclose(np.asarray(sc_f), np.asarray(sc_s),
                               atol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(ids_f), golden[f"search_ids_{variant}_{cell}"])


@pytest.mark.parametrize("variant,cell,pkw", _cells(),
                         ids=[f"{v}-{c}" for v, c, _ in _cells()])
def test_fused_contains_matches_staged_and_goldens(setup, variant, cell,
                                                   pkw):
    """Metadata-only membership: the fused kernel needs no payload, so it
    runs on the ids-only store and must reproduce the golden hit mask."""
    params, h, store, vecs, golden = setup
    rt_s, rt_f = _pair(params, M, variant, pkw)
    q = vecs[:NQ]
    hits_s, _ = rt_s.contains(h, store, q, golden["targets"])
    hits_f, _ = rt_f.contains(h, store, q, golden["targets"])
    np.testing.assert_array_equal(np.asarray(hits_f), np.asarray(hits_s))
    np.testing.assert_array_equal(
        np.asarray(hits_f), golden[f"contains_{variant}_{cell}"])


@pytest.mark.parametrize("variant,cell,pkw", _cells(),
                         ids=[f"{v}-{c}" for v, c, _ in _cells()])
def test_fused_hamming_bit_exact(setup, variant, cell, pkw):
    """Hamming mode scores are exact integers, so staged and fused agree
    on SCORES bit-for-bit, not just on ids."""
    params, h, store, vecs, golden = setup
    rt_s, rt_f = _pair(params, M, variant, pkw, score="hamming")
    w = packed.num_words(K, L)
    sth = make_store(L, params.num_buckets, 64, payload_dim=w,
                     dtype=jnp.uint32)
    sth = rt_s.insert(h, sth, vecs, np.arange(N, dtype=np.int32), 0)
    q = vecs[:NQ]
    ex = np.arange(NQ)
    ids_s, sc_s, _ = rt_s.search(h, sth, q, exclude=ex)
    ids_f, sc_f, _ = rt_f.search(h, sth, q, exclude=ex)
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_s))
    np.testing.assert_array_equal(np.asarray(sc_f), np.asarray(sc_s))


def test_hamming_store_via_migration_shim(setup):
    """`pack_store_payload` on a dot store == building the hamming store
    from scratch, and both search identically (staged vs fused)."""
    params, h, store, vecs, golden = setup
    migrated = packed.pack_store_payload(store, h)
    rt_s, rt_f = _pair(params, M, "cnb", {}, score="hamming")
    q = vecs[:NQ]
    ids_s, sc_s, _ = rt_s.search(h, migrated, q)
    ids_f, sc_f, _ = rt_f.search(h, migrated, q)
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_s))
    np.testing.assert_array_equal(np.asarray(sc_f), np.asarray(sc_s))


def test_fused_on_raises_where_unsupported(setup):
    """fused='on' must refuse (not silently degrade) when the kernel
    cannot apply: id-keyed corpus scoring and ids-only search stores."""
    from repro.core import BucketStore, DenseCorpus

    params, h, store, vecs, golden = setup
    rt = IndexRuntime(
        RuntimeConfig(params=params, variant="cnb", m=M, fused="on"))
    ids_only = BucketStore(store.ids, store.timestamps, store.write_ptr,
                           None)
    q = vecs[:8]
    with pytest.raises(ValueError, match="corpus"):
        rt.search(h, ids_only, q, corpus=DenseCorpus(jnp.asarray(vecs)))
    with pytest.raises(ValueError, match="ids-only"):
        rt.search(h, ids_only, q)


def test_fused_auto_stays_staged_on_cpu(setup):
    """'auto' must not pick interpret-mode Pallas on CPU hosts — it is
    correct but slower than the jitted staged path."""
    import jax

    from repro.core import runtime as runtime_mod

    if jax.default_backend() != "cpu":
        pytest.skip("CPU-backend specific dispatch check")
    cfg = RuntimeConfig(params=LshParams(d=D, k=K, L=L), m=M)
    assert not runtime_mod._fused_on(
        cfg, runtime_mod.LOCAL, has_payload=True, has_corpus=False)
    assert runtime_mod._fused_on(
        dataclasses.replace(cfg, fused="on"), runtime_mod.LOCAL,
        has_payload=True, has_corpus=False)


def test_hamming_insert_rejects_unpacked_payload(setup):
    """score='hamming' insert must refuse an f32 dot-mode payload store —
    casting packed words into f32 slots silently drops bits above 2^24;
    `pack_store_payload` is the migration path."""
    params, h, store, vecs, golden = setup
    rt = IndexRuntime(RuntimeConfig(params=params, m=M, score="hamming"))
    with pytest.raises(ValueError, match="packed uint32"):
        rt.insert(h, store, vecs[:4], np.arange(4, dtype=np.int32), 0)


def test_hamming_mode_validation():
    """Config-level guards: hamming is 1-node only; bad knobs raise."""
    params = LshParams(d=D, k=K, L=L)
    with pytest.raises(ValueError, match="1-node"):
        RuntimeConfig(params=params, n_nodes=2, score="hamming")
    with pytest.raises(ValueError, match="score"):
        RuntimeConfig(params=params, score="cosine")
    with pytest.raises(ValueError, match="fused"):
        RuntimeConfig(params=params, fused="maybe")
