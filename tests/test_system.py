"""End-to-end behaviour tests: the paper's system working as a whole.

Covers: (1) the full search pipeline on a synthetic OSN dataset with the
paper's headline result (CNB beats LSH at equal network cost); (2) the
training driver with checkpoint/restart (fault-tolerance path); (3) the
serving driver; (4) model-embeddings -> LSH index integration (the
framework feature of DESIGN.md Sec. 4).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    DenseCorpus, EngineConfig, LshEngine, LshParams, make_hyperplanes,
    metrics, paper_topology,
)
from repro.core.corpus import exact_topk_sparse, sparse_densify_host
from repro.core.hashing import sketch_codes_batched
from repro.core.store import build_store_host
from repro.data import osn


@pytest.fixture(scope="module")
def tiny_osn():
    spec = osn.tiny_spec()
    corpus = osn.generate(spec)
    params = LshParams(d=spec.num_interests, k=spec.k, L=4, seed=7)
    h = make_hyperplanes(params)
    dense = sparse_densify_host(corpus, np.arange(corpus.n))
    codes = sketch_codes_batched(jnp.asarray(dense), h)
    store = build_store_host(codes, params.num_buckets, capacity=128)
    return spec, corpus, params, h, dense, store


def test_paper_headline_cnb_beats_lsh_at_equal_cost(tiny_osn):
    """The paper's core claim (Sec. 6.4): at equal message budget, CNB-LSH
    achieves higher recall and NCS than plain LSH."""
    spec, corpus, params, h, dense, store = tiny_osn
    topo = paper_topology(spec.k)
    nq = 48
    qidx = np.arange(nq)
    qd = dense[qidx] / np.maximum(
        np.linalg.norm(dense[qidx], axis=1, keepdims=True), 1e-12)

    ideal_s, ideal_i = exact_topk_sparse(corpus, qd, 11)
    # drop self from the ideal set
    keep_s = np.empty((nq, 10), np.float32)
    keep_i = np.empty((nq, 10), np.int32)
    for i in range(nq):
        mask = ideal_i[i] != qidx[i]
        keep_s[i] = ideal_s[i][mask][:10]
        keep_i[i] = ideal_i[i][mask][:10]

    results = {}
    for variant in ("lsh", "cnb"):
        e = LshEngine(params, h, store, corpus, topo,
                      EngineConfig(variant=variant))
        r = e.search(jnp.asarray(qd), m=10, exclude=qidx)
        results[variant] = dict(
            recall=metrics.recall_at_m(r.ids, keep_i),
            ncs=metrics.ncs_at_m(r.scores, keep_s),
            messages=r.cost.messages,
        )
    assert results["cnb"]["messages"] == results["lsh"]["messages"]
    assert results["cnb"]["recall"] > results["lsh"]["recall"]
    assert results["cnb"]["ncs"] >= results["lsh"]["ncs"] - 1e-9


def test_success_probability_tracks_analysis(tiny_osn):
    """Fig. 4: observed success probability follows Prop. 1/4 curves."""
    from repro.core import analysis

    spec, corpus, params, h, dense, store = tiny_osn
    topo = paper_topology(spec.k)
    nq = 200
    rng = np.random.default_rng(3)
    qidx = rng.choice(corpus.n, nq, replace=False)
    qd = dense[qidx] / np.maximum(
        np.linalg.norm(dense[qidx], axis=1, keepdims=True), 1e-12)
    ideal_s, ideal_i = exact_topk_sparse(corpus, qd, 2)
    # top non-self result
    y = np.where(ideal_i[:, 0] == qidx, ideal_i[:, 1], ideal_i[:, 0])
    y_sim = np.where(ideal_i[:, 0] == qidx, ideal_s[:, 1], ideal_s[:, 0])

    for variant, spf in (("lsh", analysis.sp_lsh),
                         ("nb", analysis.sp_nearbucket)):
        e = LshEngine(params, h, store, corpus, topo,
                      EngineConfig(variant=variant))
        found = e.contains(jnp.asarray(qd), y)
        s_ang = analysis.angular_from_cosine(np.clip(y_sim, 0, 1))
        expected = spf(s_ang, params.k, params.L)
        # mean observed success within a sane band of mean analytical SP
        assert abs(found.mean() - expected.mean()) < 0.15, (
            variant, found.mean(), expected.mean())


def test_train_driver_with_restart(tmp_path):
    """Train 4 steps with checkpoints, stop, resume to 6 — the
    fault-tolerant restart path of launch/train.py."""
    from repro.launch import train as train_mod

    ckpt_dir = str(tmp_path / "ck")
    train_mod.main([
        "--arch", "starcoder2-7b", "--smoke", "--steps", "4",
        "--batch", "2", "--seq", "32", "--ckpt-dir", ckpt_dir,
        "--ckpt-every", "2", "--log-every", "2",
    ])
    train_mod.main([
        "--arch", "starcoder2-7b", "--smoke", "--steps", "6",
        "--batch", "2", "--seq", "32", "--ckpt-dir", ckpt_dir,
        "--ckpt-every", "2", "--log-every", "2", "--resume",
    ])
    from repro.checkpoint import checkpoint as ckpt

    latest = ckpt.latest_step_dir(ckpt_dir)
    assert ckpt.load_meta(latest)["step"] == 6


def test_serve_driver(capsys):
    from repro.launch import serve as serve_mod

    serve_mod.main([
        "--arch", "gemma2-2b", "--smoke", "--batch", "2",
        "--prompt-len", "16", "--gen", "4",
    ])
    out = capsys.readouterr().out
    assert "[done]" in out


def test_model_embeddings_to_lsh_index(single_mesh):
    """Framework integration: embed 'users' with an assigned arch backbone,
    index with LSH, search — similar users (shared token prefix) retrieved."""
    from repro.configs import get_config
    from repro.models import model as M
    from repro.models import sharding as sh

    cfg = get_config("xlstm-1.3b", smoke=True)
    params, _ = M.init_model(cfg, 0)
    rng = np.random.default_rng(0)
    n_users, seq = 96, 12
    # users in 8 communities share a 6-token prefix
    comm = rng.integers(0, 8, n_users)
    toks = rng.integers(0, cfg.vocab_size, (n_users, seq))
    prefix = rng.integers(0, cfg.vocab_size, (8, 6))
    toks[:, :6] = prefix[comm]
    with sh.use_mesh(single_mesh):
        hidden, _, _ = M.forward(
            params, cfg, {"tokens": jnp.asarray(toks, jnp.int32)})
    emb = np.array(hidden.mean(axis=1), np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)

    params_lsh = LshParams(d=emb.shape[1], k=5, L=4, seed=2)
    h = make_hyperplanes(params_lsh)
    codes = sketch_codes_batched(jnp.asarray(emb), h)
    store = build_store_host(codes, params_lsh.num_buckets, capacity=64)
    e = LshEngine(params_lsh, h, store, DenseCorpus(jnp.asarray(emb)), None,
                  EngineConfig(variant="cnb"))
    r = e.search(jnp.asarray(emb[:16]), m=5, exclude=np.arange(16))
    total = match = 0
    for i in range(16):
        for j in r.ids[i]:
            if j >= 0:
                total += 1
                match += int(comm[j] == comm[i])
    assert total > 0 and match / total > 0.6, (match, total)
