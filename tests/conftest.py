"""Shared test helpers.

NOTE: XLA_FLAGS / host-device-count is deliberately NOT set here — smoke
tests and benches must see 1 device.  Multi-device tests spawn subprocesses
with their own XLA_FLAGS (see `run_in_subprocess`).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 560) -> str:
    """Run python code in a fresh process with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def single_mesh():
    import jax

    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
