"""Shared test helpers.

NOTE: XLA_FLAGS / host-device-count is deliberately NOT set here — smoke
tests and benches must see 1 device.  Multi-device tests spawn subprocesses
with their own XLA_FLAGS (see `run_in_subprocess`).

Also the conftest-level guard for optional `hypothesis`: property-test
modules import `given`/`settings`/`st` from here instead of from
hypothesis directly, so collection never hard-errors when the package is
absent — the property tests individually skip instead (importorskip-style),
and every example-based test in the same module still runs.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:  # keep subprocess-free runs working without PYTHONPATH
    sys.path.insert(0, SRC)

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAS_HYPOTHESIS = True
    # Pinned profile: the property suite runs in tier-1 CI, so it must be
    # deterministic — derandomize derives examples from the test body
    # alone (no RNG state, no example database growth between runs).
    # Override locally with HYPOTHESIS_PROFILE=dev for randomized search.
    settings.register_profile(
        "tier1", derandomize=True, deadline=None, max_examples=50,
        database=None,
    )
    settings.register_profile("dev", deadline=None, max_examples=200)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "tier1"))
except ImportError:
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Inert stand-in: builds placeholders so module-level strategy
        expressions still evaluate; decorated tests skip at run time."""

        def __getattr__(self, name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            # plain wrapper (no functools.wraps) so pytest sees a
            # zero-argument test and does not try to inject fixtures.
            def skipper():
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 560) -> str:
    """Run python code in a fresh process with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def single_mesh():
    from repro.compat import make_mesh

    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
