"""Emit the EXPERIMENTS.md §Perf tables from results/{dryrun,perf}/*.json."""

from __future__ import annotations

import json
import os

from benchmarks.roofline import (
    LINK_BW, PEAK_FLOPS, _inner_scan_correction, model_flops,
)

CHIPS = 256


def load(path):
    r = json.load(open(path))
    corr = _inner_scan_correction(r["arch"], r["shape"], CHIPS) \
        if r.get("unrolled") else 0.0
    flops = (r["cost"]["flops"] or 0.0) + corr
    compute_s = flops / PEAK_FLOPS
    coll_s = r["collectives"]["total_wire_bytes"] / LINK_BW
    mf = model_flops(r["arch"], r["shape"])
    ideal = mf / (CHIPS * PEAK_FLOPS)
    step = max(compute_s, coll_s)
    return dict(
        compute_s=compute_s, coll_s=coll_s, step_s=step,
        wire_gb=r["collectives"]["total_wire_bytes"] / 2**30,
        by_op={k: round(v / 2**30, 1)
               for k, v in r["collectives"]["bytes_by_op"].items()},
        roofline=ideal / step, ideal_s=ideal,
        temp_gib=(r["memory"]["temp_bytes"] or 0) / 2**30,
    )


def row(tag, path, note=""):
    if not os.path.exists(path):
        return f"| {tag} | (missing) |  |  |  |  | {note} |"
    d = load(path)
    return (f"| {tag} | {d['compute_s']:.2f} | {d['coll_s']:.2f} "
            f"| {d['step_s']:.2f} | {d['wire_gb']:.0f} "
            f"| {d['roofline']:.3f} | {note} |")


def main():
    hdr = ("| config | compute s | collective s | step s | wire GiB/dev "
           "| roofline frac | note |\n|---|---|---|---|---|---|---|")
    print("### codeqwen1.5-7b train_4k (most collective-bound)")
    print(hdr)
    print(row("baseline (Megatron TP+FSDP)",
              "results/dryrun/codeqwen1.5-7b__train_4k__pod1__unroll.json"))
    print(row("it1: zero3 rules",
              "results/perf/codeqwen1.5-7b__train_4k__pod1__unroll__zero3.json",
              "scan-mode temp 9.9 GiB: fits"))
    print(row("it2: zero3 + no-remat",
              "results/perf/codeqwen1.5-7b__train_4k__pod1__unroll__zero3__noremat.json",
              "scan-mode temp 200 GiB: REJECTED (OOM)"))
    print(row("it3: zero3b (vocab repl.)",
              "results/perf/codeqwen1.5-7b__train_4k__pod1__unroll__zero3b.json"))
    print()
    print("### gemma2-2b train_4k (worst useful-ratio / replicated attention)")
    print(hdr)
    print(row("baseline (Megatron TP+FSDP)",
              "results/dryrun/gemma2-2b__train_4k__pod1__unroll.json"))
    print(row("it1: zero3 rules",
              "results/perf/gemma2-2b__train_4k__pod1__unroll__zero3.json",
              "scan-mode temp 7.7 GiB: fits"))
    print(row("it2: zero3b (vocab repl.)",
              "results/perf/gemma2-2b__train_4k__pod1__unroll__zero3b.json"))
    print(row("it3: zero3 + no-remat",
              "results/perf/gemma2-2b__train_4k__pod1__unroll__zero3__noremat.json",
              "scan-mode temp 106 GiB: REJECTED (OOM)"))


if __name__ == "__main__":
    main()
