"""Paper Fig. 3: analytical SP vs network cost (messages/query, k=12).

For each budget, each variant picks the largest L it can afford
(Table 1); CNB always matches LSH's cost with NB's quality."""

import numpy as np

from repro.core import analysis, costmodel


def rows():
    k = 12
    out = []
    for budget in (18, 180, 1800):
        t = np.linspace(0.0, 1.0, 101)
        s = analysis.angular_from_cosine(t)
        curves = {}
        for variant in ("lsh", "nb", "cnb"):
            L = costmodel.lsh_L_for_budget(variant, k, budget)
            spf = analysis.sp_lsh if variant == "lsh" else analysis.sp_nearbucket
            curves[variant] = spf(s, k, L) if L > 0 else np.zeros_like(s)
        auc = {v: float(np.trapezoid(c, t)) for v, c in curves.items()}
        out.append((f"fig3/budget={budget}",
                    auc["cnb"] - auc["lsh"],
                    f"auc_lsh={auc['lsh']:.4f};auc_nb={auc['nb']:.4f};"
                    f"auc_cnb={auc['cnb']:.4f}"))
    return out
