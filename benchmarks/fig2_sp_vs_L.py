"""Paper Fig. 2: analytical SP vs L at k=12 — NB >= LSH, gap grows with L."""

import numpy as np

from repro.core import analysis


def rows():
    k = 12
    out = []
    for L in (1, 10, 100):
        t = np.linspace(0.0, 1.0, 101)
        s = analysis.angular_from_cosine(t)
        gap = float(np.max(analysis.sp_nearbucket(s, k, L)
                           - analysis.sp_lsh(s, k, L)))
        out.append((f"fig2/L={L}", gap, "nb_minus_lsh_max"))
    return out
