"""Packed-mesh CI smoke: 2-shard hamming vs dot, recall gap 0.0.

The PR 10 acceptance gate in executable form: a `score="hamming"`
runtime on a REAL 2-node mesh — packed [.., W] uint32 sketch words
riding the capacitated all_to_all — must return ids bit-identical to
the 1-node hamming run on the same data (the mesh adds placement, not
drift), and its recall against the dot-mode mesh run must be exactly
the recall gap the 1-node topologies already exhibit (gap 0.0 between
topologies, per scoring mode).  Zero dropped probes throughout.

The script re-execs itself with XLA_FLAGS forcing 2 host devices (the
device count is fixed at jax backend init), so it can run inside the CI
bench step without special environment plumbing:

    PYTHONPATH=src python benchmarks/packed_mesh_smoke.py
"""

from __future__ import annotations

import os
import subprocess
import sys

N, D, K, L, M, NQ = 1200, 32, 5, 3, 10, 48


def run() -> None:
    import jax.numpy as jnp
    import numpy as np

    from repro.core import LshParams, make_hyperplanes, packed
    from repro.core.hashing import sketch_codes_batched
    from repro.core.runtime import IndexRuntime, RuntimeConfig
    from repro.core.store import build_store_host
    from repro.launch.mesh import make_zone_mesh

    rng = np.random.default_rng(17)
    vecs = rng.standard_normal((N, D)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    params = LshParams(d=D, k=K, L=L, seed=23)
    h = make_hyperplanes(params)
    codes = sketch_codes_batched(jnp.asarray(vecs), h)
    store = build_store_host(codes, params.num_buckets, capacity=64,
                             payload=vecs)
    sth = packed.pack_store_payload(store, h)
    mesh = make_zone_mesh(2)
    q = jnp.asarray(vecs[:NQ])

    ids = {}
    for score, st in (("dot", store), ("hamming", sth)):
        local = IndexRuntime(
            RuntimeConfig(params=params, variant="cnb", m=M, score=score))
        ids_1, _, _ = local.search(h, st, q)
        rt = IndexRuntime(
            RuntimeConfig(params=params, variant="cnb", m=M, n_nodes=2,
                          score=score, cap_factor=float(L)),
            mesh=mesh,
        )
        st_sh = rt.shard_store(st)
        cache = rt.refresh_cache(st_sh)
        ids_2, _, drop = rt.search(h, st_sh, q, cache=cache)
        assert int(drop) == 0, f"{score}: dropped {int(drop)} probes"
        np.testing.assert_array_equal(
            np.asarray(ids_2), np.asarray(ids_1),
            err_msg=f"{score}: 2-node ids drifted from the 1-node run")
        ids[score] = np.asarray(ids_2)

    # recall@M of each mesh run against brute force; the hamming mesh run
    # must show EXACTLY the recall its 1-node twin does (asserted above by
    # bit-identity) — report both so the smoke log shows the numbers
    sims = np.asarray(vecs[:NQ] @ vecs.T)
    truth = np.argsort(-sims, axis=1)[:, :M]
    rec = {
        s: float(np.mean([
            len(set(ids[s][i].tolist()) & set(truth[i].tolist())) / M
            for i in range(NQ)
        ]))
        for s in ids
    }
    print(f"PACKED-MESH-SMOKE-OK recall_dot={rec['dot']:.3f} "
          f"recall_hamming={rec['hamming']:.3f} "
          f"mesh_vs_1node_gap=0.0")


if __name__ == "__main__":
    if "--child" in sys.argv:
        run()
    else:
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--child"],
            env=env, check=True,
        )
