"""Paper Table 1: closed-form costs + hop-counted simulation agreement."""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, LshEngine, paper_topology
from benchmarks.common import build_dataset
from repro.data import osn


def rows():
    ds = build_dataset(osn.tiny_spec(), L=4, num_queries=64)
    topo = paper_topology(ds.spec.k)
    out = []
    for variant in ("lsh", "layered", "nb", "cnb"):
        e = LshEngine(ds.params, ds.hyperplanes, ds.store, ds.corpus, topo,
                      EngineConfig(variant=variant))
        t0 = time.time()
        r = e.search(jnp.asarray(ds.queries_dense), m=10,
                     exclude=ds.queries_idx, simulate_messages=True,
                     rng=np.random.default_rng(0))
        us = (time.time() - t0) / 64 * 1e6
        out.append((
            f"table1/{variant}", us,
            f"closed_form_msgs={r.cost.messages};sim_msgs={r.sim_messages:.1f};"
            f"vec_searched={r.cost.vectors_searched:.0f};"
            f"stored_per_node={r.cost.vectors_stored_per_node:.0f}"))
    return out
