"""Churn/soft-state benchmark (paper Sec. 4.1 dynamics, beyond-paper
quantification): CNB recall vs refresh period under profile updates and
node churn."""

import dataclasses
import time

from repro.core.churn import ChurnConfig, run_churn


def rows():
    out = []
    base = ChurnConfig(num_users=2000, epochs=8, num_queries=96,
                       update_rate=0.1, churn_rate=0.03, seed=1)
    for period in (1, 2, 4, 8):
        t0 = time.time()
        r = run_churn(dataclasses.replace(base, refresh_every=period))
        us = (time.time() - t0) / base.epochs * 1e6
        out.append((
            f"churn/refresh_every={period}", us,
            f"mean_recall={r['mean_recall']:.3f};"
            f"final_recall={r['final_recall']:.3f}"))
    return out
