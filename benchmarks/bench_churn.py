"""Churn/soft-state benchmark (paper Sec. 4.1 dynamics, beyond-paper
quantification): CNB recall vs refresh period under profile updates and
node churn — single-host, plus the same trajectory on a 2-shard mesh
(recall + estimated wire bytes/epoch vs refresh period).

The distributed cells run in a subprocess: the host device count is fixed
at jax backend init, so a multi-shard mesh needs its own process with
XLA_FLAGS set before the first jax import."""

import dataclasses
import json
import os
import subprocess
import sys
import time

from repro.core.churn import ChurnConfig, run_churn

N_SHARDS = 2

_DIST_SCRIPT = r"""
import json, sys, time
import dataclasses
import numpy as np
from repro.core.churn import ChurnConfig, run_churn_distributed
from repro.core import distributed as dist
from repro.core.hashing import LshParams

base = ChurnConfig(**json.loads(sys.argv[1]))
n_shards = int(sys.argv[2])
out = []
for period in (1, 2, 4, 8):
    cfg = dataclasses.replace(base, refresh_every=period)
    t0 = time.time()
    r = run_churn_distributed(cfg, n_shards=n_shards)
    us = (time.time() - t0) / cfg.epochs * 1e6
    params = LshParams(d=cfg.dim, k=cfg.k, L=cfg.L, seed=cfg.seed + 1)
    dcfg = dist.DistConfig(params=params, n_shards=n_shards, variant="cnb",
                           m=cfg.m + 1, cap_factor=float(n_shards))
    qbytes = dist.estimate_query_bytes(
        dcfg, batch=cfg.num_queries, d=cfg.dim, n_total=n_shards)["total"]
    rbytes = dist.estimate_refresh_bytes(dcfg, cfg.capacity, cfg.dim)
    bytes_per_epoch = qbytes + rbytes / period  # refresh amortized
    out.append(dict(period=period, us=us,
                    mean_recall=r["mean_recall"],
                    final_recall=r["final_recall"],
                    dropped=int(r["dropped_probes"].sum()),
                    max_stale=int(r["cache_staleness"].max()),
                    bytes_per_epoch=bytes_per_epoch))
print("RESULT " + json.dumps(out))
"""


_NODE_SCRIPT = r"""
import json, sys, time
import numpy as np
from repro.core.churn import ChurnConfig, NodeChurnConfig, run_node_churn

base = ChurnConfig(**json.loads(sys.argv[1]))
out = []
for name, sched in (
    ("static", (1,)),
    ("join2", (1, 2)),
    ("sawtooth", (1, 2, 4, 2, 1, 2, 1)),
):
    t0 = time.time()
    r = run_node_churn(NodeChurnConfig(churn=base, schedule=sched))
    us = (time.time() - t0) / base.epochs * 1e6
    out.append(dict(
        name=name, us=us,
        mean_recall=r["mean_recall"],
        rounds=len(r["reshard_events"]),
        handoff=int(r["total_handoff_bytes"]),
        refresh=int(r["total_refresh_bytes"]),
        dropped=int(r["dropped_probes"].sum())))
print("RESULT " + json.dumps(out))
"""

_FAILURE_SCRIPT = r"""
import json, sys, time
import numpy as np
from repro.core.churn import (
    ChurnConfig, FailureChurnConfig, run_failure_churn,
)

base = ChurnConfig(**json.loads(sys.argv[1]))
out = []
for read_mode in ("first", "quorum"):
    t0 = time.time()
    r = run_failure_churn(FailureChurnConfig(
        churn=base, n_nodes=4, replication=2, read_mode=read_mode,
        kills=((base.epochs // 2, 1),),
    ))
    us = (time.time() - t0) / base.epochs * 1e6
    out.append(dict(
        name=read_mode, us=us,
        mean_recall=float(np.mean(r["recalls"])),
        degraded_gap=r["degraded_gap"],
        recovered_gap=r["recovered_gap"],
        recovery_epochs=r["recovery_epochs"],
        replication=int(r["total_replication_bytes"]),
        recovery=int(r["total_recovery_bytes"]),
        dropped=int(r["dropped_probes"].sum())))
print("RESULT " + json.dumps(out))
"""

N_NODES_MAX = 4


def _subprocess_rows(script: str, base: ChurnConfig, devices: int,
                     extra_args=()):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
    )
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", script,
         json.dumps(dataclasses.asdict(base)), *extra_args],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"churn subprocess failed:\n{proc.stderr}")
    payload = next(ln for ln in proc.stdout.splitlines()
                   if ln.startswith("RESULT "))
    return json.loads(payload[len("RESULT "):])


def _dist_rows(base: ChurnConfig):
    out = []
    for r in _subprocess_rows(_DIST_SCRIPT, base, N_SHARDS,
                              (str(N_SHARDS),)):
        out.append((
            f"churn/dist{N_SHARDS}shard/refresh_every={r['period']}",
            r["us"],
            f"mean_recall={r['mean_recall']:.3f};"
            f"final_recall={r['final_recall']:.3f};"
            f"bytes_per_epoch={r['bytes_per_epoch']:.3e};"
            f"dropped={r['dropped']};max_cache_stale={r['max_stale']}"))
    return out


def _node_rows(base: ChurnConfig):
    """Elastic-membership cell: recall + handoff/refresh bytes as node
    join/leave rounds interleave with content churn (vs the static
    schedule on the same trajectory — the recall columns should match)."""
    out = []
    for r in _subprocess_rows(_NODE_SCRIPT, base, N_NODES_MAX):
        out.append((
            f"churn/nodes/{r['name']}", r["us"],
            f"mean_recall={r['mean_recall']:.3f};rounds={r['rounds']};"
            f"handoff_bytes={r['handoff']};refresh_bytes={r['refresh']};"
            f"dropped={r['dropped']}"))
    return out


def _failure_rows(base: ChurnConfig):
    """Fail-stop cell (DESIGN.md Sec. 10): kill 1 of 4 replicated nodes
    mid-run with NO handoff, serve through first-responder vs quorum
    reads — recall gap while degraded, epochs to parity, and the
    replication/recovery byte bill next to the Table-1 query costs."""
    out = []
    for r in _subprocess_rows(_FAILURE_SCRIPT, base, N_NODES_MAX):
        out.append((
            f"churn/failure/R2/{r['name']}", r["us"],
            f"mean_recall={r['mean_recall']:.3f};"
            f"degraded_gap={r['degraded_gap']:.3f};"
            f"recovered_gap={r['recovered_gap']:.3f};"
            f"recovery_epochs={r['recovery_epochs']};"
            f"replication_bytes={r['replication']};"
            f"recovery_bytes={r['recovery']};dropped={r['dropped']}"))
    return out


def rows():
    out = []
    base = ChurnConfig(num_users=2000, epochs=8, num_queries=96,
                       update_rate=0.1, churn_rate=0.03, seed=1)
    for period in (1, 2, 4, 8):
        t0 = time.time()
        r = run_churn(dataclasses.replace(base, refresh_every=period))
        us = (time.time() - t0) / base.epochs * 1e6
        out.append((
            f"churn/refresh_every={period}", us,
            f"mean_recall={r['mean_recall']:.3f};"
            f"final_recall={r['final_recall']:.3f}"))
    try:
        out.extend(_dist_rows(base))
    except Exception as e:  # e.g. accelerator jaxlib: the subprocess's
        # host-platform device flag can't split a GPU/TPU backend — keep
        # the single-host rows and record the actual failure in the row.
        reason = " ".join(str(e).split())[:300]
        out.append((f"churn/dist{N_SHARDS}shard/FAILED", 0.0,
                    f"{type(e).__name__}: {reason}"))
    try:
        out.extend(_node_rows(base))
    except Exception as e:
        reason = " ".join(str(e).split())[:300]
        out.append(("churn/nodes/FAILED", 0.0,
                    f"{type(e).__name__}: {reason}"))
    try:
        out.extend(_failure_rows(base))
    except Exception as e:
        reason = " ".join(str(e).split())[:300]
        out.append(("churn/failure/FAILED", 0.0,
                    f"{type(e).__name__}: {reason}"))
    return out
