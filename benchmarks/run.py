"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  `--full` switches in the larger
LiveJournal/Friendster-scale synthetic datasets (slower); default exercises
every benchmark at CPU-friendly scale.  `--json PATH` additionally writes
the same rows as machine-readable JSON (a list of
``{"name", "us_per_call", "derived", "suite"}`` objects, e.g.
``BENCH_serve.json``), so perf trajectories can be tracked across commits.
Rows whose benchmark published ``bench_dropped_probes`` /
``bench_nodes_contacted`` gauges into the obs metrics registry
(bench_serve does) additionally carry those as JSON columns.
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="use all three OSN-scale datasets")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark prefixes to run")
    ap.add_argument("--json", default="",
                    help="also write rows as JSON to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized shapes / fewer reps where supported "
                         "(kernels, roofline)")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_churn, bench_distributed, bench_kernels, bench_serve,
        fig1_sp_vs_buckets, fig2_sp_vs_L, fig3_sp_vs_cost, fig4_sp_empirical,
        fig5_quality, table1_costs,
    )
    from benchmarks import roofline
    from repro.obs.registry import REGISTRY

    suites = [
        ("fig1", lambda: fig1_sp_vs_buckets.rows()),
        ("fig2", lambda: fig2_sp_vs_L.rows()),
        ("fig3", lambda: fig3_sp_vs_cost.rows()),
        ("table1", lambda: table1_costs.rows()),
        ("fig4", lambda: fig4_sp_empirical.rows(full=args.full)),
        ("fig5", lambda: fig5_quality.rows(full=args.full)),
        ("churn", lambda: bench_churn.rows()),
        ("kernels", lambda: bench_kernels.rows(smoke=args.smoke)),
        ("dist", lambda: bench_distributed.rows()),
        ("serve", lambda: bench_serve.rows()),
        ("roofline", lambda: roofline.rows(smoke=args.smoke)),
    ]
    wanted = [w for w in args.only.split(",") if w]
    collected: list[dict] = []
    print("name,us_per_call,derived")
    for name, fn in suites:
        if wanted and not any(name.startswith(w) for w in wanted):
            continue
        t0 = time.time()
        try:
            for row_name, us, derived in fn():
                print(f"{row_name},{us:.2f},{derived}")
                row = dict(
                    name=row_name, us_per_call=round(float(us), 2),
                    derived=str(derived), suite=name,
                )
                dp = REGISTRY.value("bench_dropped_probes", row=row_name)
                if dp is not None:
                    row["dropped_probes"] = int(dp)
                nc = REGISTRY.value("bench_nodes_contacted", row=row_name)
                if nc is not None:
                    row["nodes_contacted"] = round(float(nc), 2)
                collected.append(row)
            print(f"# suite {name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # keep the harness running
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            collected.append(dict(
                name=f"{name}/ERROR", us_per_call=0.0,
                derived=f"{type(e).__name__}:{e}", suite=name,
            ))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(collected, f, indent=1)
        print(f"# wrote {len(collected)} rows to {args.json}",
              file=sys.stderr)
    sys.stdout.flush()


if __name__ == "__main__":
    main()
