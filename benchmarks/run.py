"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  `--full` switches in the larger
LiveJournal/Friendster-scale synthetic datasets (slower); default exercises
every benchmark at CPU-friendly scale.
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="use all three OSN-scale datasets")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark prefixes to run")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_churn, bench_distributed, bench_kernels, fig1_sp_vs_buckets,
        fig2_sp_vs_L, fig3_sp_vs_cost, fig4_sp_empirical, fig5_quality,
        table1_costs,
    )
    from benchmarks import roofline

    suites = [
        ("fig1", lambda: fig1_sp_vs_buckets.rows()),
        ("fig2", lambda: fig2_sp_vs_L.rows()),
        ("fig3", lambda: fig3_sp_vs_cost.rows()),
        ("table1", lambda: table1_costs.rows()),
        ("fig4", lambda: fig4_sp_empirical.rows(full=args.full)),
        ("fig5", lambda: fig5_quality.rows(full=args.full)),
        ("churn", lambda: bench_churn.rows()),
        ("kernels", lambda: bench_kernels.rows()),
        ("dist", lambda: bench_distributed.rows()),
        ("roofline", lambda: roofline.rows()),
    ]
    wanted = [w for w in args.only.split(",") if w]
    print("name,us_per_call,derived")
    for name, fn in suites:
        if wanted and not any(name.startswith(w) for w in wanted):
            continue
        t0 = time.time()
        try:
            for row_name, us, derived in fn():
                print(f"{row_name},{us:.2f},{derived}")
            print(f"# suite {name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # keep the harness running
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
    sys.stdout.flush()


if __name__ == "__main__":
    main()
