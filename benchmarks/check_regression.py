"""Gate a fresh BENCH_tier1.json against the committed baseline.

CI runs ``benchmarks.run --smoke --json BENCH_tier1.json`` and then::

    python -m benchmarks.check_regression BENCH_tier1.json \
        benchmarks/baselines/BENCH_tier1_baseline.json

Raw microseconds vary wildly across runner hardware, so the gate only
checks machine-independent signals:

  * no ``*/ERROR`` rows (a suite crashed mid-run);
  * every ``<x>_over_<y>=<r>x`` ratio present in the baseline must still
    exist and stay above ``THRESHOLD * baseline`` — e.g. the bit-packed
    hamming speedup over f32 dot (``packed_over_dot``) regressing below
    half its recorded value fails the build; likewise the serving
    architecture ratio ``pipe_over_sync`` (pipelined+background-writer
    max-qps-at-SLO over sync+inline-churn, ``serve/pipeline_speedup``) —
    its rate ladder is deliberately coarse, so a one-rung flip on a noisy
    runner stays well above ``THRESHOLD`` while a real loss of the
    writer's tail-latency win (both modes kneeing at the same rung and
    below) does not;
  * ratios in ``ABSOLUTE_FLOORS`` additionally gate against a fixed
    floor, independent of the recorded baseline — the observability
    overhead ratio (``obs_on_over_obs_off``) must stay >= 0.95, i.e.
    tracing every query may cost at most 5% qps.

Interpret-mode Pallas rows (``mode=interpret``) are exempt from the ratio
floor: their absolute cost is a CPU-emulation artifact, not a perf signal
(the row still must exist, and parity is enforced by the tests, not here).
"""

from __future__ import annotations

import json
import re
import sys

# full float syntax (sign, scientific notation): producers format ratios
# fixed-point today, but a '1.2e-01x' row must gate, not vanish silently.
# The side names allow underscores (obs_on_over_obs_off) — excluding them
# silently truncated such keys to their inner words, detaching the
# ABSOLUTE_FLOORS lookup from the row it was meant to gate.
RATIO = re.compile(
    r"([A-Za-z0-9_]+_over_[A-Za-z0-9_]+)="
    r"(-?(?:[0-9]+\.?[0-9]*|\.[0-9]+)(?:[eE][+-]?[0-9]+)?)x"
)
THRESHOLD = 0.4
# per-ratio-key hard floors (by the derived key, any row): gate against
# the constant even if the baseline itself was recorded below par
ABSOLUTE_FLOORS = {"obs_on_over_obs_off": 0.95}


def _ratios(rec: list[dict]) -> dict[str, tuple[float, bool]]:
    out = {}
    for row in rec:
        derived = str(row.get("derived", ""))
        interp = "mode=interpret" in derived
        for key, val in RATIO.findall(derived):
            out[f"{row['name']}::{key}"] = (float(val), interp)
    return out


def check(current: list[dict], baseline: list[dict]) -> list[str]:
    failures = [
        f"suite crashed: {row['name']} ({row.get('derived', '')})"
        for row in current if "/ERROR" in row["name"]
    ]
    cur = _ratios(current)
    for key, (base_val, _) in sorted(_ratios(baseline).items()):
        if key not in cur:
            failures.append(
                f"missing ratio row: {key} (baseline {base_val:.3f}x)")
            continue
        cur_val, interp = cur[key]
        if interp:
            continue
        if cur_val < base_val * THRESHOLD:
            failures.append(
                f"regressed: {key} = {cur_val:.3f}x < "
                f"{THRESHOLD} * baseline {base_val:.3f}x")
    for key, (cur_val, interp) in sorted(cur.items()):
        floor = ABSOLUTE_FLOORS.get(key.split("::")[-1])
        if floor is not None and not interp and cur_val < floor:
            failures.append(
                f"below floor: {key} = {cur_val:.3f}x < {floor}")
    return failures


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 2:
        print("usage: check_regression.py CURRENT.json BASELINE.json",
              file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        current = json.load(f)
    with open(argv[1]) as f:
        baseline = json.load(f)
    failures = check(current, baseline)
    if failures:
        for msg in failures:
            print(f"REGRESSION: {msg}")
        return 1
    n = len(_ratios(baseline))
    print(f"ok: {n} baseline ratio rows present, none below "
          f"{THRESHOLD}x of baseline, no ERROR rows "
          f"({len(current)} rows checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
