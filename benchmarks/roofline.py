"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

For every (arch x shape x mesh) JSON produced by launch/dryrun.py:
  compute_term    = HLO_FLOPs / (chips * 197e12)           [s]
  memory_term     = HLO_bytes / (chips * 819e9)            [s]
  collective_term = wire_bytes / (chips * 50e9)            [s]
with cost_analysis() reported per-device by XLA (chips divisor already
applied there => we use the per-device numbers directly), plus
  MODEL_FLOPS = 6 * N_active * D_tokens  (x3 for train: fwd+bwd)
and the useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * chips).
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config
from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12     # bf16 / chip (v5e)
HBM_BW = 819e9          # B/s / chip
LINK_BW = 50e9          # B/s / link

Q_CHUNK = 1024          # must match models/layers.py
SSM_CHUNK = 256
MLSTM_CHUNK = 256


def _inner_scan_correction(arch: str, shape_name: str, chips: int) -> float:
    """Per-device FLOPs the HLO under-reports because the *inner* sequence
    scans (flash q-chunks, mamba/mLSTM chunks, sLSTM steps) stay as while
    loops even in the unrolled dry-run: XLA counts their bodies once, so we
    add (trips - 1) x body analytically.  Matmul terms are exact; the
    elementwise terms (softmax, gate math) are ~10% estimates.

    Train steps multiply by 4 (fwd body + remat recompute + ~2x bwd); all
    dims except possibly attention heads shard over the 256 chips.
    """
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    if spec.kind == "decode":
        return 0.0  # single-token step: inner scans have 1 trip
    B, S = spec.global_batch, spec.seq_len
    mult = 4.0 if spec.kind == "train" else 1.0
    total = 0.0

    n_attn = sum(1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "attn")
    n_mamba = sum(1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "mamba")
    n_mlstm = sum(1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "mlstm")
    n_slstm = sum(1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "slstm")

    # attention q-chunk scan (active when S > 2048)
    if n_attn and S > 2048:
        trips = S // Q_CHUNK
        # per chunk: scores + out einsums (2 x 2BCS*qdim) + softmax (~6BHCS)
        body = (4.0 * B * Q_CHUNK * S * cfg.q_dim
                + 6.0 * B * cfg.num_heads * Q_CHUNK * S)
        heads_sharded = cfg.num_kv_heads % 16 == 0
        body_dev = body / chips if heads_sharded else body / (chips / 16)
        total += n_attn * (trips - 1) * body_dev
        if cfg.encoder_layers and S > 2048:
            total += cfg.encoder_layers * (trips - 1) * body_dev

    # mamba chunked selective scan
    if n_mamba:
        q = SSM_CHUNK
        trips = S // q
        di, n = cfg.d_inner, cfg.mamba_d_state
        body = (2.0 * B * q * di * n            # y = h . C einsum
                + (4.0 * 8 + 5.0) * B * q * di * n)  # assoc scan + h_t
        total += n_mamba * (trips - 1) * body / chips
    # mLSTM chunkwise-parallel scan
    if n_mlstm:
        q = MLSTM_CHUNK
        trips = S // q
        hq, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
        body = (6.0 * B * hq * q * q * dh       # scores/h_intra/n_intra
                + 4.0 * B * hq * q * dh * dh    # h_inter + C_new
                + 12.0 * B * hq * q * q)        # D/exp elementwise
        total += n_mlstm * (trips - 1) * body / chips
    # sLSTM time scan (inherently sequential)
    if n_slstm:
        hq, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
        body = 2.0 * B * hq * dh * 4 * dh + 24.0 * B * hq * dh
        total += n_slstm * (S - 1) * body / chips
    total *= mult
    # loss-chunk scan (train only; stays a lax.scan even when unrolled so
    # the embedding-grad all-reduce is counted once, as in production):
    # logits matmul 2BSdV, x4 for fwd + remat recompute + ~2x bwd
    if spec.kind == "train":
        chunk = 512
        nc = -(-S // chunk)
        body = 4.0 * 2.0 * B * chunk * cfg.d_model * cfg.vocab_size
        total += (nc - 1) * body / chips
    return total


def model_flops(arch: str, shape_name: str) -> float:
    """6*N_active*D (dense/MoE) plus the inherent attention score/output
    FLOPs (which 6ND omits and which dominate >=32k prefill)."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    n_active = cfg.active_params_per_token()
    B, S = spec.global_batch, spec.seq_len

    def attn_flops_per_seq(s_ctx):
        """fwd score+output matmul FLOPs for one full sequence."""
        total = 0.0
        for i in range(cfg.num_layers):
            if cfg.layer_kind(i) != "attn":
                continue
            win = cfg.window_size if cfg.layer_is_local_attn(i) else 0
            # causal: sum_t min(t, win or t) ~ s*s/2 (or s*win)
            pairs = s_ctx * min(win, s_ctx) if win else s_ctx * s_ctx / 2.0
            total += 4.0 * pairs * cfg.q_dim
        for _ in range(cfg.encoder_layers):
            total += 4.0 * s_ctx * s_ctx * cfg.q_dim  # bidirectional
        return total

    if spec.kind == "train":
        tokens = B * S
        return 6.0 * n_active * tokens + 3.0 * B * attn_flops_per_seq(S)
    if spec.kind == "prefill":
        tokens = B * S
        return 2.0 * n_active * tokens + B * attn_flops_per_seq(S)
    # decode: one token per sequence; attention reads the S-deep cache
    dec_attn = 0.0
    for i in range(cfg.num_layers):
        if cfg.layer_kind(i) == "attn":
            win = cfg.window_size if cfg.layer_is_local_attn(i) else 0
            ctx = min(win, S) if win else S
            dec_attn += 4.0 * ctx * cfg.q_dim
    return 2.0 * n_active * B + B * dec_attn


def model_hbm_bytes(arch: str, shape_name: str, chips: int) -> float:
    """Analytic per-device HBM traffic lower-bound estimate (bytes/step).

    The HLO 'bytes accessed' from the CPU-backend compile wildly overstates
    TPU HBM traffic (CPU fusion is far weaker), so the memory roofline term
    uses this model: weights read once per pass (x3 passes for train with
    remat: fwd, recompute, bwd) + grad write + opt state rw + activation
    checkpoints rw + KV/state reads for decode.  Documented in
    EXPERIMENTS.md §Roofline.
    """
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    B, S = spec.global_batch, spec.seq_len
    p_total = cfg.total_params()
    w_bytes = 2.0 * p_total / chips               # bf16 shard
    d = cfg.d_model
    if spec.kind == "train":
        acts = 2.0 * B * S * d * (cfg.num_layers / max(cfg.scan_period, 1)) \
            * 2 / chips                           # period-boundary checkpoints rw
        opt = 2.0 * (4.0 if arch not in ("llama4-maverick-400b-a17b",
                                         "jamba-v0.1-52b") else 1.03) \
            * p_total / chips                     # m+v read+write
        return 3.0 * w_bytes + 2.0 * w_bytes + opt + acts  # 3 passes + grads
    if spec.kind == "prefill":
        acts = 2.0 * B * S * d * cfg.num_layers / chips
        return w_bytes + acts
    # decode: weights + full KV/recurrent state read
    kv = 0.0
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            win = cfg.window_size if cfg.layer_is_local_attn(i) else 0
            ctx = min(win, S) if win else S
            kv += 2.0 * B * ctx * cfg.kv_dim * 2
        elif kind == "mamba":
            kv += 4.0 * B * cfg.d_inner * cfg.mamba_d_state
        elif kind in ("mlstm", "slstm"):
            dh = d // cfg.num_heads
            kv += 4.0 * B * cfg.num_heads * dh * (dh if kind == "mlstm" else 4)
    return w_bytes + kv / chips


def analyze(rec: dict) -> dict:
    chips = 1
    for d in rec["mesh"]:
        chips *= d
    flops_dev = rec["cost"]["flops"] or 0.0          # per-device (SPMD module)
    bytes_dev = rec["cost"]["bytes_accessed"] or 0.0
    wire = rec["collectives"]["total_wire_bytes"]    # per-device program
    corr = 0.0
    if rec.get("unrolled"):
        corr = _inner_scan_correction(rec["arch"], rec["shape"], chips)
        flops_dev += corr
    compute_t = flops_dev / PEAK_FLOPS
    hbm_model = model_hbm_bytes(rec["arch"], rec["shape"], chips)
    memory_t = hbm_model / HBM_BW                 # analytic TPU HBM model
    memory_hlo_t = bytes_dev / HBM_BW             # CPU-fusion upper bound
    coll_t = wire / LINK_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(flops_dev * chips, 1e-30)
    step_t = max(terms.values())
    ideal_t = mf / (chips * PEAK_FLOPS)
    return dict(
        arch=rec["arch"], shape=rec["shape"],
        mesh="x".join(str(d) for d in rec["mesh"]),
        compute_s=compute_t, memory_s=memory_t, collective_s=coll_t,
        memory_hlo_s=memory_hlo_t,
        dominant=dominant, model_flops=mf, hlo_flops_global=flops_dev * chips,
        inner_scan_corr_flops=corr,
        useful_ratio=useful,
        roofline_fraction=ideal_t / max(step_t, 1e-30),
        trip_corrected=bool(rec.get("unrolled")),
        memory_gib=dict(rec["memory"]),
    )


def load_all(out_dir: str = "results/dryrun", single_pod_only: bool = True):
    """Prefer unrolled (trip-count-exact) records per (arch, shape, mesh)."""
    best: dict[tuple, dict] = {}
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("skipped") or not rec.get("ok"):
            continue
        if single_pod_only and rec.get("multi_pod"):
            continue
        key = (rec["arch"], rec["shape"], rec.get("multi_pod", False))
        if key in best and best[key].get("unrolled") and not rec.get("unrolled"):
            continue
        best[key] = rec
    return [analyze(r) for r in best.values()]


def markdown_table(out_dir: str = "results/dryrun") -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(load_all(out_dir), key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_ratio']:.3f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Fused query-path roofline (DESIGN.md Sec. 11)
#
# The per-(query,table)-row candidate pipeline — bucket gather -> score ->
# top-m — has a closed-form byte/FLOP model.  The staged path materialises
# the [r, P*KC, D] gather in HBM (write + re-read by the scorer) on top of
# the payload gather itself; the fused mega-kernel streams each bucket
# block through VMEM exactly once.  Bit-packed hamming payloads shrink the
# dominant payload term by 4*D / (4*ceil(k*L/32)).
# ---------------------------------------------------------------------------

QUERY_PEAKS = {
    # per-host peaks for placing the query kernel on a roofline; the cpu
    # numbers are order-of-magnitude for a multithreaded XLA host and exist
    # so roofline_frac stays meaningful in CI, not as a precise target
    "cpu": dict(flops=2.0e11, bw=3.0e10),
    "tpu_v5_lite": dict(flops=PEAK_FLOPS, bw=HBM_BW),
    "*": dict(flops=PEAK_FLOPS, bw=HBM_BW),
}


def _query_peaks(kind: str | None = None) -> dict:
    from repro.kernels import autotune

    kind = kind or autotune.device_kind()
    if kind in QUERY_PEAKS:
        return QUERY_PEAKS[kind]
    return QUERY_PEAKS["cpu" if kind == "cpu" else "*"]


def query_model(*, r: int, p: int, kc: int, payload_bytes: int, m: int,
                score: str = "dot", fused: bool = True,
                kind: str | None = None) -> dict:
    """Analytic bytes/FLOPs/time for one query-path batch.

    r probe rows (queries x tables), p probes each, kc candidate slots per
    bucket, payload_bytes per slot (4*D for f32 dot, 4*ceil(k*L/32) for
    packed hamming).
    """
    q_bytes = r * payload_bytes
    pay = float(r) * p * kc * payload_bytes   # bucket payload gather
    ids = float(r) * p * kc * 4               # candidate id words
    outs = r * m * 8                          # top-m ids + scores
    if fused:
        bytes_total = pay + ids + q_bytes + outs
    else:
        # gather materialises in HBM (write) and the scorer re-reads it
        bytes_total = 3.0 * pay + 2.0 * ids + q_bytes + outs
    lanes = payload_bytes / 4.0               # f32 dims or uint32 words
    if score == "dot":
        flops = 2.0 * r * p * kc * lanes
    else:
        flops = 16.0 * r * p * kc * lanes     # xor + SWAR popcount ops/word
    pk = _query_peaks(kind)
    t_mem = bytes_total / pk["bw"]
    t_comp = flops / pk["flops"]
    return dict(bytes=bytes_total, flops=flops, t_mem=t_mem, t_comp=t_comp,
                t_model=max(t_mem, t_comp),
                bound="memory" if t_mem >= t_comp else "compute")


def _bench(f, *args, reps=3):
    import time as _time

    import jax

    out = f(*args)
    jax.block_until_ready(out)
    t0 = _time.time()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (_time.time() - t0) / reps * 1e6


def _query_shapes(smoke: bool):
    # k=12, L=4 -> 2 packed words vs 128 f32 payload dims; the smoke
    # shape is the smallest where the packed-payload memory win is still
    # visible over dispatch overhead on a CPU host
    if smoke:
        return dict(t=2, nb=128, c=32, d=128, r=64, p=6, m=10, k=12, L=4)
    return dict(t=4, nb=256, c=64, d=128, r=128, p=8, m=10, k=12, L=4)


def _query_inputs(s: dict):
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(11)
    tnb = s["t"] * s["nb"]
    ids = rng.integers(0, 10_000, size=(tnb, s["c"])).astype(np.int32)
    ids[rng.random(ids.shape) < 0.3] = -1
    pay = rng.standard_normal((tnb, s["c"], s["d"])).astype(np.float32)
    pay[ids < 0] = 0.0
    w = -(-(s["k"] * s["L"]) // 32)
    payw = rng.integers(0, 2**32, size=(tnb, s["c"], w), dtype=np.uint32)
    payw[ids < 0] = 0
    q = rng.standard_normal((s["r"], s["d"])).astype(np.float32)
    qw = rng.integers(0, 2**32, size=(s["r"], w), dtype=np.uint32)
    fb = rng.integers(0, tnb, size=(s["r"], s["p"])).astype(np.int32)
    meta = np.stack(
        [np.full(s["r"], (1 << s["p"]) - 1, np.int32),
         np.full(s["r"], -1, np.int32)], axis=1)
    return {k: jnp.asarray(v) for k, v in dict(
        ids=ids, pay=pay, payw=payw, q=q, qw=qw, fb=fb, meta=meta).items()}


def query_rows(smoke: bool = False):
    """Measured staged/fused query-path rows with model roofline fractions.

    On CPU hosts the fused Pallas rows run in interpret mode (correctness
    path, labelled as such); the staged rows are jit'd XLA, so the
    packed-hamming-over-dot ratio is a real measured speedup.
    """
    from functools import partial

    import jax

    from repro.kernels import ops, ref

    s = _query_shapes(smoke)
    v = _query_inputs(s)
    w = v["payw"].shape[-1]
    shared = (f"r={s['r']};P={s['p']};KC={s['c']};D={s['d']};"
              f"W={w};m={s['m']}")

    staged_dot = jax.jit(partial(ref.fused_query_ref, m=s["m"]))
    staged_ham = jax.jit(partial(ref.fused_query_ref, m=s["m"],
                                 score="hamming"))
    us_dot = _bench(staged_dot, v["ids"], v["pay"], v["q"], v["fb"],
                    v["meta"])
    us_ham = _bench(staged_ham, v["ids"], v["payw"], v["qw"], v["fb"],
                    v["meta"])

    def frac(us, *, payload_bytes, score, fused):
        mdl = query_model(r=s["r"], p=s["p"], kc=s["c"],
                          payload_bytes=payload_bytes, m=s["m"],
                          score=score, fused=fused)
        return mdl["t_model"] * 1e6 / max(us, 1e-9), mdl["bound"]

    f_dot, b_dot = frac(us_dot, payload_bytes=4 * s["d"], score="dot",
                        fused=False)
    f_ham, b_ham = frac(us_ham, payload_bytes=4 * w, score="hamming",
                        fused=False)
    out = [
        (f"roofline/query_staged_dot_{s['r']}r", us_dot,
         f"roofline_frac={f_dot:.3f};bound={b_dot};{shared}"),
        (f"roofline/query_staged_hamming_{s['r']}r", us_ham,
         f"packed_over_dot={us_dot / us_ham:.3f}x;"
         f"roofline_frac={f_ham:.3f};bound={b_ham};{shared}"),
    ]

    fused_fn = partial(ops.fused_query, m=s["m"])
    us_f = _bench(lambda *a: fused_fn(*a), v["ids"], v["pay"], v["q"],
                  v["fb"], v["meta"], reps=1 if smoke else 2)
    f_f, b_f = frac(us_f, payload_bytes=4 * s["d"], score="dot", fused=True)
    mode = "interpret" if jax.default_backend() == "cpu" else "compiled"
    out.append(
        (f"roofline/query_fused_dot_{s['r']}r", us_f,
         f"fused_over_staged={us_dot / us_f:.3f}x;mode={mode};"
         f"roofline_frac={f_f:.3f};bound={b_f};{shared}"))
    return out


def _routed_query_inputs(s: dict):
    """The routed (post-all_to_all) block shape: n*cap rows — 4x the
    1-node row count here — where a fill fraction of rows carries probe
    word 0 (the mesh send buffers pad to capacity; overflow/fill rows
    reach the kernel masked-out, not absent)."""
    import jax.numpy as jnp
    import numpy as np

    sr = dict(s, r=4 * s["r"])
    v = _query_inputs(sr)
    meta = np.asarray(v["meta"]).copy()
    meta[3 * s["r"]:, 0] = 0  # fill rows: no valid probes
    return sr, {**v, "meta": jnp.asarray(meta)}


def sweep_fused(write_cache: bool = True, smoke: bool = False,
                routed: bool = False):
    """(TB, KC) autotune sweep for the fused query kernel on this host.

    Times ops.fused_query across a block-shape grid on the representative
    query-path shape and records the winner in the autotune cache keyed by
    device kind (kernels/autotune.py), so runtime dispatch picks it up.
    With ``routed=True`` the sweep runs the routed mesh stage's block
    shape instead — n*cap rows with a fill-row tail — and records the
    winner under "fused_query_routed", the key the mesh dispatch consults.
    """
    from functools import partial

    from repro.kernels import autotune, ops

    if routed:
        s, v = _routed_query_inputs(_query_shapes(smoke))
        tune_op = "fused_query_routed"
    else:
        s = _query_shapes(smoke)
        v = _query_inputs(s)
        tune_op = "fused_query"
    grid_tb = (4, 8) if smoke else (4, 8, 16)
    grid_kc = (8, 16) if smoke else (8, 16, 32, 64)
    best, best_us = None, float("inf")
    for tb in grid_tb:
        for kc in grid_kc:
            fn = partial(ops.fused_query, m=s["m"], tb=tb, kc=kc)
            us = _bench(lambda *a: fn(*a), v["ids"], v["pay"], v["q"],
                        v["fb"], v["meta"], reps=1 if smoke else 2)
            print(f"# sweep {tune_op} tb={tb} kc={kc}: {us:.0f}us")
            if us < best_us:
                best, best_us = dict(tb=tb, kc=kc), us
    path = autotune.put(tune_op, best) if write_cache else None
    return path, best, best_us


def rows(out_dir: str = "results/dryrun", smoke: bool = False):
    out = []
    for r in load_all(out_dir):
        out.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"dom={r['dominant']};comp_s={r['compute_s']:.2e};"
            f"mem_s={r['memory_s']:.2e};coll_s={r['collective_s']:.2e};"
            f"useful={r['useful_ratio']:.3f};"
            f"roofline_frac={r['roofline_fraction']:.3f}"))
    out.extend(query_rows(smoke=smoke))
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / single rep (CI)")
    ap.add_argument("--sweep", action="store_true",
                    help="run the (TB, KC) autotune sweep and cache the "
                         "winner for this device kind")
    args = ap.parse_args()
    if args.sweep:
        for routed in (False, True):
            path, best, best_us = sweep_fused(smoke=args.smoke,
                                              routed=routed)
            op = "fused_query_routed" if routed else "fused_query"
            print(f"# autotune winner {op} {best} ({best_us:.0f}us)"
                  f" -> {path}")
    for name, us, derived in query_rows(smoke=args.smoke):
        print(f"{name},{us:.2f},{derived}")
    table = markdown_table()
    if table.count("\n") > 1:
        print(table)
