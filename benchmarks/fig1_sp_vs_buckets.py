"""Paper Fig. 1: analytical SP vs number of searched buckets (k=12).

LSH searching N exact buckets vs NearBucket-LSH searching the same N
buckets as L=(N/13) exact+near groups.  Emits CSV rows; `derived` is the
max SP gap (LSH - NB) over the curve — positive == paper's claim."""

import numpy as np

from repro.core import analysis


def rows():
    k = 12
    out = []
    for l_nb in (1, 10, 100):
        buckets = l_nb * (1 + k)
        t = np.linspace(0.0, 1.0, 101)
        s = analysis.angular_from_cosine(t)
        lsh = analysis.sp_lsh(s, k, buckets)
        nb = analysis.sp_nearbucket(s, k, l_nb)
        gap = float(np.max(lsh - nb))
        out.append((f"fig1/buckets={buckets}", gap,
                    f"sp_lsh@t0.5={analysis.sp_lsh(analysis.angular_from_cosine(0.5), k, buckets):.4f}"
                    f";sp_nb@t0.5={analysis.sp_nearbucket(analysis.angular_from_cosine(0.5), k, l_nb):.4f}"))
    return out
