"""Online serving benchmark (DESIGN.md Sec. 7): frontend throughput vs
dispatch granularity and offered load, and the cache's message saving.

Cells:
  * serve/one_at_a_time    — max_batch=1, cache off: every arrival is its
                             own jit dispatch (the no-batcher baseline);
  * serve/batched          — max_batch=64, cache off: the dynamic batcher
                             coalescing the same workload (derived reports
                             the speedup — the >= 5x acceptance cell) at
                             identical recall (ids are bit-identical, so
                             recall is equal BY CONSTRUCTION; both are
                             still measured and reported);
  * serve/offered=N        — closed-loop load sweep: qps / p99 / counted
                             admission rejects as offered load rises;
  * serve/cache_zipf       — repeated-query workload: hit rate and
                             measured messages/query vs the Table-1
                             closed form (cache hits cost zero network);
  * serve/obs_overhead     — the SAME batched workload with full
                             observability (spans + flight records) vs
                             bare, interleaved best-of runs: the derived
                             ``obs_on_over_obs_off`` qps ratio is the
                             near-zero-overhead acceptance cell
                             (check_regression.py floors it at 0.95);
  * serve/openloop_sync,
    serve/openloop_pipelined — open-loop rate ladder UNDER LIVE CHURN
                             (Poisson arrivals, latency measured from the
                             arrival schedule): max offered qps whose p99
                             meets a fixed SLO with nothing shed, plus
                             the full qps-vs-p99 knee curve per mode;
  * serve/pipeline_speedup — the gated derived ratio ``pipe_over_sync``:
                             pipelined+background-writer max-qps-at-SLO
                             over sync+inline-churn.  On a 1-core host
                             pipelining cannot raise RAW throughput (work
                             conservation) — the architectural win is the
                             tail under churn: inline prep lands as one
                             contiguous serving stall, the writer preps
                             off-thread in device-queue-bounded chunks
                             and installs at a stage boundary.

Cells additionally publish ``bench_dropped_probes`` /
``bench_nodes_contacted`` gauges (labeled by row) into the obs metrics
registry; ``run.py --json`` copies them into the row objects.
"""

from __future__ import annotations

import gc
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    DenseCorpus, EngineConfig, LshEngine, LshParams, make_hyperplanes,
    metrics,
)
from repro.core.hashing import sketch_codes_batched
from repro.core.store import build_store_host, expire, insert_batch
from repro.obs import Observability
from repro.obs.registry import REGISTRY
from repro.serve import (
    ChurnWriter, FrontendConfig, RetrievalFrontend, RuntimeBackend,
    max_qps_at_slo,
)

# shapes chosen so the serving-layer effect is measurable on CPU: small
# buckets (k=12, capacity 8) keep per-query score work light, so the fixed
# per-dispatch overhead dominates one-at-a-time serving and the batcher's
# amortization shows as a real throughput multiple.
N, D, K, L, M = 20000, 32, 12, 4, 10
CAPACITY = 8
NQ = 256          # workload size for the throughput cells
POOL = 64         # distinct queries in the cache cell
CACHE_ARRIVALS = 512


def _build(seed=0):
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((N, D)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    params = LshParams(d=D, k=K, L=L, seed=seed + 1)
    h = make_hyperplanes(params)
    codes = sketch_codes_batched(jnp.asarray(emb), h)
    store = build_store_host(codes, params.num_buckets, capacity=CAPACITY)
    engine = LshEngine(params, h, store, DenseCorpus(jnp.asarray(emb)), None,
                       EngineConfig(variant="cnb"))
    return emb, engine


def _exact_ideal(emb, qrows, m):
    sims = emb[qrows] @ emb.T
    sims[np.arange(len(qrows)), qrows] = -np.inf
    return np.argsort(-sims, axis=1)[:, :m].astype(np.int32)


def _serve_all(frontend, emb, qrows, offered):
    """Closed-loop: submit `offered` per tick, one step per tick; returns
    wall seconds for the served stream."""
    t0 = time.perf_counter()
    sent = 0
    while sent < len(qrows) or frontend.pending:
        for row in qrows[sent:sent + offered]:
            frontend.submit(emb[row], exclude=int(row))
        sent = min(sent + offered, len(qrows))
        frontend.step()
    frontend.flush()
    return time.perf_counter() - t0


def rows():
    emb, engine = _build()
    rng = np.random.default_rng(7)
    qrows = rng.integers(0, N, size=NQ)
    ideal = _exact_ideal(emb, qrows, M)
    backend = RuntimeBackend(engine)
    out = []

    def fresh(max_batch, cache, queue=512, obs=None):
        return RetrievalFrontend(
            backend,
            FrontendConfig(m=M, max_batch=max_batch, queue_capacity=queue,
                           cache=cache),
            obs=obs,
        )

    def publish(row, stats):
        s = stats.summary()
        REGISTRY.gauge("bench_dropped_probes").set(
            s["dropped_probes"], row=row)
        REGISTRY.gauge("bench_nodes_contacted").set(
            s["nodes_contacted_per_query"], row=row)

    # warm both dispatch shapes once so the cells time serving, not tracing
    fresh(1, False).search(emb[qrows[:2]], exclude=qrows[:2])
    fresh(64, False).search(emb[qrows[:65]], exclude=qrows[:65])

    # -- one-at-a-time vs batched (best of 2 — first pass absorbs any
    # remaining cold-start noise; ids come from the timed pass) --------------
    def timed(max_batch, offered):
        best, ids, stats = np.inf, None, None
        for _ in range(2):
            fe = fresh(max_batch, False)
            dt = _serve_all(fe, emb, qrows, offered=offered)
            ids = np.stack(
                [fe.poll(t)[0] for t in range(fe.stats.completed)]
            )  # tickets are 0..NQ-1 in submit order on a fresh frontend
            best = min(best, dt)
            stats = fe.stats
        return best, ids, stats

    dt1, ids1, st1 = timed(1, offered=1)
    rec1 = metrics.recall_at_m(ids1, ideal)
    publish("serve/one_at_a_time", st1)
    out.append(("serve/one_at_a_time", dt1 / NQ * 1e6,
                f"qps={NQ/dt1:.0f};recall={rec1:.3f}"))

    dtB, idsB, stB = timed(64, offered=64)
    recB = metrics.recall_at_m(idsB, ideal)
    publish("serve/batched_64", stB)
    out.append(("serve/batched_64", dtB / NQ * 1e6,
                f"qps={NQ/dtB:.0f};recall={recB:.3f};"
                f"speedup_vs_one_at_a_time={dt1/dtB:.1f}x;"
                f"ids_identical={bool(np.array_equal(ids1, idsB))}"))

    # -- offered-load sweep (fixed service rate, queue=128) -------------------
    for offered in (4, 16, 64, 256):
        fe = fresh(32, False, queue=128)
        dt = _serve_all(fe, emb, qrows, offered=offered)
        s = fe.stats.summary()
        served = s["completed"]
        publish(f"serve/offered={offered}", fe.stats)
        out.append((
            f"serve/offered={offered}", dt / max(served, 1) * 1e6,
            f"qps={served/dt:.0f};p99_us={s['p99_us']:.0f};"
            f"rejected={s['rejected']};mean_batch={s['mean_batch']:.1f}"))

    # -- repeated-query workload: the cache cell ------------------------------
    pool = rng.integers(0, N, size=POOL)
    w = 1.0 / (np.arange(POOL) + 1.0)
    arrivals = pool[rng.choice(POOL, size=CACHE_ARRIVALS, p=w / w.sum())]
    fe = fresh(32, True)
    dt = _serve_all(fe, emb, arrivals, offered=32)
    s = fe.stats.summary()
    closed = backend.cost().messages
    publish("serve/cache_zipf", fe.stats)
    out.append((
        "serve/cache_zipf", dt / CACHE_ARRIVALS * 1e6,
        f"hit_rate={s['hit_rate']:.2f};"
        f"messages_per_query={s['messages_per_query']:.1f};"
        f"closed_form_no_cache={closed:.1f};"
        f"qps={CACHE_ARRIVALS/dt:.0f}"))

    # -- observability overhead: the batched workload, bare vs fully
    # traced.  The true overhead is small (~2% of a ~40ms run), so the
    # estimator has to survive shared-runner noise that dwarfs it.  Three
    # defenses, each against a failure mode actually observed on 1-core
    # CI-like hosts:
    #   * pairs ALTERNATE in-pair order (off-then-on, on-then-off):
    #     monotonic drift otherwise penalizes whichever side always runs
    #     second (~5% phantom overhead);
    #   * each block's ratio is the MEDIAN of its pair ratios: one
    #     descheduled run can't swing it the way a best-of-minima
    #     quotient can;
    #   * the gated ratio is the MAX over independent blocks: the floor
    #     is a one-sided gate ("is obs provably costing > 5%?"), so it
    #     should only fail on evidence that REPLICATES across blocks —
    #     contended stretches last seconds and poison whole blocks at a
    #     time.  A real obs regression depresses every block.
    # 2x the workload of the other cells so per-pair noise amortizes.
    qrows2 = np.concatenate([qrows, qrows])

    def run_off():
        return _serve_all(fresh(64, False), emb, qrows2, offered=64)

    def run_on():
        # fresh ring per run: steady-state recording
        fe = fresh(64, False, obs=Observability())
        return fe, _serve_all(fe, emb, qrows2, offered=64)

    # pyperf-style GC isolation: by this point the harness has run whole
    # suites and carries a big heap, so collector passes triggered by the
    # obs side's extra allocations scan 100k+ unrelated objects — a GC
    # amplification that bills obs for heap it didn't build (measured as
    # a ~5% phantom slowdown).  Freeze moves the existing heap out of
    # the collector's reach; disable stops allocation-count collections
    # during the timed region.
    gc.collect()
    gc.freeze()
    gc.disable()
    try:
        block_medians, best_off, best_on = [], np.inf, np.inf
        for block in range(3):
            ratios = []
            for it in range(4):
                if (block * 4 + it) % 2 == 0:
                    dt_off = run_off()
                    fe_on, dt_on = run_on()
                else:
                    fe_on, dt_on = run_on()
                    dt_off = run_off()
                ratios.append(dt_off / dt_on)  # qps_on / qps_off, this pair
                best_off = min(best_off, dt_off)
                best_on = min(best_on, dt_on)
            block_medians.append(float(np.median(ratios)))
    finally:
        gc.enable()
        gc.unfreeze()
    obs = fe_on.obs
    ratio = max(block_medians)
    nq2 = len(qrows2)
    publish("serve/obs_overhead", fe_on.stats)
    out.append((
        "serve/obs_overhead", best_on / nq2 * 1e6,
        f"obs_on_over_obs_off={ratio:.3f}x;"
        f"qps_on={nq2/best_on:.0f};qps_off={nq2/best_off:.0f};"
        f"spans={len(obs.tracer.events())};"
        f"flight_records={len(obs.flight)}"))

    # -- open-loop under live churn: max qps at a fixed p99 SLO ---------------
    # Both modes serve the SAME Poisson schedules (latency measured from
    # the arrival SCHEDULE — coordinated omission counts against the
    # server) and run the SAME write epoch every PERIOD_S: drift 2% of
    # the corpus, re-sketch, re-announce every id through chunked
    # insert_batch + expire.  Only the ARCHITECTURE differs:
    #   sync      — depth 1, the epoch runs inline on the serving thread,
    #               so its full cost lands as one contiguous stall and
    #               the queue behind it must drain;
    #   pipelined — depth 2 + background ChurnWriter: prep runs
    #               off-thread, each chunk bounds its device-queue
    #               occupancy so serving dispatches interleave between
    #               chunks, and the install is a stage-boundary pointer
    #               swap + generation bump.
    # On one core the two modes spend identical total CPU; the SLO knee
    # separates because inline concentrates the cost into a p99-sized
    # spike while the writer spreads it below the SLO.
    store0, hp = engine.store, engine.hyperplanes
    corpus0 = DenseCorpus(jnp.asarray(emb))
    SLO_MS = 85.0        # ~1.5x the measured inline epoch stall: sync passes
    #                      below its collapse, with margin over the stall
    #                      noise band (p99 55-80ms) on a contended host
    PERIOD_S = 0.25      # write epoch cadence (~22% duty at these shapes)
    CHUNK = 2500         # rows per insert_batch device call
    N_ARRIVALS = 8000
    FRACS = (0.35, 0.55, 0.75, 0.95)   # rate ladder, fractions of capacity

    def ol_fresh(depth):
        return RetrievalFrontend(backend, FrontendConfig(
            m=M, max_batch=64, queue_capacity=2048, cache=False,
            pipeline_depth=depth))

    # warm every pow2 dispatch shape: open-loop staging is greedy, so
    # partial batches of any grid size are dispatched mid-run
    wfe = ol_fresh(1)
    b = 1
    while b <= 64:
        wfe.search(emb[rng.integers(0, N, size=b)])
        b *= 2

    # capacity probe: full batches, bare frontend (one untimed pass)
    meter = ol_fresh(1)
    wq = emb[rng.integers(0, N, size=64)]
    meter.search(wq)
    t0 = time.perf_counter()
    for _ in range(5):
        meter.search(wq)
    cap = 64 * 5 / (time.perf_counter() - t0)

    class _Epochs:
        """One trial's churn chain.  Chains from a snapshot copy — the
        donation contract (`repro.serve.writer`): insert_batch/expire
        donate their input, and the previous epoch's store is the LIVE
        serving one."""

        def __init__(self):
            self.store = store0
            self.emb = emb.copy()
            self.n = 0

        def prep(self):
            self.n += 1
            r = np.random.default_rng(self.n)
            upd = r.choice(N, N // 50, replace=False)
            e = self.emb
            e[upd] += 0.5 * r.standard_normal((len(upd), D)).astype(np.float32)
            e[upd] /= np.linalg.norm(e[upd], axis=1, keepdims=True)
            c = sketch_codes_batched(jnp.asarray(e), hp)
            s = jax.tree.map(jnp.copy, self.store)
            ids = np.arange(N, dtype=np.int32)
            for lo in range(0, N, CHUNK):
                s = insert_batch(s, jnp.asarray(ids[lo:lo + CHUNK]),
                                 c[lo:lo + CHUNK], jnp.int32(self.n))
                jax.block_until_ready(s)  # bound device-queue occupancy
            s = expire(s, jnp.int32(self.n), ttl=4)
            jax.block_until_ready(s)
            self.store = s
            return dict(store=s, corpus=DenseCorpus(jnp.asarray(e)))

    _Epochs().prep()  # compile the chunked prep path outside the ladder

    def make_frontend(depth):
        def build():
            backend.update(store=store0, corpus=corpus0)  # pristine state
            return ol_fresh(depth)
        return build

    def make_tick_factory(use_writer):
        def make_tick(fe):
            ep = _Epochs()
            w = ChurnWriter(fe) if use_writer else None
            state = {"next": PERIOD_S}

            def tick(now):
                if now >= state["next"]:
                    state["next"] += PERIOD_S
                    if w is None:
                        fe.apply_update(**ep.prep())  # inline stall
                    else:
                        w.submit(ep.prep)
            return tick
        return make_tick

    rates = np.asarray(FRACS) * cap
    scores = {}
    for mode, depth, use_writer in (("sync", 1, False), ("pipelined", 2, True)):
        best, knee = max_qps_at_slo(
            make_frontend(depth), emb, rates, p99_slo_ms=SLO_MS,
            n_arrivals=N_ARRIVALS, seed=11, trials=2,
            make_tick=make_tick_factory(use_writer))
        # degenerate guard: a mode that passes NO rung scores half the
        # lowest rung so the gated ratio stays finite
        scores[mode] = best if best > 0 else float(rates[0]) / 2
        top_p99 = next((p for r, p, _ in knee if r == best), knee[0][1])
        kstr = " ".join(f"{r:.0f}:{p:.1f}/{s}" for r, p, s in knee)
        out.append((
            f"serve/openloop_{mode}", top_p99 * 1e3,
            f"max_qps_at_slo={best:.0f};slo_p99_ms={SLO_MS:.0f};"
            f"knee[qps:p99ms/shed]={kstr}"))

    ratio = scores["pipelined"] / scores["sync"]
    out.append((
        "serve/pipeline_speedup", 0.0,
        f"pipe_over_sync={ratio:.2f}x;"
        f"sync_qps_at_slo={scores['sync']:.0f};"
        f"pipe_qps_at_slo={scores['pipelined']:.0f};"
        f"slo_p99_ms={SLO_MS:.0f};capacity_qps={cap:.0f}"))
    return out
