"""Online serving benchmark (DESIGN.md Sec. 7): frontend throughput vs
dispatch granularity and offered load, and the cache's message saving.

Cells:
  * serve/one_at_a_time    — max_batch=1, cache off: every arrival is its
                             own jit dispatch (the no-batcher baseline);
  * serve/batched          — max_batch=64, cache off: the dynamic batcher
                             coalescing the same workload (derived reports
                             the speedup — the >= 5x acceptance cell) at
                             identical recall (ids are bit-identical, so
                             recall is equal BY CONSTRUCTION; both are
                             still measured and reported);
  * serve/offered=N        — closed-loop load sweep: qps / p99 / counted
                             admission rejects as offered load rises;
  * serve/cache_zipf       — repeated-query workload: hit rate and
                             measured messages/query vs the Table-1
                             closed form (cache hits cost zero network);
  * serve/obs_overhead     — the SAME batched workload with full
                             observability (spans + flight records) vs
                             bare, interleaved best-of runs: the derived
                             ``obs_on_over_obs_off`` qps ratio is the
                             near-zero-overhead acceptance cell
                             (check_regression.py floors it at 0.95).

Cells additionally publish ``bench_dropped_probes`` /
``bench_nodes_contacted`` gauges (labeled by row) into the obs metrics
registry; ``run.py --json`` copies them into the row objects.
"""

from __future__ import annotations

import gc
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (
    DenseCorpus, EngineConfig, LshEngine, LshParams, make_hyperplanes,
    metrics,
)
from repro.core.hashing import sketch_codes_batched
from repro.core.store import build_store_host
from repro.obs import Observability
from repro.obs.registry import REGISTRY
from repro.serve import FrontendConfig, RetrievalFrontend, RuntimeBackend

# shapes chosen so the serving-layer effect is measurable on CPU: small
# buckets (k=12, capacity 8) keep per-query score work light, so the fixed
# per-dispatch overhead dominates one-at-a-time serving and the batcher's
# amortization shows as a real throughput multiple.
N, D, K, L, M = 20000, 32, 12, 4, 10
CAPACITY = 8
NQ = 256          # workload size for the throughput cells
POOL = 64         # distinct queries in the cache cell
CACHE_ARRIVALS = 512


def _build(seed=0):
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((N, D)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    params = LshParams(d=D, k=K, L=L, seed=seed + 1)
    h = make_hyperplanes(params)
    codes = sketch_codes_batched(jnp.asarray(emb), h)
    store = build_store_host(codes, params.num_buckets, capacity=CAPACITY)
    engine = LshEngine(params, h, store, DenseCorpus(jnp.asarray(emb)), None,
                       EngineConfig(variant="cnb"))
    return emb, engine


def _exact_ideal(emb, qrows, m):
    sims = emb[qrows] @ emb.T
    sims[np.arange(len(qrows)), qrows] = -np.inf
    return np.argsort(-sims, axis=1)[:, :m].astype(np.int32)


def _serve_all(frontend, emb, qrows, offered):
    """Closed-loop: submit `offered` per tick, one step per tick; returns
    wall seconds for the served stream."""
    t0 = time.perf_counter()
    sent = 0
    while sent < len(qrows) or frontend.pending:
        for row in qrows[sent:sent + offered]:
            frontend.submit(emb[row], exclude=int(row))
        sent = min(sent + offered, len(qrows))
        frontend.step()
    frontend.flush()
    return time.perf_counter() - t0


def rows():
    emb, engine = _build()
    rng = np.random.default_rng(7)
    qrows = rng.integers(0, N, size=NQ)
    ideal = _exact_ideal(emb, qrows, M)
    backend = RuntimeBackend(engine)
    out = []

    def fresh(max_batch, cache, queue=512, obs=None):
        return RetrievalFrontend(
            backend,
            FrontendConfig(m=M, max_batch=max_batch, queue_capacity=queue,
                           cache=cache),
            obs=obs,
        )

    def publish(row, stats):
        s = stats.summary()
        REGISTRY.gauge("bench_dropped_probes").set(
            s["dropped_probes"], row=row)
        REGISTRY.gauge("bench_nodes_contacted").set(
            s["nodes_contacted_per_query"], row=row)

    # warm both dispatch shapes once so the cells time serving, not tracing
    fresh(1, False).search(emb[qrows[:2]], exclude=qrows[:2])
    fresh(64, False).search(emb[qrows[:65]], exclude=qrows[:65])

    # -- one-at-a-time vs batched (best of 2 — first pass absorbs any
    # remaining cold-start noise; ids come from the timed pass) --------------
    def timed(max_batch, offered):
        best, ids, stats = np.inf, None, None
        for _ in range(2):
            fe = fresh(max_batch, False)
            dt = _serve_all(fe, emb, qrows, offered=offered)
            ids = np.stack(
                [fe.poll(t)[0] for t in range(fe.stats.completed)]
            )  # tickets are 0..NQ-1 in submit order on a fresh frontend
            best = min(best, dt)
            stats = fe.stats
        return best, ids, stats

    dt1, ids1, st1 = timed(1, offered=1)
    rec1 = metrics.recall_at_m(ids1, ideal)
    publish("serve/one_at_a_time", st1)
    out.append(("serve/one_at_a_time", dt1 / NQ * 1e6,
                f"qps={NQ/dt1:.0f};recall={rec1:.3f}"))

    dtB, idsB, stB = timed(64, offered=64)
    recB = metrics.recall_at_m(idsB, ideal)
    publish("serve/batched_64", stB)
    out.append(("serve/batched_64", dtB / NQ * 1e6,
                f"qps={NQ/dtB:.0f};recall={recB:.3f};"
                f"speedup_vs_one_at_a_time={dt1/dtB:.1f}x;"
                f"ids_identical={bool(np.array_equal(ids1, idsB))}"))

    # -- offered-load sweep (fixed service rate, queue=128) -------------------
    for offered in (4, 16, 64, 256):
        fe = fresh(32, False, queue=128)
        dt = _serve_all(fe, emb, qrows, offered=offered)
        s = fe.stats.summary()
        served = s["completed"]
        publish(f"serve/offered={offered}", fe.stats)
        out.append((
            f"serve/offered={offered}", dt / max(served, 1) * 1e6,
            f"qps={served/dt:.0f};p99_us={s['p99_us']:.0f};"
            f"rejected={s['rejected']};mean_batch={s['mean_batch']:.1f}"))

    # -- repeated-query workload: the cache cell ------------------------------
    pool = rng.integers(0, N, size=POOL)
    w = 1.0 / (np.arange(POOL) + 1.0)
    arrivals = pool[rng.choice(POOL, size=CACHE_ARRIVALS, p=w / w.sum())]
    fe = fresh(32, True)
    dt = _serve_all(fe, emb, arrivals, offered=32)
    s = fe.stats.summary()
    closed = backend.cost().messages
    publish("serve/cache_zipf", fe.stats)
    out.append((
        "serve/cache_zipf", dt / CACHE_ARRIVALS * 1e6,
        f"hit_rate={s['hit_rate']:.2f};"
        f"messages_per_query={s['messages_per_query']:.1f};"
        f"closed_form_no_cache={closed:.1f};"
        f"qps={CACHE_ARRIVALS/dt:.0f}"))

    # -- observability overhead: the batched workload, bare vs fully
    # traced.  The true overhead is small (~2% of a ~40ms run), so the
    # estimator has to survive shared-runner noise that dwarfs it.  Three
    # defenses, each against a failure mode actually observed on 1-core
    # CI-like hosts:
    #   * pairs ALTERNATE in-pair order (off-then-on, on-then-off):
    #     monotonic drift otherwise penalizes whichever side always runs
    #     second (~5% phantom overhead);
    #   * each block's ratio is the MEDIAN of its pair ratios: one
    #     descheduled run can't swing it the way a best-of-minima
    #     quotient can;
    #   * the gated ratio is the MAX over independent blocks: the floor
    #     is a one-sided gate ("is obs provably costing > 5%?"), so it
    #     should only fail on evidence that REPLICATES across blocks —
    #     contended stretches last seconds and poison whole blocks at a
    #     time.  A real obs regression depresses every block.
    # 2x the workload of the other cells so per-pair noise amortizes.
    qrows2 = np.concatenate([qrows, qrows])

    def run_off():
        return _serve_all(fresh(64, False), emb, qrows2, offered=64)

    def run_on():
        # fresh ring per run: steady-state recording
        fe = fresh(64, False, obs=Observability())
        return fe, _serve_all(fe, emb, qrows2, offered=64)

    # pyperf-style GC isolation: by this point the harness has run whole
    # suites and carries a big heap, so collector passes triggered by the
    # obs side's extra allocations scan 100k+ unrelated objects — a GC
    # amplification that bills obs for heap it didn't build (measured as
    # a ~5% phantom slowdown).  Freeze moves the existing heap out of
    # the collector's reach; disable stops allocation-count collections
    # during the timed region.
    gc.collect()
    gc.freeze()
    gc.disable()
    try:
        block_medians, best_off, best_on = [], np.inf, np.inf
        for block in range(3):
            ratios = []
            for it in range(4):
                if (block * 4 + it) % 2 == 0:
                    dt_off = run_off()
                    fe_on, dt_on = run_on()
                else:
                    fe_on, dt_on = run_on()
                    dt_off = run_off()
                ratios.append(dt_off / dt_on)  # qps_on / qps_off, this pair
                best_off = min(best_off, dt_off)
                best_on = min(best_on, dt_on)
            block_medians.append(float(np.median(ratios)))
    finally:
        gc.enable()
        gc.unfreeze()
    obs = fe_on.obs
    ratio = max(block_medians)
    nq2 = len(qrows2)
    publish("serve/obs_overhead", fe_on.stats)
    out.append((
        "serve/obs_overhead", best_on / nq2 * 1e6,
        f"obs_on_over_obs_off={ratio:.3f}x;"
        f"qps_on={nq2/best_on:.0f};qps_off={nq2/best_off:.0f};"
        f"spans={len(obs.tracer.events())};"
        f"flight_records={len(obs.flight)}"))
    return out
