"""Distributed search-step byte accounting (the TPU analogue of Table 1).

Lowers the sharded CNB/NB/LSH search step on a host mesh and parses the
collective bytes out of the compiled HLO — CNB must move no more bytes
than LSH while probing (k+1)x the buckets; NB pays the neighbor traffic.
Also validates the closed-form byte estimator."""

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import LshParams, make_hyperplanes
from repro.core import distributed as dist
from repro.core.store import make_store
from repro.launch.dryrun import parse_collectives


def rows():
    n_data, n_model = 1, 4  # host devices (bench runs with 1 device => 1x1)
    ndev = jax.device_count()
    if ndev >= 4:
        n_model = 4
    elif ndev >= 2:
        n_model = 2
    else:
        n_model = 1
    from repro.compat import make_mesh
    mesh = make_mesh((n_data, n_model), ("data", "model"))
    params = LshParams(d=128, k=8, L=4, seed=0)
    H = make_hyperplanes(params)
    store = make_store(params.L, params.num_buckets, 64, payload_dim=128)
    store = dist.shard_store(mesh, store)
    B = 64
    out = []
    for variant in ("lsh", "nb", "cnb"):
        cfg = dist.DistConfig(params=params, n_shards=n_model,
                              variant=variant, m=10, cap_factor=2.0)
        step = dist.make_search_step(cfg, mesh)
        q_sds = jax.ShapeDtypeStruct(
            (B, 128), jnp.float32,
            sharding=NamedSharding(mesh, P(("data", "model"), None)))
        args = [jax.ShapeDtypeStruct(H.shape, H.dtype),
                jax.ShapeDtypeStruct(store.ids.shape, store.ids.dtype,
                                     sharding=store.ids.sharding),
                jax.ShapeDtypeStruct(store.payload.shape, store.payload.dtype,
                                     sharding=store.payload.sharding)]
        if variant == "cnb" and cfg.node_bits > 0:
            refresh = dist.make_refresh_cache(cfg, mesh)
            ci, cp = refresh(store.ids, store.payload)
            args += [jax.ShapeDtypeStruct(ci.shape, ci.dtype, sharding=ci.sharding),
                     jax.ShapeDtypeStruct(cp.shape, cp.dtype, sharding=cp.sharding)]
        lowered = step.lower(*args, q_sds)
        compiled = lowered.compile()
        coll = parse_collectives(compiled.as_text())
        est = dist.estimate_query_bytes(cfg, batch=B, d=128,
                                        n_total=n_data * n_model)
        out.append((
            f"dist/{variant}/mesh{n_data}x{n_model}",
            coll["total_wire_bytes"] / B,
            f"hlo_wire_bytes={coll['total_wire_bytes']:.0f};"
            f"est_bytes={est['total']:.0f};"
            f"counts={sum(coll['counts'].values())}"))
    return out
