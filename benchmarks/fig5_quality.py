"""Paper Fig. 5 / Sec. 6.4: recall@10 and NCS@10 vs network cost.

Sweeps L per variant; reports quality at (approximately) matched message
budgets.  The headline `derived` per dataset: recall uplift of CNB over
LSH at LSH's own message cost (paper: >50% on LiveJournal)."""


from benchmarks.common import FAST_SPECS, FULL_SPECS, build_dataset, evaluate_variant


def rows(full: bool = False, num_queries: int = 400):
    out = []
    Ls = (1, 2, 4, 8)
    for spec in (FULL_SPECS if full else FAST_SPECS):
        curves = {v: [] for v in ("lsh", "layered", "nb", "cnb")}
        for L in Ls:
            ds = build_dataset(spec, L=L, num_queries=num_queries)
            for variant in curves:
                rec, ncs, msgs, dt = evaluate_variant(ds, variant)
                curves[variant].append((msgs, rec, ncs, dt))
                out.append((
                    f"fig5/{spec.name}/{variant}/L={L}", dt * 1e6,
                    f"messages={msgs};recall={rec:.3f};ncs={ncs:.3f}"))
        # headline: CNB vs LSH at equal message budget (same L => same msgs)
        same_budget = [
            (c[1] / max(l[1], 1e-9) - 1.0, c[0])
            for c, l in zip(curves["cnb"], curves["lsh"])
        ]
        best = max(same_budget)
        out.append((
            f"fig5/{spec.name}/headline", 0.0,
            f"cnb_recall_uplift_at_equal_cost={best[0]*100:.1f}%"
            f"@msgs={best[1]:.0f}"))
    return out
