"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp oracle.

On this CPU host the numbers measure the jit'd oracle (the kernels run in
interpret mode and are NOT representative); the derived column records the
validated tile shapes that the TPU path will use."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(f, *args, reps=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else None
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def rows():
    rng = np.random.default_rng(0)
    out = []
    x = jnp.asarray(rng.standard_normal((4096, 512)), jnp.float32)
    h = jnp.asarray(rng.standard_normal((4, 12, 512)), jnp.float32)
    ref_fn = jax.jit(ref.simhash_ref)
    us = _time(ref_fn, x, h)
    out.append(("kernels/simhash_oracle_4096x512xL4k12", us,
                "tile=(256,512)xLK128;validated=interpret"))

    q = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    cand = jnp.asarray(rng.standard_normal((64, 832, 128)), jnp.float32)
    valid = jnp.ones((64, 832), bool)
    ref_fn2 = jax.jit(lambda a, b, c: ref.bucket_topk_ref(a, b, c, 10))
    us = _time(ref_fn2, q, cand, valid)
    out.append(("kernels/bucket_topk_oracle_64x832x128_m10", us,
                "tile=(8,KC,128);unrolled_m=10;validated=interpret"))

    c = jnp.asarray(rng.integers(0, 2**31, (4096,)), jnp.uint32)
    cc = jnp.asarray(rng.integers(0, 2**31, (4096, 128)), jnp.uint32)
    ref_fn3 = jax.jit(ref.hamming_ref)
    us = _time(ref_fn3, c, cc)
    out.append(("kernels/hamming_oracle_4096x128", us,
                "tile=(256,128);swar_popcount;validated=interpret"))
    return out
