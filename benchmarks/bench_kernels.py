"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp oracle.

On this CPU host the Pallas kernels execute in interpret mode, so their
absolute numbers are NOT representative of the TPU path — the oracle rows
measure the jit'd reference, the kernel rows validate the exact tile shapes
the TPU path will use, and the `query_path/*` rows compare the end-to-end
fused engine dispatch (sketch -> stacked gather -> bucket_topk) against the
reference engine on identical inputs, reporting the measured ratio rather
than asserting a speedup."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(f, *args, reps=5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def _query_path_rows():
    """End-to-end single-host query path: reference vs use_kernels engine."""
    from repro.core import (
        DenseCorpus, EngineConfig, LshEngine, LshParams, make_hyperplanes,
    )
    from repro.core import hashing
    from repro.core.store import build_store_host

    rng = np.random.default_rng(0)
    N, D, k, L, B, m = 20000, 128, 8, 4, 256, 10
    params = LshParams(d=D, k=k, L=L, seed=0)
    h = make_hyperplanes(params)
    vecs = rng.standard_normal((N, D)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    codes = np.asarray(hashing.sketch_codes(jnp.asarray(vecs), h))
    store = build_store_host(codes, params.num_buckets, capacity=64)
    corpus = DenseCorpus(jnp.asarray(vecs))
    q = jnp.asarray(vecs[:B])

    def bench(cfg):
        eng = LshEngine(params, h, store, corpus, None, cfg)
        eng.search(q, m=m)  # warm up / compile
        t0 = time.time()
        reps = 3
        for _ in range(reps):
            eng.search(q, m=m)
        return (time.time() - t0) / reps * 1e6

    us_ref = bench(EngineConfig(variant="cnb", chunk=64))
    us_ker = bench(EngineConfig(variant="cnb", chunk=64, use_kernels=True))
    qps_ref = B / (us_ref / 1e6)
    qps_ker = B / (us_ker / 1e6)
    shared = f"B={B};N={N};D={D};k={k};L={L};m={m}"
    return [
        (f"kernels/query_path_reference_{B}q", us_ref,
         f"qps={qps_ref:.0f};{shared}"),
        (f"kernels/query_path_kernels_{B}q", us_ker,
         f"qps={qps_ker:.0f};kernel_over_ref={us_ref / us_ker:.3f}x;"
         f"mode=interpret;{shared}"),
    ]


def _planner_rows():
    """Probe-planner overhead on the sketch stage: make_plan = sketch +
    near-code/mask/zone arithmetic; the delta is the planner's cost."""
    from repro.core import LshParams, make_hyperplanes
    from repro.core import plan as plan_mod
    from repro.core.can import CanTopology

    rng = np.random.default_rng(1)
    B, D, k, L = 4096, 128, 12, 4
    params = LshParams(d=D, k=k, L=L, seed=0)
    h = make_hyperplanes(params)
    q = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
    topo = CanTopology(k, 16)
    shared = f"B={B};D={D};k={k};L={L};shards=16"

    sketch_fn = jax.jit(lambda x: plan_mod.sketch(x, h))
    us_sketch = _time(sketch_fn, q)
    out = [("kernels/probe_sketch_only", us_sketch, shared)]
    for name, spec in (
        ("full", plan_mod.ProbeSpec(params, "cnb")),
        ("ranked_p4", plan_mod.ProbeSpec(params, "cnb", num_probes=4,
                                         ranked_probes=True)),
    ):
        fn = jax.jit(lambda x, s=spec: plan_mod.make_plan(s, x, h, topo))
        us = _time(fn, q)
        out.append((
            f"kernels/probe_planner_{name}", us,
            f"overhead_over_sketch={us / max(us_sketch, 1e-9):.2f}x;"
            f"P={spec.probes_per_table};{shared}"))
    return out


def _fused_rows(smoke=False):
    """Tentpole rows: staged pipeline (jit'd gather->score->top-m, which is
    exactly `ref.fused_query_ref`) vs the fused mega-kernel, for f32 dot
    payloads and bit-packed hamming sketches.  The packed-over-dot ratio is
    a real measured speedup (both sides jit'd XLA); the fused Pallas row is
    interpret-mode on CPU and labelled so."""
    from functools import partial

    from benchmarks import roofline

    s = roofline._query_shapes(smoke)
    v = roofline._query_inputs(s)
    w = v["payw"].shape[-1]
    shared = f"r={s['r']};P={s['p']};KC={s['c']};D={s['d']};W={w};m={s['m']}"

    staged_dot = jax.jit(partial(ref.fused_query_ref, m=s["m"]))
    staged_ham = jax.jit(partial(ref.fused_query_ref, m=s["m"],
                                 score="hamming"))
    us_dot = _time(staged_dot, v["ids"], v["pay"], v["q"], v["fb"],
                   v["meta"], reps=2 if smoke else 5)
    us_ham = _time(staged_ham, v["ids"], v["payw"], v["qw"], v["fb"],
                   v["meta"], reps=2 if smoke else 5)

    def frac(us, payload_bytes, score, fused):
        mdl = roofline.query_model(
            r=s["r"], p=s["p"], kc=s["c"], payload_bytes=payload_bytes,
            m=s["m"], score=score, fused=fused)
        return mdl["t_model"] * 1e6 / max(us, 1e-9)

    out = [
        (f"kernels/fused_staged_dot_{s['r']}r", us_dot,
         f"roofline_frac={frac(us_dot, 4 * s['d'], 'dot', False):.3f};"
         f"{shared}"),
        (f"kernels/fused_staged_hamming_{s['r']}r", us_ham,
         f"packed_over_dot={us_dot / us_ham:.3f}x;"
         f"roofline_frac={frac(us_ham, 4 * w, 'hamming', False):.3f};"
         f"{shared}"),
    ]
    mode = "interpret" if jax.default_backend() == "cpu" else "compiled"
    fused_dot = partial(ops.fused_query, m=s["m"])
    us_f = _time(lambda *a: fused_dot(*a), v["ids"], v["pay"], v["q"],
                 v["fb"], v["meta"], reps=1)
    out.append(
        (f"kernels/fused_query_pallas_dot_{s['r']}r", us_f,
         f"fused_over_staged={us_dot / us_f:.3f}x;mode={mode};"
         f"roofline_frac={frac(us_f, 4 * s['d'], 'dot', True):.3f};"
         f"{shared}"))
    fused_ham = partial(ops.fused_query, m=s["m"], score="hamming")
    us_fh = _time(lambda *a: fused_ham(*a), v["ids"], v["payw"], v["qw"],
                  v["fb"], v["meta"], reps=1)
    out.append(
        (f"kernels/fused_query_pallas_hamming_{s['r']}r", us_fh,
         f"fused_over_staged={us_ham / us_fh:.3f}x;mode={mode};"
         f"roofline_frac={frac(us_fh, 4 * w, 'hamming', True):.3f};"
         f"{shared}"))
    return out


def _routed_rows(smoke=False):
    """Routed mesh-path rows (PR 10): the (1, 1)-mesh shard_map runtime is
    the real routed code path (MeshCollectives, capacitated all_to_all
    send buffers) on one shard, so staged dot vs staged packed-hamming is
    a REAL measured ratio (both sides jit'd XLA) — the wire now carries
    [.., W] uint32 sketch words instead of [.., D] f32 rows.  The routed
    fused row runs interpret-mode Pallas on CPU and is labelled so; the
    wire-bytes row is the deterministic `estimate_query_bytes` ratio
    (~W*4/(D*4) per routed query row)."""
    from repro.compat import make_mesh
    from repro.core import LshParams, make_hyperplanes, packed
    from repro.core import distributed as dist
    from repro.core import hashing
    from repro.core.runtime import IndexRuntime, RuntimeConfig
    from repro.core.store import build_store_host

    rng = np.random.default_rng(2)
    N, B = (4096, 64) if smoke else (20000, 256)
    D, k, L, m = 128, 12, 4, 10
    params = LshParams(d=D, k=k, L=L, seed=0)
    h = make_hyperplanes(params)
    vecs = rng.standard_normal((N, D)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    codes = np.asarray(hashing.sketch_codes_batched(jnp.asarray(vecs), h))
    store = build_store_host(codes, params.num_buckets, capacity=64,
                             payload=vecs)
    sth = packed.pack_store_payload(store, h)
    w = sth.payload.shape[-1]
    mesh = make_mesh((1, 1), ("data", "model"))
    q = jnp.asarray(vecs[:B])
    shared = f"B={B};N={N};D={D};k={k};L={L};W={w};m={m}"

    def bench(score, fused, st, reps, qb):
        rt = IndexRuntime(
            RuntimeConfig(params=params, variant="cnb", m=m, score=score,
                          cap_factor=float(L), fused=fused),
            mesh=mesh,
        )
        st_sh = rt.shard_store(st)
        rt.search(h, st_sh, qb)  # warm up / compile
        t0 = time.time()
        for _ in range(reps):
            out = rt.search(h, st_sh, qb)
        jax.block_until_ready(out[0])
        return (time.time() - t0) / reps * 1e6

    reps = 2 if smoke else 5
    us_dot = bench("dot", "off", store, reps, q)
    us_ham = bench("hamming", "off", sth, reps, q)
    out = [
        (f"kernels/routed_staged_dot_{B}q", us_dot, shared),
        (f"kernels/routed_staged_hamming_{B}q", us_ham,
         f"routed_packed_over_routed_staged={us_dot / us_ham:.3f}x;"
         f"{shared}"),
    ]
    # the routed fused cell runs interpret-mode Pallas on CPU (Python-loop
    # emulation, minutes at full batch) — time it on a small batch against
    # a same-batch staged denominator; presence, not speed, is the signal
    mode = "interpret" if jax.default_backend() == "cpu" else "compiled"
    bf = 8 if smoke else 32
    qf = jnp.asarray(vecs[:bf])
    us_hs = bench("hamming", "off", sth, 1, qf)
    us_fh = bench("hamming", "on", sth, 1, qf)
    out.append(
        (f"kernels/routed_fused_hamming_{bf}q", us_fh,
         f"routed_fused_over_routed_staged={us_hs / us_fh:.3f}x;"
         f"mode={mode};B={bf};N={N};D={D};k={k};L={L};W={w};m={m}"))
    # deterministic wire-byte model: the routed query rows shrink from
    # D*4 f32 bytes to W*4 word bytes (plus the unchanged meta ints)
    cfg_d = RuntimeConfig(params=params, variant="cnb", m=m,
                          cap_factor=float(L))
    cfg_h = RuntimeConfig(params=params, variant="cnb", m=m,
                          score="hamming", cap_factor=float(L))
    by_d = dist.estimate_query_bytes(cfg_d, B, D, 1)["query_routing"]
    by_h = dist.estimate_query_bytes(cfg_h, B, D, 1)["query_routing"]
    out.append(
        (f"kernels/routed_wire_bytes_{B}q", float(by_h),
         f"packed_wire_over_f32={by_h / by_d:.3f}x;"
         f"f32_bytes={by_d:.0f};packed_bytes={by_h:.0f};{shared}"))
    return out


def rows(smoke=False):
    rng = np.random.default_rng(0)
    out = []
    x = jnp.asarray(rng.standard_normal((4096, 512)), jnp.float32)
    h = jnp.asarray(rng.standard_normal((4, 12, 512)), jnp.float32)
    ref_fn = jax.jit(ref.simhash_ref)
    us = _time(ref_fn, x, h)
    out.append(("kernels/simhash_oracle_4096x512xL4k12", us,
                "tile=(256,512)xLK128;validated=interpret"))
    us = _time(lambda a, b: ops.simhash(a, b), x, h)
    out.append(("kernels/simhash_pallas_4096x512xL4k12", us,
                "tile=(256,512)xLK128;mode=interpret"))

    q = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    cand = jnp.asarray(rng.standard_normal((64, 832, 128)), jnp.float32)
    valid = jnp.ones((64, 832), bool)
    ref_fn2 = jax.jit(lambda a, b, c: ref.bucket_topk_ref(a, b, c, 10))
    us = _time(ref_fn2, q, cand, valid)
    out.append(("kernels/bucket_topk_oracle_64x832x128_m10", us,
                "tile=(8,KC,128);unrolled_m=10;validated=interpret"))
    us = _time(lambda a, b, c: ops.bucket_topk(a, b, c, 10), q, cand, valid)
    out.append(("kernels/bucket_topk_pallas_64x832x128_m10", us,
                "tile=(8,KC,128);unrolled_m=10;mode=interpret"))

    c = jnp.asarray(rng.integers(0, 2**31, (4096,)), jnp.uint32)
    cc = jnp.asarray(rng.integers(0, 2**31, (4096, 128)), jnp.uint32)
    ref_fn3 = jax.jit(ref.hamming_ref)
    us = _time(ref_fn3, c, cc)
    out.append(("kernels/hamming_oracle_4096x128", us,
                "tile=(256,128);swar_popcount;validated=interpret"))

    out.extend(_planner_rows())
    out.extend(_query_path_rows())
    out.extend(_fused_rows(smoke=smoke))
    out.extend(_routed_rows(smoke=smoke))
    return out
