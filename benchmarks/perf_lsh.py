import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""§Perf hillclimb cell 3: the distributed NearBucket-LSH search step on the
production (16 data x 16 model) mesh — the cell most representative of the
paper's own technique.

Baseline -> iterations, each lowered+compiled and measured from HLO:
  A. allgather routing, CNB (cache)           [dense replication baseline]
  B. alltoall routing,  CNB                   [paper's DHT-style routing]
  C. alltoall routing,  NB (no cache)         [paper's uncached variant]
  D. alltoall + margin-ranked probes p=4      [beyond-paper multiprobe]
  E. alltoall, LSH (exact only)               [quality floor reference]

Emits CSV rows: wire bytes/query, per-op breakdown, probed buckets/query.
Run:  PYTHONPATH=src python -m benchmarks.perf_lsh
"""

import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import LshParams
from repro.core import distributed as dist
# store shapes built as ShapeDtypeStructs directly
from repro.launch.dryrun import parse_collectives
from repro.launch.mesh import make_production_mesh


def lower_search(cfg: dist.DistConfig, mesh, B: int, D: int, capacity: int):
    params = cfg.params
    L, NB = params.L, params.num_buckets
    step = dist.make_search_step(cfg, mesh)
    # pure ShapeDtypeStructs — no store materialization on 512 host devices
    args = [
        jax.ShapeDtypeStruct((L, params.k, D), jnp.float32,
                             sharding=NamedSharding(mesh, P())),
        jax.ShapeDtypeStruct(
            (L, NB, capacity), jnp.int32,
            sharding=NamedSharding(mesh, P(None, "model", None))),
        jax.ShapeDtypeStruct(
            (L, NB, capacity, D), jnp.float32,
            sharding=NamedSharding(mesh, P(None, "model", None, None))),
    ]
    if cfg.variant == "cnb" and cfg.node_bits > 0:
        nbits = cfg.node_bits
        ci = jax.ShapeDtypeStruct(
            (L, nbits, NB, capacity), jnp.int32,
            sharding=NamedSharding(mesh, P(None, None, "model", None)))
        cp = jax.ShapeDtypeStruct(
            (L, nbits, NB, capacity, D), jnp.float32,
            sharding=NamedSharding(mesh, P(None, None, "model", None, None)))
        args += [ci, cp]
    q = jax.ShapeDtypeStruct(
        (B, D), jnp.float32,
        sharding=NamedSharding(mesh, P(("data", "model"), None)))
    lowered = step.lower(*args, q)
    compiled = lowered.compile()
    return compiled


def rows():
    mesh = make_production_mesh()
    B, D, capacity = 4096, 128, 128
    k, L = 12, 4
    params = LshParams(d=D, k=k, L=L, seed=0)
    variants = [
        ("A_allgather_cnb", dict(variant="cnb", routing="allgather")),
        ("B_alltoall_cnb", dict(variant="cnb", routing="alltoall")),
        ("C_alltoall_nb", dict(variant="nb", routing="alltoall")),
        # margin-ranked probe budget: p=4 of the k near buckets per table,
        # chosen per query by the shared planner's probe mask
        ("D_alltoall_cnb_ranked_p4", dict(variant="cnb", routing="alltoall",
                                          num_probes=4, ranked_probes=True)),
        ("E_alltoall_lsh", dict(variant="lsh", routing="alltoall")),
        # the kernel-backed per-shard score/top-m (same wire bytes as B —
        # the fused Pallas stage changes compute only, not routing)
        ("F_alltoall_cnb_kernels", dict(variant="cnb", routing="alltoall",
                                        use_kernels=True)),
    ]
    out = []
    for name, kw in variants:
        cfg = dist.DistConfig(params=params, n_shards=16, cap_factor=2.0, **kw)
        compiled = lower_search(cfg, mesh, B, D, capacity)
        coll = parse_collectives(compiled.as_text())
        mem = compiled.memory_analysis()
        out.append((
            f"perf_lsh/{name}",
            coll["total_wire_bytes"] / B,
            f"wire_total={coll['total_wire_bytes']:.3e};"
            f"by_op={json.dumps(coll['bytes_by_op']).replace(',', ';')};"
            f"buckets_per_query={L * cfg.probe_spec.probes_per_table};"
            f"args_gib={(mem.argument_size_in_bytes or 0)/2**30:.2f}",
        ))
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")
