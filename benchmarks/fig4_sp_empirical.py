"""Paper Fig. 4 / Sec. 6.3: analytical vs observed success probability.

(x, y) pairs with y = x's top non-self result, binned by cosine similarity
interval; observed = fraction of pairs where the algorithm searched a
bucket containing y.  `derived` reports mean |observed - analytical| over
populated bins (the paper's 'follows the trend' claim, quantified)."""

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST_SPECS, FULL_SPECS, build_dataset
from repro.core import EngineConfig, LshEngine, analysis, metrics, paper_topology


def rows(full: bool = False, num_pairs: int = 600):
    out = []
    for spec in (FULL_SPECS if full else FAST_SPECS):
        ds = build_dataset(spec, L=4, num_queries=num_pairs)
        topo = paper_topology(spec.k)
        y = ds.ideal_ids[:, 0]
        y_sim = np.clip(ds.ideal_scores[:, 0], 0, 1)
        s_ang = analysis.angular_from_cosine(y_sim)
        for variant, spf in (("lsh", analysis.sp_lsh),
                             ("nb", analysis.sp_nearbucket)):
            e = LshEngine(ds.params, ds.hyperplanes, ds.store, ds.corpus,
                          topo, EngineConfig(variant=variant))
            t0 = time.time()
            found = e.contains(jnp.asarray(ds.queries_dense), y)
            us = (time.time() - t0) / num_pairs * 1e6
            centers, frac, counts = metrics.success_probability_by_interval(
                found, y_sim)
            errs = []
            for c, f, n in zip(centers, frac, counts):
                if n >= 20:
                    a = float(np.mean(
                        spf(s_ang[(np.abs(y_sim - c) <= 0.05)],
                            spec.k, ds.params.L)))
                    errs.append(abs(f - a))
            out.append((f"fig4/{spec.name}/{variant}", us,
                        f"mean_abs_err={np.mean(errs):.3f};bins={len(errs)};"
                        f"obs_mean={np.nanmean(frac):.3f}"))
    return out
