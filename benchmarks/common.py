"""Shared benchmark machinery: dataset build, engines, quality evaluation."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BucketStore, EngineConfig, LshEngine, LshParams, make_hyperplanes,
    metrics, paper_topology,
)
from repro.core.corpus import exact_topk_sparse, sparse_densify_host
from repro.core.store import build_store_host
from repro.data import osn
from repro.obs.trace import Tracer

# module-level tracer: benchmark timings all come off one monotonic
# perf_counter clock, and drivers may export the spans for inspection
TRACER = Tracer()


def sketch_sparse_codes(corpus, hyperplanes, chunk: int = 8192) -> np.ndarray:
    """Sketch a sparse corpus chunk-by-chunk (densify per chunk)."""
    from repro.core.hashing import _sketch_codes_jit

    n = corpus.n
    L = hyperplanes.shape[0]
    out = np.empty((n, L), np.uint32)
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        dense = sparse_densify_host(corpus, np.arange(s, e))
        out[s:e] = np.asarray(_sketch_codes_jit(jnp.asarray(dense), hyperplanes))
    return out


@dataclasses.dataclass
class BuiltDataset:
    spec: osn.OsnSpec
    corpus: object
    params: LshParams
    hyperplanes: object
    store: BucketStore
    queries_idx: np.ndarray
    queries_dense: np.ndarray       # unit rows [nq, d]
    ideal_ids: np.ndarray           # [nq, m] (self excluded)
    ideal_scores: np.ndarray


_CACHE: dict = {}


def build_dataset(spec: osn.OsnSpec, L: int, num_queries: int, m: int = 10,
                  capacity: int = 256, seed: int = 0) -> BuiltDataset:
    key = (spec.name, L, num_queries, m, capacity, seed)
    if key in _CACHE:
        return _CACHE[key]
    with TRACER.span(f"bench/build:{spec.name}", cat="bench", L=L) as sp:
        corpus = osn.generate(spec)
        params = LshParams(d=spec.num_interests, k=spec.k, L=L, seed=seed + 13)
        h = make_hyperplanes(params)
        codes = sketch_sparse_codes(corpus, h)
        store = build_store_host(codes, params.num_buckets, capacity=capacity)

        rng = np.random.default_rng(seed + 4)
        qidx = rng.choice(corpus.n, num_queries, replace=False)
        qd = sparse_densify_host(corpus, qidx)
        qd /= np.maximum(np.linalg.norm(qd, axis=1, keepdims=True), 1e-12)

        ideal_s = np.empty((num_queries, m), np.float32)
        ideal_i = np.empty((num_queries, m), np.int32)
        qchunk = 256
        for s0 in range(0, num_queries, qchunk):
            e0 = min(s0 + qchunk, num_queries)
            isc, iid = exact_topk_sparse(corpus, qd[s0:e0], m + 1)
            for i in range(e0 - s0):
                mask = iid[i] != qidx[s0 + i]
                ideal_s[s0 + i] = isc[i][mask][:m]
                ideal_i[s0 + i] = iid[i][mask][:m]
        built = BuiltDataset(spec, corpus, params, h, store, qidx, qd,
                             ideal_i, ideal_s)
        _CACHE[key] = built
    print(f"# built {spec.name} (n={corpus.n}, k={spec.k}, L={L}) "
          f"in {sp.duration_s:.1f}s")
    return built


def evaluate_variant(ds: BuiltDataset, variant: str, m: int = 10):
    """Returns (recall, ncs, messages, search_seconds_per_query)."""
    topo = paper_topology(ds.spec.k)
    e = LshEngine(ds.params, ds.hyperplanes, ds.store, ds.corpus, topo,
                  EngineConfig(variant=variant))
    with TRACER.span(f"bench/search:{variant}", cat="bench",
                     dataset=ds.spec.name) as sp:
        r = e.search(jnp.asarray(ds.queries_dense), m=m,
                     exclude=ds.queries_idx)
    dt = sp.duration_s / len(ds.queries_idx)
    return (
        metrics.recall_at_m(r.ids, ds.ideal_ids),
        metrics.ncs_at_m(r.scores, ds.ideal_scores),
        r.cost.messages,
        dt,
    )


# scaled dataset registry used by the figure benchmarks; --full switches the
# larger ones in
FAST_SPECS = [osn.DBLP_S]
FULL_SPECS = [osn.DBLP_S, osn.LIVEJOURNAL_S, osn.FRIENDSTER_S]
