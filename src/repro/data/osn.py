"""Synthetic OSN interest-vector datasets (stand-ins for DBLP / LiveJournal /
Friendster, which are not available offline).

Generative model chosen to match the statistics the paper relies on:
  * users hold sparse non-negative interest vectors (tens of interests out of
    thousands..millions, paper Sec. 2.1);
  * interest popularity is power-law (OSN group sizes are heavy-tailed);
  * users belong to overlapping communities; interests are drawn from their
    communities' interest pools — this creates genuinely similar user pairs
    across the whole cosine range, which Figs. 4-5 need;
  * interests are weighted by inverse user frequency,
    w(I) = ln(N_u / (N_I + 1)) + 1   (paper Sec. 6.2).

Scaled-down sizes keep CPU runtimes sane while preserving the paper's
avg bucket size regime (N / 2^k ≈ tens..hundreds, Sec. 6.2).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.corpus import SparseCorpus, sparse_from_lists


@dataclasses.dataclass(frozen=True)
class OsnSpec:
    name: str
    num_users: int
    num_interests: int
    num_communities: int
    interests_per_user: int   # mean; actual ~ Poisson, clipped to [2, nnz_max]
    communities_per_user: int
    nnz_max: int
    k: int                    # paper's sketch size for this dataset
    seed: int = 0
    # fraction of users that are near-duplicates of another user (OSNs have
    # them: co-authors with identical venues, members of the same niche
    # groups); populates the high-similarity bins of Fig. 4
    twin_fraction: float = 0.08


# Paper Sec. 6.2: k=10 (DBLP, 260k users), k=12 (LJ, 1.1M), k=15 (FR, 7.9M);
# avg bucket ≈ 250.  Scaled ~1/8 with k chosen to keep N/2^k ≈ 57 (same
# across datasets, mirroring the paper's constant-B design).
DBLP_S = OsnSpec("dblp_s", 58_000, 8_192, 600, 12, 2, 24, k=10, seed=1)
LIVEJOURNAL_S = OsnSpec("livejournal_s", 117_000, 24_576, 1500, 16, 3, 32, k=11, seed=2)
FRIENDSTER_S = OsnSpec("friendster_s", 234_000, 49_152, 3000, 16, 3, 32, k=12, seed=3)

DATASETS = {s.name: s for s in (DBLP_S, LIVEJOURNAL_S, FRIENDSTER_S)}


def tiny_spec(seed: int = 0) -> OsnSpec:
    """Small spec for unit tests."""
    return OsnSpec("tiny", 2_000, 512, 40, 8, 2, 12, k=6, seed=seed)


def generate(spec: OsnSpec) -> SparseCorpus:
    """Sample the corpus. Deterministic in `spec.seed`."""
    rng = np.random.default_rng(spec.seed)

    # communities get power-law-ish sizes via Zipfian popularity
    comm_pop = 1.0 / np.arange(1, spec.num_communities + 1) ** 0.8
    comm_pop /= comm_pop.sum()

    # each community owns a pool of interests, pool sizes ~ community size
    pool_size = np.maximum(
        (comm_pop * spec.num_interests * 3).astype(int), 8
    )
    pools = [
        rng.choice(spec.num_interests, size=min(ps, spec.num_interests), replace=False)
        for ps in pool_size
    ]

    interest_ids: list[np.ndarray] = []
    n_per_user = np.clip(
        rng.poisson(spec.interests_per_user, size=spec.num_users), 2, spec.nnz_max
    )
    user_comms = rng.choice(
        spec.num_communities,
        size=(spec.num_users, spec.communities_per_user),
        p=comm_pop,
    )
    for u in range(spec.num_users):
        pool = np.concatenate([pools[c] for c in user_comms[u]])
        n = min(n_per_user[u], len(pool))
        ids = np.unique(rng.choice(pool, size=n, replace=True))
        # sprinkle of global interests for realism (cross-community overlap)
        if rng.random() < 0.3:
            ids = np.union1d(ids, rng.integers(0, spec.num_interests, size=1))
        interest_ids.append(ids.astype(np.int32))

    # near-duplicate users: copy a base user's interests, drop/add a couple
    n_twins = int(spec.twin_fraction * spec.num_users)
    if n_twins:
        twin_idx = rng.choice(spec.num_users, size=n_twins, replace=False)
        base_idx = rng.integers(0, spec.num_users, size=n_twins)
        for t, b in zip(twin_idx, base_idx):
            if t == b:
                continue
            ids = interest_ids[b].copy()
            if len(ids) > 3 and rng.random() < 0.7:
                ids = np.delete(ids, rng.integers(len(ids)))
            if rng.random() < 0.5:
                ids = np.union1d(
                    ids, rng.integers(0, spec.num_interests, size=1)
                ).astype(np.int32)
            interest_ids[t] = ids

    # inverse-user-frequency weights (paper Sec. 6.2)
    freq = np.zeros(spec.num_interests, np.int64)
    for ids in interest_ids:
        freq[ids] += 1
    w = np.log(spec.num_users / (freq + 1.0)) + 1.0

    interest_vals = [w[ids].astype(np.float32) for ids in interest_ids]
    return sparse_from_lists(
        interest_ids, interest_vals, d=spec.num_interests, nnz_max=spec.nnz_max
    )
