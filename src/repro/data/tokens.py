"""Deterministic synthetic LM data pipeline.

Batches are a pure function of (seed, step, shape): any host can regenerate
any shard's batch — the property that makes elastic restarts and straggler
backup-workers trivial (DESIGN.md Sec. 6).  Tokens follow a Zipfian unigram
draw with a Markov bigram twist so the loss has learnable structure.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** a
    return p / p.sum()


def make_batch(cfg: ModelConfig, dcfg: DataConfig, step: int,
               batch: int, seq: int) -> dict:
    """Host-side batch for one step (tokens, labels, modality stubs)."""
    rng = np.random.default_rng((dcfg.seed, step))
    v = cfg.vocab_size
    probs = _zipf_probs(min(v, 50_000), dcfg.zipf_a)
    body = {}
    n_text = seq
    if cfg.modality == "vision_patches":
        n_text = seq - cfg.num_prefix_embeds
        body["prefix_embeds"] = rng.standard_normal(
            (batch, cfg.num_prefix_embeds, cfg.d_model)
        ).astype(np.float32) * 0.02
    if cfg.encoder_layers:
        body["frames"] = rng.standard_normal(
            (batch, seq, cfg.d_model)
        ).astype(np.float32) * 0.02
    toks = rng.choice(len(probs), size=(batch, n_text + 1), p=probs)
    # bigram structure: token t+1 correlated with t
    corr = (toks[:, :-1] * 31 + 7) % len(probs)
    mix = rng.random((batch, n_text)) < 0.5
    nxt = np.where(mix, corr, toks[:, 1:])
    tokens = toks[:, :-1].astype(np.int32)
    labels = nxt.astype(np.int32)
    if cfg.modality == "vision_patches":
        labels = np.concatenate(
            [np.full((batch, cfg.num_prefix_embeds), -1, np.int32), labels],
            axis=1,
        )
    body["tokens"] = tokens
    body["labels"] = labels
    return {k: jnp.asarray(x) for k, x in body.items()}


def input_specs(cfg: ModelConfig, batch: int, seq: int,
                kind: str = "train") -> dict:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation).

    kind: train (tokens+labels) | prefill (tokens) | decode (one token +
    caches built separately).
    """
    f32 = jnp.float32
    i32 = jnp.int32
    out = {}
    n_text = seq
    if cfg.modality == "vision_patches":
        n_text = seq - cfg.num_prefix_embeds
        out["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_prefix_embeds, cfg.d_model), f32
        )
    if cfg.encoder_layers:
        out["frames"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), f32)
    out["tokens"] = jax.ShapeDtypeStruct((batch, n_text), i32)
    if kind == "train":
        lab_len = seq if cfg.modality == "vision_patches" else n_text
        out["labels"] = jax.ShapeDtypeStruct((batch, lab_len), i32)
    return out
