"""Failure-injection scenario driver: fail-stop node kills under content
churn, served through R-way replicas (DESIGN.md Sec. 10).

Drives `repro.core.churn.run_failure_churn` — nodes vanish with NO
handoff at scheduled epochs, queries read through zone-adjacent replicas
(first-responder or quorum), and the next re-announce revives the node
and repopulates its zone — and prints the per-epoch ledger: live nodes,
recall, recall gap vs the no-failure reference on the SAME RNG
trajectory, replication/recovery bytes, router drops.

Node counts > 1 need that many host devices; when the current process has
too few, the driver re-execs itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count`` set (the flag is
fixed at jax backend init, so it cannot be repaired in-process).

    PYTHONPATH=src python -m repro.launch.failure_churn --smoke
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys


def _parse_kills(text: str) -> tuple[tuple[int, int], ...]:
    """'epoch:node[,epoch:node...]' -> ((epoch, node), ...)."""
    kills = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            epoch, node = part.split(":")
            kills.append((int(epoch), int(node)))
        except ValueError as e:
            raise SystemExit(f"bad --kills entry {part!r} "
                             f"(want epoch:node): {e}")
    if not kills:
        raise SystemExit("--kills must name at least one epoch:node")
    return tuple(kills)


def run(args, obs=None) -> dict:
    from repro.core.churn import (
        ChurnConfig, FailureChurnConfig, run_failure_churn,
    )

    cfg = ChurnConfig(
        num_users=args.users, dim=args.d, k=args.k, L=args.L,
        capacity=args.capacity, epochs=args.epochs,
        update_rate=args.update_rate, churn_rate=args.churn_rate,
        refresh_every=args.refresh_every, ttl_epochs=args.ttl_epochs,
        num_queries=args.queries, m=args.m, seed=args.seed,
    )
    kills = _parse_kills(args.kills)
    out = run_failure_churn(FailureChurnConfig(
        churn=cfg, n_nodes=args.n_nodes, replication=args.replication,
        read_mode=args.read_mode, kills=kills,
    ), obs=obs)

    print(f"[failure-churn] n_nodes={args.n_nodes} R={args.replication} "
          f"read_mode={args.read_mode} "
          f"kills={','.join(f'{e}:{v}' for e, v in kills)} "
          f"refresh_every={cfg.refresh_every}")
    print("epoch,live,recall,ref_recall,gap,replication_bytes,"
          "recovery_bytes,dropped")
    for i in range(len(out["recalls"])):
        print(f"{i + 1},{out['live_nodes'][i]},{out['recalls'][i]:.4f},"
              f"{out['reference_recalls'][i]:.4f},"
              f"{out['recall_gap'][i]:+.4f},"
              f"{out['replication_bytes'][i]},{out['recovery_bytes'][i]},"
              f"{out['dropped_probes'][i]}")
    print(f"[failure-churn] degraded_gap={out['degraded_gap']:.4f} "
          f"recovered_gap={out['recovered_gap']:.4f} "
          f"recovery_epochs={out['recovery_epochs']} "
          f"total_replication_bytes={out['total_replication_bytes']} "
          f"total_recovery_bytes={out['total_recovery_bytes']} "
          f"dropped={int(out['dropped_probes'].sum())}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CPU-friendly preset + sanity assertions")
    ap.add_argument("--n-nodes", type=int, default=4)
    ap.add_argument("--replication", type=int, default=2)
    ap.add_argument("--read-mode", choices=("first", "quorum"),
                    default="first")
    ap.add_argument("--kills", default="3:1",
                    help="comma-separated epoch:node fail-stop events")
    ap.add_argument("--users", type=int, default=4000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--L", type=int, default=4)
    ap.add_argument("--m", type=int, default=10)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--queries", type=int, default=128)
    ap.add_argument("--update-rate", type=float, default=0.05)
    ap.add_argument("--churn-rate", type=float, default=0.02)
    ap.add_argument("--refresh-every", type=int, default=2)
    ap.add_argument("--ttl-epochs", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None,
                    help="write Chrome-trace-event JSON (Perfetto) here")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics-registry JSON snapshot here")
    ap.add_argument("--inner", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.smoke:
        args.users, args.d, args.k, args.L = 1200, 32, 5, 2
        args.epochs, args.queries, args.capacity = 6, 64, 64
        args.n_nodes, args.replication, args.kills = 4, 2, "3:1"

    need = args.n_nodes
    if not args.inner and need > 1:
        # the kill scenario needs `need` host devices; XLA fixes the count
        # at backend init, so re-exec with the flag set before importing
        # jax (same hop as node_churn)
        env = dict(os.environ)
        # append AFTER any pre-existing flags: XLA honors the LAST
        # occurrence of a duplicated flag
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={need}"
        ).strip()
        cmd = [sys.executable, "-m", "repro.launch.failure_churn",
               "--inner"]
        cmd += (argv if argv is not None else sys.argv[1:])
        proc = subprocess.run(cmd, env=env)
        raise SystemExit(proc.returncode)

    obs = None
    if args.trace_out or args.metrics_out or args.smoke:
        from repro.obs import Observability

        obs = Observability()

    out = run(args, obs=obs)

    if obs is not None:
        if args.trace_out:
            obs.export_trace(args.trace_out)
            print(f"[failure-churn] trace -> {args.trace_out}")
        if args.metrics_out:
            obs.export_metrics(args.metrics_out)
            print(f"[failure-churn] metrics -> {args.metrics_out}")

    if args.smoke:
        import numpy as np

        from repro.core import costmodel

        # acceptance gates (ISSUE 6): killing 1 of 4 nodes with NO handoff
        # keeps recall within 0.05 of the no-failure run, recovers to
        # parity within the re-announce period, and every byte of the
        # replication/recovery protocol is charged, never silent.
        assert out["degraded"].any(), "kill did not degrade liveness"
        assert out["degraded_gap"] <= 0.05, out["degraded_gap"]
        assert out["recovered_gap"] <= 0.02, out["recovered_gap"]
        assert out["recovery_epochs"] <= args.refresh_every, (
            out["recovery_epochs"])
        assert int(out["dropped_probes"].sum()) == 0
        per_announce = costmodel.estimate_replication_bytes(
            args.L, args.users, args.d, args.replication)
        announced = out["replication_bytes"] > 0
        assert per_announce > 0 and np.all(
            out["replication_bytes"][announced] == per_announce)
        assert out["total_replication_bytes"] > 0
        per_zone = costmodel.estimate_recovery_bytes(
            args.L, (1 << args.k) // args.n_nodes, args.capacity, args.d)
        recovered = out["recovery_bytes"] > 0
        assert recovered.any(), "no recovery was charged"
        assert np.all(out["recovery_bytes"][recovered] == per_zone)
        assert out["total_recovery_bytes"] == sum(
            b for _e, _n, b in out["recoveries"])
        # the observability gates (DESIGN.md Sec. 12): every kill dumped
        # the flight ring, and the ring's per-epoch records account
        # EXACTLY for the aggregate arrays asserted above — the same
        # numbers, reconstructed record by record
        fl = obs.flight
        kill_dumps = [d for d in fl.dumps if d["reason"] == "kill_node"]
        assert len(kill_dumps) == len(_parse_kills(args.kills)), fl.dumps
        assert fl.total("dropped_probes") == int(out["dropped_probes"].sum())
        assert fl.total("replication_bytes") == out["total_replication_bytes"]
        assert fl.total("recovery_bytes") == out["total_recovery_bytes"]
        assert fl.total("refresh_bytes") == out["total_refresh_bytes"]
        eps = fl.records(kind="epoch")
        assert len(eps) == args.epochs + 1  # read epochs + the epoch-0 announce
        per_epoch = [r.extra["recovery_bytes"] for r in eps[1:]]
        assert per_epoch == out["recovery_bytes"].tolist()
        print("[smoke] OK")
    return out


if __name__ == "__main__":
    main()
