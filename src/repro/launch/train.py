"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume

Fault tolerance (DESIGN.md Sec. 6):
  * step-tagged atomic checkpoints (params + opt state + data cursor);
  * --resume restarts from the latest verified checkpoint — works across
    mesh-shape changes (elastic re-sharding on restore);
  * the data pipeline is a pure function of (seed, step): after restart or
    on a backup worker, batch `step` is bit-identical.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config
from repro.data import tokens as data_tokens
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models import sharding as sh
from repro.obs.trace import Tracer
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--opt-state", default="fp32", choices=("fp32", "int8"))
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    ocfg = opt_mod.OptConfig(
        peak_lr=args.lr, warmup_steps=args.warmup, decay_steps=args.steps,
        state_dtype=args.opt_state,
    )
    hp = ts.TrainHParams(loss_chunk=min(512, args.seq))
    dcfg = data_tokens.DataConfig(seed=args.seed)

    with sh.use_mesh(mesh):
        params, specs = M.init_model(cfg, args.seed)
        opt_state = opt_mod.init_opt_state(params, ocfg)
        # place on mesh per the sharding rules
        pshard = sh.spec_tree_to_shardings(mesh, specs, params)
        params = jax.tree.map(jax.device_put, params, pshard)
        start_step = 0
        if args.resume and args.ckpt_dir:
            latest = ckpt.latest_step_dir(args.ckpt_dir)
            if latest:
                meta = ckpt.load_meta(latest)
                print(f"[resume] restoring {latest} (step {meta['step']})")
                tree = {"params": params, "opt": opt_state}
                restored = ckpt.restore(latest, tree)
                params, opt_state = restored["params"], restored["opt"]
                params = jax.tree.map(jax.device_put, params, pshard)
                start_step = int(meta["step"])

        step_fn = ts.make_train_step(cfg, ocfg, hp)
        tracer = Tracer()
        with tracer.span("train/run", cat="train", arch=args.arch) as run_sp:
            for step in range(start_step, args.steps):
                batch = data_tokens.make_batch(
                    cfg, dcfg, step, args.batch, args.seq)
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                if step % args.log_every == 0 or step == args.steps - 1:
                    loss = float(metrics["xent"])
                    gn = float(metrics["grad_norm"])
                    dt = run_sp.elapsed_s
                    print(f"[step {step:5d}] xent={loss:.4f} gnorm={gn:.2f} "
                          f"({dt:.1f}s)", flush=True)
                    if not np.isfinite(loss):
                        raise RuntimeError(f"loss diverged at step {step}")
                if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                    path = ckpt.save(
                        args.ckpt_dir, step + 1,
                        {"params": params, "opt": opt_state},
                        extra={"arch": args.arch, "data_seed": args.seed},
                    )
                    print(f"[ckpt] wrote {path}", flush=True)
    print("[done]")


if __name__ == "__main__":
    main()
