"""End-to-end serving driver: batched prefill + decode over the mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
        --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models import sharding as sh
from repro.serve import serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    rng = np.random.default_rng(args.seed)

    with sh.use_mesh(mesh):
        params, _ = M.init_model(cfg, args.seed)
        batch = {}
        if cfg.encoder_layers:
            batch["frames"] = jnp.asarray(
                rng.standard_normal(
                    (args.batch, args.prompt_len, cfg.d_model)
                ), jnp.float32) * 0.02
        if cfg.modality == "vision_patches":
            batch["prefix_embeds"] = jnp.asarray(
                rng.standard_normal(
                    (args.batch, cfg.num_prefix_embeds, cfg.d_model)
                ), jnp.float32) * 0.02
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
            jnp.int32,
        )
        max_len = args.prompt_len + args.gen + 8
        t0 = time.time()
        out = serve_step.generate(
            params, cfg, batch, steps=args.gen, max_len=max_len,
            seed=args.seed,
        )
        dt = time.time() - t0
    toks = np.asarray(out)
    print(f"[serve] generated {toks.shape} tokens in {dt:.1f}s "
          f"({toks.size / dt:.1f} tok/s)")
    print("first sequences:", toks[:2, :16].tolist())
    assert np.all(toks >= 0) and np.all(toks < cfg.vocab_size)
    print("[done]")


if __name__ == "__main__":
    main()
