"""End-to-end LM serving driver: batched prefill + decode over the mesh.

The prefill/decode step builders live here with their only consumer
(they were `repro.serve.serve_step` before the runtime consolidation
made `repro.serve` the retrieval-only serving package).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
        --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models import sharding as sh
from repro.models.config import ModelConfig
from repro.obs.trace import Tracer


def make_prefill_step(cfg: ModelConfig, max_len: int):
    @partial(jax.jit, static_argnames=())
    def prefill(params, batch):
        logits, states, _ = M.prefill(params, cfg, batch, max_len)
        return logits, states

    return prefill


def make_decode_step(cfg: ModelConfig, greedy: bool = True):
    @partial(jax.jit, donate_argnums=(1,))
    def decode(params, states, token, pos, rng):
        logits, states = M.decode_step(params, cfg, token, states, pos)
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(rng, logits).astype(jnp.int32)
        return nxt, logits, states

    return decode


def generate(params, cfg: ModelConfig, batch, steps: int, max_len: int,
             greedy: bool = True, seed: int = 0):
    """Host loop: prefill then `steps` decode steps. Returns [B, steps]."""
    prefill = make_prefill_step(cfg, max_len)
    decode = make_decode_step(cfg, greedy)
    logits, states = prefill(params, batch)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if "tokens" in batch:
        pos0 = batch["tokens"].shape[1]
        if "prefix_embeds" in batch:
            pos0 += batch["prefix_embeds"].shape[1]
    else:
        pos0 = batch["prefix_embeds"].shape[1]
    out = [tok]
    rng = jax.random.PRNGKey(seed)
    for t in range(steps - 1):
        rng, sub = jax.random.split(rng)
        tok, _, states = decode(params, states, tok,
                                jnp.int32(pos0 + t), sub)
        out.append(tok)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    rng = np.random.default_rng(args.seed)

    with sh.use_mesh(mesh):
        params, _ = M.init_model(cfg, args.seed)
        batch = {}
        if cfg.encoder_layers:
            batch["frames"] = jnp.asarray(
                rng.standard_normal(
                    (args.batch, args.prompt_len, cfg.d_model)
                ), jnp.float32) * 0.02
        if cfg.modality == "vision_patches":
            batch["prefix_embeds"] = jnp.asarray(
                rng.standard_normal(
                    (args.batch, cfg.num_prefix_embeds, cfg.d_model)
                ), jnp.float32) * 0.02
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
            jnp.int32,
        )
        max_len = args.prompt_len + args.gen + 8
        tracer = Tracer()
        with tracer.span("lm/generate", cat="lm", batch=args.batch,
                         gen=args.gen) as sp:
            out = generate(
                params, cfg, batch, steps=args.gen, max_len=max_len,
                seed=args.seed,
            )
        dt = sp.duration_s
    toks = np.asarray(out)
    print(f"[serve] generated {toks.shape} tokens in {dt:.1f}s "
          f"({toks.size / dt:.1f} tok/s)")
    print("first sequences:", toks[:2, :16].tolist())
    assert np.all(toks >= 0) and np.all(toks < cfg.vocab_size)
    print("[done]")


if __name__ == "__main__":
    main()
