"""Online retrieval serving driver: load generation over the
`repro.serve` frontend (DESIGN.md Sec. 7 + 13).

Two load modes.  The default CLOSED loop drives a zipf-skewed query
stream through the dynamic batcher tick by tick — submitting `--offered`
arrivals per tick and serving one coalesced batch per tick, so backlog
(and admission rejects) build up whenever offered load exceeds service
capacity.  `--open-loop` instead draws a Poisson arrival schedule at a
FIXED offered rate (`--rate`, qps; 0 = auto from measured capacity),
measures latency from each arrival's SCHEDULED time (coordinated
omission counts against the server), and serves the same schedule twice
on one warm runtime — synchronous (depth 1) then pipelined
(`--pipeline` staged device batches) — reporting p50/p99 against the
`--slo-p99-ms` target for each and verifying the served ids are
BIT-IDENTICAL across the two paths.

Live churn can be interleaved (`--churn-every`): every T ticks a slice
of the corpus drifts and re-announces, bumping the store generation and
invalidating the sketch-keyed result cache.

Reports p50/p99 latency, queries/sec, cache hit rate, messages/query
(Table-1 cost model — hits cost zero network), rejects, ring-full
pushback, and router `dropped_probes`.

With `--trace-out PATH` the run records every pipeline stage span and
per-query flight record and writes a Chrome-trace-event JSON loadable in
Perfetto (ui.perfetto.dev); `--metrics-out PATH` writes the metrics
registry snapshot; `--recall-probe-every N` shadow-rescores every Nth
served miss against the exact top-m (DESIGN.md Sec. 12).

    PYTHONPATH=src python -m repro.launch.serve_retrieval --smoke \
        --trace-out /tmp/serve_trace.json
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import (
    DenseCorpus, EngineConfig, LshEngine, LshParams, make_hyperplanes,
)
from repro.core.hashing import sketch_codes_batched
from repro.core.store import build_store_host, expire, insert_batch
from repro.obs import Observability, ObsConfig
from repro.serve import (
    FrontendConfig, RetrievalFrontend, RuntimeBackend, poisson_arrivals,
    run_open_loop,
)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def build_frontend(args, rng, obs=None):
    """Corpus + store + engine + frontend; returns (frontend, corpus, h)."""
    emb = _unit(rng.standard_normal((args.n, args.d))).astype(np.float32)
    params = LshParams(d=args.d, k=args.k, L=args.L, seed=args.seed + 1)
    h = make_hyperplanes(params)
    codes = sketch_codes_batched(jnp.asarray(emb), h)
    store = build_store_host(codes, params.num_buckets, capacity=args.capacity)
    engine = LshEngine(
        params, h, store, DenseCorpus(jnp.asarray(emb)), None,
        EngineConfig(variant=args.variant),
    )
    frontend = RetrievalFrontend(
        RuntimeBackend(engine),
        FrontendConfig(
            m=args.m, max_batch=args.max_batch,
            queue_capacity=args.queue_capacity, cache=not args.no_cache,
            pipeline_depth=args.pipeline,
        ),
        obs=obs,
    )
    return frontend, emb, h, store


def make_workload(args, rng):
    """Zipf-skewed arrival stream over a finite query pool (repeats are
    what a result cache exists for — the paper's OSN users re-query)."""
    pool = rng.integers(0, args.n, size=args.pool)
    w = 1.0 / (np.arange(args.pool) + 1.0)  # zipf(1) over pool ranks
    picks = rng.choice(args.pool, size=args.queries, p=w / w.sum())
    return pool[picks]  # corpus row per arrival


def churn_tick(args, rng, emb, h, store, frontend, now: int):
    """One write epoch: drift a corpus slice, re-announce all, GC.

    `now` is the write-epoch counter: re-announces are stamped with it
    and expiry collects entries whose last stamp is more than `ttl`
    epochs old — the copies a drifted vector left in its OLD buckets are
    genuinely garbage-collected after ttl write epochs (a constant stamp
    would make the GC a no-op)."""
    n_upd = max(1, int(args.churn_frac * args.n))
    upd = rng.choice(args.n, n_upd, replace=False)
    emb[upd] = _unit(
        emb[upd] + 0.5 * rng.standard_normal((n_upd, args.d))
    ).astype(np.float32)
    codes = sketch_codes_batched(jnp.asarray(emb), h)
    store = insert_batch(
        store, jnp.arange(args.n, dtype=jnp.int32), jnp.asarray(codes),
        jnp.int32(now),
    )
    store = expire(store, jnp.int32(now), ttl=args.ttl_epochs)
    frontend.backend.update(store, DenseCorpus(jnp.asarray(emb)))
    return store


def run(args, obs=None) -> dict:
    rng = np.random.default_rng(args.seed)
    frontend, emb, h, store = build_frontend(args, rng, obs=obs)
    arrivals = make_workload(args, rng)

    # warm the jit cache so reported latencies measure serving, not tracing:
    # sweep the pow-2 dispatch grid (1..max_batch) with the run's cache
    # setting, so BOTH the sketch jit and every dispatch shape the timed
    # run can hit are compiled up front; the warm frontend has its own
    # cache, so nothing leaks into the measured hit rate.
    if args.warmup:
        warm = RetrievalFrontend(
            frontend.backend,
            FrontendConfig(m=args.m, max_batch=args.max_batch,
                           queue_capacity=args.queue_capacity,
                           cache=not args.no_cache),
        )
        wrng = np.random.default_rng(args.seed + 99)
        b = 1
        while b <= args.max_batch:
            wq = _unit(wrng.standard_normal((b, args.d))).astype(np.float32)
            warm.search(wq)  # fresh vectors: all misses -> real dispatches
            b *= 2

    sent = 0
    tick = 0
    write_epoch = 0
    if args.warmup and args.churn_every:  # compile the write-epoch path too
        write_epoch += 1
        store = churn_tick(args, rng, emb, h, store, frontend, write_epoch)
    while sent < len(arrivals) or frontend.pending:
        burst = arrivals[sent:sent + args.offered]
        sent += len(burst)
        for row in burst:
            frontend.submit(emb[row], exclude=int(row))
        frontend.step()
        tick += 1
        if args.churn_every and tick % args.churn_every == 0:
            write_epoch += 1
            store = churn_tick(args, rng, emb, h, store, frontend,
                               write_epoch)
    frontend.flush()

    print(frontend.stats.format_summary())
    cost = frontend.backend.cost()
    print(f"[serve] closed-form messages/query (no cache) = {cost.messages:.1f}"
          f"  store generation = {frontend.backend.generation}")
    if obs is not None:
        frontend.stats.publish(obs.registry)
        probe = obs.registry.value("serve_recall_probe", window="mean")
        if probe is not None:
            print(f"[serve] shadow recall probe (1-in-"
                  f"{obs.config.recall_probe_every} misses) = {probe:.3f}")
    return frontend.stats.summary()


def run_openloop(args, obs=None) -> dict:
    """Open-loop mode: one Poisson/uniform arrival schedule at a fixed
    offered rate, served TWICE on the same warm runtime — synchronous
    (depth 1), then pipelined (`--pipeline`) — latency measured from the
    SCHEDULE (DESIGN.md Sec. 13).  Returns per-mode results plus the
    bit-identity verdict the smoke gate asserts on."""
    import time

    rng = np.random.default_rng(args.seed)
    frontend, emb, h, store = build_frontend(args, rng, obs=obs)
    backend = frontend.backend

    def fresh(depth):
        return RetrievalFrontend(
            backend,
            FrontendConfig(m=args.m, max_batch=args.max_batch,
                           queue_capacity=args.queue_capacity,
                           cache=not args.no_cache, pipeline_depth=depth),
        )

    # warm every dispatch shape the run can hit, then measure capacity
    if args.warmup:
        warm = fresh(1)
        wrng = np.random.default_rng(args.seed + 99)
        b = 1
        while b <= args.max_batch:
            warm.search(_unit(wrng.standard_normal(
                (b, args.d))).astype(np.float32))
            b *= 2
    wq = emb[np.random.default_rng(args.seed + 7).integers(
        0, args.n, size=args.max_batch)]
    # cache OFF for the capacity probe: repeats must redispatch, or the
    # "service time" would be a cache lookup
    meter = RetrievalFrontend(
        backend, FrontendConfig(m=args.m, max_batch=args.max_batch,
                                queue_capacity=args.queue_capacity,
                                cache=False))
    meter.search(wq)  # one untimed pass (any residual compile)
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        meter.search(wq)
    svc = (time.perf_counter() - t0) / reps
    capacity = args.max_batch / svc
    rate = args.rate if args.rate > 0 else 0.5 * capacity
    print(f"[openloop] batch service {svc * 1e3:.2f} ms "
          f"-> capacity ~{capacity:.0f} qps; offered rate {rate:.0f} qps")

    rows = np.random.default_rng(args.seed + 1).integers(
        0, args.n, size=args.queries)
    arr = poisson_arrivals(rate, args.queries, seed=args.seed,
                           deterministic=args.smoke)
    out = {}
    for name, depth in (("sync", 1), ("pipelined", max(args.pipeline, 2))):
        res = run_open_loop(fresh(depth), emb[rows], arr,
                            exclude=rows)
        out[name] = res
        verdict = "PASS" if res.slo_ok(args.slo_p99_ms) else "FAIL"
        print(f"[openloop] {name:9s} (depth {depth}): "
              f"p50 {res.p50_ms:7.2f} ms  p99 {res.p99_ms:7.2f} ms  "
              f"shed {res.shed}  served {res.served_qps:.0f} qps  "
              f"SLO p99<={args.slo_p99_ms:.0f}ms {verdict}")
    s, p = out["sync"], out["pipelined"]
    identical = (
        s.completed == p.completed == args.queries
        and set(s.ids) == set(p.ids)
        and all(np.array_equal(s.ids[i], p.ids[i]) for i in s.ids)
    )
    print(f"[openloop] sync == pipelined served ids: "
          f"{'bit-identical' if identical else 'MISMATCH'}")
    return dict(sync=s, pipelined=p, identical=identical, rate=rate,
                capacity=capacity)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CPU-friendly preset + sanity assertions (CI)")
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--L", type=int, default=4)
    ap.add_argument("--m", type=int, default=10)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--variant", default="cnb")
    ap.add_argument("--pool", type=int, default=512,
                    help="distinct queries in the workload")
    ap.add_argument("--queries", type=int, default=4000,
                    help="total arrivals")
    ap.add_argument("--offered", type=int, default=32,
                    help="arrivals submitted per tick (offered load)")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--queue-capacity", type=int, default=256)
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--churn-every", type=int, default=0,
                    help="write epoch every T ticks (0 = static index)")
    ap.add_argument("--churn-frac", type=float, default=0.02)
    ap.add_argument("--ttl-epochs", type=int, default=4,
                    help="GC horizon in write epochs (paper Sec. 4.1)")
    ap.add_argument("--no-warmup", dest="warmup", action="store_false")
    ap.add_argument("--pipeline", type=int, default=1,
                    help="staged device batches (1 = synchronous; "
                         "DESIGN.md Sec. 13)")
    ap.add_argument("--open-loop", action="store_true",
                    help="open-loop mode: fixed offered rate, latency "
                         "from scheduled arrival, sync vs pipelined")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="open-loop offered rate in qps (0 = half of "
                         "measured closed-loop capacity)")
    ap.add_argument("--slo-p99-ms", type=float, default=50.0,
                    help="open-loop p99 SLO target in milliseconds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None,
                    help="write Chrome-trace-event JSON (Perfetto) here")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics-registry JSON snapshot here")
    ap.add_argument("--recall-probe-every", type=int, default=0,
                    help="shadow-rescore every Nth served miss against "
                         "the exact top-m (0 = off; needs obs enabled)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.n, args.d, args.k = 2000, 32, 6
        args.pool, args.queries = 96, 400
        args.offered, args.max_batch, args.queue_capacity = 16, 32, 128
        if args.churn_every == 0:
            args.churn_every = 8
        if (args.trace_out or args.metrics_out) \
                and args.recall_probe_every == 0:
            args.recall_probe_every = 8

    obs = None
    if args.trace_out or args.metrics_out or args.recall_probe_every:
        obs = Observability(ObsConfig(
            recall_probe_every=max(args.recall_probe_every, 0)))

    if args.open_loop:
        ol = run_openloop(args, obs=obs)
        if args.smoke:
            # CI gate for the open-loop cell: both modes served EVERY
            # arrival (a smoke rate never sheds), the latency population
            # is sane, the SLO verdict is well-defined at both depths,
            # and — the pipeline's non-negotiable invariant — the two
            # paths served bit-identical ids on the same schedule.
            for name in ("sync", "pipelined"):
                r = ol[name]
                assert r.completed == args.queries and r.shed == 0, name
                assert r.completed + r.shed == args.queries, name
                assert np.isfinite(r.p99_ms) and r.p99_ms >= r.p50_ms > 0
                assert r.slo_ok(args.slo_p99_ms) == (
                    r.shed == 0 and r.p99_ms <= args.slo_p99_ms)
                assert r.summary["completed"] == r.completed, name
            assert ol["identical"], "pipelined ids diverged from sync"
            print("[smoke] OK")
        return ol

    s = run(args, obs=obs)

    if obs is not None:
        if args.trace_out:
            obs.export_trace(args.trace_out)
            print(f"[serve] trace -> {args.trace_out} "
                  f"(load in ui.perfetto.dev)")
        if args.metrics_out:
            obs.export_metrics(args.metrics_out)
            print(f"[serve] metrics -> {args.metrics_out}")

    if args.smoke:
        # CI gate: everything admitted was served, rejects/ring-full/
        # drops were counted (not negative/silent), and the repeated-
        # query workload actually hit the cache, reducing messages/query.
        assert s["completed"] + s["rejected"] + s["ring_full"] \
            == args.queries, s
        assert s["dropped_probes"] == 0, s
        assert np.isfinite(s["p99_us"]) and s["p99_us"] > 0, s
        if not args.no_cache:
            assert s["hit_rate"] > 0.2, s
            full = 0.5 * args.k * args.L  # Table-1 kL/2
            assert s["messages_per_query"] < full, s
        if obs is not None:
            # the observability gates: every pipeline stage traced, the
            # flight ring accounts for every completed query, and the
            # emitted Chrome trace is schema-valid JSON
            import json

            evs = obs.chrome_trace()["traceEvents"]
            names = {e["name"] for e in evs}
            for stage in ("serve/intake", "serve/enqueue", "serve/stage",
                          "serve/compute", "serve/reap", "serve/respond"):
                assert stage in names, f"missing span {stage}"
            for e in evs:
                assert {"name", "cat", "ph", "ts", "pid", "tid"} <= set(e), e
            assert len(obs.flight.records(kind="query")) == s["completed"]
            assert obs.flight.total(
                "dropped_probes", kind="dispatch") == s["dropped_probes"]
            if args.trace_out:
                with open(args.trace_out) as f:
                    assert json.load(f)["traceEvents"]
        print("[smoke] OK")
    return s


if __name__ == "__main__":
    main()
