import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the REAL step function (train_step / prefill /
decode_step), attaches the production shardings to ShapeDtypeStruct inputs
(no allocation), lowers, compiles, and records:
  * compiled.memory_analysis()  — proves the cell fits 16 GB/chip,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective bytes parsed from the post-SPMD optimized HLO,
into a JSON file consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod] \
      --out results/dryrun
"""

import argparse
import json
import re
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import SHAPES, cell_applicable
from repro.data import tokens as data_tokens
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models import model as M
from repro.models import sharding as sh
from repro.models.config import ModelConfig
from repro.obs.trace import Tracer
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts

# archs big enough to need int8 optimizer states to fit 16 GB/chip
INT8_OPT_ARCHS = {"llama4-maverick-400b-a17b", "jamba-v0.1-52b"}

# encoder context used for enc-dec decode cells (decoder KV is the cell's
# seq_len; the encoder side is a fixed audio context)
ENCDEC_DECODE_SRC_LEN = 4096

# Named sharding-rule presets (§Perf hillclimb knobs).
#   default : DP over (pod, data) x TP/EP over model (Megatron-style)
#   zero3   : pure data parallelism over ALL axes + fully-sharded weights
#             (no tensor axes) — kills TP activation all-reduces and the
#             replicated-attention waste for head counts that don't divide
#             the model axis; weights are re-gathered per layer instead.
RULE_PRESETS = {
    "default": None,
    "zero3": {
        "batch": ("pod", "data", "model"),
        "fsdp": ("data", "model"),
        "heads": None,
        "kv_heads": None,
        "d_ff": None,
        "d_inner": None,
        "expert_ff": None,
    },
    # zero3 + replicated vocab dim: the vocab-sharded lm_head conflicts with
    # fully batch-sharded hidden states at the loss (GSPMD falls back to an
    # involuntary full rematerialization); sharding the embedding only by
    # fsdp resolves it.
    "zero3b": {
        "batch": ("pod", "data", "model"),
        "fsdp": ("data", "model"),
        "heads": None,
        "kv_heads": None,
        "d_ff": None,
        "d_inner": None,
        "expert_ff": None,
        "vocab": None,
    },
}


def _sds(tree, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings,
    )


def _param_sds(cfg: ModelConfig, mesh, rules=None):
    box = {}

    def f(_):
        p, s = M.init_model(cfg, 0)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(f, 0)
    shardings = sh.spec_tree_to_shardings(mesh, box["specs"], shapes, rules)
    return _sds(shapes, shardings), box["specs"]


def _batch_sharding(mesh, tree, batch_dims_shardable: bool, rules=None):
    bx = batch_axes(mesh)
    if rules and "batch" in rules:
        bx = tuple(a for a in rules["batch"] if a in mesh.shape)

    def leaf(l):
        if not batch_dims_shardable or l.shape[0] == 1:
            return NamedSharding(mesh, P())
        prod = 1
        for a in bx:
            prod *= mesh.shape[a]
        use = bx if l.shape[0] % prod == 0 else batch_axes(mesh)
        spec = [use] + [None] * (len(l.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, tree)


def _fit_spec(mesh, spec_axes, shape):
    """Drop mesh axes (per dim, trailing-first) until every dim divides.
    Returns (fitted PartitionSpec, fully_fits: bool)."""
    out, full = [], True
    used = set()
    for dim, ax in zip(shape, spec_axes):
        axes = () if ax is None else ((ax,) if isinstance(ax, str) else tuple(ax))
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        fitted = axes
        while fitted:
            prod = 1
            for a in fitted:
                prod *= mesh.shape[a]
            if dim % prod == 0:
                break
            fitted = fitted[:-1]
        if fitted != axes:
            full = False
        used.update(fitted)
        out.append(None if not fitted else
                   (fitted[0] if len(fitted) == 1 else fitted))
    return P(*out), full


def _pick(mesh, shape, candidates):
    """First candidate that fully fits; else the fitted first candidate."""
    for cand in candidates:
        spec, full = _fit_spec(mesh, cand, shape)
        if full:
            return NamedSharding(mesh, spec)
    spec, _ = _fit_spec(mesh, candidates[0], shape)
    return NamedSharding(mesh, spec)


def _decode_state_shardings(cfg: ModelConfig, states_shape, mesh, long: bool):
    bx = batch_axes(mesh)

    def leaf(path, l):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(l.shape)
        shape = tuple(l.shape)
        if key in ("k", "v", "xk", "xv") and nd == 5:  # [P, B, S, Hkv, dh]
            return _pick(mesh, shape, [
                (None, bx, None, "model", None),       # batch + heads TP
                (None, bx, "model", None, None),       # heads don't divide:
                                                       # split the KV length
                (None, None, ("data", "model"), None, None),  # B=1 (long):
                                                       # length over all chips
            ])
        if key == "h" and nd == 4:                     # mamba h [P, B, di, N]
            return _pick(mesh, shape, [(None, bx, "model", None)])
        if key == "conv" and nd == 4:                  # [P, B, dc-1, di]
            return _pick(mesh, shape, [(None, bx, None, "model")])
        if key.startswith("s") and nd >= 3:            # xlstm [P, B, H, ...]
            rest = (None,) * (nd - 3)
            cands = [(None, bx, "model") + rest]
            if nd >= 4:
                cands.append((None, bx, None, "model") + (None,) * (nd - 4))
            return _pick(mesh, shape, cands)
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(leaf, states_shape)


def build_lowering(arch: str, shape_name: str, mesh, rules=None):
    """Returns (lowered, meta) for the cell."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    B, S = spec.global_batch, spec.seq_len
    meta = dict(arch=arch, shape=shape_name, kind=spec.kind,
                batch=B, seq=S, mesh=tuple(int(x) for x in mesh.devices.shape))

    with sh.use_mesh(mesh, rules):
        params_sds, specs = _param_sds(cfg, mesh, rules)

        if spec.kind == "train":
            ocfg = opt_mod.OptConfig(
                state_dtype="int8" if arch in INT8_OPT_ARCHS else "fp32"
            )
            opt_shapes = jax.eval_shape(
                lambda p: opt_mod.init_opt_state(p, ocfg), params_sds
            )
            opt_shardings = sh.spec_tree_to_shardings(
                mesh, opt_mod.opt_state_specs(specs, ocfg), opt_shapes, rules
            )
            opt_sds = _sds(opt_shapes, opt_shardings)
            batch_shapes = data_tokens.input_specs(cfg, B, S, kind="train")
            batch_sds = _sds(
                batch_shapes, _batch_sharding(mesh, batch_shapes, True, rules)
            )
            step = ts.make_train_step(cfg, ocfg)
            lowered = step.lower(params_sds, opt_sds, batch_sds)
            return lowered, meta

        if spec.kind == "prefill":
            batch_shapes = data_tokens.input_specs(cfg, B, S, kind="prefill")
            batch_sds = _sds(
                batch_shapes, _batch_sharding(mesh, batch_shapes, True, rules)
            )

            def prefill_fn(params, batch):
                logits, states, _ = M.prefill(params, cfg, batch, max_len=S)
                return logits, states

            lowered = jax.jit(prefill_fn).lower(params_sds, batch_sds)
            return lowered, meta

        # decode: one new token against a seq_len-deep cache
        long = shape_name == "long_500k"
        src_len = ENCDEC_DECODE_SRC_LEN if cfg.encoder_layers else None
        prefill_len = 256  # shapes of recurrent states don't depend on it

        pre_shapes = data_tokens.input_specs(cfg, B, prefill_len, kind="prefill")
        if cfg.encoder_layers:
            pre_shapes["frames"] = jax.ShapeDtypeStruct(
                (B, src_len, cfg.d_model), jnp.float32
            )

        def state_shapes_fn(params, batch):
            _, states, _ = M.prefill(params, cfg, batch, max_len=S)
            return states

        states_shape = jax.eval_shape(state_shapes_fn, params_sds, pre_shapes)
        state_sh = _decode_state_shardings(cfg, states_shape, mesh, long)
        states_sds = _sds(states_shape, state_sh)
        tok_shard = (
            NamedSharding(mesh, P(batch_axes(mesh))) if B > 1
            else NamedSharding(mesh, P())
        )
        tok_sds = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=tok_shard)
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32,
                                       sharding=NamedSharding(mesh, P()))

        def decode_fn(params, states, token, pos):
            return M.decode_step(params, cfg, token, states, pos)

        lowered = jax.jit(decode_fn).lower(
            params_sds, states_sds, tok_sds, pos_sds
        )
        return lowered, meta


# -- collective byte accounting from post-SPMD HLO ---------------------------

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"(\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2, "f8e4m3": 1,
    "f8e5m2": 1,
}
# ring-algorithm wire multipliers (bytes crossing links / buffer size)
_WIRE_FACTOR = {
    "all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}


def parse_collectives(hlo_text: str) -> dict:
    per_op: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        shape_str, op, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # async pair: count the -start only
        size = 0
        for dt, dims in _SHAPE_RE.findall(shape_str):
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            size += n * _BYTES.get(dt, 4)
        per_op[op] = per_op.get(op, 0.0) + size * _WIRE_FACTOR[op]
        counts[op] = counts.get(op, 0) + 1
    return {
        "bytes_by_op": per_op,
        "counts": counts,
        "total_wire_bytes": sum(per_op.values()),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             unroll: bool = False, rules_name: str = "default",
             remat: bool = True) -> dict:
    from repro.models import unroll as unroll_mod

    rules = RULE_PRESETS[rules_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tracer = Tracer()
    with tracer.span("dryrun/lower", cat="compile", arch=arch) as sp_lower:
        with unroll_mod.unroll_scope(unroll), unroll_mod.remat_scope(remat):
            lowered, meta = build_lowering(arch, shape_name, mesh, rules)
    t_lower = sp_lower.duration_s
    with tracer.span("dryrun/compile", cat="compile", arch=arch) as sp_comp:
        compiled = lowered.compile()
    t_compile = sp_comp.duration_s
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    out = dict(
        **meta,
        multi_pod=multi_pod,
        unrolled=unroll,
        rules=rules_name,
        remat=remat,
        ok=True,
        t_lower_s=round(t_lower, 1),
        t_compile_s=round(t_compile, 1),
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            generated_code_bytes=getattr(mem, "generated_code_size_in_bytes", None),
            alias_bytes=getattr(mem, "alias_size_in_bytes", None),
        ),
        cost=dict(
            flops=cost.get("flops"),
            bytes_accessed=cost.get("bytes accessed"),
            transcendentals=cost.get("transcendentals"),
        ),
        collectives=coll,
        hlo_lines=hlo.count("\n"),
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer/loss scans for exact cost analysis")
    ap.add_argument("--rules", default="default", choices=sorted(RULE_PRESETS),
                    help="sharding-rule preset (perf iterations)")
    ap.add_argument("--no-remat", action="store_true",
                    help="disable activation remat (perf iterations)")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = list(ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(args.out, exist_ok=True)

    for arch in archs:
        for shape_name in shapes:
            tag = (f"{arch}__{shape_name}__"
                   f"{'pod2' if args.multi_pod else 'pod1'}"
                   + ("__unroll" if args.unroll else "")
                   + (f"__{args.rules}" if args.rules != "default" else "")
                   + ("__noremat" if args.no_remat else ""))
            path = os.path.join(args.out, tag + ".json")
            if not cell_applicable(arch, shape_name):
                rec = dict(arch=arch, shape=shape_name, ok=True,
                           skipped=True, multi_pod=args.multi_pod,
                           reason="full-attention arch: long_500k requires "
                                  "sub-quadratic mixing (DESIGN.md Sec. 5)")
                json.dump(rec, open(path, "w"), indent=1)
                print(f"[skip] {tag}")
                continue
            print(f"[cell] {tag} ...", flush=True)
            try:
                rec = run_cell(arch, shape_name, args.multi_pod,
                               unroll=args.unroll, rules_name=args.rules,
                               remat=not args.no_remat)
                mb = (rec["memory"]["argument_bytes"] or 0) / 2**30
                print(f"  ok: compile {rec['t_compile_s']}s, "
                      f"args {mb:.2f} GiB/dev, "
                      f"flops {rec['cost']['flops']:.3e}, "
                      f"wire {rec['collectives']['total_wire_bytes']:.3e} B",
                      flush=True)
            except Exception as e:
                rec = dict(arch=arch, shape=shape_name, ok=False,
                           multi_pod=args.multi_pod, error=str(e),
                           traceback=traceback.format_exc())
                print(f"  FAIL: {e}", flush=True)
            json.dump(rec, open(path, "w"), indent=1)


if __name__ == "__main__":
    main()
