"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
Mesh construction goes through `repro.compat` so the same code runs on
jax versions with and without `jax.sharding.AxisType`.
"""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small mesh over host devices (tests / examples)."""
    if pod:
        return compat.make_mesh((pod, data, model), ("pod", "data", "model"))
    return compat.make_mesh((data, model), ("data", "model"))


def require_host_devices(n: int) -> None:
    """Fail fast, with the recipe, when fewer than n host devices exist.

    XLA fixes the device count at backend init, so this cannot be repaired
    from inside the process — callers that need a multi-shard host mesh
    (distributed churn, the subprocess tests) must set the flag first.
    """
    import jax

    have = jax.device_count()
    if have < n:
        raise RuntimeError(
            f"need {n} host devices, have {have}: set "
            f'XLA_FLAGS="--xla_force_host_platform_device_count={n}" '
            "before the first jax import"
        )


def make_zone_mesh(n_model: int, data: int = 1):
    """Mesh over the FIRST data*n_model host devices.

    Elastic membership (`repro.core.runtime.reshard`) runs meshes of
    several model-axis sizes in ONE process — each must build over a
    device prefix instead of the full device set, so a 4-device process
    can host the n_nodes=2 and n_nodes=4 topologies of one join/leave
    schedule side by side."""
    import jax

    require_host_devices(data * n_model)
    devs = jax.devices()[: data * n_model]
    return compat.make_mesh((data, n_model), ("data", "model"),
                            devices=devs)


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
