"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small mesh over host devices (tests / examples)."""
    if pod:
        return jax.make_mesh(
            (pod, data, model), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3,
        )
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
