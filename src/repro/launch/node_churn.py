"""Elastic-membership scenario driver: live node join/leave under content
churn (DESIGN.md Sec. 9).

Drives `repro.core.churn.run_node_churn` — interleaved membership rounds
(zone split/merge + bucket-state handoff), soft-state content churn, and
queries — and prints the per-epoch ledger: node count, recall, handoff
bytes, refresh bytes, router drops.  Optionally runs the static-topology
reference (`run_churn`) on the SAME RNG trajectory and reports the recall
gap (the acceptance bound is 0.02; in practice the gap is 0.0 — the
global bucket array is invariant under a membership round).

Node counts > 1 need that many host devices; when the current process has
too few, the driver re-execs itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count`` set (the flag is
fixed at jax backend init, so it cannot be repaired in-process).

    PYTHONPATH=src python -m repro.launch.node_churn --smoke
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys


def _parse_schedule(text: str) -> tuple[int, ...]:
    try:
        sched = tuple(int(x) for x in text.split(",") if x.strip())
    except ValueError as e:
        raise SystemExit(f"bad --schedule {text!r}: {e}")
    if not sched:
        raise SystemExit("--schedule must name at least one node count")
    return sched


def run(args, obs=None) -> dict:
    import numpy as np

    from repro.core.churn import (
        ChurnConfig, NodeChurnConfig, run_churn, run_node_churn,
    )

    cfg = ChurnConfig(
        num_users=args.users, dim=args.d, k=args.k, L=args.L,
        capacity=args.capacity, epochs=args.epochs,
        update_rate=args.update_rate, churn_rate=args.churn_rate,
        refresh_every=args.refresh_every, ttl_epochs=args.ttl_epochs,
        num_queries=args.queries, m=args.m, seed=args.seed,
    )
    sched = _parse_schedule(args.schedule)
    out = run_node_churn(NodeChurnConfig(churn=cfg, schedule=sched), obs=obs)

    print(f"[node-churn] schedule={','.join(map(str, sched))} "
          f"refresh_every={cfg.refresh_every}")
    print("epoch,n_nodes,recall,handoff_bytes,refresh_bytes,dropped")
    for i in range(len(out["recalls"])):
        print(f"{i + 1},{out['n_nodes'][i]},{out['recalls'][i]:.4f},"
              f"{out['handoff_bytes'][i]},{out['refresh_bytes'][i]},"
              f"{out['dropped_probes'][i]}")
    print(f"[node-churn] mean_recall={out['mean_recall']:.4f} "
          f"rounds={len(out['reshard_events'])} "
          f"total_handoff_bytes={out['total_handoff_bytes']} "
          f"total_refresh_bytes={out['total_refresh_bytes']} "
          f"dropped={int(out['dropped_probes'].sum())}")

    if args.reference:
        ref = run_churn(cfg)
        gap = float(np.abs(out["recalls"] - ref["recalls"]).max())
        print(f"[node-churn] static-reference recall gap (max |diff|) = "
              f"{gap:.4f}")
        out["reference_gap"] = gap
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CPU-friendly preset + sanity assertions")
    ap.add_argument("--schedule", default="1,2,4,2,1,2,1",
                    help="comma-separated node count per epoch "
                         "(powers of two; last value holds)")
    ap.add_argument("--users", type=int, default=4000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--L", type=int, default=4)
    ap.add_argument("--m", type=int, default=10)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--queries", type=int, default=128)
    ap.add_argument("--update-rate", type=float, default=0.05)
    ap.add_argument("--churn-rate", type=float, default=0.02)
    ap.add_argument("--refresh-every", type=int, default=2)
    ap.add_argument("--ttl-epochs", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-reference", dest="reference",
                    action="store_false",
                    help="skip the static-topology comparison run")
    ap.add_argument("--trace-out", default=None,
                    help="write Chrome-trace-event JSON (Perfetto) here")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics-registry JSON snapshot here")
    ap.add_argument("--inner", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.smoke:
        args.users, args.d, args.k, args.L = 1200, 32, 5, 2
        args.epochs, args.queries, args.capacity = 6, 64, 64
        args.schedule = "1,2,4,2,1,2,1"
        args.reference = True  # the smoke gate asserts the recall gap

    need = max(_parse_schedule(args.schedule))
    if not args.inner and need > 1:
        # membership needs `need` host devices; XLA fixes the count at
        # backend init, so re-exec with the flag set (jax not yet imported
        # in THIS process only if we exec before touching it — hence the
        # unconditional subprocess hop instead of a device-count probe).
        env = dict(os.environ)
        # append AFTER any pre-existing flags: XLA honors the LAST
        # occurrence of a duplicated flag, so prepending would let an
        # exported --xla_force_host_platform_device_count silently win
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={need}"
        ).strip()
        cmd = [sys.executable, "-m", "repro.launch.node_churn", "--inner"]
        cmd += (argv if argv is not None else sys.argv[1:])
        proc = subprocess.run(cmd, env=env)
        raise SystemExit(proc.returncode)

    obs = None
    if args.trace_out or args.metrics_out:
        from repro.obs import Observability

        obs = Observability()

    out = run(args, obs=obs)

    if obs is not None:
        # every membership round must have dumped the flight ring
        rounds = len(out["reshard_events"])
        dumped = sum(d["reason"] == "reshard" for d in obs.flight.dumps)
        assert dumped == rounds, (dumped, rounds)
        if args.trace_out:
            obs.export_trace(args.trace_out)
            print(f"[node-churn] trace -> {args.trace_out}")
        if args.metrics_out:
            obs.export_metrics(args.metrics_out)
            print(f"[node-churn] metrics -> {args.metrics_out}")

    if args.smoke:
        import numpy as np

        from repro.core import costmodel

        # the elastic run must track the static reference on the same RNG
        # trajectory (acceptance bound), charge handoff on exactly the
        # membership epochs, and drop nothing in the router.
        assert out["reference_gap"] <= 0.02, out["reference_gap"]
        assert int(out["dropped_probes"].sum()) == 0
        n = out["n_nodes"]
        n0 = _parse_schedule(args.schedule)[0]
        changed = np.concatenate([[n[0] != n0], n[1:] != n[:-1]])
        assert np.all((out["handoff_bytes"] > 0) == changed), (
            out["handoff_bytes"], n)
        ev = out["reshard_events"][0]
        assert ev.handoff_bytes == costmodel.estimate_handoff_bytes(
            args.L, 1 << args.k, args.capacity, args.d, ev.old_n, ev.new_n)
        print("[smoke] OK")
    return out


if __name__ == "__main__":
    main()
