"""Pallas mega-kernel: fused gather -> score -> top-m for the per-node
query path (DESIGN.md Sec. 11).

The staged path materializes a [r, P*C] candidate id buffer and a
[r, P*C, D] payload buffer in HBM between its gather, score, and top-m
stages.  This kernel runs the whole per-(query, table) pipeline inside
one `pallas_call`: probed bucket rows are gathered straight into VMEM
via scalar-prefetch-driven BlockSpecs (the flattened bucket index of
every (row, probe) pair is prefetched, so the gather IS the block index
map — no gathered intermediate ever exists in HBM), scored in-register
(dot product for embedded f32 payloads, SWAR-popcount hamming for
bit-packed sketch words), deduplicated, and reduced to the top m
(id, score) pairs per row.

Grid: (r/TB, P, TB) — probe steps and rows-within-block iterate
sequentially ("arbitrary" semantics) while a [TB, P*KC] VMEM scratch
accumulates (id, score) lanes; the final step of each row block runs the
dedup + m-step selection and writes the [TB, m] outputs.  TB and KC
(the per-probe candidate lane width) are the autotuned block shape
(`kernels/autotune.py`, swept by benchmarks/roofline.py).

Semantics are pinned bit-exactly to the staged path
(`core.scoring.score_topk` over the stacked gather):
  * candidate validity: probe bit p of the prefetched probe-word must be
    set, slot id >= 0, id != the row's exclude id — EMPTY (-1) sentinels
    ride in-register, there is no separate mask buffer;
  * duplicate ids: the FIRST occurrence in (probe-major, slot-minor)
    flat order survives with its own score — identical to the stable
    id-sort + repeat-of-previous mask in `core.scoring.dedupe_topk`;
  * selection: descending score, ties to the LOWEST id (the staged
    top_k over id-sorted lanes breaks ties the same way).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.hamming import _popcount32

NEG = float("-inf")
IMAX = 2**31 - 1  # id sentinel > any real id (ids are int32 >= 0)


def _probe_scores(ids_row, pay, q, pw, excl, p, *, score: str):
    """[KC] (ids, scores) of one probed bucket row, invalids -1 / -inf."""
    pvalid = ((pw >> p) & 1) > 0
    cand = jnp.where(pvalid & (ids_row >= 0), ids_row, jnp.int32(-1))
    cand = jnp.where(cand == excl, jnp.int32(-1), cand)
    if score == "dot":
        s = jax.lax.dot_general(
            pay, q, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # [KC]
    else:
        s = -jnp.sum(_popcount32(jnp.bitwise_xor(pay, q[None, :])),
                     axis=-1).astype(jnp.float32)
    return cand, jnp.where(cand >= 0, s, NEG)


def _select_topm(ids_all, sc_all, m: int):
    """Dedup (first occurrence wins) + m-step (max score, min id) select.

    ids_all/sc_all: [TB, K].  Returns (ids [TB, m], scores [TB, m]).
    """
    eq = ids_all[:, :, None] == ids_all[:, None, :]       # [TB, Ki, Kj]
    pos_i = jax.lax.broadcasted_iota(jnp.int32, eq.shape, 1)
    pos_j = jax.lax.broadcasted_iota(jnp.int32, eq.shape, 2)
    dup = jnp.any(eq & (pos_i < pos_j), axis=1)           # [TB, K] (j axis)
    sc = jnp.where(dup | (ids_all < 0), NEG, sc_all)
    out_i, out_s = [], []
    for _ in range(m):  # m static & small: unrolled selection
        bs = jnp.max(sc, axis=1)                          # [TB]
        is_best = sc == bs[:, None]
        bi = jnp.min(jnp.where(is_best, ids_all, IMAX), axis=1)
        dead = jnp.isneginf(bs)
        out_i.append(jnp.where(dead, jnp.int32(-1), bi.astype(jnp.int32)))
        out_s.append(bs)
        sc = jnp.where(ids_all == bi[:, None], NEG, sc)
    return jnp.stack(out_i, axis=1), jnp.stack(out_s, axis=1)


def _fused_query_kernel(
    fb_ref, meta_ref,               # scalar prefetch: [r, P], [r, 2]
    q_ref, ids_ref, pay_ref,        # blocks: [1, DW], [1, KC], [1, KC, DW]
    ids_out_ref, sc_out_ref,        # blocks: [TB, m]
    id_acc, sc_acc,                 # VMEM scratch: [TB, P*KC]
    *, m: int, tb: int, kc: int, n_probes: int, score: str,
):
    p = pl.program_id(1)
    t = pl.program_id(2)
    r = pl.program_id(0) * tb + t
    cand, s = _probe_scores(
        ids_ref[0], pay_ref[0], q_ref[0],
        meta_ref[r, 0], meta_ref[r, 1], p, score=score,
    )
    idx = (pl.dslice(t, 1), pl.dslice(p * kc, kc))
    pl.store(id_acc, idx, cand[None, :])
    pl.store(sc_acc, idx, s[None, :])

    @pl.when((p == n_probes - 1) & (t == tb - 1))
    def _reduce():
        top_i, top_s = _select_topm(id_acc[...], sc_acc[...], m)
        ids_out_ref[...] = top_i
        sc_out_ref[...] = top_s


@functools.partial(
    jax.jit, static_argnames=("m", "tb", "kc", "score", "interpret")
)
def fused_query_pallas(
    ids_flat: jax.Array,   # int32 [T*NB, KC] (capacity padded with -1)
    pay_flat: jax.Array,   # [T*NB, KC, DW] f32 vectors or uint32 words
    q: jax.Array,          # [r, DW] f32 queries or uint32 query words
    fb: jax.Array,         # int32 [r, P] flattened bucket row per probe
    meta: jax.Array,       # int32 [r, 2] (probe-validity word, exclude id)
    *,
    m: int,
    tb: int,
    kc: int,
    score: str = "dot",
    interpret: bool = False,
):
    """(ids int32 [r, m], scores f32 [r, m]) — r % tb == 0 required;
    pad rows must carry probe-word 0 (they return all -1 / -inf)."""
    r, n_probes = fb.shape
    dw = pay_flat.shape[-1]
    grid = (r // tb, n_probes, tb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q.shape[-1]),
                         lambda i, p, t, fb_, mt: (i * tb + t, 0)),
            pl.BlockSpec((1, kc),
                         lambda i, p, t, fb_, mt: (fb_[i * tb + t, p], 0)),
            pl.BlockSpec((1, kc, dw),
                         lambda i, p, t, fb_, mt: (fb_[i * tb + t, p], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, m), lambda i, p, t, fb_, mt: (i, 0)),
            pl.BlockSpec((tb, m), lambda i, p, t, fb_, mt: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((tb, n_probes * kc), jnp.int32),
            pltpu.VMEM((tb, n_probes * kc), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _fused_query_kernel,
            m=m, tb=tb, kc=kc, n_probes=n_probes, score=score,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((r, m), jnp.int32),
            jax.ShapeDtypeStruct((r, m), jnp.float32),
        ],
        compiler_params=dict(
            mosaic=dict(
                dimension_semantics=("arbitrary", "arbitrary", "arbitrary")
            )
        ),
        interpret=interpret,
    )(fb, meta, q, ids_flat, pay_flat)


def _fused_contains_kernel(
    fb_ref, meta_ref,               # scalar prefetch: [r, P], [r, 2]
    ids_ref,                        # block: [1, KC]
    hit_ref,                        # block: [TB, 1] int32
    acc,                            # VMEM scratch: [TB, 1] int32
    *, tb: int, n_probes: int,
):
    p = pl.program_id(1)
    t = pl.program_id(2)
    r = pl.program_id(0) * tb + t
    pvalid = ((meta_ref[r, 0] >> p) & 1) > 0
    hit = jnp.any((ids_ref[0] == meta_ref[r, 1]) & pvalid)
    prev = pl.load(acc, (pl.dslice(t, 1), pl.dslice(0, 1)))  # [1, 1]
    cur = jnp.where(p == 0, hit.astype(jnp.int32),
                    prev[0, 0] | hit.astype(jnp.int32))
    pl.store(acc, (pl.dslice(t, 1), pl.dslice(0, 1)), cur[None, None])

    @pl.when((p == n_probes - 1) & (t == tb - 1))
    def _emit():
        hit_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def fused_contains_pallas(
    ids_flat: jax.Array,   # int32 [T*NB, KC]
    fb: jax.Array,         # int32 [r, P]
    meta: jax.Array,       # int32 [r, 2] (probe-validity word, target id)
    *,
    tb: int,
    interpret: bool = False,
):
    """int32 [r, 1]: nonzero iff the target id sits in any valid probed
    bucket of the row.  Same gather discipline as `fused_query_pallas`,
    metadata-only (no payload blocks travel)."""
    r, n_probes = fb.shape
    kc = ids_flat.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(r // tb, n_probes, tb),
        in_specs=[
            pl.BlockSpec((1, kc),
                         lambda i, p, t, fb_, mt: (fb_[i * tb + t, p], 0)),
        ],
        out_specs=pl.BlockSpec((tb, 1), lambda i, p, t, fb_, mt: (i, 0)),
        scratch_shapes=[pltpu.VMEM((tb, 1), jnp.int32)],
    )
    return pl.pallas_call(
        functools.partial(_fused_contains_kernel, tb=tb, n_probes=n_probes),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.int32),
        compiler_params=dict(
            mosaic=dict(
                dimension_semantics=("arbitrary", "arbitrary", "arbitrary")
            )
        ),
        interpret=interpret,
    )(fb, meta, ids_flat)
