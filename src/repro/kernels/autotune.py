"""Per-device-kind block-shape cache for the fused query kernel.

The fused kernel's block shape — TB (query rows per block) and KC
(candidate lanes per probe, the bucket-capacity pad) — trades VMEM
scratch footprint against grid overhead, and the right point differs per
device kind.  `benchmarks/roofline.py --sweep` is the oracle: it times
the (TB, KC) grid against the analytic query-path roofline and calls
`put()` with the winner.  `kernels/ops.py` consults `get()` at dispatch
time and falls back to `DEFAULTS` when no entry exists, so a missing or
stale cache degrades to working (just untuned) kernels, never to an
error.

The cache is a committed JSON file next to this module keyed by
`{device_kind: {op: {params...}}}`; set REPRO_AUTOTUNE_CACHE to point at
a scratch file when sweeping without dirtying the tree.
"""

from __future__ import annotations

import functools
import json
import os
import pathlib

import jax

_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_CACHE_FILE = pathlib.Path(__file__).resolve().parent / "autotune_cache.json"

# Safe fallbacks when no swept entry exists.  CPU runs the kernel in
# interpret mode, where big lane pads only add python-loop work; real
# accelerators want full 128-wide lanes.
DEFAULTS = {
    "cpu": {
        "fused_query": {"tb": 8, "kc": 8},
        "fused_query_routed": {"tb": 8, "kc": 8},
    },
    "*": {
        "fused_query": {"tb": 8, "kc": 128},
        "fused_query_routed": {"tb": 8, "kc": 128},
    },
}


def cache_path() -> pathlib.Path:
    return pathlib.Path(os.environ.get(_CACHE_ENV, _CACHE_FILE))


def device_kind() -> str:
    """Normalized device kind of the default backend (cache key)."""
    kind = jax.devices()[0].device_kind
    return kind.strip().lower().replace(" ", "_")


@functools.lru_cache(maxsize=None)
def _load(path_str: str) -> dict:
    path = pathlib.Path(path_str)
    if not path.exists():
        return {}
    try:
        return json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return {}


def get(op: str, kind: str | None = None) -> dict:
    """Tuned params for `op` on this device kind (or `DEFAULTS`)."""
    kind = kind or device_kind()
    entry = _load(str(cache_path())).get(kind, {}).get(op)
    if entry:
        return dict(entry)
    fam = "cpu" if kind == "cpu" else "*"
    return dict(DEFAULTS.get(fam, {}).get(op, {}))


def put(op: str, params: dict, kind: str | None = None) -> pathlib.Path:
    """Record swept winners for `op`; returns the cache path written."""
    kind = kind or device_kind()
    path = cache_path()
    cache = dict(_load(str(path)))
    cache.setdefault(kind, {})
    cache[kind] = {**cache[kind], op: dict(params)}
    path.write_text(json.dumps(cache, indent=2, sort_keys=True) + "\n")
    _load.cache_clear()
    return path
