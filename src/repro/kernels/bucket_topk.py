"""Pallas TPU kernel: fused bucket scoring + top-m selection.

The paper's per-node `LocalSimSearch` (Alg. 1 line 11 / Alg. 2 line 2):
score a query against every vector in the probed bucket(s) and keep the
best m.  On TPU the bucket payload tile lives in VMEM, the scoring is a
[TB, D] x [TB, KC, D] batched dot on the MXU, and the top-m selection is an
m-step argmax loop on the VPU — the [TB, KC] score matrix never leaves VMEM.

m is small and static (paper uses m = 10), so the unrolled selection loop
beats a full sort by a wide margin.

Tiling: grid over the query batch (b/TB).  KC (candidates per query =
L * probes * capacity, gathered by the caller) is lane-padded to 128;
invalid slots carry a 0 validity bit and return score=-inf, idx=-1.
Validity arrives as packed uint32 bitfield words ([TB, KC/32], bit i of
word w = slot w*32 + i) and is unpacked in-register — the int8 mask
lanes that used to ride beside the payload tile are gone.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = float("-inf")  # plain Python float: jnp constants can't be captured by kernels


def _unpack_bits(words: jax.Array, kc: int) -> jax.Array:
    """uint32 bitfield words [TB, KC/32] -> bool mask [TB, KC]."""
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    bits = (words[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(words.shape[0], kc) != 0


def _topk_kernel(q_ref, cand_ref, vwords_ref, s_ref, i_ref, *, m: int):
    q = q_ref[...]            # [TB, D]
    cand = cand_ref[...]      # [TB, KC, D]
    vwords = vwords_ref[...]  # [TB, KC/32] uint32 bitfields

    scores = jax.lax.dot_general(
        cand,
        q,
        (((2,), (1,)), ((0,), (0,))),  # batch over TB, contract D
        preferred_element_type=jnp.float32,
    )  # [TB, KC]
    scores = jnp.where(_unpack_bits(vwords, scores.shape[1]), scores, NEG)

    kc = scores.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    cur = scores
    for j in range(m):  # m static & small: unrolled argmax selection
        best_s = jnp.max(cur, axis=1)                       # [TB]
        is_best = cur == best_s[:, None]
        best_i = jnp.min(jnp.where(is_best, col, kc), axis=1)  # lowest index
        s_ref[:, j] = jnp.where(jnp.isneginf(best_s), NEG, best_s)
        i_ref[:, j] = jnp.where(
            jnp.isneginf(best_s), jnp.int32(-1), best_i.astype(jnp.int32)
        )
        cur = jnp.where(col == best_i[:, None], NEG, cur)


@functools.partial(jax.jit, static_argnames=("m", "tb", "interpret"))
def bucket_topk_pallas(
    q: jax.Array,       # [b, d] float32   (b % tb == 0, d lane-padded)
    cand: jax.Array,    # [b, kc, d] float32 (kc % 128 == 0)
    vwords: jax.Array,  # [b, kc/32] uint32 validity bitfields
    *,
    m: int,
    tb: int = 8,
    interpret: bool = False,
):
    b, kc, d = cand.shape
    grid = (b // tb,)
    return pl.pallas_call(
        functools.partial(_topk_kernel, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((tb, kc, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, kc // 32), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, m), lambda i: (i, 0)),
            pl.BlockSpec((tb, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), jnp.float32),
            jax.ShapeDtypeStruct((b, m), jnp.int32),
        ],
        interpret=interpret,
    )(q, cand, vwords)
