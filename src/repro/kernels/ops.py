"""Public jit'd wrappers for the Pallas kernels.

Each op pads inputs to hardware-aligned tiles (lane = 128, MXU-friendly
contraction dims), dispatches to the Pallas kernel, and slices the result
back.  On CPU hosts the kernels execute in interpret mode (the kernel body
runs as traced jnp ops) — the TPU path is identical code with
interpret=False.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import bucket_topk as _bt
from repro.kernels import hamming as _hm
from repro.kernels import simhash as _sh

LANE = 128


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def simhash(
    x: jax.Array,            # [n, d] float
    hyperplanes: jax.Array,  # [L, k, d] float
    *,
    tn: int = 256,
    td: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Packed LSH codes, uint32 [n, L]. Matches `ref.simhash_ref`."""
    interpret = _on_cpu() if interpret is None else interpret
    n, d = x.shape
    L, k, _ = hyperplanes.shape
    h_t = hyperplanes.reshape(L * k, d).T.astype(jnp.float32)  # [d, L*k]
    h_t = _pad_to(h_t, 1, LANE)
    tn_eff = min(tn, max(8, n))
    x_p = _pad_to(x.astype(jnp.float32), 0, tn_eff)
    td_eff = min(td, d) if d % min(td, d) == 0 else d
    # choose a td that divides d (fall back to whole-d single step)
    if d % td == 0:
        td_eff = td
    else:
        td_eff = d
        h_t = h_t  # single d-step
    x_p = _pad_to(x_p, 1, td_eff)
    h_t = _pad_to(h_t, 0, td_eff)
    out = _sh.simhash_pallas(
        x_p, h_t, k=k, L=L, tn=tn_eff, td=td_eff, interpret=interpret
    )
    return out[:n]


def bucket_topk(
    q: jax.Array,      # [b, d] float
    cand: jax.Array,   # [b, kc, d] float candidate payloads
    valid: jax.Array,  # bool [b, kc]
    m: int,
    *,
    tb: int = 8,
    interpret: bool | None = None,
):
    """Fused score + top-m. Returns (scores [b, m] f32, idx [b, m] i32).
    Matches `ref.bucket_topk_ref` (ties -> lowest index)."""
    interpret = _on_cpu() if interpret is None else interpret
    b, kc, d = cand.shape
    tb_eff = min(tb, max(1, b))
    q_p = _pad_to(q.astype(jnp.float32), 0, tb_eff)
    cand_p = _pad_to(cand.astype(jnp.float32), 0, tb_eff)
    valid_p = _pad_to(valid.astype(jnp.int8), 0, tb_eff)
    q_p = _pad_to(q_p, 1, LANE)
    cand_p = _pad_to(_pad_to(cand_p, 2, LANE), 1, LANE)
    valid_p = _pad_to(valid_p, 1, LANE)
    s, i = _bt.bucket_topk_pallas(
        q_p, cand_p, valid_p, m=m, tb=tb_eff, interpret=interpret
    )
    return s[:b], i[:b]


def hamming(
    codes: jax.Array,       # [n] uint32
    cand_codes: jax.Array,  # [n, kc] uint32
    *,
    tn: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Hamming distances int32 [n, kc]. Matches `ref.hamming_ref`.
    Padded candidate columns return distance vs code 0 and are sliced off."""
    interpret = _on_cpu() if interpret is None else interpret
    n, kc = cand_codes.shape
    tn_eff = min(tn, max(8, n))
    codes_p = _pad_to(codes.astype(jnp.uint32), 0, tn_eff)
    cand_p = _pad_to(cand_codes.astype(jnp.uint32), 0, tn_eff)
    cand_p = _pad_to(cand_p, 1, LANE)
    out = _hm.hamming_pallas(codes_p, cand_p, tn=tn_eff, interpret=interpret)
    return out[:n, :kc]
