"""Public jit'd wrappers for the Pallas kernels.

Each op pads inputs to hardware-aligned tiles (lane = 128, MXU-friendly
contraction dims), dispatches to the Pallas kernel, and slices the result
back.  On CPU hosts the kernels execute in interpret mode (the kernel body
runs as traced jnp ops) — the TPU path is identical code with
interpret=False.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels import bucket_topk as _bt
from repro.kernels import fused_query as _fq
from repro.kernels import hamming as _hm
from repro.kernels import simhash as _sh

LANE = 128


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _pack_bits(bits: jax.Array) -> jax.Array:
    """bool/int [..., n] (n % 32 == 0) -> uint32 bitfield words [..., n/32].

    Kernel-side validity layout only (little-endian, bit i of word w =
    lane w*32 + i); the canonical sketch-code packing lives in
    `core.packed` — this tiny twin exists so kernels/ has no import edge
    into core/.
    """
    *lead, n = bits.shape
    grouped = bits.reshape(*lead, n // 32, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32)


def simhash(
    x: jax.Array,            # [n, d] float
    hyperplanes: jax.Array,  # [L, k, d] float
    *,
    tn: int = 256,
    td: int = 512,
    packed: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """LSH sketch codes: uint32 [n, L] per-table codes, or with
    packed=True dense `core.packed` words uint32 [n, ceil(L*k/32)]
    emitted directly in-kernel.  Matches `ref.simhash_ref` (resp. its
    pack_codes composition)."""
    interpret = _on_cpu() if interpret is None else interpret
    n, d = x.shape
    L, k, _ = hyperplanes.shape
    h_t = hyperplanes.reshape(L * k, d).T.astype(jnp.float32)  # [d, L*k]
    h_t = _pad_to(h_t, 1, LANE)
    tn_eff = min(tn, max(8, n))
    x_p = _pad_to(x.astype(jnp.float32), 0, tn_eff)
    td_eff = min(td, d) if d % min(td, d) == 0 else d
    # choose a td that divides d (fall back to whole-d single step)
    if d % td == 0:
        td_eff = td
    else:
        td_eff = d
        h_t = h_t  # single d-step
    x_p = _pad_to(x_p, 1, td_eff)
    h_t = _pad_to(h_t, 0, td_eff)
    out = _sh.simhash_pallas(
        x_p, h_t, k=k, L=L, tn=tn_eff, td=td_eff, packed=packed,
        interpret=interpret,
    )
    return out[:n]


def bucket_topk(
    q: jax.Array,      # [b, d] float
    cand: jax.Array,   # [b, kc, d] float candidate payloads
    valid: jax.Array,  # bool [b, kc]
    m: int,
    *,
    tb: int = 8,
    interpret: bool | None = None,
):
    """Fused score + top-m. Returns (scores [b, m] f32, idx [b, m] i32).
    Matches `ref.bucket_topk_ref` (ties -> lowest index).  Validity
    travels as packed uint32 bitfield words (32x less mask traffic than
    the old int8 lanes); the kernel unpacks bits in-register."""
    interpret = _on_cpu() if interpret is None else interpret
    b, kc, d = cand.shape
    tb_eff = min(tb, max(1, b))
    q_p = _pad_to(q.astype(jnp.float32), 0, tb_eff)
    cand_p = _pad_to(cand.astype(jnp.float32), 0, tb_eff)
    valid_p = _pad_to(valid.astype(jnp.int8), 0, tb_eff)
    q_p = _pad_to(q_p, 1, LANE)
    cand_p = _pad_to(_pad_to(cand_p, 2, LANE), 1, LANE)
    valid_p = _pad_to(valid_p, 1, LANE)
    s, i = _bt.bucket_topk_pallas(
        q_p, cand_p, _pack_bits(valid_p), m=m, tb=tb_eff, interpret=interpret
    )
    return s[:b], i[:b]


def hamming(
    codes: jax.Array,       # [n] uint32 or [n, W] packed words
    cand_codes: jax.Array,  # [n, kc] uint32 or [n, kc, W] packed words
    *,
    tn: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Hamming distances int32 [n, kc].

    Single-word inputs ([n] vs [n, kc]) match `ref.hamming_ref`;
    multi-word packed rows ([n, W] vs [n, kc, W], the `core.packed`
    layout) match `ref.hamming_words_ref` — this shape is the staged
    scoring primitive of `score="hamming"` runtimes.  Padded candidate
    columns return distance vs code 0 and are sliced off."""
    interpret = _on_cpu() if interpret is None else interpret
    if cand_codes.ndim == 3:
        n, kc, w = cand_codes.shape
        tn_eff = min(tn, max(8, n))
        codes_p = _pad_to(codes.astype(jnp.uint32), 0, tn_eff)
        cand_p = _pad_to(cand_codes.astype(jnp.uint32), 0, tn_eff)
        cand_p = _pad_to(cand_p, 1, LANE if not interpret else 8)
        out = _hm.hamming_words_pallas(
            codes_p, cand_p, tn=tn_eff, interpret=interpret
        )
        return out[:n, :kc]
    n, kc = cand_codes.shape
    tn_eff = min(tn, max(8, n))
    codes_p = _pad_to(codes.astype(jnp.uint32), 0, tn_eff)
    cand_p = _pad_to(cand_codes.astype(jnp.uint32), 0, tn_eff)
    cand_p = _pad_to(cand_p, 1, LANE)
    out = _hm.hamming_pallas(codes_p, cand_p, tn=tn_eff, interpret=interpret)
    return out[:n, :kc]


def fused_query(
    ids_flat: jax.Array,   # int32 [T*NB, C] bucket slot ids (-1 = empty)
    pay_flat: jax.Array,   # [T*NB, C, D] f32 vectors or [T*NB, C, W] words
    q: jax.Array,          # [r, D] f32 queries or [r, W] packed query words
    fb: jax.Array,         # int32 [r, P] flattened bucket row per probe
    meta: jax.Array,       # int32 [r, 2] (probe-validity word, exclude id)
    *,
    m: int,
    score: str = "dot",
    tb: int | None = None,
    kc: int | None = None,
    tune_op: str = "fused_query",
    interpret: bool | None = None,
):
    """Fused gather -> score -> top-m (ids [r, m] i32, scores [r, m] f32).

    Matches `ref.fused_query_ref` — which routes through
    `core.scoring.dedupe_topk`, so fused results are bit-identical to
    the staged path by construction.  tb/kc default to the autotuned
    block shape for this device kind (`kernels.autotune`) under the
    `tune_op` key — the routed mesh stage sweeps separately as
    "fused_query_routed" since its row count is n·cap, not b·L; kc is
    the capacity pad multiple (bucket rows are padded to a whole number
    of candidate lanes)."""
    interpret = _on_cpu() if interpret is None else interpret
    tuned = autotune.get(tune_op)
    tb = int(tuned.get("tb", 8)) if tb is None else tb
    kc = int(tuned.get("kc", 8 if interpret else LANE)) if kc is None else kc
    r, _ = fb.shape
    c = ids_flat.shape[-1]
    kc_eff = min(kc, max(8, c)) if interpret else kc
    ids_p = _pad_to(ids_flat.astype(jnp.int32), 1, kc_eff, value=-1)
    pay_p = _pad_to(pay_flat, 1, kc_eff)
    if score == "dot":
        pay_p = _pad_to(pay_p.astype(jnp.float32), 2, 8 if interpret else LANE)
        q_p = _pad_to(q.astype(jnp.float32), 1, 8 if interpret else LANE)
    else:
        pay_p = pay_p.astype(jnp.uint32)
        q_p = q.astype(jnp.uint32)
    tb_eff = min(tb, max(1, r))
    fb_p = jnp.clip(
        _pad_to(fb.astype(jnp.int32), 0, tb_eff), 0, ids_p.shape[0] - 1
    )
    meta_p = _pad_to(meta.astype(jnp.int32), 0, tb_eff)  # pad: pword 0
    ids_r, sc_r = _fq.fused_query_pallas(
        ids_p, pay_p, _pad_to(q_p, 0, tb_eff), fb_p, meta_p,
        m=m, tb=tb_eff, kc=ids_p.shape[-1], score=score, interpret=interpret,
    )
    return ids_r[:r], sc_r[:r]


def fused_contains(
    ids_flat: jax.Array,   # int32 [T*NB, C]
    fb: jax.Array,         # int32 [r, P]
    meta: jax.Array,       # int32 [r, 2] (probe-validity word, target id)
    *,
    tb: int | None = None,
    tune_op: str = "fused_query",
    interpret: bool | None = None,
) -> jax.Array:
    """Fused membership probe: bool [r]. Matches `ref.fused_contains_ref`.
    Needs no payload, so it serves ids-only stores too."""
    interpret = _on_cpu() if interpret is None else interpret
    tuned = autotune.get(tune_op)
    tb = int(tuned.get("tb", 8)) if tb is None else tb
    r, _ = fb.shape
    c = ids_flat.shape[-1]
    kc_eff = min(8, max(1, c)) if interpret else LANE
    ids_p = _pad_to(ids_flat.astype(jnp.int32), 1, kc_eff, value=-1)
    tb_eff = min(tb, max(1, r))
    fb_p = jnp.clip(
        _pad_to(fb.astype(jnp.int32), 0, tb_eff), 0, ids_p.shape[0] - 1
    )
    meta_p = _pad_to(meta.astype(jnp.int32), 0, tb_eff)
    hit = _fq.fused_contains_pallas(
        ids_p, fb_p, meta_p, tb=tb_eff, interpret=interpret
    )
    return hit[:r, 0] > 0
