"""Pallas TPU kernel: popcount Hamming distance over packed sketch codes.

Two entry points sharing one SWAR popcount (pure VPU bit arithmetic, no
MXU):

  * `hamming_pallas` — single-word codes ([n] vs [n, kc]), used by
    ranked multi-probe planning and Layered-LSH node assignment;
  * `hamming_words_pallas` — multi-word packed rows ([n, W] vs
    [n, kc, W], the `core.packed` layout), the staged scoring primitive
    of `score="hamming"` runtimes; the fused query kernel inlines the
    same popcount for its hamming mode.

Tiling: grid over (n/TN); candidate dim KC is lane-padded to 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _popcount32(x: jax.Array) -> jax.Array:
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _hamming_kernel(codes_ref, cand_ref, out_ref):
    codes = codes_ref[...]  # [TN, 1] uint32
    cand = cand_ref[...]    # [TN, KC] uint32
    out_ref[...] = _popcount32(jnp.bitwise_xor(codes, cand))


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def hamming_pallas(
    codes: jax.Array,       # [n] uint32 (n % tn == 0)
    cand_codes: jax.Array,  # [n, kc] uint32 (kc % 128 == 0)
    *,
    tn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    n, kc = cand_codes.shape
    grid = (n // tn,)
    return pl.pallas_call(
        _hamming_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, 1), lambda i: (i, 0)),
            pl.BlockSpec((tn, kc), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tn, kc), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, kc), jnp.int32),
        interpret=interpret,
    )(codes[:, None], cand_codes)


def _hamming_words_kernel(codes_ref, cand_ref, out_ref):
    codes = codes_ref[...]  # [TN, 1, W] uint32
    cand = cand_ref[...]    # [TN, KC, W] uint32
    out_ref[...] = jnp.sum(
        _popcount32(jnp.bitwise_xor(codes, cand)), axis=-1
    )


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def hamming_words_pallas(
    codes: jax.Array,       # [n, W] uint32 packed words (n % tn == 0)
    cand_codes: jax.Array,  # [n, kc, W] uint32 (kc % 128 == 0)
    *,
    tn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    n, kc, w = cand_codes.shape
    grid = (n // tn,)
    return pl.pallas_call(
        _hamming_words_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, 1, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((tn, kc, w), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tn, kc), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, kc), jnp.int32),
        interpret=interpret,
    )(codes[:, None, :], cand_codes)
