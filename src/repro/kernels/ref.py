"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; kernels must match them bit-for-bit (integer
outputs) or to float tolerance (scores).  Top-k selection ties are broken
by lowest index in both ref and kernel.
"""

from __future__ import annotations

import jax.numpy as jnp


def simhash_ref(x: jnp.ndarray, hyperplanes: jnp.ndarray) -> jnp.ndarray:
    """Packed sign-random-projection sketches.

    Args:
      x: [n, d] float.
      hyperplanes: [L, k, d] float.
    Returns:
      uint32 [n, L]; bit j of table l is (x . h_{l,j} >= 0).
    """
    proj = jnp.einsum(
        "nd,lkd->nlk", x.astype(jnp.float32), hyperplanes.astype(jnp.float32)
    )
    bits = (proj >= 0).astype(jnp.uint32)
    k = hyperplanes.shape[1]
    weights = jnp.uint32(1) << jnp.arange(k, dtype=jnp.uint32)
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


def bucket_topk_ref(
    q: jnp.ndarray, cand: jnp.ndarray, valid: jnp.ndarray, m: int
):
    """Fused candidate scoring + top-m.

    Args:
      q: [b, d] unit queries.
      cand: [b, kc, d] candidate vectors (gathered bucket payloads).
      valid: bool [b, kc] — invalid candidates must not be returned.
      m: results per query.
    Returns:
      (scores f32 [b, m], idx int32 [b, m]) — idx into kc, -1 where no valid
      candidate; sorted by descending score, ties -> lowest index.
    """
    scores = jnp.einsum(
        "bd,bkd->bk", q.astype(jnp.float32), cand.astype(jnp.float32)
    )
    scores = jnp.where(valid, scores, -jnp.inf)
    kc = scores.shape[1]
    # tie-break by lowest index: subtract a tiny index-based epsilon ordering
    # implemented exactly via lexicographic argmax loop.
    out_s, out_i = [], []
    cur = scores
    idxs = jnp.arange(kc, dtype=jnp.int32)
    for _ in range(m):
        best = jnp.argmax(cur, axis=1)  # first occurrence of max => lowest idx
        s = jnp.take_along_axis(cur, best[:, None], axis=1)[:, 0]
        out_s.append(s)
        out_i.append(jnp.where(jnp.isfinite(s), best.astype(jnp.int32), -1))
        cur = jnp.where(idxs[None, :] == best[:, None], -jnp.inf, cur)
    return jnp.stack(out_s, axis=1), jnp.stack(out_i, axis=1)


def _popcount32_ref(x: jnp.ndarray) -> jnp.ndarray:
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def hamming_ref(codes: jnp.ndarray, cand_codes: jnp.ndarray) -> jnp.ndarray:
    """Popcount Hamming distances between uint32 codes.

    Args:
      codes: [n] uint32.
      cand_codes: [n, kc] uint32.
    Returns:
      int32 [n, kc].
    """
    x = jnp.bitwise_xor(
        codes[:, None].astype(jnp.uint32), cand_codes.astype(jnp.uint32)
    )
    return _popcount32_ref(x)


def hamming_words_ref(
    codes: jnp.ndarray, cand_codes: jnp.ndarray
) -> jnp.ndarray:
    """Multi-word variant: distances over packed sketch-word rows.

    Args:
      codes: [n, W] uint32 packed words (core.packed layout).
      cand_codes: [n, kc, W] uint32.
    Returns:
      int32 [n, kc] — popcount summed over the word axis.
    """
    x = jnp.bitwise_xor(
        codes[:, None, :].astype(jnp.uint32), cand_codes.astype(jnp.uint32)
    )
    return jnp.sum(_popcount32_ref(x), axis=-1)


def fused_query_ref(
    ids_flat: jnp.ndarray,   # int32 [T*NB, KC]
    pay_flat: jnp.ndarray,   # [T*NB, KC, DW] f32 vectors or uint32 words
    q: jnp.ndarray,          # [r, DW]
    fb: jnp.ndarray,         # int32 [r, P] flattened bucket row per probe
    meta: jnp.ndarray,       # int32 [r, 2] (probe-validity word, exclude id)
    *,
    m: int,
    score: str = "dot",
):
    """Oracle for the fused query mega-kernel: explicit staged pipeline.

    Gathers the probed bucket rows ([r, P, KC] intermediates — exactly
    the HBM traffic the fused kernel exists to avoid), masks candidates
    by probe-validity bit / EMPTY sentinel / exclude id, scores, and
    reduces through `core.scoring.dedupe_topk` — so the oracle IS the
    staged path's semantics, not a re-derivation of them.

    Returns (ids int32 [r, m], scores f32 [r, m]).
    """
    from repro.core.scoring import dedupe_topk  # deps run kernels->core here

    r, n_probes = fb.shape
    kc = ids_flat.shape[-1]
    pw, excl = meta[:, 0], meta[:, 1]
    cand = jnp.take(ids_flat, fb, axis=0)                  # [r, P, KC]
    pvalid = ((pw[:, None] >> jnp.arange(n_probes)) & 1) > 0
    cand = jnp.where(pvalid[:, :, None] & (cand >= 0), cand, -1)
    cand = jnp.where(cand == excl[:, None, None], -1, cand)
    pay = jnp.take(pay_flat, fb, axis=0)                   # [r, P, KC, DW]
    if score == "dot":
        s = jnp.einsum(
            "rd,rpkd->rpk", q.astype(jnp.float32), pay.astype(jnp.float32)
        )
    elif score == "hamming":
        s = -hamming_words_ref(
            q.reshape(r, 1, -1).repeat(n_probes, axis=1).reshape(-1, q.shape[-1]),
            pay.reshape(r * n_probes, kc, -1),
        ).reshape(r, n_probes, kc).astype(jnp.float32)
    else:
        raise ValueError(f"unknown score mode: {score!r}")
    flat_ids = cand.reshape(r, n_probes * kc)
    flat_s = jnp.where(flat_ids >= 0, s.reshape(r, n_probes * kc), -jnp.inf)
    return dedupe_topk(flat_ids, flat_s, m)


def fused_contains_ref(
    ids_flat: jnp.ndarray,   # int32 [T*NB, KC]
    fb: jnp.ndarray,         # int32 [r, P]
    meta: jnp.ndarray,       # int32 [r, 2] (probe-validity word, target id)
) -> jnp.ndarray:
    """Oracle for `fused_contains`: int32 [r, 1] hit flags."""
    r, n_probes = fb.shape
    pw, tgt = meta[:, 0], meta[:, 1]
    cand = jnp.take(ids_flat, fb, axis=0)                  # [r, P, KC]
    pvalid = ((pw[:, None] >> jnp.arange(n_probes)) & 1) > 0
    hit = jnp.any((cand == tgt[:, None, None]) & pvalid[:, :, None], axis=(1, 2))
    return hit.astype(jnp.int32)[:, None]
