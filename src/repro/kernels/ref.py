"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; kernels must match them bit-for-bit (integer
outputs) or to float tolerance (scores).  Top-k selection ties are broken
by lowest index in both ref and kernel.
"""

from __future__ import annotations

import jax.numpy as jnp


def simhash_ref(x: jnp.ndarray, hyperplanes: jnp.ndarray) -> jnp.ndarray:
    """Packed sign-random-projection sketches.

    Args:
      x: [n, d] float.
      hyperplanes: [L, k, d] float.
    Returns:
      uint32 [n, L]; bit j of table l is (x . h_{l,j} >= 0).
    """
    proj = jnp.einsum(
        "nd,lkd->nlk", x.astype(jnp.float32), hyperplanes.astype(jnp.float32)
    )
    bits = (proj >= 0).astype(jnp.uint32)
    k = hyperplanes.shape[1]
    weights = jnp.uint32(1) << jnp.arange(k, dtype=jnp.uint32)
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


def bucket_topk_ref(
    q: jnp.ndarray, cand: jnp.ndarray, valid: jnp.ndarray, m: int
):
    """Fused candidate scoring + top-m.

    Args:
      q: [b, d] unit queries.
      cand: [b, kc, d] candidate vectors (gathered bucket payloads).
      valid: bool [b, kc] — invalid candidates must not be returned.
      m: results per query.
    Returns:
      (scores f32 [b, m], idx int32 [b, m]) — idx into kc, -1 where no valid
      candidate; sorted by descending score, ties -> lowest index.
    """
    scores = jnp.einsum(
        "bd,bkd->bk", q.astype(jnp.float32), cand.astype(jnp.float32)
    )
    scores = jnp.where(valid, scores, -jnp.inf)
    kc = scores.shape[1]
    # tie-break by lowest index: subtract a tiny index-based epsilon ordering
    # implemented exactly via lexicographic argmax loop.
    out_s, out_i = [], []
    cur = scores
    idxs = jnp.arange(kc, dtype=jnp.int32)
    for _ in range(m):
        best = jnp.argmax(cur, axis=1)  # first occurrence of max => lowest idx
        s = jnp.take_along_axis(cur, best[:, None], axis=1)[:, 0]
        out_s.append(s)
        out_i.append(jnp.where(jnp.isfinite(s), best.astype(jnp.int32), -1))
        cur = jnp.where(idxs[None, :] == best[:, None], -jnp.inf, cur)
    return jnp.stack(out_s, axis=1), jnp.stack(out_i, axis=1)


def hamming_ref(codes: jnp.ndarray, cand_codes: jnp.ndarray) -> jnp.ndarray:
    """Popcount Hamming distances between uint32 codes.

    Args:
      codes: [n] uint32.
      cand_codes: [n, kc] uint32.
    Returns:
      int32 [n, kc].
    """
    x = jnp.bitwise_xor(codes[:, None].astype(jnp.uint32), cand_codes.astype(jnp.uint32))
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
