"""Pallas TPU kernel: fused sign-random-projection sketching (simhash).

Computes packed LSH sketch codes  codes[n, L] = pack_k(sign(X @ H^T))  in one
pass: the projection matmul runs on the MXU, sign + bit-pack on the VPU, and
only the 4-byte codes leave VMEM — the [n, L*k] projection intermediate never
touches HBM.  This is the hash hot-spot of the paper's pre-processing and
query paths (Sec. 4.1: every user re-hashes periodically; every query hashes
into L sketches).

Tiling: grid (n/TN, d/TD).  d is the contraction dim; a VMEM scratch
accumulator [TN, LK] carries partial projections across d-steps
("arbitrary" semantics); the pack happens on the last d-step.
LK = L*k is zero-padded to a lane multiple (128) by the ops.py wrapper.

Two output layouts, chosen by `packed`:
  * per-table codes uint32 [n, L] (k live bits per lane) — the classic
    layout every bucket mapper consumes;
  * dense packed words uint32 [n, W], W = ceil(L*k/32), the
    `core.packed` layout — global bit l*k + j lands in word (l*k+j)/32.
    Hamming-mode runtimes sketch queries straight into this layout, so
    the unpacked [n, L] intermediate never exists.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _simhash_kernel(
    x_ref, h_ref, out_ref, acc_ref, *, k: int, L: int, packed: bool = False
):
    d_step = pl.program_id(1)
    n_dsteps = pl.num_programs(1)

    @pl.when(d_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # [TN, TD] @ [TD, LKpad] -> [TN, LKpad] partial projection on the MXU.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        h_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(d_step == n_dsteps - 1)
    def _pack():
        proj = acc_ref[...]  # [TN, LKpad]
        bits = (proj >= 0).astype(jnp.uint32)  # [TN, LKpad]
        # lane l*k + j holds bit j of table l.
        lane = jax.lax.broadcasted_iota(jnp.int32, proj.shape, 1)
        if packed:
            # dense core.packed layout: lane g -> word g/32 bit g%32.
            # padded tail lanes (proj 0 => bit 1) must be masked here.
            n_words = -(-(L * k) // 32)
            live = jnp.where(lane < L * k, bits, jnp.uint32(0))
            shifted = live << (lane % 32).astype(jnp.uint32)
            words = [
                jnp.sum(jnp.where(lane // 32 == w, shifted, jnp.uint32(0)),
                        axis=1)
                for w in range(n_words)
            ]
            out_ref[...] = jnp.stack(words, axis=1)
        else:
            # (lane % k) is the in-code bit position; padded tail lanes
            # (>= L*k) are never sliced below.
            weighted = bits << (lane % k).astype(jnp.uint32)
            # per-table static slices + lane reduction (no in-kernel scatter)
            codes = [
                jnp.sum(weighted[:, l * k : (l + 1) * k], axis=1)
                for l in range(L)
            ]
            out_ref[...] = jnp.stack(codes, axis=1)


@functools.partial(
    jax.jit, static_argnames=("k", "L", "tn", "td", "packed", "interpret")
)
def simhash_pallas(
    x: jax.Array,          # [n, d] float32 (padded: n % tn == 0, d % td == 0)
    h_t: jax.Array,        # [d, LKpad] float32, transposed + lane-padded H
    *,
    k: int,
    L: int,
    tn: int = 256,
    td: int = 512,
    packed: bool = False,
    interpret: bool = False,
) -> jax.Array:
    n, d = x.shape
    lkpad = h_t.shape[1]
    grid = (n // tn, d // td)
    width = -(-(L * k) // 32) if packed else L
    return pl.pallas_call(
        functools.partial(_simhash_kernel, k=k, L=L, packed=packed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, td), lambda i, j: (i, j)),
            pl.BlockSpec((td, lkpad), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tn, width), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, width), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((tn, lkpad), jnp.float32)],
        interpret=interpret,
    )(x, h_t)
