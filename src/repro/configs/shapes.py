"""Assigned input-shape cells (seq_len x global_batch) and applicability."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic sequence mixing (DESIGN.md Sec. 5): only the
# hybrid/SSM archs run it; pure full-attention archs skip (recorded, not run).
LONG_CAPABLE = {"jamba-v0.1-52b", "xlstm-1.3b"}


def cell_applicable(arch_name: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_name in LONG_CAPABLE
    return True


def all_cells(arch_names):
    """(arch, shape, applicable) triples — 40 nominal cells."""
    out = []
    for a in arch_names:
        for s in SHAPES:
            out.append((a, s, cell_applicable(a, s)))
    return out
