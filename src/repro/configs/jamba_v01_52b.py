"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) d_ff=14336
vocab=65536. Mamba:attention 7:1 interleave, MoE (16e top-2) every other
layer. [arXiv:2403.19887; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=14336,
    moe_every=2,
    attn_every=8,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    scan_period=8,
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    moe_num_experts=4,
    moe_top_k=2,
    moe_d_ff=128,
    moe_capacity_factor=8.0,
    moe_every=2,
    attn_every=8,
    mamba_d_state=8,
    mamba_d_conv=4,
    mamba_expand=2,
    scan_period=8,
)
