"""Architecture registry: --arch <id> selects one of the assigned configs."""

from __future__ import annotations

import importlib

_MODULES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "phi3-medium-14b": "phi3_medium_14b",
    "starcoder2-7b": "starcoder2_7b",
    "gemma2-2b": "gemma2_2b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "xlstm-1.3b": "xlstm_1_3b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, smoke: bool = False):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG


from repro.configs.shapes import SHAPES, ShapeSpec, cell_applicable, all_cells  # noqa: F401,E402
