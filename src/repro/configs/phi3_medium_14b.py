"""phi3-medium-14b [dense]: 40L d=5120 40H (GQA kv=10) d_ff=17920
vocab=100352. RoPE + SwiGLU + GQA. [arXiv:2404.14219; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    scan_period=1,
)

SMOKE = ModelConfig(
    name="phi3-medium-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=512,
    scan_period=1,
)
