"""codeqwen1.5-7b [dense]: 32L d=4096 32H (MHA kv=32) d_ff=13440
vocab=92416, qkv bias (qwen1.5 arch). [hf:Qwen/CodeQwen1.5-7B; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,
    rope_theta=1000000.0,
    scan_period=1,
)

SMOKE = ModelConfig(
    name="codeqwen-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=192,
    vocab_size=512,
    qkv_bias=True,
    scan_period=1,
)
