"""xlstm-1.3b [ssm]: 48 blocks d=2048 4H, alternating mLSTM/sLSTM,
no separate MLP (d_ff=0), vocab=50304. [arXiv:2405.04517; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    xlstm=True,
    scan_period=2,
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=0,
    vocab_size=512,
    xlstm=True,
    scan_period=2,
)
