"""starcoder2-7b [dense]: 32L d=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
GELU 2-matrix MLP, RoPE. [arXiv:2402.19173; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    mlp_type="gelu",
    scan_period=1,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    mlp_type="gelu",
    scan_period=1,
)
