"""seamless-m4t-medium [audio]: enc-dec 12L+12L d=1024 16H (MHA kv=16)
d_ff=4096 vocab=256206. Audio frontend STUBBED: input_specs provides
precomputed frame embeddings. ReLU MLP, tied embeddings.
[arXiv:2308.11596; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    mlp_type="relu",
    modality="audio_frames",
    tie_embeddings=True,
    scan_period=1,
)

SMOKE = ModelConfig(
    name="seamless-smoke",
    family="audio",
    num_layers=2,
    encoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mlp_type="relu",
    modality="audio_frames",
    tie_embeddings=True,
    scan_period=1,
)
