"""deepseek-moe-16b [moe]: 28L d=2048 16H (MHA kv=16) vocab=102400,
2 shared + 64 routed top-6 fine-grained experts (d_ff=1408).
[arXiv:2401.06066; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    moe_num_experts=64,
    moe_top_k=6,
    moe_num_shared=2,
    moe_d_ff=1408,
    moe_every=1,
    scan_period=1,
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=96,
    vocab_size=512,
    moe_num_experts=8,
    moe_top_k=3,
    moe_num_shared=2,
    moe_d_ff=96,
    moe_capacity_factor=8.0,
    moe_every=1,
    scan_period=1,
)
