"""llama4-maverick-400b-a17b [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, 128 routed experts top-1 + 1 shared, MoE every other layer
(matches 400B total / 17B active). [hf:meta-llama/Llama-4-*; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    moe_num_experts=128,
    moe_top_k=1,
    moe_num_shared=1,
    moe_d_ff=8192,
    moe_every=2,
    rope_theta=500000.0,
    scan_period=2,
)

SMOKE = ModelConfig(
    name="llama4-maverick-smoke",
    family="moe",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    moe_num_experts=8,
    moe_top_k=1,
    moe_num_shared=1,
    moe_d_ff=128,
    moe_capacity_factor=8.0,
    moe_every=2,
    scan_period=2,
)
