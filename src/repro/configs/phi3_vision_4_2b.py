"""phi-3-vision-4.2b [vlm]: 32L d=3072 32H (MHA kv=32) d_ff=8192
vocab=32064; phi3-mini backbone + CLIP frontend STUBBED (input_specs
provides patch embeddings prepended to text).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    modality="vision_patches",
    num_prefix_embeds=256,
    scan_period=1,
)

SMOKE = ModelConfig(
    name="phi3-vision-smoke",
    family="vlm",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    modality="vision_patches",
    num_prefix_embeds=8,
    scan_period=1,
)
