"""gemma2-2b [dense]: 26L d=2304 8H (GQA kv=4, head_dim 256) d_ff=9216
vocab=256000. Alternating local(4096)/global attention, attn softcap 50,
final logit softcap 30, tied embeddings. [arXiv:2408.00118; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    window_size=4096,
    alt_local_global=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    scan_period=2,
)

SMOKE = ModelConfig(
    name="gemma2-smoke",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    window_size=16,
    alt_local_global=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    scan_period=2,
)
