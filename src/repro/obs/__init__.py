"""Unified observability layer (DESIGN.md Sec. 12).

Three small host-side pieces, shared by serving, the churn drivers, and
the benchmarks:

  * `registry` — labeled counters / gauges / histograms with JSON and
    Prometheus-text snapshots.  THE sink every producer publishes into
    (`ServeStats.publish`, `MessageCounter.publish`, churn drivers),
    replacing the per-subsystem ad-hoc dict formats as the machine
    interface;
  * `trace` — a span API on `time.perf_counter` (monotonic — the repo's
    one timer, also used by the launch drivers and benchmarks for their
    wall-clock numbers), exportable as Chrome-trace-event JSON that
    loads directly in Perfetto / chrome://tracing;
  * `flight` — a bounded ring of structured per-query / per-dispatch
    `QueryRecord`s, dumped automatically on anomalies (drop spike,
    `kill_node`, reshard) so the records AROUND a failure survive it.

Everything here is host-side plain Python: enabling observability never
changes what jax traces (the `StepStats` aux output of the runtime steps
is always computed), which is what the zero-retrace assertion in
tests/test_obs.py pins down.
"""

from __future__ import annotations

import dataclasses
import json

from repro.obs.flight import FlightRecorder, QueryRecord
from repro.obs.registry import (
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    Registry,
)
from repro.obs.trace import Span, Tracer, span_or_null

__all__ = [
    "REGISTRY",
    "Counter",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "ObsConfig",
    "Observability",
    "QueryRecord",
    "Registry",
    "Span",
    "Tracer",
    "span_or_null",
]


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Static observability knobs.

    Frozen on purpose: the frontend reads it once at construction, so the
    obs configuration can never become a traced value — obs-on and
    obs-off run the SAME compiled executables (tests/test_obs.py counts
    retraces to prove it).
    """

    trace_capacity: int = 65536     # span ring (events, not bytes)
    flight_capacity: int = 4096     # flight-recorder ring (records)
    drop_spike: int = 1             # auto-dump when a dispatch/epoch record
    #                                 drops >= this many probes (<=0: off)
    recall_probe_every: int = 0     # shadow-rescore 1-in-N served queries
    #                                 (0 disables the recall probe)

    def __post_init__(self):
        if self.trace_capacity < 1 or self.flight_capacity < 1:
            raise ValueError("obs ring capacities must be >= 1")


class Observability:
    """One bundle of (config, registry, tracer, flight recorder).

    Pass it to `RetrievalFrontend(obs=...)` or the churn drivers; pass
    None (the default everywhere) and nothing is recorded.
    """

    def __init__(
        self,
        config: ObsConfig = ObsConfig(),
        registry: Registry | None = None,
        tracer: Tracer | None = None,
        flight: FlightRecorder | None = None,
    ):
        self.config = config
        self.registry = Registry() if registry is None else registry
        self.tracer = (
            Tracer(capacity=config.trace_capacity) if tracer is None
            else tracer
        )
        self.flight = (
            FlightRecorder(
                capacity=config.flight_capacity,
                drop_spike=config.drop_spike,
            )
            if flight is None
            else flight
        )

    def chrome_trace(self) -> dict:
        """Spans + flight records as one Chrome-trace-event document."""
        doc = self.tracer.to_chrome_trace()
        doc["traceEvents"].extend(self.flight.to_chrome_trace()["traceEvents"])
        return doc

    def export_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def export_metrics(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.registry.snapshot(), f, indent=1, sort_keys=True)
