"""Labeled metrics registry: counters, gauges, histograms (DESIGN.md
Sec. 12).

One `Registry` holds named metrics; each metric holds one series per
label set (labels are plain keyword arguments).  Two export formats:

  * `snapshot()` — a JSON-able dict (what `--metrics-out` writes and
    `benchmarks/run.py --json` reads columns from);
  * `prometheus_text()` — the Prometheus text exposition format, so a
    scrape endpoint needs nothing beyond serving this string.

Registration is idempotent: asking for an existing name returns the same
metric object (re-registering under a different kind is an error), so
library code can `registry.counter("x").inc()` without coordinating
who creates what.  Everything is plain host-side Python — publishing is
never traced.
"""

from __future__ import annotations

import bisect

import numpy as np

# default histogram buckets: microsecond-latency oriented, widening
# geometrically; anything above the last edge lands in +Inf
DEFAULT_BUCKETS = (
    50.0, 100.0, 250.0, 500.0, 1_000.0, 2_500.0, 5_000.0, 10_000.0,
    25_000.0, 50_000.0, 100_000.0, 250_000.0, 1_000_000.0,
)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: tuple, extra: tuple = ()) -> str:
    parts = [f'{k}="{v}"' for k, v in (*key, *extra)]
    return "{" + ",".join(parts) + "}" if parts else ""


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: dict[tuple, object] = {}

    def labelsets(self) -> list[dict]:
        return [dict(k) for k in self._series]


class Counter(_Metric):
    """Monotonic accumulator (`inc`); one value per label set."""

    kind = "counter"

    def inc(self, value: float = 1, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name}: inc({value}) < 0")
        k = _label_key(labels)
        self._series[k] = self._series.get(k, 0) + value

    def value(self, **labels):
        return self._series.get(_label_key(labels), 0)


class Gauge(_Metric):
    """Last-write-wins value (`set`); one value per label set."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[_label_key(labels)] = float(value)

    def value(self, **labels):
        return self._series.get(_label_key(labels))


class Histogram(_Metric):
    """Cumulative-bucket histogram (`observe`); Prometheus semantics."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: tuple = DEFAULT_BUCKETS):
        super().__init__(name, help)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError(f"histogram {self.name}: needs >= 1 bucket")

    def observe(self, value: float, **labels) -> None:
        k = _label_key(labels)
        st = self._series.get(k)
        if st is None:
            # one slot per finite bucket plus +Inf
            st = self._series[k] = {
                "counts": [0] * (len(self.buckets) + 1),
                "sum": 0.0,
                "count": 0,
            }
        st["counts"][bisect.bisect_left(self.buckets, float(value))] += 1
        st["sum"] += float(value)
        st["count"] += 1

    def observe_many(self, values, **labels) -> None:
        """Bulk `observe`: one vectorized pass over a batch of samples.
        Hot-path API — the serving frontend observes queue time for
        every ring row of every staged batch, and a Python-level
        `observe` per row is measurable against its near-zero-overhead
        budget (bench `serve/obs_overhead`)."""
        vals = np.asarray(values, dtype=float)
        if vals.size == 0:
            return
        k = _label_key(labels)
        st = self._series.get(k)
        if st is None:
            st = self._series[k] = {
                "counts": [0] * (len(self.buckets) + 1),
                "sum": 0.0,
                "count": 0,
            }
        # searchsorted(side='left') == bisect_left: same bucket edges
        idx, cnt = np.unique(
            np.searchsorted(self.buckets, vals, side="left"),
            return_counts=True)
        for i, c in zip(idx, cnt):
            st["counts"][int(i)] += int(c)
        st["sum"] += float(vals.sum())
        st["count"] += int(vals.size)

    def value(self, **labels):
        """Observation count for the label set (0 when never observed)."""
        st = self._series.get(_label_key(labels))
        return 0 if st is None else st["count"]

    def quantile(self, q: float, **labels) -> float:
        """Bucket-resolution quantile: the upper edge of the first bucket
        whose cumulative count covers q (conservative, like Prometheus'
        `histogram_quantile` without interpolation)."""
        st = self._series.get(_label_key(labels))
        if st is None or st["count"] == 0:
            return 0.0
        target = q * st["count"]
        cum = 0
        for i, c in enumerate(st["counts"]):
            cum += c
            if cum >= target:
                return self.buckets[i] if i < len(self.buckets) \
                    else float("inf")
        return float("inf")


class Registry:
    """A namespace of metrics; see the module docstring."""

    _KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _register(self, cls, name: str, help: str, **kw) -> _Metric:
        m = self._metrics.get(name)
        if m is not None:
            if type(m) is not cls:
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"not {cls.kind}"
                )
            return m
        m = cls(name, help, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple = DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> _Metric | None:
        return self._metrics.get(name)

    def value(self, name: str, default=None, **labels):
        """Convenience read: the metric's value for a label set, or
        `default` when the metric or series does not exist."""
        m = self._metrics.get(name)
        if m is None:
            return default
        v = m.value(**labels)
        return default if v is None else v

    def reset(self) -> None:
        self._metrics.clear()

    # -- exports --------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able snapshot: {name: {type, help, samples: [...]}}.

        Counter/gauge samples are {labels, value}; histogram samples are
        {labels, count, sum, buckets: {upper_edge: cumulative_count}}.
        """
        out = {}
        for name, m in sorted(self._metrics.items()):
            samples = []
            for key, st in sorted(m._series.items()):
                labels = dict(key)
                if m.kind == "histogram":
                    cum, buckets = 0, {}
                    for i, c in enumerate(st["counts"]):
                        cum += c
                        edge = (f"{m.buckets[i]:g}"
                                if i < len(m.buckets) else "+Inf")
                        buckets[edge] = cum
                    samples.append(dict(labels=labels, count=st["count"],
                                        sum=st["sum"], buckets=buckets))
                else:
                    samples.append(dict(labels=labels, value=st))
            out[name] = dict(type=m.kind, help=m.help, samples=samples)
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (one scrape body)."""
        lines = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key, st in sorted(m._series.items()):
                if m.kind == "histogram":
                    cum = 0
                    for i, c in enumerate(st["counts"]):
                        cum += c
                        edge = (f"{m.buckets[i]:g}"
                                if i < len(m.buckets) else "+Inf")
                        lines.append(
                            f"{name}_bucket"
                            f"{_label_str(key, (('le', edge),))} {cum}"
                        )
                    lines.append(f"{name}_sum{_label_str(key)} {st['sum']:g}")
                    lines.append(
                        f"{name}_count{_label_str(key)} {st['count']}")
                else:
                    lines.append(f"{name}{_label_str(key)} {st:g}")
        return "\n".join(lines) + ("\n" if lines else "")


# the process-default registry: CLIs and benchmarks publish here unless
# handed an explicit one (tests build their own for isolation)
REGISTRY = Registry()
