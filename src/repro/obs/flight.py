"""Flight recorder: a bounded ring of structured query/dispatch records,
dumped automatically on anomalies (DESIGN.md Sec. 12).

The serving frontend and the churn drivers append `QueryRecord`s as they
run; the ring keeps only the most recent `capacity` records, so a
long-lived process carries a fixed-size black box.  When something goes
wrong — a dispatch drops probes (`drop_spike`), a node is killed, a
reshard fires — `note_anomaly` (or the automatic drop-spike trigger)
snapshots the ring into `dumps`, preserving exactly the records that
led up to the event even after the ring has wrapped past them.

Record kinds and their accounting contract:

  * ``kind="query"`` — one per served query: latency breakdown, cache
    hit/miss + generation, and its dispatch batch number.  Per-query
    cost fields are its batch's uniform per-row share.
  * ``kind="dispatch"`` / ``kind="epoch"`` — one per backend dispatch
    (or churn epoch): the EXACT `StepStats` totals for that step.
    Summing a stats field over these records reproduces the aggregate
    counters bit-for-bit (asserted by `failure_churn --smoke` against
    the per-epoch arrays test_failure.py pins).
"""

from __future__ import annotations

import dataclasses
import json
import time
from collections import deque


@dataclasses.dataclass(slots=True)
class QueryRecord:
    """One flight-recorder entry; see the module docstring for kinds.

    `slots=True`: one record is appended per served query on the serving
    hot path, so construction cost is part of the obs overhead budget."""

    qid: int = -1                 # ticket (query) / sequence (dispatch/epoch)
    kind: str = "query"           # "query" | "dispatch" | "epoch" | "event"
    t_us: float = 0.0             # completion time, µs since recorder start
    latency_us: float = 0.0       # submit -> respond (query records)
    cache_hit: bool | None = None
    generation: int = -1          # store generation served under
    batch: int = -1               # dispatch sequence this query rode (-1: hit)
    batch_size: int = 0           # padded rows in that dispatch
    probes_issued: int = 0        # planned bucket probes (exact + near)
    probes_routed: int = 0        # rows sent through the capacitated router
    dropped_probes: int = 0       # router-overflow drops
    dropped_by_dest: tuple = ()   # per-destination overflow counts
    nodes_contacted: int = 0      # distinct (query, destination) deliveries
    replica_fanout: int = 1       # quorum fan-out (1 = first-responder)
    stage_us: dict = dataclasses.field(default_factory=dict)
    extra: dict = dataclasses.field(default_factory=dict)


class FlightRecorder:
    """Bounded record ring + anomaly dumps; see the module docstring."""

    def __init__(self, capacity: int = 4096, drop_spike: int = 1):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.drop_spike = drop_spike
        self._ring: deque[QueryRecord] = deque(maxlen=capacity)
        self.dumps: list[dict] = []
        self._t0 = time.perf_counter()

    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def to_us(self, t_perf: float) -> float:
        """Map an absolute `time.perf_counter()` stamp onto this
        recorder's µs-since-start clock — lets a hot loop stamp a whole
        batch of records from one clock read (pass the result as
        `t_us=`) instead of paying `now_us()` per record."""
        return (t_perf - self._t0) * 1e6

    def __len__(self) -> int:
        return len(self._ring)

    def record(self, rec: QueryRecord) -> QueryRecord:
        """Append one record; auto-dumps on a drop spike (a dispatch/epoch
        record losing >= `drop_spike` probes)."""
        if not rec.t_us:
            rec.t_us = self.now_us()
        self._ring.append(rec)
        if (
            self.drop_spike > 0
            and rec.kind in ("dispatch", "epoch")
            and rec.dropped_probes >= self.drop_spike
        ):
            self.note_anomaly(
                "drop_spike", qid=rec.qid, kind=rec.kind,
                dropped_probes=rec.dropped_probes,
            )
        return rec

    def records(self, kind: str | None = None) -> list[QueryRecord]:
        if kind is None:
            return list(self._ring)
        return [r for r in self._ring if r.kind == kind]

    def total(self, field: str, kind: str = "epoch"):
        """Sum a stats field (or an `extra` entry under that name) over
        the authoritative dispatch/epoch records of the ring."""
        direct = field in QueryRecord.__dataclass_fields__
        return sum(
            getattr(r, field) if direct else r.extra.get(field, 0)
            for r in self.records(kind)
        )

    def note_anomaly(self, reason: str, **detail) -> dict:
        """Snapshot the ring into `dumps` (kill_node, reshard, drop spike)."""
        dump = dict(
            reason=reason,
            detail=detail,
            t_us=self.now_us(),
            n_records=len(self._ring),
            records=[dataclasses.asdict(r) for r in self._ring],
        )
        self.dumps.append(dump)
        return dump

    # -- exports --------------------------------------------------------------

    def to_chrome_trace(self) -> dict:
        """Records as Chrome trace events: queries become complete events
        on a `flight` track (ts at submit, dur = latency), dispatch/epoch
        records and dumps become instants."""
        import os

        pid = os.getpid()
        out = []
        for r in self._ring:
            args = {
                f.name: getattr(r, f.name)
                for f in dataclasses.fields(r)
                if f.name not in ("stage_us", "extra")
            }
            args.update(r.stage_us)
            args.update(r.extra)
            if r.kind == "query":
                out.append(dict(
                    name=f"query:{r.qid}", cat="flight", ph="X",
                    ts=max(r.t_us - r.latency_us, 0.0), dur=r.latency_us,
                    pid=pid, tid=1, args=args,
                ))
            else:
                out.append(dict(
                    name=f"{r.kind}:{r.qid}", cat="flight", ph="i",
                    ts=r.t_us, pid=pid, tid=1, s="t", args=args,
                ))
        for d in self.dumps:
            out.append(dict(
                name=f"anomaly:{d['reason']}", cat="flight", ph="i",
                ts=d["t_us"], pid=pid, tid=1, s="p",
                args=dict(d["detail"], n_records=d["n_records"]),
            ))
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(
                dict(
                    capacity=self.capacity,
                    records=[dataclasses.asdict(r) for r in self._ring],
                    dumps=self.dumps,
                ),
                f,
            )
