"""Span tracing on `time.perf_counter`, exportable as Chrome trace
events (DESIGN.md Sec. 12).

`Tracer.span(name)` is the repo's ONE wall-clock primitive: it times on
the monotonic `time.perf_counter` (never `time.time()`, which steps
under NTP adjustments), works as a plain stopwatch even when event
recording is disabled, and — when enabled — appends a complete event to
a bounded ring.  `to_chrome_trace()` emits the Chrome trace-event JSON
format (`ph: "X"` complete events, microsecond timestamps), which loads
directly in Perfetto (ui.perfetto.dev) or chrome://tracing.

Single-threaded by design, like the serving loop it instruments: spans
nest via a plain stack, and nesting shows up in Perfetto through
ts/duration containment on one track.
"""

from __future__ import annotations

import contextlib
import json
import os
import time
from collections import deque


class Span:
    """One timed region.  Usable as a bare stopwatch: `duration_s` after
    the `with` block, `elapsed_s` inside it (both perf_counter-based).

    Implements the with-statement protocol directly rather than via a
    `@contextmanager` generator: this sits on the serving hot path, and
    the generator machinery costs more than the timing itself."""

    __slots__ = ("name", "cat", "args", "depth", "t0", "t1", "_tracer")

    def __init__(self, name: str, cat: str, args: dict, tracer=None):
        self.name = name
        self.cat = cat
        self.args = args
        self.depth = 0
        self._tracer = tracer
        self.t0 = time.perf_counter()
        self.t1: float | None = None

    def __enter__(self) -> "Span":
        tr = self._tracer
        if tr is not None:
            self.depth = len(tr._stack)
            tr._stack.append(self.name)
        self.t0 = time.perf_counter()  # re-arm: timing starts at entry
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.t1 = time.perf_counter()
        tr = self._tracer
        if tr is not None:
            tr._stack.pop()
            if tr.enabled:
                tr._events.append((
                    "X", self.name, self.cat, (self.t0 - tr._t0) * 1e6,
                    (self.t1 - self.t0) * 1e6, self.depth, self.args,
                ))
        return False

    @property
    def elapsed_s(self) -> float:
        """Seconds since the span opened (live reads mid-span)."""
        return time.perf_counter() - self.t0

    @property
    def duration_s(self) -> float:
        return (self.t1 if self.t1 is not None
                else time.perf_counter()) - self.t0

    @property
    def duration_us(self) -> float:
        return self.duration_s * 1e6


class Tracer:
    """Bounded ring of spans + instants; see the module docstring."""

    def __init__(self, enabled: bool = True, capacity: int = 65536):
        self.enabled = enabled
        self._events: deque = deque(maxlen=capacity)
        self._stack: list[str] = []
        self._t0 = time.perf_counter()  # trace epoch (ts are relative)

    @property
    def depth(self) -> int:
        """Current span-nesting depth (0 outside any span)."""
        return len(self._stack)

    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def span(self, name: str, cat: str = "serve", **args) -> Span:
        """A `with`-able Span; recorded into the ring on exit."""
        return Span(name, cat, args, tracer=self)

    def instant(self, name: str, cat: str = "serve", **args) -> None:
        """A zero-duration marker (anomalies, state events)."""
        if self.enabled:
            self._events.append(
                ("i", name, cat, self.now_us(), 0.0, len(self._stack), args))

    def events(self) -> list:
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()

    def to_chrome_trace(self) -> dict:
        pid = os.getpid()
        out = []
        for ph, name, cat, ts, dur, depth, args in self._events:
            ev = dict(name=name, cat=cat, ph=ph, ts=ts, pid=pid, tid=0,
                      args=dict(args, depth=depth))
            if ph == "X":
                ev["dur"] = dur
            else:
                ev["s"] = "t"  # thread-scoped instant
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)


def span_or_null(tracer: Tracer | None, name: str, **args):
    """`tracer.span(...)` when a tracer is present, else a no-op context —
    the idiom instrumented code uses so the obs-off path stays bare."""
    if tracer is None:
        return contextlib.nullcontext()
    return tracer.span(name, **args)
