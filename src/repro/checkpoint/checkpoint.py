"""Fault-tolerant checkpointing with elastic re-sharding.

Format: one directory per step —
    step_<N>/
      meta.json       (step, arch name, leaf paths, data cursor, wall time)
      arrays.npz      (flattened leaf-path -> ndarray)
      CHECKSUM        (sha256 of arrays.npz — torn-write detection)
Writes are atomic (tmp dir + rename); `latest` is re-pointed only after the
payload is durable, so a crash mid-write can never corrupt the restore path.

Elastic restore: arrays are loaded host-side and re-placed with whatever
shardings the *current* mesh dictates (device count may differ from the
writer's) — this is the restart-on-fewer/more-chips path.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import time

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # npz can't round-trip ml_dtypes (bf16 etc.): widen to f32 —
            # lossless for bf16; restore casts back to the template dtype.
            arr = arr.astype(np.float32)
        out[key] = arr
    return out, treedef


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None,
         keep_last: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, _ = _flatten(tree)
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **arrays)
    digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
    with open(os.path.join(tmp, "CHECKSUM"), "w") as f:
        f.write(digest)
    meta = {
        "step": step,
        "time": time.time(),
        "leaves": sorted(arrays),
        **(extra or {}),
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(ckpt_dir, "latest.tmp"),
               os.path.join(ckpt_dir, "latest"))
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step_dir(ckpt_dir: str) -> str | None:
    marker = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(marker):
        return None
    name = open(marker).read().strip()
    path = os.path.join(ckpt_dir, name)
    return path if os.path.exists(path) else None


def verify(step_dir: str) -> bool:
    npz_path = os.path.join(step_dir, "arrays.npz")
    want = open(os.path.join(step_dir, "CHECKSUM")).read().strip()
    got = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
    return want == got


def restore(step_dir: str, template, shardings=None):
    """Restore into `template`'s structure.

    shardings: optional pytree of NamedShardings (same structure) for
    elastic re-placement onto the current mesh.
    """
    if not verify(step_dir):
        raise IOError(f"checksum mismatch in {step_dir}")
    data = np.load(os.path.join(step_dir, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (
        [None] * len(flat)
        if shardings is None
        else [s for _, s in jax.tree_util.tree_flatten_with_path(shardings)[0]]
    )
    leaves = []
    for (path, leaf), shard in zip(flat, shard_flat):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        leaves.append(
            jax.device_put(arr, shard) if shard is not None else jax.numpy.asarray(arr)
        )
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_meta(step_dir: str) -> dict:
    return json.load(open(os.path.join(step_dir, "meta.json")))
