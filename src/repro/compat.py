"""Version-compat shims over jax APIs that moved between releases.

Everything in the repo that builds a mesh or wraps a function in shard_map
goes through this module, so a single file absorbs the API drift:

  * ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
    ``jax.make_mesh``) only exist in newer jax; older releases build the
    same (fully ``Auto``) mesh without the kwarg.
  * ``jax.shard_map`` with ``check_vma=`` is the newer spelling of
    ``jax.experimental.shard_map.shard_map`` with ``check_rep=``.

Like ``launch.mesh``, importing this module never touches jax device state.
"""

from __future__ import annotations

import jax


def has_axis_type() -> bool:
    """True when this jax exposes ``jax.sharding.AxisType``."""
    return hasattr(jax.sharding, "AxisType")


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with every axis ``Auto``, on any jax version."""
    if has_axis_type():
        try:
            return jax.make_mesh(
                axis_shapes, axis_names, devices=devices,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
            )
        except TypeError:
            pass  # AxisType exists but make_mesh predates the kwarg
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Map ``f`` over the mesh shards; ``check`` gates the replication /
    varying-manual-axes check (named ``check_vma`` or ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=check,
            )
        except TypeError:
            pass  # jax.shard_map is public but still spells it check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check,
    )
