"""Online updates: interleave churn maintenance with serving (DESIGN.md
Sec. 7 read/write epochs).

The churn module measures index freshness with a fresh engine per epoch;
this driver measures it END-TO-END through the serving stack instead: ONE
long-lived `RetrievalFrontend` serves every epoch's queries while the
soft-state maintenance (`insert_batch` + `expire`, paper Sec. 4.1) runs
between read epochs.  Each write epoch bumps the store generation, which
is exactly what invalidates the sketch-keyed result cache — so the run
demonstrates the full contract: repeated queries hit the cache WITHIN a
store generation, never across a mutation, and recall under live churn
matches the reference trajectory (`core.churn.run_churn`) bit-exactly.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import hashing, metrics
from repro.core.churn import ChurnConfig, _lsh_setup, _trajectory
from repro.core.corpus import DenseCorpus
from repro.core.engine import EngineConfig, LshEngine
from repro.core.store import expire, insert_batch, make_store
from repro.serve.frontend import FrontendConfig, RetrievalFrontend, RuntimeBackend


@dataclasses.dataclass(frozen=True)
class ServeChurnConfig:
    churn: ChurnConfig = ChurnConfig()
    query_repeats: int = 2     # replays of each epoch's query batch — the
    #                            repeats exercise the cache within an epoch
    max_batch: int = 32
    queue_capacity: int = 512
    cache: bool = True
    variant: str = "cnb"


def run_serve_churn(cfg: ServeChurnConfig) -> dict:
    """Drive the churn trajectory through the serving frontend.

    Write epochs: announce (insert_batch) + GC (expire) + backend.update —
    one generation bump per mutation, invalidating the cache.  Read
    epochs: the epoch's query batch is served `query_repeats` times; all
    repeats must return identical ids (cache hits are real results, never
    stale ones), and repeat recall is measured per epoch.
    """
    c = cfg.churn
    params, hp = _lsh_setup(c)
    store = make_store(c.L, params.num_buckets, c.capacity)
    announced = None

    # one engine for the whole run; the backend swaps store/corpus per
    # write epoch WITHOUT retracing (they are jit arguments, not closures)
    engine = LshEngine(
        params, hp, store, DenseCorpus(jnp.zeros((c.num_users, c.dim))),
        None, EngineConfig(variant=cfg.variant),
    )
    backend = RuntimeBackend(engine)
    frontend = RetrievalFrontend(
        backend,
        FrontendConfig(
            m=c.m, max_batch=cfg.max_batch,
            queue_capacity=cfg.queue_capacity, cache=cfg.cache,
        ),
    )

    recalls, generations, repeat_mismatches = [], [], 0
    for epoch, vecs, do_refresh, qidx, ideal in _trajectory(c):
        if do_refresh:  # -- write epoch -----------------------------------
            announced = vecs.copy()
            codes = hashing.sketch_codes(jnp.asarray(announced), hp)
            store = insert_batch(
                store, jnp.arange(c.num_users, dtype=jnp.int32), codes,
                jnp.int32(epoch),
            )
            if epoch > 0:
                store = expire(store, jnp.int32(epoch), ttl=c.ttl_epochs)
            backend.update(store, DenseCorpus(jnp.asarray(announced)))
        if epoch == 0:
            continue

        # -- read epoch -----------------------------------------------------
        q = vecs[qidx]
        first_ids = None
        for _ in range(max(cfg.query_repeats, 1)):
            ids, _scores = frontend.search(q, exclude=qidx)
            if first_ids is None:
                first_ids = ids
                recalls.append(metrics.recall_at_m(ids, ideal))
            elif not np.array_equal(ids, first_ids):
                repeat_mismatches += 1  # a cache hit diverged — must be 0
        generations.append(backend.generation)

    return dict(
        recalls=np.asarray(recalls),
        final_recall=float(recalls[-1]),
        mean_recall=float(np.mean(recalls)),
        generations=np.asarray(generations),
        store_generation=int(store.generation),
        repeat_mismatches=repeat_mismatches,
        stats=frontend.stats,
        summary=frontend.stats.summary(),
        refresh_every=c.refresh_every,
    )
