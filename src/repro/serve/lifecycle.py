"""Online updates: interleave churn maintenance with serving (DESIGN.md
Sec. 7 read/write epochs).

The churn module measures index freshness with a fresh engine per epoch;
this driver measures it END-TO-END through the serving stack instead: ONE
long-lived `RetrievalFrontend` serves every epoch's queries while the
soft-state maintenance (`insert_batch` + `expire`, paper Sec. 4.1) runs
between read epochs.  Each write epoch bumps the store generation, which
is exactly what invalidates the sketch-keyed result cache — so the run
demonstrates the full contract: repeated queries hit the cache WITHIN a
store generation, never across a mutation, and recall under live churn
matches the reference trajectory (`core.churn.run_churn`) bit-exactly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel, hashing, metrics
from repro.core.churn import (
    ChurnConfig, _lsh_setup, _pad_to, _trajectory, _zone_mesh,
    make_churn_runtime,
)
from repro.core.corpus import DenseCorpus
from repro.core.engine import EngineConfig, LshEngine
from repro.core.runtime import IndexRuntime, RuntimeConfig, kill_node, reshard
from repro.core.store import expire, insert_batch, make_store
from repro.serve.frontend import FrontendConfig, RetrievalFrontend, RuntimeBackend
from repro.serve.writer import ChurnWriter


@dataclasses.dataclass(frozen=True)
class ServeChurnConfig:
    churn: ChurnConfig = ChurnConfig()
    query_repeats: int = 2     # replays of each epoch's query batch — the
    #                            repeats exercise the cache within an epoch
    max_batch: int = 32
    queue_capacity: int = 512
    cache: bool = True
    variant: str = "cnb"
    pipeline_depth: int = 1    # staged device batches (DESIGN.md Sec. 13);
    #                            the trajectory is bit-identical at any depth
    use_writer: bool = False   # route write epochs through the background
    #                            ChurnWriter (prepare/install split) instead
    #                            of mutating the backend on the serving path


def run_serve_churn(cfg: ServeChurnConfig, obs=None) -> dict:
    """Drive the churn trajectory through the serving frontend.

    Write epochs: announce (insert_batch) + GC (expire) + backend.update —
    one generation bump per mutation, invalidating the cache.  Read
    epochs: the epoch's query batch is served `query_repeats` times; all
    repeats must return identical ids (cache hits are real results, never
    stale ones), and repeat recall is measured per epoch.  With `obs`
    (an `repro.obs.Observability`) the frontend traces its pipeline
    spans and flight records per query (DESIGN.md Sec. 12).

    `cfg.use_writer` routes each write epoch through the `ChurnWriter`
    prepare/install split (DESIGN.md Sec. 13): the epoch's announce +
    expire build the new store inside the writer's prep function and the
    prepared update installs through `apply_update` at the next stage
    boundary — `drain()` is the per-epoch barrier, so the trajectory
    (and every recall number) stays bit-identical to the direct path.
    `cfg.pipeline_depth` deepens the device dispatch queue; depth changes
    batch OVERLAP, never batch composition, so the trajectory is
    bit-identical there too (tests/test_pipeline.py).
    """
    c = cfg.churn
    params, hp = _lsh_setup(c)
    store = make_store(c.L, params.num_buckets, c.capacity)
    announced = None

    # one engine for the whole run; the backend swaps store/corpus per
    # write epoch WITHOUT retracing (they are jit arguments, not closures)
    engine = LshEngine(
        params, hp, store, DenseCorpus(jnp.zeros((c.num_users, c.dim))),
        None, EngineConfig(variant=cfg.variant),
    )
    backend = RuntimeBackend(engine)
    frontend = RetrievalFrontend(
        backend,
        FrontendConfig(
            m=c.m, max_batch=cfg.max_batch,
            queue_capacity=cfg.queue_capacity, cache=cfg.cache,
            pipeline_depth=cfg.pipeline_depth,
        ),
        obs=obs,
    )
    writer = ChurnWriter(frontend) if cfg.use_writer else None

    def prep_write(epoch, vecs):
        """One write epoch's heavy half: sketch + insert + expire.  Runs
        on the writer thread when `use_writer`; returns the update kwargs
        the install half applies at a stage boundary.  Mutates the
        closed-over `store` chain so consecutive epochs compose (the
        writer runs preps FIFO on one thread)."""
        nonlocal store
        codes = hashing.sketch_codes(jnp.asarray(vecs), hp)
        # `insert_batch`/`expire` DONATE their input store, and once an
        # epoch has installed, the chained `store` IS the live serving
        # one — donating it would invalidate buffers an overlapped
        # dispatch still reads (the writer runs while serving continues).
        # Prep therefore always chains from a snapshot copy.
        store = jax.tree.map(jnp.copy, store)
        store = insert_batch(
            store, jnp.arange(c.num_users, dtype=jnp.int32), codes,
            jnp.int32(epoch),
        )
        if epoch > 0:
            store = expire(store, jnp.int32(epoch), ttl=c.ttl_epochs)
        return dict(store=store, corpus=DenseCorpus(jnp.asarray(vecs)))

    recalls, generations, repeat_mismatches = [], [], 0
    for epoch, vecs, do_refresh, qidx, ideal in _trajectory(c):
        if do_refresh:  # -- write epoch -----------------------------------
            announced = vecs.copy()
            if writer is not None:
                ep = int(epoch)
                writer.submit(lambda v=announced, e=ep: prep_write(e, v))
                # per-epoch barrier: prepared AND installed before the
                # epoch's reads, so the trajectory matches the reference
                writer.drain()
            else:
                backend.update(**prep_write(epoch, announced))
        if epoch == 0:
            continue

        # -- read epoch -----------------------------------------------------
        q = vecs[qidx]
        first_ids = None
        for _ in range(max(cfg.query_repeats, 1)):
            ids, _scores = frontend.search(q, exclude=qidx)
            if first_ids is None:
                first_ids = ids
                recalls.append(metrics.recall_at_m(ids, ideal))
            elif not np.array_equal(ids, first_ids):
                repeat_mismatches += 1  # a cache hit diverged — must be 0
        generations.append(backend.generation)

    if writer is not None:
        writer.close()
    if obs is not None:
        frontend.stats.publish(obs.registry)
    return dict(
        recalls=np.asarray(recalls),
        final_recall=float(recalls[-1]),
        mean_recall=float(np.mean(recalls)),
        generations=np.asarray(generations),
        store_generation=int(store.generation),
        repeat_mismatches=repeat_mismatches,
        writer_installed=0 if writer is None else writer.installed,
        stats=frontend.stats,
        summary=frontend.stats.summary(),
        refresh_every=c.refresh_every,
    )


def run_serve_reshard(cfg: ServeChurnConfig, mesh=None, obs=None) -> dict:
    """Churn trajectory through the frontend with a LIVE topology swap at
    every read epoch (the serving half of elastic membership, DESIGN.md
    Sec. 9).

    One long-lived `RetrievalFrontend` over a payload-carrying store; the
    backend alternates between the 1-node runtime and a 1-shard mesh
    runtime — the two execution contexts a single device can host — via
    `runtime.reshard` + `frontend.update_backend`.  Each read epoch
    serves its query batch three times: before the swap, right after it
    (every cached entry must be stale — the generation bump — and the
    recomputed ids must be IDENTICAL, the reshard bit-identity contract
    live on the serving path), and once more (hits again, same ids).
    Soft-state maintenance runs between read epochs on whichever topology
    is current; recall matches the `run_churn` reference trajectory
    exactly (tests/test_serve.py).
    """
    c = cfg.churn
    params, hp = _lsh_setup(c)
    if mesh is None:
        from repro.compat import make_mesh

        mesh = make_mesh((1, 1), ("data", "model"))
    # m+1 headroom: the mesh dispatch has no wire exclusion, the serving
    # layer filters the self id host-side (the churn drivers' convention)
    rcfg = RuntimeConfig(params=params, variant=cfg.variant, m=c.m + 1,
                         n_nodes=1, cap_factor=1.0)
    rt = IndexRuntime(rcfg)
    rt_other = {False: IndexRuntime(rcfg, mesh=mesh), True: rt}
    store = make_store(c.L, params.num_buckets, c.capacity,
                       payload_dim=c.dim)

    backend = RuntimeBackend(rt, hyperplanes=hp, store=store)
    frontend = RetrievalFrontend(
        backend,
        FrontendConfig(
            m=c.m, max_batch=cfg.max_batch,
            queue_capacity=cfg.queue_capacity, cache=cfg.cache,
        ),
        obs=obs,
    )

    recalls, generations = [], []
    repeat_mismatches = swaps = 0
    total_handoff = 0
    for epoch, vecs, do_refresh, qidx, ideal in _trajectory(c):
        if do_refresh:  # -- write epoch (current topology) ---------------
            nu = -(-c.num_users // rt.n_devices) * rt.n_devices
            vpad = _pad_to(vecs, nu, 0.0)
            ids_pad = _pad_to(np.arange(c.num_users, dtype=np.int32), nu, -1)
            store = rt.insert(hp, store, vpad, ids_pad, epoch)
            if epoch > 0:
                store = rt.expire(store, epoch, ttl=c.ttl_epochs)
            store = rt.payload_sync(store, vpad)
            frontend.update_backend(store=store)
        if epoch == 0:
            continue

        # -- read epoch: serve, swap topology live, serve again ------------
        q = vecs[qidx]
        ids_pre, _ = frontend.search(q, exclude=qidx)
        recalls.append(metrics.recall_at_m(ids_pre, ideal))

        rt_new = rt_other[rt.is_distributed]
        rt, store, ev = reshard(rt, store, runtime=rt_new)
        total_handoff += ev.handoff_bytes
        swaps += 1
        if obs is not None:
            obs.flight.note_anomaly(
                "reshard", epoch=int(epoch), old_n=int(ev.old_n),
                new_n=int(ev.new_n), handoff_bytes=int(ev.handoff_bytes),
            )
        frontend.update_backend(runtime=rt, store=store)

        for _ in range(2):  # post-swap recompute, then cache-served
            ids_post, _ = frontend.search(q, exclude=qidx)
            if not np.array_equal(ids_post, ids_pre):
                repeat_mismatches += 1
        generations.append(backend.generation)

    if obs is not None:
        frontend.stats.publish(obs.registry)
    cache = frontend.cache
    return dict(
        recalls=np.asarray(recalls),
        final_recall=float(recalls[-1]),
        mean_recall=float(np.mean(recalls)),
        generations=np.asarray(generations),
        repeat_mismatches=repeat_mismatches,
        swaps=swaps,
        total_handoff_bytes=int(total_handoff),
        stale_evictions=0 if cache is None else cache.stale_evictions,
        cache_hits=0 if cache is None else cache.hits,
        stats=frontend.stats,
        summary=frontend.stats.summary(),
    )


@dataclasses.dataclass(frozen=True)
class ServeFailureConfig:
    """Serving through a fail-stop node loss (DESIGN.md Sec. 10): one
    node of an R-way replicated mesh dies MID-EPOCH with no handoff, the
    frontend keeps serving through the surviving replicas, and the next
    announce epoch revives the node."""

    churn: ChurnConfig = ChurnConfig()
    n_nodes: int = 4
    replication: int = 2
    read_mode: str = "first"        # first | quorum
    kill_epoch: int = 3             # read epoch the node dies in
    kill_node: int = 1
    max_batch: int = 32
    queue_capacity: int = 512
    cache: bool = True


def run_serve_failure(cfg: ServeFailureConfig, mesh=None, obs=None) -> dict:
    """Churn trajectory through ONE long-lived frontend while a node dies
    and revives under it.

    The backend is a replicated mesh runtime (`make_churn_runtime` with
    R > 1); every write epoch re-announces, refreshes the NB cache,
    re-replicates (`IndexRuntime.replicate_store`, bytes charged via the
    Sec. 10 closed form), and installs the lot through
    `frontend.update_backend`.  At `kill_epoch` the epoch's queries are
    served once at full liveness, then `kill_node` blanks the victim's
    zone and replica slices and the DEAD-node state installs as a plain
    `update(store=, replicas=, live=)` — no runtime swap, so the dispatch
    binding (and its m-headroom) survives while the generation bump kills
    every pre-failure cached result.  The same queries are served again
    through the survivors; the next announce revives the node (recovery
    bytes charged) and serving returns to full liveness.

    Returns per-epoch recalls plus the kill-epoch pair
    (`recall_before_kill` / `recall_after_kill`), generation trace, and
    the usual cache/stats evidence that repeats within a generation are
    bit-identical and nothing stale is ever served.
    """
    c = cfg.churn
    if not 1 <= cfg.kill_epoch <= c.epochs:
        raise ValueError(f"kill_epoch {cfg.kill_epoch} outside the "
                         f"trajectory's read epochs 1..{c.epochs}")
    if not 0 <= cfg.kill_node < cfg.n_nodes:
        raise ValueError(f"kill_node {cfg.kill_node} outside "
                         f"0..{cfg.n_nodes - 1}")
    params, hp = _lsh_setup(c)
    if mesh is None:
        mesh = _zone_mesh(cfg.n_nodes)
    rt = make_churn_runtime(
        c, cfg.n_nodes, mesh=mesh,
        replication=cfg.replication, read_mode=cfg.read_mode,
    )
    store = make_store(c.L, params.num_buckets, c.capacity,
                      payload_dim=c.dim)
    live = np.ones((cfg.n_nodes,), np.int32)
    replicas = rt.replicate_store(store)
    nbcache = rt.refresh_cache(store)

    backend = RuntimeBackend(rt, hyperplanes=hp, store=store,
                             cache=nbcache, replicas=replicas)
    frontend = RetrievalFrontend(
        backend,
        FrontendConfig(
            m=c.m, max_batch=cfg.max_batch,
            queue_capacity=cfg.queue_capacity, cache=cfg.cache,
        ),
        obs=obs,
    )

    recalls, generations, degraded = [], [], []
    repeat_mismatches = 0
    replication_bytes = recovery_bytes = 0
    recall_before_kill = recall_after_kill = None
    per_rep = costmodel.estimate_replication_bytes(
        c.L, c.num_users, c.dim, cfg.replication)
    per_zone = costmodel.estimate_recovery_bytes(
        c.L, params.num_buckets // cfg.n_nodes, c.capacity, c.dim)
    for epoch, vecs, do_refresh, qidx, ideal in _trajectory(c):
        if do_refresh:  # -- write epoch (revives any dead node) ----------
            if not live.all():
                recovery_bytes += per_zone * int((live == 0).sum())
                live[:] = 1
            nu = -(-c.num_users // rt.n_devices) * rt.n_devices
            vpad = _pad_to(vecs, nu, 0.0)
            ids_pad = _pad_to(np.arange(c.num_users, dtype=np.int32),
                              nu, -1)
            store = rt.insert(hp, store, vpad, ids_pad, epoch)
            if epoch > 0:
                store = rt.expire(store, epoch, ttl=c.ttl_epochs)
            store = rt.payload_sync(store, vpad)
            nbcache = rt.refresh_cache(store)
            replicas = rt.replicate_store(store)
            replication_bytes += per_rep
            frontend.update_backend(store=store, cache=nbcache,
                                    replicas=replicas, live=live.copy())
        if epoch == 0:
            continue

        # -- read epoch ----------------------------------------------------
        q = vecs[qidx]
        if epoch == cfg.kill_epoch:
            # full-liveness pass first, then the node dies MID-EPOCH
            ids_pre, _ = frontend.search(q, exclude=qidx)
            recall_before_kill = metrics.recall_at_m(ids_pre, ideal)
            store, replicas = kill_node(rt, store, replicas, cfg.kill_node)
            live[cfg.kill_node] = 0
            if obs is not None:
                # the mid-epoch fail-stop: dump the flight ring so the
                # pre-failure query records are preserved for post-mortem
                obs.flight.note_anomaly(
                    "kill_node", node=int(cfg.kill_node), epoch=int(epoch),
                    live_nodes=int(live.sum()),
                )
            frontend.update_backend(store=store, replicas=replicas,
                                    live=live.copy())
        ids, _ = frontend.search(q, exclude=qidx)
        recalls.append(metrics.recall_at_m(ids, ideal))
        if epoch == cfg.kill_epoch:
            recall_after_kill = recalls[-1]
        ids2, _ = frontend.search(q, exclude=qidx)
        if not np.array_equal(ids2, ids):
            repeat_mismatches += 1  # a cache hit diverged — must be 0
        generations.append(backend.generation)
        degraded.append(bool((live == 0).any()))

    if obs is not None:
        frontend.stats.publish(obs.registry)
    cache = frontend.cache
    return dict(
        recalls=np.asarray(recalls),
        final_recall=float(recalls[-1]),
        generations=np.asarray(generations),
        degraded=np.asarray(degraded),
        recall_before_kill=recall_before_kill,
        recall_after_kill=recall_after_kill,
        repeat_mismatches=repeat_mismatches,
        replication_bytes=int(replication_bytes),
        recovery_bytes=int(recovery_bytes),
        stale_evictions=0 if cache is None else cache.stale_evictions,
        cache_hits=0 if cache is None else cache.hits,
        stats=frontend.stats,
        summary=frontend.stats.summary(),
    )
