"""Online retrieval serving: the NearBucket-LSH query service (DESIGN.md
Sec. 7), driven by `repro.launch.serve_retrieval`.

  - `frontend`  — request ring, dynamic pow-2 batching, admission
                  control, the depth-K pipelined dispatch machine
                  (DESIGN.md Sec. 13), and the ONE dispatch backend
                  (`RuntimeBackend`) over an `IndexRuntime` of any
                  topology (DESIGN.md Sec. 8);
  - `qcache`    — sketch-keyed result cache with generation-based
                  invalidation wired to store churn;
  - `writer`    — background churn writer: prepare off-thread, install
                  at stage boundaries;
  - `loadgen`   — open-loop Poisson load + the max-qps-at-SLO sweep;
  - `lifecycle` — read/write epochs: churn maintenance interleaved
                  with serving;
  - `telemetry` — p50/p99 latency, time-in-queue, qps, hit rate,
                  Table-1 cost and dropped-probe aggregation.

(LM prefill/decode serving lives with its driver in
`repro.launch.serve`; it shares nothing with the retrieval service.)
"""

from repro.serve.frontend import (  # noqa: F401
    ADMIT_REJECT,
    RING_FULL,
    FrontendConfig,
    PendingDispatch,
    RetrievalFrontend,
    RuntimeBackend,
    SubmitReject,
    dispatch_pad,
    pow2_pad,
)
from repro.serve.lifecycle import (  # noqa: F401
    ServeChurnConfig,
    ServeFailureConfig,
    run_serve_churn,
    run_serve_failure,
    run_serve_reshard,
)
from repro.serve.loadgen import (  # noqa: F401
    OpenLoopResult,
    max_qps_at_slo,
    poisson_arrivals,
    run_open_loop,
)
from repro.serve.qcache import CacheEntry, QueryCache  # noqa: F401
from repro.serve.telemetry import ServeStats  # noqa: F401
from repro.serve.writer import ChurnWriter  # noqa: F401
