"""Serving layer.

Two services share this package:

  * LM serving — `repro.serve.serve_step` (batched prefill + decode),
    driven by `repro.launch.serve`;
  * online retrieval — the NearBucket-LSH query service (DESIGN.md
    Sec. 7), driven by `repro.launch.serve_retrieval`:
      - `frontend`  — request ring, dynamic pow-2 batching, admission
                      control, pluggable engine/mesh dispatch backends;
      - `qcache`    — sketch-keyed result cache with generation-based
                      invalidation wired to store churn;
      - `lifecycle` — read/write epochs: churn maintenance interleaved
                      with serving;
      - `telemetry` — p50/p99 latency, qps, hit rate, Table-1 cost and
                      dropped-probe aggregation.

`serve_step` is intentionally NOT imported here: it pulls the model
stack, which the retrieval service does not need.
"""

from repro.serve.frontend import (  # noqa: F401
    DistBackend,
    EngineBackend,
    FrontendConfig,
    RetrievalFrontend,
    dispatch_pad,
    pow2_pad,
)
from repro.serve.lifecycle import ServeChurnConfig, run_serve_churn  # noqa: F401
from repro.serve.qcache import CacheEntry, QueryCache  # noqa: F401
from repro.serve.telemetry import ServeStats  # noqa: F401
