"""Serving telemetry for the online retrieval frontend (DESIGN.md Sec. 7).

One mutable `ServeStats` object rides along with a `RetrievalFrontend` and
aggregates everything the per-step objects only report individually:

  * request accounting — accepted / rejected (admission shed) /
    ring_full (transient backpressure, retryable) / completed, cache
    hits vs misses, dispatched batch sizes and padding overhead;
  * latency — per-request microseconds from submit to result, with
    p50/p99 read out of the recorded population, plus time-in-queue
    (submit to device stage) for the pipelined frontend;
  * network cost — the Table-1 `QueryCost` closed form is charged per
    *dispatched* (cache-miss) query and averaged over ALL completed
    queries, so a cache hit genuinely shows up as saved messages;
  * `dropped_probes` — router-overflow counts from the distributed steps,
    summed across batches (the PR-2 counted-never-silent discipline,
    surfaced at the serving summary instead of per-`SearchResult`).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import costmodel


@dataclasses.dataclass
class ServeStats:
    """Mutable aggregate counters for one serving run."""

    accepted: int = 0        # requests admitted into the ring
    rejected: int = 0        # admission-control rejects (counted, not silent)
    ring_full: int = 0       # transient full-ring pushback (retryable —
    #                          distinct from `rejected`, which is a shed)
    completed: int = 0       # results delivered (hit or miss)
    cache_hits: int = 0
    cache_misses: int = 0
    batches: int = 0         # backend dispatches
    dispatched: int = 0      # cache-miss queries sent to the backend
    padded: int = 0          # dead rows added by pow-2 batch padding
    dropped_probes: int = 0  # router overflow across all dispatches
    # Table-1 cost accumulators (charged per dispatched query)
    messages: float = 0.0
    vectors_searched: float = 0.0
    nodes_contacted: float = 0.0
    # latency samples live in a fixed ring of the most recent
    # `latency_window` requests, so a long-lived frontend's memory stays
    # O(window), not O(total requests served)
    latency_window: int = 65536
    _lat: np.ndarray | None = None
    # time-in-queue samples (submit -> device stage), same ring discipline
    staged: int = 0
    _queue: np.ndarray | None = None
    _t_first: float | None = None
    _t_last: float | None = None

    # -- recording hooks (called by the frontend) ----------------------------

    def record_submit(self, admitted: bool) -> None:
        now = time.perf_counter()
        if self._t_first is None:
            self._t_first = now
        if admitted:
            self.accepted += 1
        else:
            self.rejected += 1

    def record_ring_full(self) -> None:
        """One transient full-ring pushback — the RETRYABLE submit outcome
        (the caller may step/retry), kept apart from `rejected` so the
        two failure modes never collapse into one count again."""
        now = time.perf_counter()
        if self._t_first is None:
            self._t_first = now
        self.ring_full += 1

    def record_queue_time(self, queue_us: float) -> None:
        """Time one request spent in the ring before its batch was staged
        onto the device queue."""
        if self._queue is None:
            self._queue = np.empty((self.latency_window,), np.float64)
        self._queue[self.staged % self.latency_window] = queue_us
        self.staged += 1

    def record_done(self, latency_us: float, *, hit: bool) -> None:
        if hit:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
        if self._lat is None:
            self._lat = np.empty((self.latency_window,), np.float64)
        self._lat[self.completed % self.latency_window] = latency_us
        self.completed += 1
        self._t_last = time.perf_counter()

    def record_batch(
        self,
        n_queries: int,
        n_padded: int,
        dropped_probes: int,
        cost: costmodel.QueryCost | None,
    ) -> None:
        """One backend dispatch: `n_queries` live rows, `n_padded` dead
        rows, the router drop count, and the per-query Table-1 cost in
        effect (None when the backend has no closed form)."""
        self.batches += 1
        self.dispatched += int(n_queries)
        self.padded += int(n_padded)
        self.dropped_probes += int(dropped_probes)
        if cost is not None:
            self.messages += cost.messages * n_queries
            self.vectors_searched += cost.vectors_searched * n_queries
            self.nodes_contacted += cost.nodes_contacted * n_queries

    # -- read-out -------------------------------------------------------------

    @property
    def latencies_us(self) -> np.ndarray:
        """The retained latency samples (most recent `latency_window`)."""
        if self._lat is None:
            return np.empty((0,), np.float64)
        return self._lat[: min(self.completed, self.latency_window)]

    def percentile(self, p: float) -> float:
        """Latency percentile in microseconds over the retained window
        (0.0 when nothing completed — summaries must stay printable, and
        a nan would poison any downstream arithmetic silently)."""
        lat = self.latencies_us
        if lat.size == 0:
            return 0.0
        return float(np.percentile(lat, p))

    def queue_percentile(self, p: float) -> float:
        """Time-in-queue percentile in microseconds (same no-nan
        contract as `percentile`)."""
        if self._queue is None:
            return 0.0
        q = self._queue[: min(self.staged, self.latency_window)]
        if q.size == 0:
            return 0.0
        return float(np.percentile(q, p))

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / max(self.completed, 1)

    @property
    def wall_seconds(self) -> float:
        if self._t_first is None or self._t_last is None:
            return 0.0
        return max(self._t_last - self._t_first, 0.0)

    @property
    def qps(self) -> float:
        """Completed queries per wall second (0.0 before any completion —
        same no-nan contract as `percentile`)."""
        w = self.wall_seconds
        return self.completed / w if w > 0 else 0.0

    @property
    def messages_per_query(self) -> float:
        """Average overlay messages per COMPLETED query — cache hits cost 0,
        so this drops below the Table-1 closed form as the hit rate rises."""
        return self.messages / max(self.completed, 1)

    @property
    def nodes_contacted_per_query(self) -> float:
        """Average overlay nodes contacted per COMPLETED query (Table 1's
        first column, hit-rate discounted like `messages_per_query`)."""
        return self.nodes_contacted / max(self.completed, 1)

    def publish(self, registry, **labels) -> None:
        """Mirror the summary into an `repro.obs` metrics registry — the
        machine-readable export surface (DESIGN.md Sec. 12); `summary()`
        stays as the in-process dict view.  Gauges, not counters: this
        object is already the accumulator, so publishing is an idempotent
        snapshot, safe to repeat mid-run."""
        for key, val in self.summary().items():
            registry.gauge(f"serve_{key}").set(float(val), **labels)

    def summary(self) -> dict:
        return dict(
            accepted=self.accepted,
            rejected=self.rejected,
            ring_full=self.ring_full,
            completed=self.completed,
            cache_hits=self.cache_hits,
            cache_misses=self.cache_misses,
            hit_rate=self.hit_rate,
            batches=self.batches,
            dispatched=self.dispatched,
            padded=self.padded,
            mean_batch=self.dispatched / max(self.batches, 1),
            dropped_probes=self.dropped_probes,
            messages_per_query=self.messages_per_query,
            nodes_contacted_per_query=self.nodes_contacted_per_query,
            vectors_searched_per_query=(
                self.vectors_searched / max(self.completed, 1)
            ),
            p50_us=self.percentile(50),
            p99_us=self.percentile(99),
            p50_queue_us=self.queue_percentile(50),
            p99_queue_us=self.queue_percentile(99),
            qps=self.qps,
        )

    def format_summary(self) -> str:
        s = self.summary()
        return (
            f"[serve] completed={s['completed']} rejected={s['rejected']} "
            f"ring_full={s['ring_full']} qps={s['qps']:.0f}\n"
            f"[serve] latency p50={s['p50_us']:.0f}us "
            f"p99={s['p99_us']:.0f}us  "
            f"batches={s['batches']} (mean size {s['mean_batch']:.1f}, "
            f"{s['padded']} padded rows)\n"
            f"[serve] cache hit rate={s['hit_rate']:.2f} "
            f"({s['cache_hits']}/{s['completed']})  "
            f"messages/query={s['messages_per_query']:.1f}  "
            f"nodes/query={s['nodes_contacted_per_query']:.1f}  "
            f"dropped_probes={s['dropped_probes']}"
        )
