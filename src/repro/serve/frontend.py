"""Online retrieval frontend: request ring, dynamic batching, admission
control (DESIGN.md Sec. 7).

Turns the batch-oriented query runtimes into an online service without
adding a serving-only query path:

  * requests land in a FIXED-CAPACITY ring (`submit`); arrivals beyond
    capacity are rejected and COUNTED (`ServeStats.rejected`) — the same
    counted-never-silent discipline as the router's `dropped_probes`;
  * `step` coalesces up to `max_batch` pending requests, pads the batch
    to a power of two (so the jit'd dispatch sees a BOUNDED set of
    compiled shapes — at most log2(max_batch)+1 — instead of one trace
    per arrival count), consults the sketch-keyed result cache
    (`repro.serve.qcache`), dispatches only the misses, and scatters
    results back per request;
  * dispatch goes through ONE backend — `RuntimeBackend` — wrapping an
    `IndexRuntime` search step on ANY topology (DESIGN.md Sec. 8): over
    the 1-node runtime of an `LshEngine` it returns ids bit-identical to
    a direct `engine.search` (CI-checked); over a mesh runtime it runs
    the shard_map step with host-side self-exclusion and one result of
    wire headroom.  The store (and corpus/cache) are jit ARGUMENTS, so
    live store updates (churn) never retrace the query path.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core import costmodel
from repro.core import metrics as metrics_mod
from repro.core import plan as plan_mod
from repro.core.engine import LshEngine
from repro.core.runtime import IndexRuntime
from repro.obs import QueryRecord
from repro.obs.trace import span_or_null
from repro.serve.qcache import QueryCache
from repro.serve.telemetry import ServeStats

NO_EXCLUDE = -2  # matches LshEngine.search's "no self id" sentinel


def pow2_pad(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor) — the dispatch shape grid."""
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()


def dispatch_pad(n: int, multiple: int = 1) -> int:
    """Dispatch size for `n` live rows: the smallest multiple of
    `multiple` >= pow2_pad(n).  `multiple` is a sharded backend's device
    count — the global batch must divide evenly over the mesh, which a
    bare power of two does not guarantee on non-pow-2 meshes.  Still a
    bounded shape set: each pow-2 value maps to exactly one padded size."""
    m = max(int(multiple), 1)
    return -(-pow2_pad(n) // m) * m


# -----------------------------------------------------------------------------
# the dispatch backend (one class, any topology)
# -----------------------------------------------------------------------------


class RuntimeBackend:
    """THE dispatch adapter: an `IndexRuntime` search step behind the
    frontend, on any topology.

    Built from an `LshEngine` (its 1-node runtime + store + corpus: result
    ids are bit-identical to a direct `engine.search`, CI-checked) or from
    a mesh `IndexRuntime` (+ hyperplanes/store/cache).  Either way the
    runtime kernel is re-jitted here with the store, corpus, and cache as
    ARGUMENTS instead of closed-over constants, so a churn update
    (`update`) swaps state without recompiling; `traces` counts actual
    retraces (trace-time side effect), which is what the pow-2
    shape-budget test asserts on.

    The one topology-dependent branch is exclusion: the 1-node kernel
    excludes in-kernel (the reference semantics), while the mesh wire
    path has no exclusion support (the id is not secret, paper Sec. 6) —
    the step is built with one result of headroom (`cfg.m = serve_m + 1`)
    and the self id is filtered host-side, the distributed churn driver's
    convention.  `dropped_probes` from the capacitated router flows
    through to the telemetry (structurally 0 on one node).
    """

    def __init__(self, source, hyperplanes=None, store=None, corpus=None,
                 cache=None, replicas=None, live=None):
        if isinstance(source, LshEngine):
            runtime = source.runtime
            hyperplanes = source.hyperplanes if hyperplanes is None else hyperplanes
            store = source.store if store is None else store
            corpus = source.corpus if corpus is None else corpus
        elif isinstance(source, IndexRuntime):
            runtime = source
            if hyperplanes is None or store is None:
                raise ValueError(
                    "RuntimeBackend(IndexRuntime) needs hyperplanes= and "
                    "store="
                )
        else:
            raise TypeError(f"expected LshEngine or IndexRuntime, got "
                            f"{type(source).__name__}")
        if runtime.is_distributed and corpus is not None:
            raise ValueError("corpus scoring is 1-node only (mesh shards "
                             "embed payloads in their bucket slots)")
        if not runtime.is_distributed and cache is not None:
            raise ValueError("neighbor caches exist only on mesh runtimes "
                             "(the 1-node topology has no node bits)")
        if runtime.cfg.replication > 1 and replicas is None:
            raise ValueError(
                "cfg.replication > 1 needs replicas= "
                "(IndexRuntime.replicate_store)"
            )
        if runtime.cfg.replication == 1 and (replicas is not None
                                             or live is not None):
            raise ValueError("replicas/live require cfg.replication > 1")
        self._rt = runtime
        self._hp = hyperplanes
        self._store = store
        self._corpus = corpus
        self._cache = cache
        self._replicas = replicas
        self._live = self._live_arr(runtime, live)
        self._generation = int(np.asarray(store.generation))
        self._cost_gen: int | None = None
        self._cost: costmodel.QueryCost | None = None
        self.traces = 0
        self.sketch_traces = 0
        # observability hooks — host-side only, never traced: the frontend
        # installs a Tracer here when built with obs; the exact-rescoring
        # corpus cache backs the sampled recall probe
        self.tracer = None
        self._exact_vecs: np.ndarray | None = None
        self._bind()

    def _bind(self) -> None:
        """(Re)build the jit'd dispatch/sketch for the CURRENT runtime.

        Called at construction and again on every topology swap
        (`update(runtime=...)`): the dispatch shape, sharding spec, and
        exclusion discipline are all functions of the runtime, so a
        resharded runtime gets a fresh binding.  `traces` keeps
        accumulating across rebinds (each swap pays its retraces — the
        shape-budget tests count within one binding)."""
        runtime = self._rt
        if runtime.is_distributed:
            from jax.sharding import NamedSharding, PartitionSpec as P

            self._qspec = NamedSharding(
                runtime.mesh, P(runtime.batch_axes, None)
            )
            step = runtime.search_step_fn()

            def _impl(hp, *args):
                self.traces += 1  # runs at trace time only
                return step(hp, *args)

            self._dispatch_jit = jax.jit(_impl)
        else:
            self._qspec = None
            step = runtime.search_step_fn(
                with_corpus=self._corpus is not None)

            def _impl(hp, store_ids, payload, q, ex, m):
                self.traces += 1  # runs at trace time only
                return step(hp, store_ids, payload, q, ex, m)

            self._dispatch_jit = jax.jit(_impl, static_argnums=(5,))

        def _sketch(q):
            self.sketch_traces += 1
            return plan_mod.sketch(
                q, self._hp,
                use_kernels=runtime.cfg.use_kernels
                and not runtime.is_distributed,
            )

        self._sketch_jit = jax.jit(_sketch)

    @staticmethod
    def _live_arr(runtime, live):
        if runtime.cfg.replication == 1:
            return None
        if live is None:
            return np.ones(runtime.cfg.n_nodes, np.int32)
        return np.asarray(live, np.int32)

    @property
    def runtime(self) -> IndexRuntime:
        return self._rt

    @property
    def dim(self) -> int:
        return self._hp.shape[-1]

    @property
    def min_batch(self) -> int:
        # the global batch shards over every device, so dispatch sizes
        # must be multiples of the device count (dispatch_pad enforces it;
        # 1 on the 1-node runtime)
        return self._rt.n_devices

    @property
    def max_m(self) -> int | None:
        if not self._rt.is_distributed:
            return None  # m is a static call argument — no baked ceiling
        return self._rt.cfg.m - 1  # headroom for host-side self-exclusion

    @property
    def generation(self) -> int:
        return self._generation

    def update(self, store=None, corpus=None, cache=None, *,
               runtime=None, hyperplanes=None, replicas=None,
               live=None) -> None:
        """Install new store state (and/or corpus / refreshed neighbor
        cache) — a write epoch.  The host-side generation snapshot is what
        cache lookups compare against, so it syncs here, once per update,
        off the query path.  It bumps on EVERY update, even when the store
        object is unchanged: a corpus swap or NB-cache refresh also
        changes scores, so cached results must die with it.

        `runtime=` accepts a RESHARDED runtime (a membership round,
        DESIGN.md Sec. 9): the dispatch is rebound to the new topology
        and `store=` (the migrated store, placed by the reshard) becomes
        mandatory.  The generation bump is what keeps the sketch-keyed
        cache honest across the swap — a result computed on the old
        topology is bit-identical to the new one's, but its entry still
        dies with the round (membership is a state event).  The NB cache
        never survives a swap (its shape is topology-bound): pass the
        rewarmed one or it resets to None.  A pre-existing corpus is
        dropped when swapping to a mesh runtime, whose shards embed
        payloads in their bucket slots.  Callers serving live traffic
        should swap through `RetrievalFrontend.update_backend`, which
        drains in-flight batches on the OLD topology first.

        `replicas=`/`live=` install fresh replica slices and a liveness
        mask on a replicated backend (DESIGN.md Sec. 10) — the failure
        path: a kill or a revival arrives as `update(store=...,
        replicas=..., live=...)` with NO runtime swap, so serving
        continues on the same binding (m-headroom preserved) while the
        generation bump kills every pre-failure cached result."""
        # -- validate the whole request before mutating anything ----------
        new_rt = self._rt if runtime is None else runtime
        if runtime is not None and store is None:
            raise ValueError(
                "a topology swap must install the migrated store "
                "(reshard returns it)"
            )
        if runtime is not None and runtime.is_distributed \
                and store.payload is None:
            # the mesh dispatch scores embedded slot payloads; an
            # ids-only store would only fail later, at trace time,
            # with the backend already mutated
            raise ValueError(
                "swapping to a mesh runtime needs a payload-carrying "
                "store (mesh shards embed payloads in their bucket slots)"
            )
        if runtime is None and hyperplanes is not None:
            raise ValueError("hyperplanes only change with a runtime swap")
        if corpus is not None and new_rt.is_distributed:
            # same guard as __init__: the mesh dispatch path scores slot
            # payloads and would silently ignore an installed corpus
            raise ValueError("corpus scoring is 1-node only (mesh shards "
                             "embed payloads in their bucket slots)")
        if corpus is not None and self._corpus is None and runtime is None:
            # the dispatch jit was baked for slot-payload scoring at
            # construction; a late corpus would crash it at trace time
            raise ValueError("this backend was built without a corpus "
                             "(slot-payload scoring); corpus swaps need a "
                             "corpus-built backend")
        if cache is not None and not new_rt.is_distributed:
            raise ValueError("neighbor caches exist only on mesh runtimes "
                             "(the 1-node topology has no node bits)")
        if new_rt.cfg.replication == 1 and (replicas is not None
                                            or live is not None):
            raise ValueError("replicas/live require cfg.replication > 1")
        if runtime is not None and runtime.cfg.replication > 1 \
                and replicas is None:
            raise ValueError(
                "swapping to a replicated runtime needs replicas= "
                "(IndexRuntime.replicate_store)"
            )

        # -- apply (each field assigned once; _bind reads the final state)
        if store is not None:
            self._store = store
        if corpus is not None:
            self._corpus = corpus
            self._exact_vecs = None  # recall-probe ground truth died too
        if cache is not None:
            self._cache = cache
        if replicas is not None:
            self._replicas = replicas
        if live is not None:
            self._live = self._live_arr(new_rt, live)
        if runtime is not None:
            self._rt = runtime
            if hyperplanes is not None:
                self._hp = hyperplanes
            # topology-bound state never crosses a swap: a mesh target
            # scores slot payloads (no corpus), and the NB cache dies
            # unless the rewarmed one arrived with the swap
            if runtime.is_distributed:
                self._corpus = None
                self._exact_vecs = None
            if cache is None:
                self._cache = None
            # replica state is topology-bound too: an unreplicated target
            # drops it; a replicated one resets liveness to all-ones
            # unless the swap brought a mask along
            if runtime.cfg.replication == 1:
                self._replicas = None
                self._live = None
            elif live is None:
                self._live = self._live_arr(runtime, None)
            self._bind()
        self._generation = max(
            int(np.asarray(self._store.generation)), self._generation + 1
        )

    def sketch_codes(self, q_pad: np.ndarray) -> np.ndarray:
        return np.asarray(self._sketch_jit(q_pad))

    def cost(self) -> costmodel.QueryCost:
        """Table-1 closed form at the current store occupancy (cached per
        generation — occupancy only changes when the store does)."""
        if self._cost_gen != self._generation:
            b = float(np.mean(np.asarray(self._store.occupancy())))
            c = self._rt.cfg
            self._cost = costmodel.table1(
                c.variant, c.params.k, c.params.L, b
            )
            self._cost_gen = self._generation
        return self._cost

    def dispatch(self, q_pad: np.ndarray, ex_pad: np.ndarray, m: int):
        """One batch through the jit'd step.  Returns (ids, scores,
        stats): `stats` is the step's `StepStats` aux output — use
        `int(stats)` for the bare dropped-probe count (the telemetry
        does), `stats.host()` for the full accounting record."""
        import jax.numpy as jnp

        with span_or_null(self.tracer, "serve/device"):
            if not self._rt.is_distributed:
                payload = (
                    self._corpus if self._corpus is not None
                    else self._store.payload
                )
                ids, scores, stats = self._dispatch_jit(
                    self._hp, self._store.ids, payload,
                    jnp.asarray(q_pad, jnp.float32), jnp.asarray(ex_pad), m,
                )
                return np.asarray(ids), np.asarray(scores), stats

            if m > self.max_m:
                raise ValueError(
                    f"m={m} exceeds the step's headroom (built with "
                    f"cfg.m={self._rt.cfg.m}; serveable m <= {self.max_m})"
                )
            q = jax.device_put(jnp.asarray(q_pad, jnp.float32), self._qspec)
            args = (self._hp, self._store.ids, self._store.payload)
            if self._cache is not None:
                args += tuple(self._cache)
            if self._rt.cfg.replication > 1:
                args += (self._replicas[0], self._replicas[1],
                         jnp.asarray(self._live, jnp.int32))
            ids, scores, stats = self._dispatch_jit(*args, q)
            ids = np.asarray(ids)
            scores = np.asarray(scores)
            # host-side self-exclusion + slice to the serving m
            out_i = np.full((ids.shape[0], m), -1, np.int32)
            out_s = np.full((ids.shape[0], m), -np.inf, np.float32)
            for i in range(ids.shape[0]):
                keep = ids[i] != ex_pad[i]
                out_i[i] = ids[i][keep][:m]
                out_s[i] = scores[i][keep][:m]
            return out_i, out_s, stats

    def exact_topm(self, q: np.ndarray, exclude: int, m: int):
        """Exact top-m ids by full corpus scan — ground truth for the
        sampled shadow-rescoring recall probe.  None when this backend
        cannot rescore exactly (mesh topologies embed payloads in bucket
        slots; sparse corpora have no dense row matrix)."""
        if self._corpus is None or not hasattr(self._corpus, "vectors"):
            return None
        if self._exact_vecs is None:
            self._exact_vecs = np.asarray(self._corpus.vectors)
        sims = self._exact_vecs @ np.asarray(q, np.float32)
        if 0 <= exclude < sims.size:
            sims[exclude] = -np.inf
        m = min(m, sims.size)
        top = np.argpartition(-sims, m - 1)[:m]
        return top[np.argsort(-sims[top])].astype(np.int32)


# -----------------------------------------------------------------------------
# the frontend
# -----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    m: int = 10                   # results per query
    max_batch: int = 64           # max requests coalesced per dispatch
    queue_capacity: int = 256     # request ring size (admission control)
    cache: bool = True            # sketch-keyed result cache on/off
    cache_capacity: int = 4096
    sketch_only_cache: bool = False  # approximate keying (see qcache)

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.queue_capacity < 1:
            raise ValueError(
                f"queue_capacity must be >= 1, got {self.queue_capacity}"
            )


class RetrievalFrontend:
    """Single-threaded event-loop frontend over a dispatch backend.

    submit() -> ticket (or None on admission reject); step() serves one
    coalesced batch; poll(ticket) -> (ids, scores) once served.  The
    convenience `search()` drives the loop synchronously for a whole
    query matrix and is the surface the bit-identity tests compare
    against `engine.search`.
    """

    def __init__(
        self,
        backend,
        config: FrontendConfig = FrontendConfig(),
        stats: ServeStats | None = None,
        obs=None,
    ):
        if backend.max_m is not None and config.m > backend.max_m:
            raise ValueError(
                f"m={config.m} unsupported by backend (max {backend.max_m})"
            )
        self.backend = backend
        self.cfg = config
        self.stats = stats if stats is not None else ServeStats()
        # observability (DESIGN.md Sec. 12): `obs` is an
        # `repro.obs.Observability` bundle or None.  Strictly host-side —
        # the dispatch jit is identical either way (tests/test_obs.py
        # counts retraces obs-on vs obs-off to prove it).
        self.obs = obs
        if obs is not None:
            backend.tracer = obs.tracer
        self._dispatch_seq = 0
        self._probe_seen = 0    # served misses, for 1-in-N probe sampling
        self._probe_sum = 0.0
        self._probe_n = 0
        self.cache = (
            QueryCache(config.cache_capacity, config.sketch_only_cache)
            if config.cache
            else None
        )
        cap, d = config.queue_capacity, backend.dim
        # fixed-capacity request ring (preallocated; no per-request alloc)
        self._ring_q = np.zeros((cap, d), np.float32)
        self._ring_ex = np.full((cap,), NO_EXCLUDE, np.int32)
        self._ring_ticket = np.zeros((cap,), np.int64)
        self._ring_t = np.zeros((cap,), np.float64)
        self._head = 0
        self._size = 0
        self._next_ticket = 0
        self._results: dict[int, tuple[np.ndarray, np.ndarray]] = {}

    # -- request lifecycle ----------------------------------------------------

    @property
    def pending(self) -> int:
        return self._size

    @property
    def free(self) -> int:
        return self.cfg.queue_capacity - self._size

    def submit(self, q: np.ndarray, exclude: int = NO_EXCLUDE) -> int | None:
        """Admit one query into the ring; None (counted) when over capacity."""
        if self._size >= self.cfg.queue_capacity:
            self.stats.record_submit(False)
            return None
        slot = (self._head + self._size) % self.cfg.queue_capacity
        self._ring_q[slot] = q
        self._ring_ex[slot] = exclude
        ticket = self._next_ticket
        self._next_ticket += 1
        self._ring_ticket[slot] = ticket
        self._ring_t[slot] = time.perf_counter()
        self._size += 1
        self.stats.record_submit(True)
        return ticket

    def poll(self, ticket: int):
        """(ids, scores) for a served ticket, else None. Pops the result."""
        return self._results.pop(ticket, None)

    def step(self) -> int:
        """Serve one coalesced batch from the ring; returns #completed.

        With obs installed, the pipeline stages emit spans
        (intake -> batch -> dispatch -> device -> merge -> respond) and
        every served query + every backend dispatch appends a
        `QueryRecord` to the flight recorder — dispatch records carry the
        step's EXACT `StepStats`, query records their batch's per-row
        share plus the latency breakdown.
        """
        n = min(self._size, self.cfg.max_batch)
        if n == 0:
            return 0
        obs = self.obs
        tr = obs.tracer if obs is not None else None
        cap = self.cfg.queue_capacity
        with span_or_null(tr, "serve/intake", n=n):
            idx = (self._head + np.arange(n)) % cap
            q = self._ring_q[idx].copy()
            ex = self._ring_ex[idx].copy()
            tickets = self._ring_ticket[idx].copy()
            t_sub = self._ring_t[idx].copy()
            self._head = (self._head + n) % cap
            self._size -= n

        gen = self.backend.generation
        m = self.cfg.m
        miss_rows = list(range(n))
        keys: list[tuple | None] = [None] * n
        with span_or_null(tr, "serve/batch"):
            if self.cache is not None:
                # sketch once for the whole coalesced batch (pow-2 padded,
                # so the sketch jit shares the dispatch shape grid)
                pad = dispatch_pad(n, self.backend.min_batch)
                q_pad = np.zeros((pad, q.shape[1]), np.float32)
                q_pad[:n] = q
                codes = self.backend.sketch_codes(q_pad)[:n]
                miss_rows = []
                for i in range(n):
                    keys[i] = self.cache.key(codes[i], int(ex[i]), q[i], m)
                    e = self.cache.get(keys[i], gen)
                    if e is None:
                        miss_rows.append(i)
                    else:
                        self._results[int(tickets[i])] = (e.ids, e.scores)
                        lat = (time.perf_counter() - t_sub[i]) * 1e6
                        self.stats.record_done(lat, hit=True)
                        if obs is not None:
                            obs.flight.record(QueryRecord(
                                qid=int(tickets[i]), kind="query",
                                latency_us=lat, cache_hit=True,
                                generation=gen,
                            ))

        if miss_rows:
            nm = len(miss_rows)
            pad = dispatch_pad(nm, self.backend.min_batch)
            mq = np.zeros((pad, q.shape[1]), np.float32)
            mex = np.full((pad,), NO_EXCLUDE, np.int32)
            mq[:nm] = q[miss_rows]
            mex[:nm] = ex[miss_rows]
            with span_or_null(tr, "serve/dispatch", rows=nm, pad=pad) as dsp:
                ids, scores, stats = self.backend.dispatch(mq, mex, m)
            self.stats.record_batch(nm, pad - nm, stats, self.backend.cost())
            seq, hs = self._dispatch_seq, None
            self._dispatch_seq += 1
            if obs is not None:
                hs = (stats.host() if hasattr(stats, "host")
                      else dict(dropped_probes=int(stats)))
                obs.flight.record(QueryRecord(
                    qid=seq, kind="dispatch", batch=seq, batch_size=pad,
                    generation=gen,
                    stage_us=dict(dispatch=dsp.duration_us),
                    extra=dict(live_rows=nm, padded_rows=pad - nm), **hs,
                ))
            with span_or_null(tr, "serve/merge"):
                for j, i in enumerate(miss_rows):
                    ids_i, sc_i = ids[j], scores[j]
                    self._results[int(tickets[i])] = (ids_i, sc_i)
                    if self.cache is not None:
                        self.cache.put(keys[i], ids_i, sc_i, gen)
            with span_or_null(tr, "serve/respond"):
                t_done = time.perf_counter()
                if obs is not None:
                    # per-row share of the batch's planned probes (uniform:
                    # the planner issues the same probe count per row);
                    # drops stay on the dispatch record — the
                    # authoritative sum.  stage dict shared read-only.
                    share = hs["probes_issued"] // pad
                    fanout = hs.get("replica_fanout", 1)
                    stage = dict(dispatch=dsp.duration_us)
                    t_rec = obs.flight.to_us(t_done)  # one stamp per batch
                for j, i in enumerate(miss_rows):
                    lat = (t_done - t_sub[i]) * 1e6
                    self.stats.record_done(lat, hit=False)
                    if obs is not None:
                        obs.flight.record(QueryRecord(
                            qid=int(tickets[i]), kind="query", t_us=t_rec,
                            latency_us=lat, cache_hit=False, generation=gen,
                            batch=seq, batch_size=pad,
                            probes_issued=share, replica_fanout=fanout,
                            stage_us=stage,
                        ))
            if obs is not None and obs.config.recall_probe_every > 0:
                self._recall_probe(obs, mq, mex, ids, nm, m)
        return n

    def _recall_probe(self, obs, mq, mex, ids, nm, m) -> None:
        """Sampled shadow-rescoring recall probe (DESIGN.md Sec. 12): every
        `recall_probe_every`-th served miss is rescored EXACTLY against
        the corpus and `recall_at_m` lands in the registry — live search
        quality next to the live cost counters.  Silently inactive on
        backends with no exact ground truth (mesh topologies)."""
        every = obs.config.recall_probe_every
        for j in range(nm):
            self._probe_seen += 1
            if self._probe_seen % every:
                continue
            exact = self.backend.exact_topm(mq[j], int(mex[j]), m)
            if exact is None:
                return
            r = metrics_mod.recall_at_m(ids[j][None, :], exact[None, :])
            self._probe_sum += r
            self._probe_n += 1
            obs.registry.counter(
                "serve_recall_probes_total",
                "queries shadow-rescored against the exact corpus",
            ).inc()
            g = obs.registry.gauge(
                "serve_recall_probe",
                "recall@m of sampled served queries vs exact top-m",
            )
            g.set(r, window="last")
            g.set(self._probe_sum / self._probe_n, window="mean")

    def flush(self) -> None:
        while self._size:
            self.step()

    def update_backend(self, **kw) -> None:
        """Live backend update through the frontend — REQUIRED for topology
        swaps while serving: in-flight batches (everything already in the
        ring) drain on the OLD topology first, then the new runtime/store
        install via `backend.update(**kw)`.  The generation bump that
        comes with every update is what makes each cached result from
        before the swap stale — the sketch-keyed cache serves nothing
        across a reshard (tests/test_serve.py)."""
        rt = kw.get("runtime")
        if rt is not None and rt.is_distributed and self.cfg.m > rt.cfg.m - 1:
            raise ValueError(
                f"serving m={self.cfg.m} exceeds the new runtime's headroom "
                f"(cfg.m={rt.cfg.m}; mesh dispatch keeps one result for "
                "host-side self-exclusion)"
            )
        self.flush()  # in-flight batches complete on the old topology
        self.backend.update(**kw)

    # -- synchronous convenience (tests / examples) ---------------------------

    def search(
        self, queries: np.ndarray, exclude: np.ndarray | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """Submit a whole query matrix, drive the loop, gather results in
        order — the drop-in replacement for `engine.search(...)[:2]`."""
        queries = np.asarray(queries, np.float32)
        nq = queries.shape[0]
        m = self.cfg.m
        out_i = np.full((nq, m), -1, np.int32)
        out_s = np.full((nq, m), -np.inf, np.float32)
        tickets = np.empty((nq,), np.int64)
        for i in range(nq):
            if self.free == 0:
                self.step()  # drain before the ring would reject
            ex = NO_EXCLUDE if exclude is None else int(exclude[i])
            t = self.submit(queries[i], ex)
            assert t is not None  # free>=1 guaranteed above
            tickets[i] = t
        self.flush()
        for i in range(nq):
            ids_i, sc_i = self._results.pop(int(tickets[i]))
            out_i[i], out_s[i] = ids_i, sc_i
        return out_i, out_s
