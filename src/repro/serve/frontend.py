"""Online retrieval frontend: request ring, dynamic batching, admission
control, and the pipelined dispatch machine (DESIGN.md Sec. 7 + 13).

Turns the batch-oriented query runtimes into an online service without
adding a serving-only query path:

  * requests land in a FIXED-CAPACITY ring (`submit`); the sketch-keyed
    result cache (`repro.serve.qcache`) is consulted AT INTAKE — a hit
    is answered immediately and never occupies a ring slot or a
    dispatch-queue slot, so cache hits cannot be backpressured by
    queued misses; a miss beyond ring capacity gets the RETRYABLE
    `RING_FULL` pushback, an over-committed service sheds with
    `ADMIT_REJECT` — two distinct, counted outcomes
    (`ServeStats.ring_full` vs `.rejected`), the same
    counted-never-silent discipline as the router's `dropped_probes`;
  * the step machine coalesces up to `max_batch` pending requests, pads
    the batch to a power of two (so the jit'd dispatch sees a BOUNDED
    set of compiled shapes — at most log2(max_batch)+1 — instead of one
    trace per arrival count), and STAGES it onto a depth-K device queue
    (`FrontendConfig.pipeline_depth`): JAX async dispatch returns before
    the batch computes, so batch N+1 is staged while batch N runs, and
    completions are REAPED out of order by ticket (`wait`/`poll`).
    `pipeline_depth=1` is the synchronous path — stage then block — and
    pipelined served ids are bit-identical to it under any schedule
    (tests/test_pipeline.py proves it on a deterministic one);
  * dispatch goes through ONE backend — `RuntimeBackend` — wrapping an
    `IndexRuntime` search step on ANY topology (DESIGN.md Sec. 8): over
    the 1-node runtime of an `LshEngine` it returns ids bit-identical to
    a direct `engine.search` (CI-checked); over a mesh runtime it runs
    the shard_map step with host-side self-exclusion and one result of
    wire headroom.  The store (and corpus/cache) are jit ARGUMENTS, so
    live store updates (churn) never retrace the query path — and
    because an in-flight batch holds references to the store pytree it
    was dispatched with (immutable arrays), a churn update may install
    BETWEEN dispatches (`apply_update`, the background-writer path)
    without draining: the in-flight batch completes as if serialized
    before the update, and its results are cached at its stage-time
    generation, which the update's bump makes stale on the next lookup.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core import costmodel
from repro.core import metrics as metrics_mod
from repro.core import plan as plan_mod
from repro.core.engine import LshEngine
from repro.core.runtime import IndexRuntime
from repro.obs import QueryRecord
from repro.obs.trace import span_or_null
from repro.serve.qcache import QueryCache
from repro.serve.telemetry import ServeStats

NO_EXCLUDE = -2  # matches LshEngine.search's "no self id" sentinel


class SubmitReject:
    """Falsy `submit` outcome carrying WHY the request was not admitted.

    `retryable=True` (`RING_FULL`) means transient backpressure: the ring
    has no free slot right now, but a `step`/`pump` will drain it — the
    caller should retry.  `retryable=False` (`ADMIT_REJECT`) means
    admission control shed the request because the service is
    over-committed (`FrontendConfig.admit_limit`) — retrying immediately
    is pointless.  Instances are module-level singletons, so callers may
    compare with `is`; truthiness is False either way, so
    `if not ticket:` treats both as failure (note ticket 0 is a VALID
    ticket — compare against the sentinels or `isinstance`, never
    truthiness, when the distinction matters)."""

    __slots__ = ("reason", "retryable")

    def __init__(self, reason: str, retryable: bool):
        self.reason = reason
        self.retryable = retryable

    def __bool__(self) -> bool:
        return False

    def __repr__(self) -> str:
        return f"SubmitReject({self.reason!r}, retryable={self.retryable})"


RING_FULL = SubmitReject("ring_full", retryable=True)
ADMIT_REJECT = SubmitReject("admission", retryable=False)


def pow2_pad(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor) — the dispatch shape grid."""
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()


def dispatch_pad(n: int, multiple: int = 1) -> int:
    """Dispatch size for `n` live rows: the smallest multiple of
    `multiple` >= pow2_pad(n).  `multiple` is a sharded backend's device
    count — the global batch must divide evenly over the mesh, which a
    bare power of two does not guarantee on non-pow-2 meshes.  Still a
    bounded shape set: each pow-2 value maps to exactly one padded size."""
    m = max(int(multiple), 1)
    return -(-pow2_pad(n) // m) * m


# -----------------------------------------------------------------------------
# the dispatch backend (one class, any topology)
# -----------------------------------------------------------------------------


class PendingDispatch:
    """One in-flight jit'd search step: device handles plus enough
    context to finish host-side.

    JAX async dispatch means `RuntimeBackend.dispatch_async` returns one
    of these BEFORE the batch computes; `ready()` is a non-blocking
    completion probe and `wait()` is the only place a device sync
    happens — it blocks, converts to host arrays, and (on a mesh
    backend) applies the host-side self-exclusion.  The exclusion row
    and m are captured at dispatch time, so a backend update installed
    while the batch is in flight cannot change how it finishes."""

    __slots__ = ("_backend", "_raw", "_ex", "_m", "_distributed", "_done")

    def __init__(self, backend, raw, ex_pad, m, distributed):
        self._backend = backend
        self._raw = raw
        self._ex = ex_pad
        self._m = m
        self._distributed = distributed
        self._done = None

    def ready(self) -> bool:
        """True once the device result is materialized (non-blocking)."""
        if self._done is not None:
            return True
        return bool(self._raw[0].is_ready())

    def wait(self):
        """Block until complete; returns (ids, scores, stats) host-side."""
        if self._done is None:
            with span_or_null(self._backend.tracer, "serve/compute"):
                jax.block_until_ready(self._raw)
            self._done = self._backend._finish(
                self._raw, self._ex, self._m, self._distributed
            )
            self._raw = None  # drop the device handles
        return self._done


class RuntimeBackend:
    """THE dispatch adapter: an `IndexRuntime` search step behind the
    frontend, on any topology.

    Built from an `LshEngine` (its 1-node runtime + store + corpus: result
    ids are bit-identical to a direct `engine.search`, CI-checked) or from
    a mesh `IndexRuntime` (+ hyperplanes/store/cache).  Either way the
    runtime kernel is re-jitted here with the store, corpus, and cache as
    ARGUMENTS instead of closed-over constants, so a churn update
    (`update`) swaps state without recompiling; `traces` counts actual
    retraces (trace-time side effect), which is what the pow-2
    shape-budget test asserts on.

    The one topology-dependent branch is exclusion: the 1-node kernel
    excludes in-kernel (the reference semantics), while the mesh wire
    path has no exclusion support (the id is not secret, paper Sec. 6) —
    the step is built with one result of headroom (`cfg.m = serve_m + 1`)
    and the self id is filtered host-side, the distributed churn driver's
    convention.  `dropped_probes` from the capacitated router flows
    through to the telemetry (structurally 0 on one node).
    """

    def __init__(self, source, hyperplanes=None, store=None, corpus=None,
                 cache=None, replicas=None, live=None):
        if isinstance(source, LshEngine):
            runtime = source.runtime
            hyperplanes = source.hyperplanes if hyperplanes is None else hyperplanes
            store = source.store if store is None else store
            corpus = source.corpus if corpus is None else corpus
        elif isinstance(source, IndexRuntime):
            runtime = source
            if hyperplanes is None or store is None:
                raise ValueError(
                    "RuntimeBackend(IndexRuntime) needs hyperplanes= and "
                    "store="
                )
        else:
            raise TypeError(f"expected LshEngine or IndexRuntime, got "
                            f"{type(source).__name__}")
        if runtime.is_distributed and corpus is not None:
            raise ValueError("corpus scoring is 1-node only (mesh shards "
                             "embed payloads in their bucket slots)")
        if not runtime.is_distributed and cache is not None:
            raise ValueError("neighbor caches exist only on mesh runtimes "
                             "(the 1-node topology has no node bits)")
        if runtime.cfg.replication > 1 and replicas is None:
            raise ValueError(
                "cfg.replication > 1 needs replicas= "
                "(IndexRuntime.replicate_store)"
            )
        if runtime.cfg.replication == 1 and (replicas is not None
                                             or live is not None):
            raise ValueError("replicas/live require cfg.replication > 1")
        self._rt = runtime
        self._hp = hyperplanes
        self._store = store
        self._corpus = corpus
        self._cache = cache
        self._replicas = replicas
        self._live = self._live_arr(runtime, live)
        self._generation = int(np.asarray(store.generation))
        self._cost_gen: int | None = None
        self._cost: costmodel.QueryCost | None = None
        self.traces = 0
        self.sketch_traces = 0
        # observability hooks — host-side only, never traced: the frontend
        # installs a Tracer here when built with obs; the exact-rescoring
        # corpus cache backs the sampled recall probe
        self.tracer = None
        self._exact_vecs: np.ndarray | None = None
        self._bind()

    def _bind(self) -> None:
        """(Re)build the jit'd dispatch/sketch for the CURRENT runtime.

        Called at construction and again on every topology swap
        (`update(runtime=...)`): the dispatch shape, sharding spec, and
        exclusion discipline are all functions of the runtime, so a
        resharded runtime gets a fresh binding.  `traces` keeps
        accumulating across rebinds (each swap pays its retraces — the
        shape-budget tests count within one binding)."""
        runtime = self._rt
        if runtime.is_distributed:
            from jax.sharding import NamedSharding, PartitionSpec as P

            self._qspec = NamedSharding(
                runtime.mesh, P(runtime.batch_axes, None)
            )
            step = runtime.search_step_fn()

            def _impl(hp, *args):
                self.traces += 1  # runs at trace time only
                return step(hp, *args)

            self._dispatch_jit = jax.jit(_impl)
        else:
            self._qspec = None
            step = runtime.search_step_fn(
                with_corpus=self._corpus is not None)

            def _impl(hp, store_ids, payload, q, ex, m):
                self.traces += 1  # runs at trace time only
                return step(hp, store_ids, payload, q, ex, m)

            self._dispatch_jit = jax.jit(_impl, static_argnums=(5,))

        def _sketch(q):
            self.sketch_traces += 1
            return plan_mod.sketch(
                q, self._hp,
                use_kernels=runtime.cfg.use_kernels
                and not runtime.is_distributed,
            )

        self._sketch_jit = jax.jit(_sketch)

    @staticmethod
    def _live_arr(runtime, live):
        if runtime.cfg.replication == 1:
            return None
        if live is None:
            return np.ones(runtime.cfg.n_nodes, np.int32)
        return np.asarray(live, np.int32)

    @property
    def runtime(self) -> IndexRuntime:
        return self._rt

    @property
    def dim(self) -> int:
        return self._hp.shape[-1]

    @property
    def min_batch(self) -> int:
        # the global batch shards over every device, so dispatch sizes
        # must be multiples of the device count (dispatch_pad enforces it;
        # 1 on the 1-node runtime)
        return self._rt.n_devices

    @property
    def max_m(self) -> int | None:
        if not self._rt.is_distributed:
            return None  # m is a static call argument — no baked ceiling
        return self._rt.cfg.m - 1  # headroom for host-side self-exclusion

    @property
    def generation(self) -> int:
        return self._generation

    def update(self, store=None, corpus=None, cache=None, *,
               runtime=None, hyperplanes=None, replicas=None,
               live=None) -> None:
        """Install new store state (and/or corpus / refreshed neighbor
        cache) — a write epoch.  The host-side generation snapshot is what
        cache lookups compare against, so it syncs here, once per update,
        off the query path.  It bumps on EVERY update, even when the store
        object is unchanged: a corpus swap or NB-cache refresh also
        changes scores, so cached results must die with it.

        `runtime=` accepts a RESHARDED runtime (a membership round,
        DESIGN.md Sec. 9): the dispatch is rebound to the new topology
        and `store=` (the migrated store, placed by the reshard) becomes
        mandatory.  The generation bump is what keeps the sketch-keyed
        cache honest across the swap — a result computed on the old
        topology is bit-identical to the new one's, but its entry still
        dies with the round (membership is a state event).  The NB cache
        never survives a swap (its shape is topology-bound): pass the
        rewarmed one or it resets to None.  A pre-existing corpus is
        dropped when swapping to a mesh runtime, whose shards embed
        payloads in their bucket slots.  Callers serving live traffic
        should swap through `RetrievalFrontend.update_backend`, which
        drains in-flight batches on the OLD topology first.

        `replicas=`/`live=` install fresh replica slices and a liveness
        mask on a replicated backend (DESIGN.md Sec. 10) — the failure
        path: a kill or a revival arrives as `update(store=...,
        replicas=..., live=...)` with NO runtime swap, so serving
        continues on the same binding (m-headroom preserved) while the
        generation bump kills every pre-failure cached result."""
        # -- validate the whole request before mutating anything ----------
        new_rt = self._rt if runtime is None else runtime
        if runtime is not None and store is None:
            raise ValueError(
                "a topology swap must install the migrated store "
                "(reshard returns it)"
            )
        if runtime is not None and runtime.is_distributed \
                and store.payload is None:
            # the mesh dispatch scores embedded slot payloads; an
            # ids-only store would only fail later, at trace time,
            # with the backend already mutated
            raise ValueError(
                "swapping to a mesh runtime needs a payload-carrying "
                "store (mesh shards embed payloads in their bucket slots)"
            )
        if runtime is None and hyperplanes is not None:
            raise ValueError("hyperplanes only change with a runtime swap")
        if corpus is not None and new_rt.is_distributed:
            # same guard as __init__: the mesh dispatch path scores slot
            # payloads and would silently ignore an installed corpus
            raise ValueError("corpus scoring is 1-node only (mesh shards "
                             "embed payloads in their bucket slots)")
        if corpus is not None and self._corpus is None and runtime is None:
            # the dispatch jit was baked for slot-payload scoring at
            # construction; a late corpus would crash it at trace time
            raise ValueError("this backend was built without a corpus "
                             "(slot-payload scoring); corpus swaps need a "
                             "corpus-built backend")
        if cache is not None and not new_rt.is_distributed:
            raise ValueError("neighbor caches exist only on mesh runtimes "
                             "(the 1-node topology has no node bits)")
        if new_rt.cfg.replication == 1 and (replicas is not None
                                            or live is not None):
            raise ValueError("replicas/live require cfg.replication > 1")
        if runtime is not None and runtime.cfg.replication > 1 \
                and replicas is None:
            raise ValueError(
                "swapping to a replicated runtime needs replicas= "
                "(IndexRuntime.replicate_store)"
            )

        # -- apply (each field assigned once; _bind reads the final state)
        if store is not None:
            self._store = store
        if corpus is not None:
            self._corpus = corpus
            self._exact_vecs = None  # recall-probe ground truth died too
        if cache is not None:
            self._cache = cache
        if replicas is not None:
            self._replicas = replicas
        if live is not None:
            self._live = self._live_arr(new_rt, live)
        if runtime is not None:
            self._rt = runtime
            if hyperplanes is not None:
                self._hp = hyperplanes
            # topology-bound state never crosses a swap: a mesh target
            # scores slot payloads (no corpus), and the NB cache dies
            # unless the rewarmed one arrived with the swap
            if runtime.is_distributed:
                self._corpus = None
                self._exact_vecs = None
            if cache is None:
                self._cache = None
            # replica state is topology-bound too: an unreplicated target
            # drops it; a replicated one resets liveness to all-ones
            # unless the swap brought a mask along
            if runtime.cfg.replication == 1:
                self._replicas = None
                self._live = None
            elif live is None:
                self._live = self._live_arr(runtime, None)
            self._bind()
        self._generation = max(
            int(np.asarray(self._store.generation)), self._generation + 1
        )

    def sketch_codes(self, q_pad: np.ndarray) -> np.ndarray:
        return np.asarray(self._sketch_jit(q_pad))

    def cost(self) -> costmodel.QueryCost:
        """Table-1 closed form at the current store occupancy (cached per
        generation — occupancy only changes when the store does)."""
        if self._cost_gen != self._generation:
            b = float(np.mean(np.asarray(self._store.occupancy())))
            c = self._rt.cfg
            self._cost = costmodel.table1(
                c.variant, c.params.k, c.params.L, b
            )
            self._cost_gen = self._generation
        return self._cost

    def dispatch_async(self, q_pad: np.ndarray, ex_pad: np.ndarray,
                       m: int) -> PendingDispatch:
        """Launch one batch through the jit'd step WITHOUT waiting.

        JAX dispatches asynchronously, so this returns (host -> device
        transfer + enqueue, the "stage" pipeline phase) while the device
        computes; the returned `PendingDispatch` finishes the batch —
        `wait()` for the host-side results, `ready()` to probe without
        blocking.  Keeping stage and wait apart is what lets the
        frontend hold `pipeline_depth` batches in flight."""
        import jax.numpy as jnp

        distributed = self._rt.is_distributed
        with span_or_null(self.tracer, "serve/stage",
                          pad=int(q_pad.shape[0])):
            if not distributed:
                payload = (
                    self._corpus if self._corpus is not None
                    else self._store.payload
                )
                raw = self._dispatch_jit(
                    self._hp, self._store.ids, payload,
                    jnp.asarray(q_pad, jnp.float32), jnp.asarray(ex_pad), m,
                )
                return PendingDispatch(self, raw, None, m, False)

            if m > self.max_m:
                raise ValueError(
                    f"m={m} exceeds the step's headroom (built with "
                    f"cfg.m={self._rt.cfg.m}; serveable m <= {self.max_m})"
                )
            q = jax.device_put(jnp.asarray(q_pad, jnp.float32), self._qspec)
            args = (self._hp, self._store.ids, self._store.payload)
            if self._cache is not None:
                args += tuple(self._cache)
            if self._rt.cfg.replication > 1:
                args += (self._replicas[0], self._replicas[1],
                         jnp.asarray(self._live, jnp.int32))
            raw = self._dispatch_jit(*args, q)
            return PendingDispatch(self, raw, np.asarray(ex_pad), m, True)

    def _finish(self, raw, ex_pad, m, distributed):
        """Host-side tail of a dispatch (called by `PendingDispatch.wait`
        after the device sync): array conversion, and on a mesh the
        self-exclusion filter + slice to the serving m."""
        ids, scores, stats = raw
        ids = np.asarray(ids)
        scores = np.asarray(scores)
        if not distributed:
            return ids, scores, stats
        out_i = np.full((ids.shape[0], m), -1, np.int32)
        out_s = np.full((ids.shape[0], m), -np.inf, np.float32)
        for i in range(ids.shape[0]):
            keep = ids[i] != ex_pad[i]
            out_i[i] = ids[i][keep][:m]
            out_s[i] = scores[i][keep][:m]
        return out_i, out_s, stats

    def dispatch(self, q_pad: np.ndarray, ex_pad: np.ndarray, m: int):
        """One batch through the jit'd step, synchronously.  Returns
        (ids, scores, stats): `stats` is the step's `StepStats` aux
        output — use `int(stats)` for the bare dropped-probe count (the
        telemetry does), `stats.host()` for the full accounting record."""
        return self.dispatch_async(q_pad, ex_pad, m).wait()

    def exact_topm(self, q: np.ndarray, exclude: int, m: int):
        """Exact top-m ids by full corpus scan — ground truth for the
        sampled shadow-rescoring recall probe.  None when this backend
        cannot rescore exactly (mesh topologies embed payloads in bucket
        slots; sparse corpora have no dense row matrix)."""
        if self._corpus is None or not hasattr(self._corpus, "vectors"):
            return None
        if self._exact_vecs is None:
            self._exact_vecs = np.asarray(self._corpus.vectors)
        sims = self._exact_vecs @ np.asarray(q, np.float32)
        if 0 <= exclude < sims.size:
            sims[exclude] = -np.inf
        m = min(m, sims.size)
        top = np.argpartition(-sims, m - 1)[:m]
        return top[np.argsort(-sims[top])].astype(np.int32)


# -----------------------------------------------------------------------------
# the frontend
# -----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    m: int = 10                   # results per query
    max_batch: int = 64           # max requests coalesced per dispatch
    queue_capacity: int = 256     # request ring size (backpressure)
    cache: bool = True            # sketch-keyed result cache on/off
    cache_capacity: int = 4096
    sketch_only_cache: bool = False  # approximate keying (see qcache)
    pipeline_depth: int = 1       # in-flight device batches (1 = sync:
    #                               stage then block — the reference path)
    admit_limit: int | None = None  # shed (ADMIT_REJECT) when ring +
    #                                 in-flight rows reach this; None = off

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.queue_capacity < 1:
            raise ValueError(
                f"queue_capacity must be >= 1, got {self.queue_capacity}"
            )
        if self.pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1, got {self.pipeline_depth}"
            )
        if self.admit_limit is not None and self.admit_limit < 1:
            raise ValueError(
                f"admit_limit must be >= 1 (or None), got {self.admit_limit}"
            )


class _InflightBatch:
    """One staged batch on the device dispatch queue: the
    `PendingDispatch` plus everything needed to reap it host-side."""

    __slots__ = ("pending", "tickets", "ticket_set", "keys", "t_sub",
                 "mq", "mex", "nm", "pad", "gen", "seq", "stage_us")

    def __init__(self, pending, tickets, keys, t_sub, mq, mex, nm, pad,
                 gen, seq, stage_us):
        self.pending = pending
        self.tickets = tickets
        self.ticket_set = {int(t) for t in tickets}
        self.keys = keys
        self.t_sub = t_sub
        self.mq = mq
        self.mex = mex
        self.nm = nm
        self.pad = pad
        self.gen = gen
        self.seq = seq
        self.stage_us = stage_us


class RetrievalFrontend:
    """Single-threaded event-loop frontend over a dispatch backend.

    submit() -> int ticket, or a falsy `SubmitReject` (`RING_FULL` to
    retry, `ADMIT_REJECT` on shed); cache hits are answered at intake —
    the ticket's result is immediately pollable and no ring slot is
    consumed.  step() advances the pipelined step machine one
    deterministic notch (stage a batch if there is room, block-reap when
    the pipeline is full); pump() advances it without unnecessary
    blocking (the open-loop serving loop); poll(ticket) -> (ids, scores)
    once served, wait(ticket) block-reaps exactly the batch carrying the
    ticket — out-of-order completion.  The convenience `search()` drives
    the loop synchronously for a whole query matrix and is the surface
    the bit-identity tests compare against `engine.search`.

    With `pipeline_depth=1` every stage is immediately followed by a
    blocking reap — the synchronous reference path.  Deeper pipelines
    keep up to K batches in flight on the device queue; batch
    composition depends only on the submit/step schedule (FIFO intake of
    min(pending, max_batch) rows), and per-row results are independent
    of batch composition, so served ids are bit-identical across depths
    (tests/test_pipeline.py).
    """

    def __init__(
        self,
        backend,
        config: FrontendConfig = FrontendConfig(),
        stats: ServeStats | None = None,
        obs=None,
    ):
        if backend.max_m is not None and config.m > backend.max_m:
            raise ValueError(
                f"m={config.m} unsupported by backend (max {backend.max_m})"
            )
        self.backend = backend
        self.cfg = config
        self.stats = stats if stats is not None else ServeStats()
        # observability (DESIGN.md Sec. 12): `obs` is an
        # `repro.obs.Observability` bundle or None.  Strictly host-side —
        # the dispatch jit is identical either way (tests/test_obs.py
        # counts retraces obs-on vs obs-off to prove it).
        self.obs = obs
        if obs is not None:
            backend.tracer = obs.tracer
        self._dispatch_seq = 0
        self._probe_seen = 0    # served misses, for 1-in-N probe sampling
        self._probe_sum = 0.0
        self._probe_n = 0
        self.cache = (
            QueryCache(config.cache_capacity, config.sketch_only_cache)
            if config.cache
            else None
        )
        cap, d = config.queue_capacity, backend.dim
        # fixed-capacity request ring (preallocated; no per-request alloc)
        self._ring_q = np.zeros((cap, d), np.float32)
        self._ring_ex = np.full((cap,), NO_EXCLUDE, np.int32)
        self._ring_ticket = np.zeros((cap,), np.int64)
        self._ring_t = np.zeros((cap,), np.float64)
        # cache key per ring slot, computed once at intake (None w/o cache)
        self._ring_key: list = [None] * cap
        self._head = 0
        self._size = 0
        self._next_ticket = 0
        self._results: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        # the device dispatch queue: up to pipeline_depth staged batches,
        # in dispatch order (reaped FIFO by step/flush, out-of-order by
        # ready()/wait(ticket))
        self._inflight: list[_InflightBatch] = []
        # host-side hyperplanes for intake-time cache keys (lazy; see
        # _intake_codes)
        self._hp_host: np.ndarray | None = None
        self._bit_weights: np.ndarray | None = None
        # background churn writer hook (repro.serve.writer): prepared
        # updates install at stage boundaries on THIS thread
        self.writer = None
        # obs instrument handles, resolved once (the submit path is hot)
        if obs is not None:
            self._g_depth = obs.registry.gauge(
                "serve_queue_depth",
                "requests waiting in the intake ring",
            )
            self._h_queue = obs.registry.histogram(
                "serve_time_in_queue_us",
                "submit -> device stage, per request",
            )
        else:
            self._g_depth = self._h_queue = None

    # -- request lifecycle ----------------------------------------------------

    @property
    def pending(self) -> int:
        return self._size

    @property
    def free(self) -> int:
        return self.cfg.queue_capacity - self._size

    @property
    def inflight(self) -> int:
        """Batches currently staged on the device dispatch queue."""
        return len(self._inflight)

    @property
    def inflight_rows(self) -> int:
        """Live (non-padding) queries across all in-flight batches."""
        return sum(b.nm for b in self._inflight)

    def _intake_codes(self, q: np.ndarray) -> np.ndarray:
        """Sketch codes for ONE query, host-side — the intake cache key.

        A numpy replica of `hashing.sketch_codes` (sign bits of the
        random projections, packed little-endian): cheap enough to run
        per arrival, no device round-trip on the submit path.  Keys only
        have to be consistent WITH EACH OTHER — every lookup and every
        put uses this function — so the (measure-zero) risk of a sign
        differing from the device sketch at a projection that is exactly
        0.0 costs at most a cache miss, never a wrong result (exact-mode
        keys carry the raw query bytes regardless)."""
        hp = self._hp_host
        if hp is None:
            hp = np.asarray(self.backend._hp, np.float32)
            L, k, d = hp.shape
            self._hp_host = hp = hp.reshape(L * k, d)
            self._bit_weights = (
                np.uint32(1) << np.arange(k, dtype=np.uint32)
            )
        bits = (hp @ q >= 0).reshape(-1, self._bit_weights.size)
        return (bits * self._bit_weights).sum(axis=1, dtype=np.uint32)

    def submit(self, q: np.ndarray, exclude: int = NO_EXCLUDE):
        """Admit one query; returns an int ticket or a falsy
        `SubmitReject`.

        The sketch-keyed cache is consulted HERE, at intake: a hit's
        result is stored against the ticket immediately — it never
        occupies a ring or dispatch-queue slot, so a full queue cannot
        backpressure hits behind queued misses.  Misses enter the ring;
        `RING_FULL` (retryable) when the ring has no slot, `ADMIT_REJECT`
        (shed) when `admit_limit` says the service is over-committed.
        The cache linearizes at submit time: a hit observes the store
        generation current at THIS call, which is exactly when the
        caller handed the query over."""
        t0 = time.perf_counter()
        q = np.asarray(q, np.float32)
        key = None
        if self.cache is not None:
            gen = self.backend.generation
            key = self.cache.key(
                self._intake_codes(q), int(exclude), q, self.cfg.m
            )
            e = self.cache.get(key, gen)
            if e is not None:
                ticket = self._next_ticket
                self._next_ticket += 1
                self._results[ticket] = (e.ids, e.scores)
                self.stats.record_submit(True)
                lat = (time.perf_counter() - t0) * 1e6
                self.stats.record_done(lat, hit=True)
                if self.obs is not None:
                    self.obs.flight.record(QueryRecord(
                        qid=ticket, kind="query", latency_us=lat,
                        cache_hit=True, generation=gen,
                    ))
                return ticket
        if self.cfg.admit_limit is not None and \
                self._size + self.inflight_rows >= self.cfg.admit_limit:
            self.stats.record_submit(False)
            return ADMIT_REJECT
        if self._size >= self.cfg.queue_capacity:
            self.stats.record_ring_full()
            return RING_FULL
        slot = (self._head + self._size) % self.cfg.queue_capacity
        self._ring_q[slot] = q
        self._ring_ex[slot] = exclude
        self._ring_key[slot] = key
        ticket = self._next_ticket
        self._next_ticket += 1
        self._ring_ticket[slot] = ticket
        self._ring_t[slot] = t0
        self._size += 1
        self.stats.record_submit(True)
        if self._g_depth is not None:
            self._g_depth.set(self._size)
        return ticket

    def poll(self, ticket: int):
        """(ids, scores) for a served ticket, else None.  Pops the
        result.  Sweeps completed in-flight batches first (non-blocking),
        so out-of-order completions become visible as the device
        finishes them."""
        if ticket not in self._results and self._inflight:
            self._reap_ready()
        return self._results.pop(ticket, None)

    def wait(self, ticket: int):
        """Block until `ticket` is served; returns and pops its result.

        Reaps exactly the batch carrying the ticket — batches dispatched
        BEFORE it stay in flight (out-of-order reap by ticket).  A
        ticket still in the intake ring drives the step machine until
        its batch stages and completes."""
        r = self._results.pop(ticket, None)
        if r is not None:
            return r
        for b in list(self._inflight):
            if ticket in b.ticket_set:
                self._reap_batch(b)
                return self._results.pop(ticket)
        while self._size or self._inflight:
            self.step()
            r = self._results.pop(ticket, None)
            if r is not None:
                return r
        raise KeyError(f"unknown ticket {ticket}")

    def take_results(self) -> dict:
        """Pop every completed result at once: {ticket: (ids, scores)}.
        The open-loop serving loop's bulk drain."""
        out = self._results
        self._results = {}
        return out

    # -- the pipelined step machine -------------------------------------------

    def _install_updates(self) -> None:
        """Stage boundary hook: install any churn updates the background
        writer has prepared (repro.serve.writer).  Runs on the serving
        thread, BETWEEN dispatches — the writer never touches the
        backend from its own thread."""
        if self.writer is not None:
            self.writer.install(self)

    def _stage_batch(self) -> None:
        """Intake up to `max_batch` ring rows and stage them onto the
        device dispatch queue (async — returns before the batch
        computes).  Caller guarantees ring rows exist and the pipeline
        has a free slot."""
        self._install_updates()
        obs = self.obs
        tr = obs.tracer if obs is not None else None
        cap = self.cfg.queue_capacity
        n = min(self._size, self.cfg.max_batch)
        with span_or_null(tr, "serve/intake", n=n):
            idx = (self._head + np.arange(n)) % cap
            q = self._ring_q[idx].copy()
            ex = self._ring_ex[idx].copy()
            tickets = self._ring_ticket[idx].copy()
            t_sub = self._ring_t[idx].copy()
            keys = [self._ring_key[i] for i in idx]
            self._head = (self._head + n) % cap
            self._size -= n

        with span_or_null(tr, "serve/enqueue", rows=n):
            pad = dispatch_pad(n, self.backend.min_batch)
            mq = np.zeros((pad, q.shape[1]), np.float32)
            mex = np.full((pad,), NO_EXCLUDE, np.int32)
            mq[:n] = q
            mex[:n] = ex
            t_stage = time.perf_counter()
            queue_us = (t_stage - t_sub[:n]) * 1e6
            for us in queue_us:
                self.stats.record_queue_time(us)
            if self._h_queue is not None:
                # bulk observe: per-row Python observes are measurable
                # against the obs_overhead budget
                self._h_queue.observe_many(queue_us)
            if self._g_depth is not None:
                self._g_depth.set(self._size)

        gen = self.backend.generation
        t0 = time.perf_counter()
        pending = self.backend.dispatch_async(mq, mex, self.cfg.m)
        stage_us = (time.perf_counter() - t0) * 1e6
        seq = self._dispatch_seq
        self._dispatch_seq += 1
        self._inflight.append(_InflightBatch(
            pending, tickets, keys, t_sub, mq, mex, n, pad, gen, seq,
            stage_us,
        ))

    def _reap_ready(self) -> int:
        """Reap every in-flight batch whose device result is already
        materialized (non-blocking, out of dispatch order)."""
        done = 0
        for b in list(self._inflight):
            if b.pending.ready():
                done += self._reap_batch(b)
        return done

    def _reap_batch(self, b: _InflightBatch) -> int:
        """Finish one staged batch: device sync (if still computing),
        host conversion, result scatter, cache fill at the STAGE-TIME
        generation, telemetry, and flight records."""
        self._inflight.remove(b)
        obs = self.obs
        tr = obs.tracer if obs is not None else None
        t0 = time.perf_counter()
        ids, scores, stats = b.pending.wait()
        compute_us = (time.perf_counter() - t0) * 1e6
        nm, pad, gen, seq, m = b.nm, b.pad, b.gen, b.seq, self.cfg.m
        # the batch's StepStats sync to host here, at reap — never on the
        # stage path (that would serialize the pipeline on the device)
        self.stats.record_batch(nm, pad - nm, stats, self.backend.cost())
        hs = None
        if obs is not None:
            hs = (stats.host() if hasattr(stats, "host")
                  else dict(dropped_probes=int(stats)))
            obs.flight.record(QueryRecord(
                qid=seq, kind="dispatch", batch=seq, batch_size=pad,
                generation=gen,
                stage_us=dict(stage=b.stage_us, compute=compute_us),
                extra=dict(live_rows=nm, padded_rows=pad - nm), **hs,
            ))
        with span_or_null(tr, "serve/reap", batch=seq, rows=nm):
            for j in range(nm):
                ids_j, sc_j = ids[j], scores[j]
                self._results[int(b.tickets[j])] = (ids_j, sc_j)
                if self.cache is not None and b.keys[j] is not None:
                    # stage-time generation: a write installed while this
                    # batch was in flight already bumped past `gen`, so
                    # the entry is born stale and dies on its next lookup
                    # — never served across the update
                    self.cache.put(b.keys[j], ids_j, sc_j, gen)
        with span_or_null(tr, "serve/respond", batch=seq):
            t_done = time.perf_counter()
            if obs is not None:
                # per-row share of the batch's planned probes (uniform:
                # the planner issues the same probe count per row); drops
                # stay on the dispatch record — the authoritative sum.
                share = hs["probes_issued"] // pad
                fanout = hs.get("replica_fanout", 1)
                stage = dict(stage=b.stage_us, compute=compute_us)
                t_rec = obs.flight.to_us(t_done)  # one stamp per batch
            for j in range(nm):
                lat = (t_done - b.t_sub[j]) * 1e6
                self.stats.record_done(lat, hit=False)
                if obs is not None:
                    obs.flight.record(QueryRecord(
                        qid=int(b.tickets[j]), kind="query", t_us=t_rec,
                        latency_us=lat, cache_hit=False, generation=gen,
                        batch=seq, batch_size=pad,
                        probes_issued=share, replica_fanout=fanout,
                        stage_us=stage,
                    ))
        if obs is not None and obs.config.recall_probe_every > 0:
            self._recall_probe(obs, b.mq, b.mex, ids, nm, m)
        return nm

    def step(self) -> int:
        """Advance the step machine one DETERMINISTIC notch; returns
        #completed.

        Stages one batch when ring rows are pending and the pipeline has
        a free slot; block-reaps the OLDEST in-flight batch when the
        pipeline is full (or when there was nothing to stage).  With
        `pipeline_depth=1` that is exactly the synchronous loop — stage,
        then block on it.  Deliberately no `ready()` probes here: the
        call sequence alone determines batch composition and reap order,
        which is what the pipelined==synchronous equivalence test pins
        down.  (The open-loop serving path uses `pump`, which does probe.)

        With obs installed the stages emit spans (intake -> enqueue ->
        stage -> compute -> reap -> respond) and every served query +
        every backend dispatch appends a `QueryRecord` to the flight
        recorder — dispatch records carry the step's EXACT `StepStats`,
        query records their batch's per-row share plus the latency
        breakdown.
        """
        done = 0
        staged = False
        if self._size and len(self._inflight) < self.cfg.pipeline_depth:
            self._stage_batch()
            staged = True
        if self._inflight and (
            len(self._inflight) >= self.cfg.pipeline_depth or not staged
        ):
            done += self._reap_batch(self._inflight[0])
        return done

    def pump(self) -> int:
        """Advance without unnecessary blocking — the open-loop serving
        loop's driver.  Reaps whatever the device has finished
        (out-of-order), stages GREEDILY whenever the pipeline has a free
        slot (batch N+1 goes onto the device queue while batch N
        computes — partial batches included: the pow-2 grid makes small
        dispatches cheap, and waiting to fill `max_batch` would trade
        tail latency for nothing), and blocks only when the pipeline is
        completely full.  Returns #completed."""
        done = self._reap_ready()
        depth = self.cfg.pipeline_depth
        if depth == 1:
            if self._size:
                done += self.step()
            return done
        if self._size and len(self._inflight) < depth:
            self._stage_batch()
        elif self._inflight and len(self._inflight) >= depth:
            done += self._reap_batch(self._inflight[0])
        return done

    def _recall_probe(self, obs, mq, mex, ids, nm, m) -> None:
        """Sampled shadow-rescoring recall probe (DESIGN.md Sec. 12): every
        `recall_probe_every`-th served miss is rescored EXACTLY against
        the corpus and `recall_at_m` lands in the registry — live search
        quality next to the live cost counters.  Silently inactive on
        backends with no exact ground truth (mesh topologies)."""
        every = obs.config.recall_probe_every
        for j in range(nm):
            self._probe_seen += 1
            if self._probe_seen % every:
                continue
            exact = self.backend.exact_topm(mq[j], int(mex[j]), m)
            if exact is None:
                return
            r = metrics_mod.recall_at_m(ids[j][None, :], exact[None, :])
            self._probe_sum += r
            self._probe_n += 1
            obs.registry.counter(
                "serve_recall_probes_total",
                "queries shadow-rescored against the exact corpus",
            ).inc()
            g = obs.registry.gauge(
                "serve_recall_probe",
                "recall@m of sampled served queries vs exact top-m",
            )
            g.set(r, window="last")
            g.set(self._probe_sum / self._probe_n, window="mean")

    def flush(self) -> None:
        """Drive the step machine until the ring AND the device dispatch
        queue are empty."""
        while self._size or self._inflight:
            self.step()

    def apply_update(self, **kw) -> None:
        """Install a backend update WITHOUT draining in-flight batches —
        the background-writer path for store/corpus/replica churn.

        Safe because a staged batch holds references to the store pytree
        it was dispatched with (immutable arrays): it completes as if
        serialized before this update, and its results enter the cache
        at its stage-time generation, which this update's bump makes
        stale on the next lookup.  Topology swaps rebind the dispatch
        and must drain first — use `update_backend`."""
        if kw.get("runtime") is not None:
            raise ValueError(
                "topology swaps must go through update_backend (drains "
                "in-flight batches before rebinding the dispatch)"
            )
        self.backend.update(**kw)

    def update_backend(self, **kw) -> None:
        """Live backend update through the frontend — REQUIRED for topology
        swaps while serving: in-flight batches (everything already in the
        ring) drain on the OLD topology first, then the new runtime/store
        install via `backend.update(**kw)`.  The generation bump that
        comes with every update is what makes each cached result from
        before the swap stale — the sketch-keyed cache serves nothing
        across a reshard (tests/test_serve.py)."""
        rt = kw.get("runtime")
        if rt is not None and rt.is_distributed and self.cfg.m > rt.cfg.m - 1:
            raise ValueError(
                f"serving m={self.cfg.m} exceeds the new runtime's headroom "
                f"(cfg.m={rt.cfg.m}; mesh dispatch keeps one result for "
                "host-side self-exclusion)"
            )
        self.flush()  # in-flight batches complete on the old topology
        self.backend.update(**kw)

    # -- synchronous convenience (tests / examples) ---------------------------

    def search(
        self, queries: np.ndarray, exclude: np.ndarray | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """Submit a whole query matrix, drive the loop, gather results in
        order — the drop-in replacement for `engine.search(...)[:2]`."""
        queries = np.asarray(queries, np.float32)
        nq = queries.shape[0]
        m = self.cfg.m
        out_i = np.full((nq, m), -1, np.int32)
        out_s = np.full((nq, m), -np.inf, np.float32)
        tickets = np.empty((nq,), np.int64)
        for i in range(nq):
            while self.free == 0:
                self.step()  # drain before the ring would push back
            ex = NO_EXCLUDE if exclude is None else int(exclude[i])
            t = self.submit(queries[i], ex)
            assert not isinstance(t, SubmitReject)  # free>=1 guaranteed
            tickets[i] = t
        self.flush()
        for i in range(nq):
            ids_i, sc_i = self._results.pop(int(tickets[i]))
            out_i[i], out_s[i] = ids_i, sc_i
        return out_i, out_s
