"""Online retrieval frontend: request ring, dynamic batching, admission
control (DESIGN.md Sec. 7).

Turns the batch-oriented query runtimes into an online service without
adding a serving-only query path:

  * requests land in a FIXED-CAPACITY ring (`submit`); arrivals beyond
    capacity are rejected and COUNTED (`ServeStats.rejected`) — the same
    counted-never-silent discipline as the router's `dropped_probes`;
  * `step` coalesces up to `max_batch` pending requests, pads the batch
    to a power of two (so the jit'd dispatch sees a BOUNDED set of
    compiled shapes — at most log2(max_batch)+1 — instead of one trace
    per arrival count), consults the sketch-keyed result cache
    (`repro.serve.qcache`), dispatches only the misses, and scatters
    results back per request;
  * dispatch goes through a pluggable backend: `EngineBackend` wraps the
    single-host `LshEngine`'s own chunk implementation (result ids are
    bit-identical to a direct `engine.search` — CI-checked), and
    `DistBackend` wraps a `make_search_step` mesh step.  Both take the
    store as a jit ARGUMENT, so live store updates (churn) never retrace
    the query path.
"""

from __future__ import annotations

import copy
import dataclasses
import time

import jax
import numpy as np

from repro.core import costmodel
from repro.core import plan as plan_mod
from repro.core.engine import LshEngine
from repro.serve.qcache import QueryCache
from repro.serve.telemetry import ServeStats

NO_EXCLUDE = -2  # matches LshEngine.search's "no self id" sentinel


def pow2_pad(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor) — the dispatch shape grid."""
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()


def dispatch_pad(n: int, multiple: int = 1) -> int:
    """Dispatch size for `n` live rows: the smallest multiple of
    `multiple` >= pow2_pad(n).  `multiple` is a sharded backend's device
    count — the global batch must divide evenly over the mesh, which a
    bare power of two does not guarantee on non-pow-2 meshes.  Still a
    bounded shape set: each pow-2 value maps to exactly one padded size."""
    m = max(int(multiple), 1)
    return -(-pow2_pad(n) // m) * m


# -----------------------------------------------------------------------------
# dispatch backends
# -----------------------------------------------------------------------------


class EngineBackend:
    """Dispatch adapter over the single-host `LshEngine` query path.

    Reuses `engine._search_chunk_impl` verbatim — the scoring/top-m/dedup
    semantics cannot drift from the reference — but re-jits it with the
    store and corpus as ARGUMENTS instead of closed-over constants, so a
    churn update (`update`) swaps state without recompiling.  `traces`
    counts actual retraces (trace-time side effect), which is what the
    pow-2 shape-budget test asserts on.
    """

    max_m = None  # no backend-imposed ceiling

    def __init__(self, engine: LshEngine):
        self._engine = engine
        self._store = engine.store
        self._corpus = engine.corpus
        self._generation = int(np.asarray(engine.store.generation))
        self._cost_gen: int | None = None
        self._cost: costmodel.QueryCost | None = None
        self.traces = 0
        self.sketch_traces = 0

        def _impl(store, corpus, q, ex, m):
            self.traces += 1  # runs at trace time only
            eng = copy.copy(engine)
            eng.store = store
            eng.corpus = corpus
            return eng._search_chunk_impl(q, ex, m)

        def _sketch(q):
            self.sketch_traces += 1
            return plan_mod.sketch(
                q, engine.hyperplanes, use_kernels=engine.config.use_kernels
            )

        self._dispatch_jit = jax.jit(_impl, static_argnums=(4,))
        self._sketch_jit = jax.jit(_sketch)

    @property
    def dim(self) -> int:
        return self._engine.hyperplanes.shape[-1]

    @property
    def min_batch(self) -> int:
        return 1

    @property
    def generation(self) -> int:
        return self._generation

    def update(self, store, corpus=None) -> None:
        """Install a new store (and optionally corpus) — a write epoch.
        The host-side generation snapshot is what cache lookups compare
        against, so it syncs here, once per update, off the query path.
        It bumps on EVERY update, even when the store object is unchanged:
        a corpus-only swap also changes scores, so cached results must
        die with it."""
        self._store = store
        if corpus is not None:
            self._corpus = corpus
        self._generation = max(
            int(np.asarray(store.generation)), self._generation + 1
        )

    def sketch_codes(self, q_pad: np.ndarray) -> np.ndarray:
        return np.asarray(self._sketch_jit(q_pad))

    def cost(self) -> costmodel.QueryCost:
        """Table-1 closed form at the current store occupancy (cached per
        generation — occupancy only changes when the store does)."""
        if self._cost_gen != self._generation:
            b = float(np.mean(np.asarray(self._store.occupancy())))
            c = self._engine.config
            self._cost = costmodel.table1(
                c.variant, self._engine.params.k, self._engine.params.L, b
            )
            self._cost_gen = self._generation
        return self._cost

    def dispatch(self, q_pad: np.ndarray, ex_pad: np.ndarray, m: int):
        ids, scores = self._dispatch_jit(
            self._store, self._corpus, q_pad, ex_pad, m
        )
        return np.asarray(ids), np.asarray(scores), 0


class DistBackend:
    """Dispatch adapter over the `make_search_step` mesh step.

    The wire path has no exclusion support (the id is not secret, paper
    Sec. 6), so the step is built with one result of headroom
    (`dcfg.m = serve_m + 1`) and the self id is filtered host-side —
    exactly the distributed churn driver's convention.  `dropped_probes`
    from the capacitated router flows through to the telemetry.
    """

    def __init__(self, dcfg, mesh, hyperplanes, store, cache=None,
                 batch_axes=("data", "model")):
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.core import distributed as dist

        self._dcfg = dcfg
        self._mesh = mesh
        self._hp = hyperplanes
        self._store = store
        self._cache = cache
        self._step = dist.make_search_step(dcfg, mesh, batch_axes)
        self._qspec = NamedSharding(mesh, P(batch_axes, None))
        self._n_dev = int(np.prod([mesh.shape[a] for a in batch_axes]))
        self._generation = int(np.asarray(store.generation))
        self._cost_gen: int | None = None
        self._cost: costmodel.QueryCost | None = None
        self.traces = 0
        self.sketch_traces = 0

        def _sketch(q):
            self.sketch_traces += 1
            return plan_mod.sketch(q, hyperplanes)

        self._sketch_jit = jax.jit(_sketch)

    @property
    def dim(self) -> int:
        return self._hp.shape[-1]

    @property
    def min_batch(self) -> int:
        # the global batch shards over every device, so dispatch sizes
        # must be multiples of the device count (dispatch_pad enforces it)
        return self._n_dev

    @property
    def max_m(self) -> int:
        return self._dcfg.m - 1  # headroom for host-side self-exclusion

    @property
    def generation(self) -> int:
        return self._generation

    def update(self, store, cache=None) -> None:
        """Install new store state and/or a refreshed neighbor cache.
        Bumps the serving generation unconditionally (like EngineBackend):
        an NB-cache refresh changes results without touching the store."""
        self._store = store
        if cache is not None:
            self._cache = cache
        self._generation = max(
            int(np.asarray(store.generation)), self._generation + 1
        )

    def sketch_codes(self, q_pad: np.ndarray) -> np.ndarray:
        return np.asarray(self._sketch_jit(q_pad))

    def cost(self) -> costmodel.QueryCost:
        if self._cost_gen != self._generation:
            b = float(np.mean(np.asarray(self._store.occupancy())))
            self._cost = costmodel.table1(
                self._dcfg.variant, self._dcfg.params.k, self._dcfg.params.L, b
            )
            self._cost_gen = self._generation
        return self._cost

    def dispatch(self, q_pad: np.ndarray, ex_pad: np.ndarray, m: int):
        import jax.numpy as jnp

        if m > self.max_m:
            raise ValueError(
                f"m={m} exceeds the step's headroom (built with "
                f"dcfg.m={self._dcfg.m}; serveable m <= {self.max_m})"
            )
        q = jax.device_put(jnp.asarray(q_pad, jnp.float32), self._qspec)
        args = (self._hp, self._store.ids, self._store.payload)
        if self._cache is not None:
            args += tuple(self._cache)
        ids, scores, dropped = self._step(*args, q)
        ids = np.asarray(ids)
        scores = np.asarray(scores)
        # host-side self-exclusion + slice to the serving m
        out_i = np.full((ids.shape[0], m), -1, np.int32)
        out_s = np.full((ids.shape[0], m), -np.inf, np.float32)
        for i in range(ids.shape[0]):
            keep = ids[i] != ex_pad[i]
            out_i[i] = ids[i][keep][:m]
            out_s[i] = scores[i][keep][:m]
        return out_i, out_s, int(dropped)


# -----------------------------------------------------------------------------
# the frontend
# -----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    m: int = 10                   # results per query
    max_batch: int = 64           # max requests coalesced per dispatch
    queue_capacity: int = 256     # request ring size (admission control)
    cache: bool = True            # sketch-keyed result cache on/off
    cache_capacity: int = 4096
    sketch_only_cache: bool = False  # approximate keying (see qcache)

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.queue_capacity < 1:
            raise ValueError(
                f"queue_capacity must be >= 1, got {self.queue_capacity}"
            )


class RetrievalFrontend:
    """Single-threaded event-loop frontend over a dispatch backend.

    submit() -> ticket (or None on admission reject); step() serves one
    coalesced batch; poll(ticket) -> (ids, scores) once served.  The
    convenience `search()` drives the loop synchronously for a whole
    query matrix and is the surface the bit-identity tests compare
    against `engine.search`.
    """

    def __init__(
        self,
        backend,
        config: FrontendConfig = FrontendConfig(),
        stats: ServeStats | None = None,
    ):
        if backend.max_m is not None and config.m > backend.max_m:
            raise ValueError(
                f"m={config.m} unsupported by backend (max {backend.max_m})"
            )
        self.backend = backend
        self.cfg = config
        self.stats = stats if stats is not None else ServeStats()
        self.cache = (
            QueryCache(config.cache_capacity, config.sketch_only_cache)
            if config.cache
            else None
        )
        cap, d = config.queue_capacity, backend.dim
        # fixed-capacity request ring (preallocated; no per-request alloc)
        self._ring_q = np.zeros((cap, d), np.float32)
        self._ring_ex = np.full((cap,), NO_EXCLUDE, np.int32)
        self._ring_ticket = np.zeros((cap,), np.int64)
        self._ring_t = np.zeros((cap,), np.float64)
        self._head = 0
        self._size = 0
        self._next_ticket = 0
        self._results: dict[int, tuple[np.ndarray, np.ndarray]] = {}

    # -- request lifecycle ----------------------------------------------------

    @property
    def pending(self) -> int:
        return self._size

    @property
    def free(self) -> int:
        return self.cfg.queue_capacity - self._size

    def submit(self, q: np.ndarray, exclude: int = NO_EXCLUDE) -> int | None:
        """Admit one query into the ring; None (counted) when over capacity."""
        if self._size >= self.cfg.queue_capacity:
            self.stats.record_submit(False)
            return None
        slot = (self._head + self._size) % self.cfg.queue_capacity
        self._ring_q[slot] = q
        self._ring_ex[slot] = exclude
        ticket = self._next_ticket
        self._next_ticket += 1
        self._ring_ticket[slot] = ticket
        self._ring_t[slot] = time.perf_counter()
        self._size += 1
        self.stats.record_submit(True)
        return ticket

    def poll(self, ticket: int):
        """(ids, scores) for a served ticket, else None. Pops the result."""
        return self._results.pop(ticket, None)

    def step(self) -> int:
        """Serve one coalesced batch from the ring; returns #completed."""
        n = min(self._size, self.cfg.max_batch)
        if n == 0:
            return 0
        cap = self.cfg.queue_capacity
        idx = (self._head + np.arange(n)) % cap
        q = self._ring_q[idx].copy()
        ex = self._ring_ex[idx].copy()
        tickets = self._ring_ticket[idx].copy()
        t_sub = self._ring_t[idx].copy()
        self._head = (self._head + n) % cap
        self._size -= n

        gen = self.backend.generation
        m = self.cfg.m
        miss_rows = list(range(n))
        keys: list[tuple | None] = [None] * n
        if self.cache is not None:
            # sketch once for the whole coalesced batch (pow-2 padded, so
            # the sketch jit shares the dispatch shape grid)
            pad = dispatch_pad(n, self.backend.min_batch)
            q_pad = np.zeros((pad, q.shape[1]), np.float32)
            q_pad[:n] = q
            codes = self.backend.sketch_codes(q_pad)[:n]
            miss_rows = []
            for i in range(n):
                keys[i] = self.cache.key(codes[i], int(ex[i]), q[i])
                e = self.cache.get(keys[i], gen)
                if e is None:
                    miss_rows.append(i)
                else:
                    self._results[int(tickets[i])] = (e.ids, e.scores)
                    lat = (time.perf_counter() - t_sub[i]) * 1e6
                    self.stats.record_done(lat, hit=True)

        if miss_rows:
            nm = len(miss_rows)
            pad = dispatch_pad(nm, self.backend.min_batch)
            mq = np.zeros((pad, q.shape[1]), np.float32)
            mex = np.full((pad,), NO_EXCLUDE, np.int32)
            mq[:nm] = q[miss_rows]
            mex[:nm] = ex[miss_rows]
            ids, scores, dropped = self.backend.dispatch(mq, mex, m)
            self.stats.record_batch(nm, pad - nm, dropped, self.backend.cost())
            t_done = time.perf_counter()
            for j, i in enumerate(miss_rows):
                ids_i, sc_i = ids[j], scores[j]
                self._results[int(tickets[i])] = (ids_i, sc_i)
                if self.cache is not None:
                    self.cache.put(keys[i], ids_i, sc_i, gen)
                self.stats.record_done((t_done - t_sub[i]) * 1e6, hit=False)
        return n

    def flush(self) -> None:
        while self._size:
            self.step()

    # -- synchronous convenience (tests / examples) ---------------------------

    def search(
        self, queries: np.ndarray, exclude: np.ndarray | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """Submit a whole query matrix, drive the loop, gather results in
        order — the drop-in replacement for `engine.search(...)[:2]`."""
        queries = np.asarray(queries, np.float32)
        nq = queries.shape[0]
        m = self.cfg.m
        out_i = np.full((nq, m), -1, np.int32)
        out_s = np.full((nq, m), -np.inf, np.float32)
        tickets = np.empty((nq,), np.int64)
        for i in range(nq):
            if self.free == 0:
                self.step()  # drain before the ring would reject
            ex = NO_EXCLUDE if exclude is None else int(exclude[i])
            t = self.submit(queries[i], ex)
            assert t is not None  # free>=1 guaranteed above
            tickets[i] = t
        self.flush()
        for i in range(nq):
            ids_i, sc_i = self._results.pop(int(tickets[i]))
            out_i[i], out_s[i] = ids_i, sc_i
        return out_i, out_s
