"""Serving steps: prefill + decode with greedy/temperature sampling."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, max_len: int):
    @partial(jax.jit, static_argnames=())
    def prefill(params, batch):
        logits, states, _ = M.prefill(params, cfg, batch, max_len)
        return logits, states

    return prefill


def make_decode_step(cfg: ModelConfig, greedy: bool = True):
    @partial(jax.jit, donate_argnums=(1,))
    def decode(params, states, token, pos, rng):
        logits, states = M.decode_step(params, cfg, token, states, pos)
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(rng, logits).astype(jnp.int32)
        return nxt, logits, states

    return decode


def generate(params, cfg: ModelConfig, batch, steps: int, max_len: int,
             greedy: bool = True, seed: int = 0):
    """Host loop: prefill then `steps` decode steps. Returns [B, steps]."""
    prefill = make_prefill_step(cfg, max_len)
    decode = make_decode_step(cfg, greedy)
    logits, states = prefill(params, batch)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if "tokens" in batch:
        pos0 = batch["tokens"].shape[1]
        if "prefix_embeds" in batch:
            pos0 += batch["prefix_embeds"].shape[1]
    else:
        pos0 = batch["prefix_embeds"].shape[1]
    out = [tok]
    rng = jax.random.PRNGKey(seed)
    for t in range(steps - 1):
        rng, sub = jax.random.split(rng)
        tok, _, states = decode(params, states, tok,
                                jnp.int32(pos0 + t), sub)
        out.append(tok)
    return jnp.stack(out, axis=1)
