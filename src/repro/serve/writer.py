"""Background churn writer: prepare write-epoch state off the serving
thread, install it at stage boundaries (DESIGN.md Sec. 13).

A write epoch has two halves with very different costs.  PREPARATION —
sketching re-announced vectors, building the inserted/expired store,
re-replicating — is heavy host+device work that has no business on the
serving thread.  INSTALLATION — swapping the backend's store/corpus
references and bumping the generation — is a few pointer writes, but it
mutates state the step machine reads, so it must happen on the serving
thread at a well-defined point.

`ChurnWriter` splits them exactly there: `submit(prep_fn)` hands the
heavy half to a daemon worker thread (`inline=True` runs it on the spot —
the deterministic mode the equivalence tests use); the worker queues the
prepared update kwargs; and the frontend drains that queue through
`install` at every STAGE BOUNDARY — immediately before a new batch is
dispatched, never while one is being assembled.  In-flight batches are
not drained first: they hold references to the store pytree they were
dispatched with, complete as if serialized before the update, and their
cached results die with the generation bump (`RetrievalFrontend.
apply_update`).  Prepared updates therefore interleave BETWEEN
dispatches at whatever rate serving allows, and the never-serve-stale
cache rules hold throughout.

Topology swaps (runtime=) are refused — those rebind the dispatch jit
and must drain through `update_backend` on the serving thread.

DONATION CONTRACT: `core.store.insert_batch` and `expire` donate their
input store's buffers to XLA.  A prep function must never feed the
INSTALLED store into them — serving dispatches overlapping the prep
would read deleted buffers.  Chain from a snapshot instead
(`jax.tree.map(jnp.copy, store)` — the copy is a few hundred
microseconds at the shapes here) or build the new store from scratch.
Preps should also keep each device computation small (chunk bulk
inserts): a single CPU/GPU device executes its queue FIFO, so one
monolithic multi-ms prep op would stall every serving dispatch enqueued
behind it just as badly as an inline stall.
"""

from __future__ import annotations

import queue
import threading
import time
from collections import deque


class ChurnWriter:
    """Background writer for one `RetrievalFrontend`.

    prep_fn: () -> dict of `RuntimeBackend.update` kwargs.  Jobs run
    FIFO on ONE worker thread, so a prep that chains on the previous
    epoch's store sees it completed.  `prepared`/`installed` count the
    two halves; `drain()` blocks until every submitted job is prepared
    AND installed (the end-of-run / deterministic-test barrier).
    """

    def __init__(self, frontend, *, inline: bool = False):
        self._frontend = frontend
        self._inline = inline
        self._ready: deque = deque()  # prepared kwargs, install order
        self._submitted = 0
        self.prepared = 0
        self.installed = 0
        self._error: BaseException | None = None
        if inline:
            self._jobs = None
            self._thread = None
        else:
            self._jobs: queue.Queue = queue.Queue()
            self._thread = threading.Thread(
                target=self._worker, name="serve-churn-writer", daemon=True
            )
            self._thread.start()
        frontend.writer = self

    def _worker(self) -> None:
        while True:
            fn = self._jobs.get()
            if fn is None:
                return
            try:
                self._ready.append(fn())
                self.prepared += 1
            except BaseException as e:  # surfaced on the serving thread
                self._error = e
                return

    def submit(self, prep_fn) -> None:
        """Queue one write epoch for preparation (non-blocking unless
        `inline`)."""
        if self._error is not None:
            raise RuntimeError("churn writer died") from self._error
        self._submitted += 1
        if self._inline:
            self._ready.append(prep_fn())
            self.prepared += 1
        else:
            self._jobs.put(prep_fn)

    def install(self, frontend=None) -> int:
        """Install every prepared update — called by the frontend at
        stage boundaries, on the serving thread.  Returns #installed."""
        if self._error is not None:
            raise RuntimeError("churn writer died") from self._error
        fe = self._frontend if frontend is None else frontend
        n = 0
        while True:
            try:
                kw = self._ready.popleft()
            except IndexError:
                break
            fe.apply_update(**kw)
            self.installed += 1
            n += 1
        return n

    def drain(self, timeout_s: float = 30.0) -> None:
        """Block until every submitted epoch is prepared, then install
        the lot.  The end-of-run barrier (and the whole story in
        `inline` mode, where nothing was ever pending)."""
        deadline = time.perf_counter() + timeout_s
        while self.prepared < self._submitted:
            if self._error is not None:
                raise RuntimeError("churn writer died") from self._error
            if time.perf_counter() > deadline:
                raise TimeoutError(
                    f"churn writer: {self._submitted - self.prepared} "
                    f"epoch(s) still preparing after {timeout_s}s"
                )
            time.sleep(0.0005)
        self.install()

    def close(self) -> None:
        """Stop the worker (prepared-but-uninstalled updates are
        dropped); detaches from the frontend."""
        if self._thread is not None:
            self._jobs.put(None)
            self._thread.join(timeout=10.0)
            self._thread = None
        if self._frontend.writer is self:
            self._frontend.writer = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
