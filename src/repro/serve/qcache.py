"""Sketch-keyed query-result cache with generation invalidation (DESIGN.md
Sec. 7).

The CNB-LSH insight — shift cost off the query path into state refreshed
out-of-band — extends one level above the bucket cache: two queries whose
L-table sketch-code tuples are equal probe *identical bucket sets*
(`core.plan` derives the probe plan from the codes alone), so their
results can be shared.  The cache key is therefore the sketch tuple plus
the exclusion id; by default a digest of the raw query bytes is appended
so a cached entry is only ever served for a *bit-identical* query (exact
mode — result ids provably match a direct `engine.search`).  With
`sketch_only=True` the digest is dropped and any same-sketch query shares
the entry — the paper-spirit approximate mode, trading exactness for hit
rate (the served ids are still a valid CNB probe-set result for the
sketch, just scored against the first query that populated the entry).

Invalidation is generation-based, wired to churn: every store mutation
(`insert_masked` / `expire` / payload sync) bumps `BucketStore.generation`;
entries carry the generation they were computed at and are evicted on
lookup when it no longer matches — a stale-generation entry is NEVER
served (tested under live churn in tests/test_serve.py).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np


def query_digest(q: np.ndarray) -> bytes:
    """Raw query bytes (exact-mode key component).

    The bytes themselves, not a hash: a digest collision would silently
    serve another query's results, and the memory cost of keeping the
    bytes is comparable to the stored entry — so exactness is actual,
    not probabilistic."""
    return np.ascontiguousarray(q).tobytes()


@dataclasses.dataclass
class CacheEntry:
    ids: np.ndarray      # int32 [m]
    scores: np.ndarray   # f32   [m]
    generation: int      # backend generation the result was computed at


class QueryCache:
    """Bounded LRU of search results keyed on (sketch codes, exclude[, digest])."""

    def __init__(self, capacity: int = 4096, sketch_only: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.sketch_only = sketch_only
        self._entries: OrderedDict[tuple, CacheEntry] = OrderedDict()
        # counters (the frontend's telemetry aggregates across components;
        # these are the cache's own ground truth)
        self.hits = 0
        self.misses = 0
        self.stale_evictions = 0
        self.lru_evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def key(self, codes, exclude: int, q: np.ndarray | None = None,
            m: int | None = None) -> tuple:
        """Build the lookup key for one query.

        codes: the L-table sketch-code tuple/array of the query;
        exclude: the self-exclusion id (-2 when unused) — part of the key
        because it changes the result set; q: raw query vector, digested
        in exact mode and ignored in sketch_only mode; m: the requested
        top-m — also part of the key (an entry computed at a smaller m is
        a TRUNCATED result and must never serve a larger-m request).
        """
        code_t = tuple(int(c) for c in np.asarray(codes).reshape(-1))
        m_t = -1 if m is None else int(m)
        if self.sketch_only or q is None:
            return (code_t, int(exclude), m_t)
        return (code_t, int(exclude), m_t, query_digest(q))

    def get(self, key: tuple, generation: int) -> CacheEntry | None:
        """Entry for `key` iff it was computed at `generation`; a stale
        entry is evicted (and counted) instead of served."""
        e = self._entries.get(key)
        if e is None:
            self.misses += 1
            return None
        if e.generation != generation:
            del self._entries[key]
            self.stale_evictions += 1
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return e

    def put(
        self, key: tuple, ids: np.ndarray, scores: np.ndarray, generation: int
    ) -> None:
        if key in self._entries:
            self._entries.move_to_end(key)
        self._entries[key] = CacheEntry(
            np.asarray(ids), np.asarray(scores), int(generation)
        )
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.lru_evictions += 1

    def clear(self) -> None:
        self._entries.clear()
