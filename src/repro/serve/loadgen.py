"""Open-loop load generation: offered load decoupled from completion
(DESIGN.md Sec. 13).

A closed-loop driver submits the next request only after an earlier one
finishes, so its "qps" is just the service rate and its latency hides
queueing behind the submit gate — the coordinated-omission trap: the
slower the server, the less load the measurement applies.  The open-loop
generator instead draws a Poisson arrival schedule at a FIXED offered
rate before the run, stamps every query with its SCHEDULED arrival time,
and measures latency from that stamp.  If the serving loop was blocked
when an arrival came due, the late submission counts against the server,
exactly as a real client would experience it.

`run_open_loop` drives one `RetrievalFrontend` (any `pipeline_depth`)
through a schedule; `max_qps_at_slo` sweeps a rate ladder and reports
the highest offered rate whose p99 (measured from schedule) meets the
SLO with nothing shed — the "max qps at SLO" headline plus the full
qps-vs-p99 knee curve.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.serve.frontend import NO_EXCLUDE, SubmitReject


def poisson_arrivals(rate_qps: float, n: int, seed: int = 0,
                     deterministic: bool = False) -> np.ndarray:
    """Scheduled arrival times (seconds from t0) for `n` queries at
    `rate_qps` offered.  Poisson process (exponential gaps) by default;
    `deterministic=True` spaces them uniformly — the low-variance
    schedule the smoke tests use."""
    if rate_qps <= 0:
        raise ValueError(f"rate_qps must be > 0, got {rate_qps}")
    if deterministic:
        return (np.arange(n) + 1.0) / rate_qps
    gaps = np.random.default_rng(seed).exponential(1.0 / rate_qps, size=n)
    return np.cumsum(gaps)


@dataclasses.dataclass
class OpenLoopResult:
    """One open-loop run: latency population measured from the arrival
    SCHEDULE, plus the shed count (ring-full pushback and admission
    rejects both count — an unserved arrival is an SLO event, whatever
    the frontend called it)."""

    offered_qps: float
    completed: int
    shed: int
    duration_s: float
    latencies_ms: np.ndarray          # per completed arrival, schedule->done
    ids: dict                          # arrival index -> served ids
    summary: dict                      # the frontend's ServeStats summary

    @property
    def served_qps(self) -> float:
        return self.completed / self.duration_s if self.duration_s > 0 else 0.0

    def percentile_ms(self, p: float) -> float:
        if self.latencies_ms.size == 0:
            return float("inf")
        return float(np.percentile(self.latencies_ms, p))

    @property
    def p50_ms(self) -> float:
        return self.percentile_ms(50)

    @property
    def p99_ms(self) -> float:
        return self.percentile_ms(99)

    def slo_ok(self, p99_slo_ms: float) -> bool:
        """SLO = p99 under the bound AND nothing shed."""
        return self.shed == 0 and self.p99_ms <= p99_slo_ms


def run_open_loop(frontend, queries: np.ndarray,
                  arrivals: np.ndarray,
                  exclude: np.ndarray | None = None,
                  on_tick=None) -> OpenLoopResult:
    """Serve `queries[i]` at scheduled time `arrivals[i]` through
    `frontend`; returns the latency population measured from schedule.

    The loop alternates three duties: submit every due arrival, advance
    the step machine (`frontend.pump` — blocking per batch at
    `pipeline_depth=1`, non-blocking staging above it), and drain
    completed tickets.  Between duties it SLEEPS to the next arrival
    rather than spinning — a spin would steal the core from the device
    compute it is supposedly waiting for.

    `on_tick(now_s)`, called once per loop iteration with elapsed time,
    is the maintenance hook: a churn driver uses it to fire write epochs
    mid-run — either INLINE (prep + apply on this thread: the epoch's
    full cost lands as a serving stall, the synchronous architecture) or
    via a background `ChurnWriter` (hand the prep off-thread; the
    prepared update installs at the next stage boundary)."""
    n = len(arrivals)
    if len(queries) != n:
        raise ValueError(f"{len(queries)} queries for {n} arrivals")
    lat_ms = np.full(n, np.nan)
    ids: dict = {}
    ticket_arrival: dict = {}
    shed = 0
    i = 0
    t0 = time.perf_counter()

    def drain():
        done = frontend.take_results()
        if done:
            now = time.perf_counter() - t0
            for tk, (r_ids, _scores) in done.items():
                a = ticket_arrival.pop(tk, None)
                if a is not None:
                    lat_ms[a] = (now - arrivals[a]) * 1e3
                    ids[a] = r_ids

    while i < n or frontend.pending or frontend.inflight or ticket_arrival:
        now = time.perf_counter() - t0
        if on_tick is not None:
            on_tick(now)
        while i < n and arrivals[i] <= now:
            ex = NO_EXCLUDE if exclude is None else int(exclude[i])
            t = frontend.submit(queries[i], ex)
            if isinstance(t, SubmitReject):
                shed += 1
            else:
                ticket_arrival[t] = i
            i += 1
        frontend.pump()
        drain()
        if i < n:
            gap = arrivals[i] - (time.perf_counter() - t0)
            if gap > 0.0002 and not (
                frontend.pending >= frontend.cfg.max_batch
            ):
                time.sleep(min(gap - 0.0001, 0.002))
        elif not (frontend.pending or frontend.inflight):
            break
    frontend.flush()
    drain()
    duration = time.perf_counter() - t0
    done_mask = ~np.isnan(lat_ms)
    return OpenLoopResult(
        offered_qps=float(n / arrivals[-1]) if n else 0.0,
        completed=int(done_mask.sum()),
        shed=shed,
        duration_s=duration,
        latencies_ms=lat_ms[done_mask],
        ids=ids,
        summary=frontend.stats.summary(),
    )


def max_qps_at_slo(make_frontend, queries: np.ndarray,
                   rates: np.ndarray, *, p99_slo_ms: float,
                   n_arrivals: int, seed: int = 0, trials: int = 2,
                   exclude: np.ndarray | None = None, make_tick=None):
    """Sweep a rate ladder; returns (max_passing_qps, knee).

    `make_frontend()` builds a FRESH frontend per trial (steady-state
    stats, cold result cache) over the shared warm runtime;
    `make_tick(frontend)`, when given, builds that trial's maintenance
    hook (see `run_open_loop`).  Each rate runs `trials` independent
    schedules and keeps the MEDIAN p99 — one descheduled trial on a
    noisy host cannot flip a rung by itself — and the worst (max) shed
    count, so shedding can never be averaged away.  `knee` is the
    [(rate, p99_ms, shed), ...] curve; the headline is the highest rung
    that met the SLO."""
    knee = []
    best = 0.0
    nq = len(queries)
    for r_i, rate in enumerate(rates):
        p99s, sheds = [], 0
        for t_i in range(trials):
            arr = poisson_arrivals(float(rate), n_arrivals,
                                   seed=seed + 1000 * r_i + t_i)
            pick = np.random.default_rng(seed + t_i).integers(
                0, nq, size=n_arrivals)
            fe = make_frontend()
            res = run_open_loop(fe, queries[pick], arr,
                                exclude=None if exclude is None
                                else exclude[pick],
                                on_tick=None if make_tick is None
                                else make_tick(fe))
            if fe.writer is not None:  # tick attached a ChurnWriter:
                fe.writer.close()      # the sweep owns the teardown
            p99s.append(res.p99_ms)
            sheds = max(sheds, res.shed)
        p99 = float(np.median(p99s))
        knee.append((float(rate), p99, int(sheds)))
        if sheds == 0 and p99 <= p99_slo_ms:
            best = max(best, float(rate))
    return best, knee
