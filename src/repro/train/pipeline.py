"""GPipe-style pipeline parallelism over a `stage` mesh axis.

The scanned period stack is split across stages (periods sharded over the
`stage` axis); microbatches flow through stages via collective_permute, one
hop per tick — T = M + S - 1 ticks for M microbatches over S stages.  The
backward schedule emerges from differentiating the tick scan (ppermute's
transpose is the reverse permute), i.e. classic GPipe fill/drain.

Intended deployment: `pod` as the stage axis (DESIGN.md §6) — cross-pod
links carry only the [mb, S, d] activation handoff per tick instead of
whole-model gradient reductions; combine with train/compression.py for the
remaining cross-pod traffic.

This module is deliberately self-contained (pure function over the block
stack); embedding/loss stay outside the pipelined region.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models import model as M
from repro.models.config import ModelConfig


def _apply_local_periods(cfg: ModelConfig, local_blocks, x, positions):
    """Apply this stage's share of the period stack (scan over periods)."""

    def body(xc, pp):
        y, _, _ = M._period_forward(cfg, pp, xc, positions, mode="train")
        return y, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, local_blocks)
    return x


def pipeline_forward(
    cfg: ModelConfig,
    mesh,
    blocks,            # stacked period params [num_periods, ...]
    x: jax.Array,      # [B, S, d] embedded inputs (B % microbatches == 0)
    positions,         # [B, S] int32
    num_microbatches: int,
    stage_axis: str = "stage",
):
    """Returns hidden [B, S, d] after the full stack, pipelined over stages."""
    n_stages = mesh.shape[stage_axis]
    if cfg.num_periods % n_stages:
        raise ValueError("num_periods must divide over stages")
    mb = x.shape[0] // num_microbatches
    M_ = num_microbatches
    T = M_ + n_stages - 1

    def stage_fn(local_blocks, x_all, pos_all):
        sid = jax.lax.axis_index(stage_axis)
        xmb = x_all.reshape(M_, mb, *x_all.shape[1:])
        pmb = pos_all.reshape(M_, mb, *pos_all.shape[1:])
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            prev_out = carry                      # my output of last tick
            recv = jax.lax.ppermute(prev_out, stage_axis, fwd_perm)
            inject = xmb[jnp.clip(t, 0, M_ - 1)]
            my_in = jnp.where(sid == 0, inject, recv)
            pos_t = pmb[jnp.clip(t - sid, 0, M_ - 1)]
            my_out = _apply_local_periods(cfg, local_blocks, my_in, pos_t)
            return my_out, my_out

        zeros = jnp.zeros((mb,) + x_all.shape[1:], x_all.dtype)
        _, outs = jax.lax.scan(tick, zeros, jnp.arange(T))
        # stage s produced microbatch (t - s) at tick t; only the LAST
        # stage's outputs for t in [n_stages-1, T) are the model outputs.
        valid = outs[n_stages - 1:]               # [M_, mb, S, d]
        out = valid.reshape(x_all.shape)
        # every stage computed `outs`; only the last stage's is meaningful —
        # masked psum replicates it (ppermute cannot fan out 1 -> many).
        last = n_stages - 1
        out = jax.lax.psum(
            jnp.where(sid == last, out, jnp.zeros_like(out)), stage_axis
        )
        return out

    fn = compat.shard_map(
        stage_fn,
        mesh=mesh,
        in_specs=(P(stage_axis), P(), P()),
        out_specs=P(),
    )
    return fn(blocks, x, positions)
