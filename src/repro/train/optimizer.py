"""AdamW with optional int8 block-quantized moments.

The int8 states (blockwise absmax quantization, bitsandbytes-style) are a
distributed-optimization feature: they cut optimizer memory from 8 bytes to
~2.03 bytes per parameter, which is what lets the 400B llama4 config train
inside 16 GB/chip on a single 256-chip pod (DESIGN.md Sec. 6).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "fp32"   # fp32 | int8
    quant_block: int = 256


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, decay)


# -- blockwise int8 quantization ---------------------------------------------


def _blocked(x: jax.Array, block: int) -> jax.Array:
    """[..., last] -> [..., nb, block] (zero-padded): blocking along the
    LAST axis keeps the leading axes identical to the parameter's, so the
    quantized state shards exactly like its parameter (no resharding
    collectives in the update step)."""
    lead, last = x.shape[:-1], x.shape[-1]
    nb = -(-last // block)
    pad = nb * block - last
    xp = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    return xp.reshape(*lead, nb, block)


def _unblocked(xb: jax.Array, shape) -> jax.Array:
    out = xb.reshape(*shape[:-1], -1)
    return out[..., : shape[-1]]


def quantize_blockwise(x: jax.Array, block: int):
    xb = _blocked(x.astype(jnp.float32), block)
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    q = jnp.round(xb / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_blockwise(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    return _unblocked(q.astype(jnp.float32) * scale, shape)


# Log-codebook quantization for the (non-negative) second moment: linear
# absmax int8 collapses small v entries in a block to 0, and Adam divides by
# sqrt(v) — the resulting explosion is why 8-bit Adam uses *dynamic* (log)
# quantization.  Codebook: code 0 -> 0; codes 1..255 -> scale * 10^(-DECADES
# * (1 - (k-1)/254)), i.e. log-spaced over DECADES decades (<=5.6% rel err).
_V_DECADES = 12.0


def quantize_v_log(x: jax.Array, block: int):
    blocks = _blocked(x.astype(jnp.float32), block)
    scale = jnp.max(blocks, axis=-1, keepdims=True)
    safe = jnp.maximum(scale, 1e-38)
    r = jnp.clip(blocks / safe, 0.0, 1.0)
    logr = jnp.log10(jnp.maximum(r, 10.0 ** (-_V_DECADES - 1)))
    k = jnp.round((logr / _V_DECADES + 1.0) * 254.0) + 1.0
    k = jnp.where(r < 10.0 ** (-_V_DECADES), 0.0, jnp.clip(k, 1.0, 255.0))
    # store as uint8 range in int8 container (k - 128)
    return (k - 128.0).astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_v_log(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    k = q.astype(jnp.float32) + 128.0
    r = jnp.where(
        k <= 0.5, 0.0, 10.0 ** (_V_DECADES * ((k - 1.0) / 254.0 - 1.0))
    )
    return _unblocked(r * scale, shape)


# -- state -------------------------------------------------------------------


def init_opt_state(params, cfg: OptConfig):
    def leaf_state(p):
        if cfg.state_dtype == "int8":
            zq, zs = quantize_blockwise(jnp.zeros_like(p, jnp.float32),
                                        cfg.quant_block)
            vq, vs = quantize_v_log(jnp.zeros_like(p, jnp.float32),
                                    cfg.quant_block)
            return {"m_q": zq, "m_s": zs, "v_q": vq, "v_s": vs}
        return {
            "m": jnp.zeros_like(p, jnp.float32),
            "v": jnp.zeros_like(p, jnp.float32),
        }

    return {
        "count": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(leaf_state, params),
    }


def opt_state_specs(param_specs, cfg: OptConfig):
    """Logical-axis specs for the optimizer state (mirrors param specs)."""

    def leaf(spec):
        if cfg.state_dtype == "int8":
            # [..., nb, block]: leading axes shard like the parameter; the
            # parameter's last-axis rule lands on the *block* axis (block =
            # 256 divides any mesh axis; nb often doesn't — 5120/256 = 20
            # blocks can't split 16 ways and would silently replicate GiBs).
            qspec = tuple(spec[:-1]) + (None, spec[-1])
            # scales [..., nb, 1]: try the nb axis, drop if indivisible
            sspec = tuple(spec[:-1]) + (spec[-1], None)
            return {"m_q": qspec, "m_s": sspec, "v_q": qspec, "v_s": sspec}
        return {"m": tuple(spec), "v": tuple(spec)}

    return {
        "count": (),
        "mu": jax.tree.map(leaf, param_specs,
                           is_leaf=lambda x: isinstance(x, tuple)),
    }


# -- update ------------------------------------------------------------------


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def apply_updates(params, grads, state, cfg: OptConfig):
    """One AdamW step; returns (params, state, metrics)."""
    count = state["count"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    lr = lr_at(cfg, count)
    bc1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu):
        g = g.astype(jnp.float32) * scale
        if cfg.state_dtype == "int8":
            m = dequantize_blockwise(mu["m_q"], mu["m_s"], p.shape)
            v = dequantize_v_log(mu["v_q"], mu["v_s"], p.shape)
        else:
            m, v = mu["m"], mu["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (
            step_ + cfg.weight_decay * p.astype(jnp.float32)
        )
        if cfg.state_dtype == "int8":
            mq, ms = quantize_blockwise(m, cfg.quant_block)
            vq, vs = quantize_v_log(v, cfg.quant_block)
            new_mu = {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
        else:
            new_mu = {"m": m, "v": v}
        return new_p.astype(p.dtype), new_mu

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = tree.flatten_up_to(state["mu"])
    new_p, new_mu = [], []
    for p, g, mu in zip(flat_p, flat_g, flat_mu):
        np_, nmu = upd(p, g, mu)
        new_p.append(np_)
        new_mu.append(nmu)
    params = jax.tree.unflatten(tree, new_p)
    mu = jax.tree.unflatten(tree, new_mu)
    metrics = {"grad_norm": gn, "lr": lr}
    return params, {"count": count, "mu": mu}, metrics
