"""Training step: chunked-vocab cross-entropy, grads, AdamW update.

The loss never materializes the full [B, S, V] logits tensor: a scan over
sequence chunks computes per-chunk logits + logsumexp and discards them
(with remat this bounds the loss memory to [B, chunk, V] per device) —
required for the 200k+ vocab configs at seq 4096.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.unroll import maybe_checkpoint
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    loss_chunk: int = 512
    lb_loss_weight: float = 0.01
    z_loss_weight: float = 1e-3
    remat: bool = True   # False: save all activations (no recompute pass —
                         # one fewer FSDP weight re-gather; needs memory)


def chunked_xent(params, cfg: ModelConfig, hidden, labels, chunk: int):
    """Cross-entropy via scan over sequence chunks.

    hidden: [B, S, d]; labels: [B, S] int32, -1 = masked.
    Returns (sum_loss, num_valid).
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    if s % chunk:  # pad to a multiple (masked labels)
        pad = chunk - s % chunk
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        s = hidden.shape[1]
    nc = s // chunk
    hs = jnp.moveaxis(hidden.reshape(b, nc, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    def body(carry, xs):
        h, lab = xs
        logits = M.logits_from_hidden(params, cfg, h)  # [B, chunk, V] f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.maximum(lab, 0)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        valid = lab >= 0
        nll = jnp.where(valid, lse - gold, 0.0)
        sum_loss, n_valid = carry
        return (sum_loss + jnp.sum(nll), n_valid + jnp.sum(valid)), None

    # ALWAYS a lax.scan (even in the dry-run's unroll mode): the scan's AD
    # accumulates the embedding/lm_head cotangents in the carry and
    # all-reduces ONCE after the loop; unrolling would eagerly reduce per
    # chunk and overstate production wire bytes ~8x.  The under-counted
    # loss-matmul FLOPs are corrected analytically in benchmarks/roofline.
    (sum_loss, n_valid), _ = jax.lax.scan(
        maybe_checkpoint(body), (jnp.float32(0.0), jnp.int32(0)), (hs, ls)
    )
    return sum_loss, n_valid


def make_loss_fn(cfg: ModelConfig, hp: TrainHParams):
    def loss_fn(params, batch):
        hidden, aux, _ = M.forward(params, cfg, batch, collect="train")
        sum_loss, n_valid = chunked_xent(
            params, cfg, hidden, batch["labels"], hp.loss_chunk
        )
        xent = sum_loss / jnp.maximum(n_valid.astype(jnp.float32), 1.0)
        total = xent + hp.lb_loss_weight * aux[0] + hp.z_loss_weight * aux[1]
        metrics = {
            "loss": total,
            "xent": xent,
            "lb_loss": aux[0],
            "z_loss": aux[1],
            "tokens": n_valid,
        }
        return total, metrics

    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: opt.OptConfig,
                    hp: TrainHParams = TrainHParams()):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    jit with donated params/opt_state; shardings come from the surrounding
    use_mesh context via constraints + param placement.
    """
    loss_fn = make_loss_fn(cfg, hp)

    @partial(jax.jit, donate_argnums=(0, 1))
    def step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state, om = opt.apply_updates(
            params, grads, opt_state, opt_cfg
        )
        metrics.update(om)
        return params, opt_state, metrics

    return step


def make_grad_accum_train_step(cfg: ModelConfig, opt_cfg: opt.OptConfig,
                               hp: TrainHParams, num_microbatches: int):
    """Gradient-accumulation variant: batch [A, B/A, S] scanned."""
    loss_fn = make_loss_fn(cfg, hp)

    @partial(jax.jit, donate_argnums=(0, 1))
    def step(params, opt_state, batch):
        def micro(carry, mb):
            gsum, msum = carry
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            gsum = jax.tree.map(jnp.add, gsum, grads)
            msum = msum + metrics["loss"]
            return (gsum, msum), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (gsum, msum), _ = jax.lax.scan(
            micro, (zeros, jnp.float32(0.0)), batch
        )
        grads = jax.tree.map(lambda g: g / num_microbatches, gsum)
        params, opt_state, om = opt.apply_updates(
            params, grads, opt_state, opt_cfg
        )
        om["loss"] = msum / num_microbatches
        return params, opt_state, om

    return step
