"""Gradient compression for cross-pod data parallelism.

int8 blockwise quantization + error feedback (1-bit-Adam style residual
accumulation): the psum over the `pod` axis moves ~4x fewer bytes (int8 +
per-block f32 scales vs f32), while error feedback keeps the *accumulated*
update unbiased, so convergence matches uncompressed DP up to float noise
(tested in tests/test_train.py).

Used by the pipeline/hierarchical trainers where the cross-pod reduction is
an explicit collective; within-pod reductions stay uncompressed (ICI is
cheap; DCN between pods is not).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.train.optimizer import dequantize_blockwise, quantize_blockwise


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, error, axis: str, block: int = 256):
    """Quantize (grads + error) to int8, psum, dequantize; returns
    (reduced_grads, new_error).  Must run inside shard_map with `axis`."""

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_blockwise(g32, block)   # int8 payload + f32/block scale
        deq = dequantize_blockwise(q, s, g.shape)
        new_e = g32 - deq                       # error feedback residual
        # int8 bytes on the wire: all_gather the quantized payloads (+tiny
        # scales) and reduce locally — per-shard scales make a direct int8
        # psum ill-defined, and this keeps the payload 4x smaller than an
        # f32 psum.
        qg = jax.lax.all_gather(q, axis)        # [P, ..., nb, block] int8
        sg = jax.lax.all_gather(s, axis)        # [P, ..., nb, 1] f32
        red_blocks = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
        # strip per-row block padding (NOT a flat slice)
        red = red_blocks.reshape(*g.shape[:-1], -1)[..., : g.shape[-1]]
        return red, new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    red = jax.tree.unflatten(tree, [o[0] for o in outs])
    err = jax.tree.unflatten(tree, [o[1] for o in outs])
    return red, err
