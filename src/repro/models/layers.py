"""Transformer building blocks: norms, RoPE, GQA attention, MLPs.

Pure functions over parameter dicts.  Every init function returns
(params, specs) where `specs` mirrors the params pytree with tuples of
*logical* axis names (resolved to mesh axes by `repro.models.sharding`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import sharding as sh
from repro.models.config import ModelConfig


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return jax.random.normal(key, shape, dtype) * scale


def key_for(root: jax.Array, name: str) -> jax.Array:
    import zlib

    return jax.random.fold_in(root, zlib.crc32(name.encode()) % (2**31))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(
        dtype
    )


def init_rmsnorm(d: int):
    return jnp.zeros((d,), jnp.float32), ("d_model",)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # [head_dim/2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, dh]; positions: [B, S] (or [S]) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, dh/2]
    sin = jnp.sin(angles)[..., None, :]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key, cross: bool = False):
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    params = {
        "wq": _init(ks[0], (d, hq, dh)),
        "wk": _init(ks[1], (d, hkv, dh)),
        "wv": _init(ks[2], (d, hkv, dh)),
        "wo": _init(ks[3], (hq, dh, d), scale=1.0 / np.sqrt(hq * dh)),
    }
    specs = {
        "wq": ("fsdp", "heads", "head_dim"),
        "wk": ("fsdp", "kv_heads", "head_dim"),
        "wv": ("fsdp", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "fsdp"),
    }
    if cfg.qkv_bias and not cross:
        params.update(
            bq=jnp.zeros((hq, dh)), bk=jnp.zeros((hkv, dh)), bv=jnp.zeros((hkv, dh))
        )
        specs.update(
            bq=("heads", "head_dim"),
            bk=("kv_heads", "head_dim"),
            bv=("kv_heads", "head_dim"),
        )
    return params, specs


def _qkv(p, x, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return scores
    return cap * jnp.tanh(scores / cap)


def _sdpa(q, k, v, mask, cfg: ModelConfig, seq_axis: str | None = "seq"):
    """Grouped-query attention core.

    q: [B, Sq, Hq, dh]; k/v: [B, Sk, Hkv, dh]; mask: broadcastable to
    [B, 1, 1, Sq, Sk] (True = attend).
    """
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    scores = jnp.einsum("bqhgk,bshk->bhgqs", qg, k) / np.sqrt(dh)
    scores = _softcap(scores.astype(jnp.float32), cfg.attn_logit_softcap)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs, v)
    return out.reshape(b, sq, hq, dh)


def causal_mask(sq: int, sk: int, window: int = 0) -> jax.Array:
    """[1, 1, 1, sq, sk] mask; window > 0 adds a sliding-window band."""
    qi = jnp.arange(sq)[:, None] + (sk - sq)  # align ends (prefill)
    ki = jnp.arange(sk)[None, :]
    m = ki <= qi
    if window > 0:
        m &= ki > qi - window
    return m[None, None, None]


# Above this many query rows, attention runs q-chunked (exact, flash-style
# row blocking): the [Sq, Sk] score matrix never materializes — each scan
# step holds one [chunk, Sk] row block in f32.  Bounds 32k-prefill memory.
Q_CHUNK_THRESHOLD = 2048
Q_CHUNK = 1024


def _sdpa_qchunked(q, k, v, cfg: ModelConfig, causal: bool, window: int):
    """Exact attention with the query dim scanned in chunks.

    q: [B, Sq, Hq, dh]; k/v: [B, Sk, Hkv, dh].  Assumes Sq == Sk alignment
    at the sequence end (prefill/training layout).
    """
    b, sq, hq, dh = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    c = Q_CHUNK
    assert sq % c == 0, (sq, c)
    nc = sq // c
    qg = q.reshape(b, nc, c, hkv, g, dh)
    ki = jnp.arange(sk)

    def step(_, inp):
        qc, idx = inp                      # [b, c, hkv, g, dh], scalar chunk id
        q0 = idx * c + (sk - sq)
        qi = q0 + jnp.arange(c)
        scores = jnp.einsum("bqhgk,bshk->bhgqs", qc, k) / np.sqrt(dh)
        scores = _softcap(scores.astype(jnp.float32), cfg.attn_logit_softcap)
        if causal:
            m = ki[None, :] <= qi[:, None]
            if window > 0:
                m &= ki[None, :] > qi[:, None] - window
            scores = jnp.where(m[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhgqs,bshk->bqhgk", probs, v)
        return None, out.reshape(b, c, hq, dh)

    _, outs = jax.lax.scan(
        step, None, (jnp.moveaxis(qg, 1, 0), jnp.arange(nc))
    )
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, hq, dh)


def attention(
    p,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    local: bool = False,
    causal: bool = True,
    kv_override: tuple[jax.Array, jax.Array] | None = None,
) -> jax.Array:
    """Full-sequence attention (training / prefill).

    kv_override supplies cross-attention keys/values (encoder states),
    already projected.
    """
    q, k, v = _qkv(p, x, cfg)
    if kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
    q = sh.constrain(q, "batch", "seq", "heads", None)
    k = sh.constrain(k, "batch", "seq", "kv_heads", None)
    v = sh.constrain(v, "batch", "seq", "kv_heads", None)
    sq, sk = q.shape[1], k.shape[1]
    window = cfg.window_size if local else 0
    if sq > Q_CHUNK_THRESHOLD and sq % Q_CHUNK == 0 and sq == sk:
        out = _sdpa_qchunked(q, k, v, cfg, causal, window)
    else:
        if causal:
            mask = causal_mask(sq, sk, window)
        else:
            mask = jnp.ones((1, 1, 1, sq, sk), bool)
        out = _sdpa(q, k, v, mask, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return sh.constrain(out, "batch", "seq", None)


def project_cross_kv(p, enc: jax.Array, cfg: ModelConfig):
    """Project encoder states once for all decoder cross-attention calls."""
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"].astype(enc.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"].astype(enc.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(enc.dtype)
        v = v + p["bv"].astype(enc.dtype)
    return k, v


def attention_decode(
    p,
    x: jax.Array,          # [B, 1, d]
    cache_k: jax.Array,    # [B, S, Hkv, dh]
    cache_v: jax.Array,
    pos: jax.Array,        # scalar int32 — current position
    cfg: ModelConfig,
    local: bool = False,
    cross: bool = False,
):
    """One decode step; returns (out [B, 1, d], new_cache_k, new_cache_v).

    For cross-attention the cache holds projected encoder states and is not
    updated.
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    if not cross:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
        if "bk" in p:
            k = k + p["bk"].astype(x.dtype)
            v = v + p["bv"].astype(x.dtype)
        posb = jnp.broadcast_to(pos, (x.shape[0], 1))
        q = apply_rope(q, posb, cfg.rope_theta)
        k = apply_rope(k, posb, cfg.rope_theta)
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, pos, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, pos, axis=1)
    s = cache_k.shape[1]
    ki = jnp.arange(s)
    if cross:
        mask = jnp.ones((s,), bool)
    else:
        mask = ki <= pos
        if local and cfg.window_size > 0:
            mask &= ki > pos - cfg.window_size
    mask = mask[None, None, None, None, :]
    out = _sdpa(q, cache_k, cache_v, mask, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key, hidden: int | None = None):
    d = cfg.d_model
    f = hidden or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        params = {
            "w_gate": _init(ks[0], (d, f)),
            "w_up": _init(ks[1], (d, f)),
            "w_down": _init(ks[2], (f, d), scale=1.0 / np.sqrt(f)),
        }
        specs = {
            "w_gate": ("fsdp", "d_ff"),
            "w_up": ("fsdp", "d_ff"),
            "w_down": ("d_ff", "fsdp"),
        }
    else:
        params = {
            "w_in": _init(ks[0], (d, f)),
            "w_down": _init(ks[1], (f, d), scale=1.0 / np.sqrt(f)),
        }
        specs = {"w_in": ("fsdp", "d_ff"), "w_down": ("d_ff", "fsdp")}
    return params, specs


def mlp(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.silu(gate) * up
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(x.dtype))
        h = jax.nn.gelu(h) if cfg.mlp_type == "gelu" else jax.nn.relu(h)
    h = sh.constrain(h, "batch", "seq", "d_ff")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    return sh.constrain(out, "batch", "seq", None)
