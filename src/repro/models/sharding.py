"""Logical-axis sharding (MaxText-style rules) + mesh context.

Every tensor in the model is annotated with *logical* axis names; a rules
table maps logical axes to mesh axes.  Changing the parallelism layout means
changing the rules, not the model code — this is what the §Perf iterations
tweak.

The context (`use_mesh`) carries (mesh, rules).  Outside a mesh context all
constraints are no-ops, so the same model code runs in single-device tests.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default rules: how logical axes map onto the production mesh.
#   batch       -> all data-parallel axes (pod + data)
#   fsdp        -> weight sharding over the data axis (ZeRO-3 style)
#   tensor axes -> model
DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": "data",      # long-context KV/state sharding (SP)
    "d_model": None,
    "fsdp": "data",           # weight d_model/ d_inner rows (ZeRO-3)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ff": None,
    "d_inner": "model",
    "d_state": None,
    "conv": None,
    "layers": None,
    "dt_rank": None,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: dict | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict | None = None):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    base = dict(DEFAULT_RULES)
    if rules:
        base.update(rules)
    _CTX.rules = base
    try:
        with mesh:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Mesh | None:
    return _CTX.mesh


def axis_size(name: str) -> int:
    m = _CTX.mesh
    if m is None or name not in m.shape:
        return 1
    return m.shape[name]


def _resolve(
    logical_axes: tuple[str | None, ...], shape: tuple[int, ...] | None = None
) -> P:
    rules = _CTX.rules or DEFAULT_RULES
    mesh = _CTX.mesh
    out, used = [], set()
    for i, ax in enumerate(logical_axes):
        if ax is None:
            out.append(None)
            continue
        mapped = rules.get(ax)
        if mapped is None:
            out.append(None)
            continue
        axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        # drop mesh axes that don't exist (e.g. 'pod' on single-pod) or were
        # already consumed by an earlier tensor dim
        axes = tuple(
            a for a in axes
            if mesh is not None and a in mesh.shape and a not in used
        )
        # shape-aware fallback: drop trailing mesh axes until the dim
        # divides evenly (jit-boundary shardings must divide; e.g. 10 KV
        # heads cannot shard over a 16-way model axis -> replicate).
        if shape is not None and axes:
            dim = shape[i]
            while axes:
                prod = 1
                for a in axes:
                    prod *= mesh.shape[a]
                if dim % prod == 0:
                    break
                axes = axes[:-1]
        used.update(axes)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def logical_spec(logical_axes: tuple[str | None, ...]) -> P:
    """PartitionSpec for the given logical axes under the current rules."""
    return _resolve(logical_axes)


def constrain(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op without mesh)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = _resolve(tuple(logical_axes), tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*logical_axes: str | None) -> NamedSharding | None:
    mesh = _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, _resolve(tuple(logical_axes)))


def spec_tree_to_shardings(mesh: Mesh, spec_tree, shape_tree=None,
                           rules: dict | None = None):
    """Map a pytree of logical-axis tuples to NamedShardings (for jit
    in_shardings / device_put of the parameter tree).  When shape_tree is
    given (same structure, leaves with .shape), non-dividing mesh axes are
    dropped per-dim."""
    base = dict(DEFAULT_RULES)
    if rules:
        base.update(rules)
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, base
    is_leaf = lambda x: isinstance(x, tuple)  # noqa: E731
    try:
        if shape_tree is None:
            return jax.tree.map(
                lambda axes: NamedSharding(mesh, _resolve(tuple(axes))),
                spec_tree, is_leaf=is_leaf,
            )
        return jax.tree.map(
            lambda axes, l: NamedSharding(
                mesh, _resolve(tuple(axes), tuple(l.shape))
            ),
            spec_tree, shape_tree, is_leaf=is_leaf,
        )
    finally:
        _CTX.mesh, _CTX.rules = prev
