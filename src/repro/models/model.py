"""Model assembly: embeddings, scanned block stack, heads, modality stubs.

Layers are grouped into *periods* (`cfg.scan_period` layers each) so that
heterogeneous stacks (jamba's 1-attention:7-mamba interleave, gemma2's
local/global alternation, llama4's dense/MoE alternation) scan cleanly:
every period has identical pytree structure, parameters are stacked along a
leading `num_periods` axis, and `jax.lax.scan` + remat gives O(1) HLO size
in depth.

Three entry points:
  forward(...)      — full-sequence training forward -> hidden states + aux
  prefill(...)      — forward that also returns decode state (KV caches /
                      recurrent states) and last-position logits
  decode_step(...)  — one-token step over the decode state
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as ly
from repro.models import moe as moe_mod
from repro.models import sharding as sh
from repro.models import ssm, xlstm
from repro.models.unroll import maybe_checkpoint, scan as maybe_unrolled_scan
from repro.models.config import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_sub(cfg: ModelConfig, key, j: int, cross: bool):
    kind, is_moe, _ = cfg.period_kinds()[j]
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["norm1"], s["norm1"] = ly.init_rmsnorm(cfg.d_model)
    if kind == "attn":
        p["attn"], s["attn"] = ly.init_attention(cfg, ks[0])
        if cross:
            p["cross"], s["cross"] = ly.init_attention(cfg, ks[1], cross=True)
            p["norm_x"], s["norm_x"] = ly.init_rmsnorm(cfg.d_model)
    elif kind == "mamba":
        p["mamba"], s["mamba"] = ssm.init_mamba(cfg, ks[0])
    elif kind == "mlstm":
        p["mlstm"], s["mlstm"] = xlstm.init_mlstm(cfg, ks[0])
    elif kind == "slstm":
        p["slstm"], s["slstm"] = xlstm.init_slstm(cfg, ks[0])
    if is_moe:
        p["norm2"], s["norm2"] = ly.init_rmsnorm(cfg.d_model)
        p["moe"], s["moe"] = moe_mod.init_moe(cfg, ks[2])
    elif cfg.d_ff > 0:
        p["norm2"], s["norm2"] = ly.init_rmsnorm(cfg.d_model)
        p["mlp"], s["mlp"] = ly.init_mlp(cfg, ks[2])
    return p, s


def _init_period(cfg: ModelConfig, key, cross: bool):
    p, s = {}, {}
    for j in range(cfg.scan_period):
        kj = jax.random.fold_in(key, j)
        p[f"sub{j}"], s[f"sub{j}"] = _init_sub(cfg, kj, j, cross)
    return p, s


def _stack_specs(spec, extra=("layers",)):
    return jax.tree.map(
        lambda axes: tuple(extra) + tuple(axes),
        spec,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def init_model(cfg: ModelConfig, seed: int = 0):
    """Returns (params, specs) — specs mirror params with logical axes."""
    root = jax.random.PRNGKey(seed)
    dt = _dtype(cfg)
    params, specs = {}, {}
    params["embed"] = (
        jax.random.normal(ly.key_for(root, "embed"), (cfg.vocab_size, cfg.d_model))
        * 0.02
    )
    specs["embed"] = ("vocab", "fsdp")
    if not cfg.tie_embeddings:
        params["lm_head"] = ly._init(
            ly.key_for(root, "lm_head"), (cfg.d_model, cfg.vocab_size)
        )
        specs["lm_head"] = ("fsdp", "vocab")
    if cfg.num_prefix_embeds or cfg.encoder_layers:
        params["prefix_proj"] = ly._init(
            ly.key_for(root, "prefix"), (cfg.d_model, cfg.d_model)
        )
        specs["prefix_proj"] = ("fsdp", "d_model")

    cross = cfg.encoder_layers > 0
    keys = jax.random.split(ly.key_for(root, "blocks"), cfg.num_periods)
    params["blocks"] = jax.vmap(lambda k: _init_period(cfg, k, cross)[0])(keys)
    _, block_specs = _init_period(cfg, keys[0], cross)  # structure only
    specs["blocks"] = _stack_specs(block_specs)
    params["final_norm"], specs["final_norm"] = ly.init_rmsnorm(cfg.d_model)

    if cfg.encoder_layers:
        enc_cfg = _encoder_cfg(cfg)
        ekeys = jax.random.split(ly.key_for(root, "enc"), enc_cfg.num_periods)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: _init_period(enc_cfg, k, False)[0])(ekeys)
        }
        _, enc_specs = _init_period(enc_cfg, ekeys[0], False)
        specs["encoder"] = {"blocks": _stack_specs(enc_specs)}
        params["enc_norm"], specs["enc_norm"] = ly.init_rmsnorm(cfg.d_model)

    params = jax.tree.map(
        lambda x: x.astype(dt) if x.dtype == jnp.float32 else x, params
    )
    return params, specs


def _encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg, num_layers=cfg.encoder_layers, encoder_layers=0,
        scan_period=1, moe_num_experts=0, attn_every=1, xlstm=False,
    )


# ---------------------------------------------------------------------------
# block forward (one period)
# ---------------------------------------------------------------------------


def _period_forward(
    cfg: ModelConfig,
    pparams,
    x,
    positions,
    enc_out=None,
    mode: str = "train",       # train | prefill | decode
    states=None,               # per-sub dict of decode state (mode != train)
    pos=None,                  # scalar decode position
):
    aux = jnp.zeros((2,), jnp.float32)  # (lb_loss, z_loss)
    new_states = {}
    for j, (kind, is_moe, is_local) in enumerate(cfg.period_kinds()):
        sub = pparams[f"sub{j}"]
        st = (states or {}).get(f"sub{j}")
        h = ly.rmsnorm(x, sub["norm1"], cfg.norm_eps)
        if kind == "attn":
            if mode == "train":
                mix = ly.attention(sub["attn"], h, cfg, positions, local=is_local)
                nst = {}
            elif mode == "prefill":
                mix, (ck, cv) = _attn_prefill(sub["attn"], h, cfg, positions,
                                              is_local, st)
                nst = {"k": ck, "v": cv}
            else:
                mix, ck, cv = ly.attention_decode(
                    sub["attn"], h, st["k"], st["v"], pos, cfg, local=is_local
                )
                nst = {"k": ck, "v": cv}
            x = x + mix
            if "cross" in sub:
                hx = ly.rmsnorm(x, sub["norm_x"], cfg.norm_eps)
                if mode == "decode":
                    cx, _, _ = ly.attention_decode(
                        sub["cross"], hx, st["xk"], st["xv"], pos, cfg,
                        cross=True,
                    )
                    # cross cache is static; carry it for the next step
                    nst.update(xk=st["xk"], xv=st["xv"])
                else:
                    kx, vx = ly.project_cross_kv(sub["cross"], enc_out, cfg)
                    cx = ly.attention(
                        sub["cross"], hx, cfg, positions, causal=False,
                        kv_override=(kx, vx),
                    )
                    if mode == "prefill":
                        nst.update(xk=kx, xv=vx)
                x = x + cx
            new_states[f"sub{j}"] = nst
        elif kind == "mamba":
            if mode == "train":
                mix = ssm.mamba(sub["mamba"], h, cfg)
                nst = {}
            elif mode == "prefill":
                mix, (hh, conv) = ssm.mamba_with_state(
                    sub["mamba"], h, cfg, None, None
                )
                nst = {"h": hh, "conv": conv}
            else:
                mix, (hh, conv) = ssm.mamba_decode(
                    sub["mamba"], h, (st["h"], st["conv"]), cfg
                )
                nst = {"h": hh, "conv": conv}
            x = x + mix
            new_states[f"sub{j}"] = nst
        elif kind in ("mlstm", "slstm"):
            fwd = (
                xlstm.mlstm_with_state if kind == "mlstm"
                else xlstm.slstm_with_state
            )
            init_st = None if mode != "decode" else tuple(
                st[k] for k in sorted(st)
            )
            mix, nst_t = fwd(sub[kind], h, cfg, init_st)
            nst = (
                {f"s{i}": v for i, v in enumerate(nst_t)}
                if mode != "train"
                else {}
            )
            x = x + mix
            new_states[f"sub{j}"] = nst
        if "moe" in sub:
            h2 = ly.rmsnorm(x, sub["norm2"], cfg.norm_eps)
            y, maux = moe_mod.moe(sub["moe"], h2, cfg)
            aux = aux + jnp.stack([maux.load_balance_loss, maux.router_z_loss])
            x = x + y
        elif "mlp" in sub:
            h2 = ly.rmsnorm(x, sub["norm2"], cfg.norm_eps)
            x = x + ly.mlp(sub["mlp"], h2, cfg)
        x = sh.constrain(x, "batch", "seq", None)
    return x, aux, new_states


def _attn_prefill(p, h, cfg, positions, is_local, _st):
    """Full attention that also returns the rope'd K/V for the cache."""
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(h.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(h.dtype)
        k = k + p["bk"].astype(h.dtype)
        v = v + p["bv"].astype(h.dtype)
    q = ly.apply_rope(q, positions, cfg.rope_theta)
    k = ly.apply_rope(k, positions, cfg.rope_theta)
    sq = q.shape[1]
    window = cfg.window_size if is_local else 0
    if sq > ly.Q_CHUNK_THRESHOLD and sq % ly.Q_CHUNK == 0:
        out = ly._sdpa_qchunked(q, k, v, cfg, True, window)
    else:
        mask = ly.causal_mask(sq, sq, window)
        out = ly._sdpa(q, k, v, mask, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(h.dtype))
    return out, (k, v)


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, batch):
    dt = _dtype(cfg)
    parts = []
    if "prefix_embeds" in batch:
        pe = batch["prefix_embeds"].astype(dt)
        pe = jnp.einsum("bpd,de->bpe", pe, params["prefix_proj"].astype(dt))
        parts.append(pe)
    if "tokens" in batch:
        tok = params["embed"].astype(dt)[batch["tokens"]] * np.sqrt(cfg.d_model)
        parts.append(tok)
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return sh.constrain(x, "batch", "seq", None)


def _encode(params, cfg: ModelConfig, frames):
    """Bidirectional encoder over frontend-provided frame embeddings."""
    dt = _dtype(cfg)
    x = jnp.einsum(
        "bsd,de->bse", frames.astype(dt), params["prefix_proj"].astype(dt)
    )
    enc_cfg = _encoder_cfg(cfg)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None]

    def body(xc, pp):
        sub = pp["sub0"]
        h = ly.rmsnorm(xc, sub["norm1"], enc_cfg.norm_eps)
        mix = ly.attention(sub["attn"], h, enc_cfg, positions, causal=False)
        xc = xc + mix
        h2 = ly.rmsnorm(xc, sub["norm2"], enc_cfg.norm_eps)
        xc = xc + ly.mlp(sub["mlp"], h2, enc_cfg)
        return xc, None

    x, _ = maybe_unrolled_scan(maybe_checkpoint(body), x,
                               params["encoder"]["blocks"])
    return ly.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, batch, collect: str = "train"):
    """Full-sequence forward.

    batch keys: tokens [B, S_text] and/or prefix_embeds [B, P, d];
    frames [B, S_src, d] for enc-dec.
    Returns (hidden [B, S, d], aux [2], states or None).
    """
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _encode(params, cfg, batch["frames"])
    x = _embed_inputs(params, cfg, batch)
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2]
    )

    def body(carry, pp):
        xc, aux = carry
        y, a, st = _period_forward(
            cfg, pp, xc, positions, enc_out=enc_out, mode=collect
        )
        return (y, aux + a), (st if collect == "prefill" else None)

    (x, aux), states = maybe_unrolled_scan(
        maybe_checkpoint(body),
        (x, jnp.zeros((2,), jnp.float32)),
        params["blocks"],
    )
    x = ly.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux, states


def logits_from_hidden(params, cfg: ModelConfig, hidden):
    dt = hidden.dtype
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", hidden, params["embed"].astype(dt))
    else:
        logits = jnp.einsum("bsd,dv->bsv", hidden, params["lm_head"].astype(dt))
    logits = logits.astype(jnp.float32)
    if cfg.final_logit_softcap > 0:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return sh.constrain(logits, "batch", "seq", "vocab")


def prefill(params, cfg: ModelConfig, batch, max_len: int):
    """Returns (last_logits [B, V], decode_states). KV caches are padded to
    max_len so decode_step can extend in place."""
    hidden, aux, states = forward(params, cfg, batch, collect="prefill")

    def pad(path, leaf):
        # self-attention caches [P, B, S, H, dh] pad S to max_len; cross
        # caches ('xk'/'xv') keep the encoder length; recurrent states are
        # fixed-size.
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key in ("k", "v") and leaf.ndim == 5:
            padw = [(0, 0)] * leaf.ndim
            padw[2] = (0, max_len - leaf.shape[2])
            return jnp.pad(leaf, padw)
        return leaf

    states = jax.tree_util.tree_map_with_path(pad, states)
    last = logits_from_hidden(params, cfg, hidden[:, -1:, :])[:, 0]
    return last, states, aux


def decode_step(params, cfg: ModelConfig, token, states, pos):
    """token: [B] int32; pos: scalar int32. Returns (logits [B,V], states)."""
    batch = {"tokens": token[:, None]}
    x = _embed_inputs(params, cfg, batch)
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)

    def body(xc, inp):
        pp, st = inp
        y, _, nst = _period_forward(
            cfg, pp, xc, positions, mode="decode", states=st, pos=pos
        )
        return y, nst

    x, new_states = maybe_unrolled_scan(body, x, (params["blocks"], states))
    x = ly.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x)[:, 0]
    return logits, new_states
