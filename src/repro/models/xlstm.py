"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly recurrent).

TPU adaptation: the GPU reference implements mLSTM with a fused recurrent
kernel; here the mLSTM uses the *chunkwise-parallel* form — quadratic
within a VMEM-sized chunk (MXU-friendly), recurrent (C, n, m) state across
chunks — the same hierarchy as our Mamba scan.  sLSTM is inherently
sequential (h_{t-1} feeds the gate pre-activations through a recurrent
matrix), so it runs as a lax.scan over time with exp-gate stabilization;
xLSTM interleaves few of them by design.

Decode for both is an O(1) state update.
  mLSTM state: (C [B,H,dh,dh], n [B,H,dh], m [B,H])
  sLSTM state: (c [B,H,dh], n [B,H,dh], h [B,H,dh], m [B,H,dh])
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import sharding as sh
from repro.models.config import ModelConfig
from repro.models.layers import _init


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(cfg: ModelConfig, key):
    d = cfg.d_model
    di = 2 * d
    ks = jax.random.split(key, 7)
    params = {
        "up_proj": _init(ks[0], (d, 2 * di)),          # x-branch + gate branch
        "wq": _init(ks[1], (d, d)),
        "wk": _init(ks[2], (d, d)),
        "wv": _init(ks[3], (d, d)),
        "w_if": _init(ks[4], (d, 2 * cfg.num_heads), scale=0.02),
        "b_i": jnp.zeros((cfg.num_heads,)),
        "b_f": jnp.full((cfg.num_heads,), 3.0),        # open forget gates
        "down_proj": _init(ks[5], (di, d), scale=1.0 / np.sqrt(di)),
    }
    specs = {
        "up_proj": ("fsdp", "d_inner"),
        "wq": ("fsdp", "heads"),
        "wk": ("fsdp", "heads"),
        "wv": ("fsdp", "heads"),
        "w_if": ("fsdp", None),
        "b_i": (None,),
        "b_f": (None,),
        "down_proj": ("d_inner", "fsdp"),
    }
    return params, specs


def _mlstm_chunk(q, k, v, li, lf, state):
    """One chunkwise-parallel mLSTM step.

    q/k/v: [B, H, Q, dh]; li/lf: [B, H, Q] log input/forget gates.
    state: (C [B,H,dh,dh], n [B,H,dh], m [B,H]).
    """
    C, n, m = state
    b_cum = jnp.cumsum(lf, axis=-1)                    # [B,H,Q]
    B_tot = b_cum[..., -1]
    u = li - b_cum                                     # li_t - b_t
    u_max = jax.lax.cummax(u, axis=u.ndim - 1)
    m_t = b_cum + jnp.maximum(m[..., None], u_max)     # [B,H,Q]

    inter_w = jnp.exp(b_cum + m[..., None] - m_t)      # [B,H,Q]
    # intra weights D_{tτ} = exp(b_t - b_τ + li_τ - m_t), τ <= t
    lD = (
        b_cum[..., :, None]
        - b_cum[..., None, :]
        + li[..., None, :]
        - m_t[..., :, None]
    )
    tri = jnp.tril(jnp.ones(lD.shape[-2:], bool))
    D = jnp.where(tri, jnp.exp(lD), 0.0)               # [B,H,Q,Q]

    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * D
    h_intra = jnp.einsum("bhqk,bhkd->bhqd", scores, v)
    h_inter = jnp.einsum("bhqd,bhde->bhqe", q, C) * inter_w[..., None]
    num = h_intra + h_inter

    n_intra = jnp.einsum("bhqk,bhkd->bhqd", D, k)  # n_t = Σ_τ D_tτ k_τ (+ inter)
    n_t = n_intra + n[..., None, :] * inter_w[..., None]
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhqd,bhqd->bhq", q, n_t)), jnp.exp(-m_t)
    )
    h = num / denom[..., None]                          # [B,H,Q,dh]

    # state update to end of chunk
    m_new = B_tot + jnp.maximum(m, u_max[..., -1])
    decay_prev = jnp.exp(B_tot + m - m_new)             # [B,H]
    w_tau = jnp.exp(B_tot[..., None] - b_cum + li - m_new[..., None])  # [B,H,Q]
    C_new = C * decay_prev[..., None, None] + jnp.einsum(
        "bhqd,bhqe,bhq->bhde", k, v, w_tau
    )
    n_new = n * decay_prev[..., None] + jnp.einsum("bhqd,bhq->bhd", k, w_tau)
    return h, (C_new, n_new, m_new)


def _heads(x, h):
    b, s, d = x.shape
    return x.reshape(b, s, h, d // h).transpose(0, 2, 1, 3)  # [B,H,S,dh]


def mlstm_with_state(p, x: jax.Array, cfg: ModelConfig, state=None,
                     chunk: int = 256):
    """x: [B, S, d] -> ([B, S, d], state)."""
    b, s, d = x.shape
    hn = cfg.num_heads
    dh = d // hn
    xz = jnp.einsum("bsd,de->bse", x, p["up_proj"].astype(x.dtype))
    x_br, z = jnp.split(xz, 2, axis=-1)                 # [B,S,di]
    q = _heads(jnp.einsum("bsd,de->bse", x, p["wq"].astype(x.dtype)), hn)
    k = _heads(jnp.einsum("bsd,de->bse", x, p["wk"].astype(x.dtype)), hn) / np.sqrt(dh)
    v = _heads(jnp.einsum("bsd,de->bse", x, p["wv"].astype(x.dtype)), hn)
    gates = jnp.einsum(
        "bsd,dg->bsg", x.astype(jnp.float32), p["w_if"].astype(jnp.float32)
    )
    li = (gates[..., :hn] + p["b_i"]).transpose(0, 2, 1)          # [B,H,S]
    lf = jax.nn.log_sigmoid(gates[..., hn:] + p["b_f"]).transpose(0, 2, 1)

    if state is None:
        state = (
            jnp.zeros((b, hn, dh, dh), jnp.float32),
            jnp.zeros((b, hn, dh), jnp.float32),
            jnp.full((b, hn), -1e30, jnp.float32),
        )
    qn = min(chunk, s)
    assert s % qn == 0
    nc = s // qn

    def step(st, inp):
        qc, kc, vc, lic, lfc = inp
        h, st = _mlstm_chunk(
            qc.astype(jnp.float32), kc.astype(jnp.float32),
            vc.astype(jnp.float32), lic, lfc, st,
        )
        return st, h

    def chunked(t):  # [B,H,S,*] -> [nc, B,H,Q,*]
        return jnp.moveaxis(
            t.reshape(t.shape[0], t.shape[1], nc, qn, *t.shape[3:]), 2, 0
        )

    state, hs = jax.lax.scan(
        step, state, (chunked(q), chunked(k), chunked(v),
                      chunked(li), chunked(lf))
    )
    # hs: [nc, B, H, Q, dh] -> [B, S, d]
    h = jnp.moveaxis(hs, 0, 2).reshape(b, hn, s, dh)
    h = h.transpose(0, 2, 1, 3).reshape(b, s, d)
    # GLU-style block output: the memory read-out h modulates the
    # up-projected branch, gated by silu(z) (pre-LN projected-GLU variant).
    out = x_br * jax.nn.silu(z)
    out = out * jnp.concatenate([h.astype(x.dtype)] * (out.shape[-1] // d), -1)
    out = jnp.einsum("bsi,id->bsd", out, p["down_proj"].astype(x.dtype))
    return sh.constrain(out, "batch", "seq", None), state


def mlstm_decode(p, x: jax.Array, cfg: ModelConfig, state):
    """x: [B, 1, d]; O(1) recurrent update."""
    y, state = mlstm_with_state(p, x, cfg, state, chunk=1)
    return y, state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(cfg: ModelConfig, key):
    d = cfg.d_model
    hn = cfg.num_heads
    dh = d // hn
    ks = jax.random.split(key, 3)
    params = {
        "w_gates": _init(ks[0], (d, 4 * d)),            # i, f, z, o pre-acts
        "r_gates": _init(ks[1], (hn, dh, 4 * dh), scale=1.0 / np.sqrt(dh)),
        "b_gates": jnp.concatenate(
            [jnp.zeros((d,)), jnp.full((d,), 3.0), jnp.zeros((2 * d,))]
        ),
        "out_proj": _init(ks[2], (d, d)),
    }
    specs = {
        "w_gates": ("fsdp", "d_inner"),
        "r_gates": ("heads", None, None),
        "b_gates": ("d_inner",),
        "out_proj": ("fsdp", "d_model"),
    }
    return params, specs


def slstm_with_state(p, x: jax.Array, cfg: ModelConfig, state=None):
    """Strictly recurrent scan over time. x: [B, S, d]."""
    b, s, d = x.shape
    hn = cfg.num_heads
    dh = d // hn
    pre_x = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["w_gates"].astype(jnp.float32)
    ) + p["b_gates"].astype(jnp.float32)                # [B,S,4d]
    pre_x = pre_x.reshape(b, s, hn, 4 * dh)

    if state is None:
        zero = jnp.zeros((b, hn, dh), jnp.float32)
        state = (zero, zero + 1e-6, zero, zero - 1e30)  # c, n, h, m

    r = p["r_gates"].astype(jnp.float32)

    def step(st, pre_t):                                # pre_t: [B,H,4dh]
        c, n, h, m = st
        pre = pre_t + jnp.einsum("bhd,hde->bhe", h, r)
        it, ft, zt, ot = jnp.split(pre, 4, axis=-1)
        m_new = jnp.maximum(ft + m, it)
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(ft + m - m_new)
        c_new = fp * c + ip * jnp.tanh(zt)
        n_new = fp * n + ip
        h_new = jax.nn.sigmoid(ot) * (c_new / jnp.maximum(n_new, 1e-6))
        return (c_new, n_new, h_new, m_new), h_new

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(pre_x, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", h, p["out_proj"].astype(x.dtype))
    return sh.constrain(out, "batch", "seq", None), state


def slstm_decode(p, x: jax.Array, cfg: ModelConfig, state):
    y, state = slstm_with_state(p, x, cfg, state)
    return y, state
