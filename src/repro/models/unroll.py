"""Switchable scan: lax.scan (production) or Python unroll (cost analysis).

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so a scanned-layers model under-reports FLOPs/bytes/collectives by
~num_layers.  The dry-run's single-pod roofline pass unrolls the layer and
loss scans (`set_unroll(True)`) so the compiled HLO carries the true
totals; production / multi-pod lowering keeps lax.scan (small HLO, fast
compile, identical math).

Inner sequence-chunk scans (flash attention rows, mamba/mLSTM chunks,
sLSTM steps) stay as lax.scan even when unrolled=True — their trip-count
correction is applied analytically in benchmarks/roofline.py.
"""

from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

_UNROLL = False


def set_unroll(value: bool) -> None:
    global _UNROLL
    _UNROLL = bool(value)


def unrolling() -> bool:
    return _UNROLL


@contextlib.contextmanager
def unroll_scope(value: bool = True):
    global _UNROLL
    prev = _UNROLL
    _UNROLL = value
    try:
        yield
    finally:
        _UNROLL = prev


def scan(f, init, xs, length: int | None = None):
    """Drop-in for jax.lax.scan (the subset this codebase uses)."""
    if not _UNROLL:
        return jax.lax.scan(f, init, xs, length=length)
    if xs is None:
        n = length
        get = lambda i: None  # noqa: E731
    else:
        leaves = jax.tree.leaves(xs)
        n = leaves[0].shape[0]
        get = lambda i: jax.tree.map(lambda l: l[i], xs)  # noqa: E731
    carry = init
    ys = []
    for i in range(n):
        carry, y = f(carry, get(i))
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls, axis=0), *ys)
    return carry, stacked


_REMAT = True


@contextlib.contextmanager
def remat_scope(value: bool):
    """Toggle activation rematerialization (jax.checkpoint) around the
    layer/loss bodies — a §Perf knob: remat=False saves one FSDP weight
    re-gather pass at the cost of storing activations."""
    global _REMAT
    prev = _REMAT
    _REMAT = value
    try:
        yield
    finally:
        _REMAT = prev


def maybe_checkpoint(f, policy=None):
    if not _REMAT:
        return f
    return jax.checkpoint(f, policy=policy)
