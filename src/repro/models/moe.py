"""Mixture-of-Experts layer with expert parallelism over the `model` axis.

Design (DESIGN.md Sec. 6): experts are sharded over `model`; activations
enter the layer batch-sharded over (pod, data) and replicated over `model`
(the standard GSPMD layout after an attention block).  Each model-shard
gathers the tokens routed to ITS experts (capacity-bounded, Switch-style),
runs the expert MLPs as one batched einsum, scatter-adds the weighted
outputs, and a single psum over `model` combines the partial outputs.

Routing (top-k + load-balance loss) happens outside the shard_map in plain
GSPMD; only dispatch/compute/combine are manual.  The gather/scatter slot
assignment is `repro.core.routing.run_ranks` — the same sort-rank
machinery as the LSH store and the LSH all_to_all router (one mechanism,
three uses; DESIGN.md Sec. 3.2).

The `dense_ep` combine (psum of [B,S,d]) is the robust baseline; §Perf
iterations may switch hot configs to sequence-sharded all_to_all dispatch.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import routing
from repro.models import sharding as sh
from repro.models.config import ModelConfig
from repro.models.layers import _init


def init_moe(cfg: ModelConfig, key):
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.moe_num_experts
    ks = jax.random.split(key, 5)
    params = {
        "router": _init(ks[0], (d, e), scale=0.02),
        "w_gate": _init(ks[1], (e, d, f)),
        "w_up": _init(ks[2], (e, d, f)),
        "w_down": _init(ks[3], (e, f, d), scale=1.0 / np.sqrt(f)),
    }
    specs = {
        "router": (None, None),
        "w_gate": ("experts", "fsdp", "expert_ff"),
        "w_up": ("experts", "fsdp", "expert_ff"),
        "w_down": ("experts", "expert_ff", "fsdp"),
    }
    if cfg.moe_num_shared:
        fs = f * cfg.moe_num_shared
        ks2 = jax.random.split(ks[4], 3)
        params["shared"] = {
            "w_gate": _init(ks2[0], (d, fs)),
            "w_up": _init(ks2[1], (d, fs)),
            "w_down": _init(ks2[2], (fs, d), scale=1.0 / np.sqrt(fs)),
        }
        specs["shared"] = {
            "w_gate": ("fsdp", "d_ff"),
            "w_up": ("fsdp", "d_ff"),
            "w_down": ("d_ff", "fsdp"),
        }
    return params, specs


def _expert_compute(wg, wu, wd, xe):
    """xe: [E_loc, cap, d] -> [E_loc, cap, d]."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum(
        "ecd,edf->ecf", xe, wu
    )
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _moe_shard(
    x, topk_idx, topk_w, wg, wu, wd, *, e_total: int, cap: int, axis: str | None
):
    """Per-shard dispatch/compute/combine.

    x: [B_loc, S, d]; topk_idx/w: [B_loc, S, K]; w*: [E_loc, ...] local experts.
    """
    b, s, d = x.shape
    k = topk_idx.shape[-1]
    e_loc = wg.shape[0]
    me = jax.lax.axis_index(axis) if axis else 0
    first = me * e_loc

    x_flat = x.reshape(b * s, d)
    flat_e = topk_idx.reshape(-1)                   # [N*K] global expert ids
    flat_w = topk_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(b * s, dtype=jnp.int32), k)

    local_e = flat_e - first
    mine = (local_e >= 0) & (local_e < e_loc)
    sort_key = jnp.where(mine, local_e, e_loc)      # foreign last
    order = jnp.argsort(sort_key)
    e_sorted = sort_key[order]
    rank = routing.run_ranks(e_sorted)
    # dispatch table [E_loc, cap] of flat token indices (-1 = empty);
    # foreign entries (e_sorted == e_loc) and over-capacity ranks fall
    # out-of-bounds and are dropped by the scatter.
    disp = jnp.full((e_loc, cap), -1, jnp.int32)
    disp = disp.at[e_sorted, rank].set(flat_tok[order], mode="drop")
    wdisp = jnp.zeros((e_loc, cap), x.dtype)
    wdisp = wdisp.at[e_sorted, rank].set(
        flat_w[order].astype(x.dtype), mode="drop"
    )

    xe = jnp.where(
        (disp >= 0)[..., None], x_flat[jnp.maximum(disp, 0)], 0.0
    )  # [E_loc, cap, d]
    ye = _expert_compute(wg.astype(x.dtype), wu.astype(x.dtype),
                         wd.astype(x.dtype), xe)
    ye = ye * wdisp[..., None]

    y_flat = jnp.zeros_like(x_flat)
    y_flat = y_flat.at[jnp.where(disp >= 0, disp, b * s)].add(
        ye, mode="drop"
    )
    y = y_flat.reshape(b, s, d)
    if axis:
        y = jax.lax.psum(y, axis)
    return y


@dataclasses.dataclass
class MoeAux:
    load_balance_loss: jax.Array
    router_z_loss: jax.Array
    dropped_fraction: jax.Array


def moe(p, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, MoeAux]:
    """x: [B, S, d] -> (y, aux).  Must run under sharding.use_mesh."""
    e = cfg.moe_num_experts
    k = cfg.moe_top_k
    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, k)
    topk_w = topk_w / jnp.maximum(
        jnp.sum(topk_w, axis=-1, keepdims=True), 1e-9
    )

    # Switch-style load-balance loss + router z-loss (computed globally);
    # density via scatter-add, not one_hot (no [B,S,K,E] intermediate).
    density = jnp.zeros((e,), jnp.float32).at[topk_idx.reshape(-1)].add(
        1.0
    ) / float(np.prod(topk_idx.shape))
    p_mean = jnp.mean(probs, axis=(0, 1))
    lb_loss = e * jnp.sum(density * p_mean)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    mesh = sh.current_mesh()
    n_model = sh.axis_size("model")
    if e % n_model != 0:
        raise ValueError(f"experts {e} must divide over model axis {n_model}")

    # capacity per expert, from this shard's local token count
    def local_tokens(b, s):
        dp = sh.axis_size("data") * sh.axis_size("pod")
        return max(b // max(dp, 1), 1) * s

    b, s, _ = x.shape
    cap = int(np.ceil(local_tokens(b, s) * k / e * cfg.moe_capacity_factor))
    cap = max(cap, 4)

    if mesh is None:
        y = _moe_shard(
            x, topk_idx, topk_w, p["w_gate"], p["w_up"], p["w_down"],
            e_total=e, cap=cap, axis=None,
        )
    else:
        from jax.sharding import PartitionSpec as P

        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        dp = 1
        for a in batch_axes:
            dp *= mesh.shape[a]
        if b % dp != 0:  # e.g. decode with B=1: replicate over DP axes
            batch_axes = ()
        xspec = P(batch_axes, None, None)
        kspec = P(batch_axes, None, None)
        wspec = P("model", None, None)
        fn = compat.shard_map(
            partial(_moe_shard, e_total=e, cap=cap, axis="model"),
            mesh=mesh,
            in_specs=(xspec, kspec, kspec, wspec, wspec, wspec),
            out_specs=xspec,
        )
        y = fn(x, topk_idx, topk_w, p["w_gate"], p["w_up"], p["w_down"])

    if "shared" in p:
        from repro.models.layers import mlp

        y = y + mlp(p["shared"], x, cfg)

    # dropped fraction diagnostic (capacity overflow), cheap closed form
    dropped = jnp.float32(0.0)  # counted in tests via dispatch table
    return y, MoeAux(lb_loss, z_loss, dropped)
