"""Model configuration schema covering all assigned architecture families."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str            # dense | moe | hybrid | ssm | encdec | vlm | audio
    num_layers: int        # decoder layers for enc-dec
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- MLP flavor ---
    mlp_type: str = "swiglu"   # swiglu | gelu (2-matrix) | relu (2-matrix)

    # --- MoE ---
    moe_num_experts: int = 0   # routed experts (0 => dense)
    moe_top_k: int = 0
    moe_num_shared: int = 0    # always-on shared experts
    moe_d_ff: int = 0          # per-expert hidden dim (fine-grained MoE)
    moe_every: int = 1         # MoE replaces dense MLP every Nth layer
    moe_capacity_factor: float = 1.5

    # --- attention flavor ---
    rope_theta: float = 10000.0
    window_size: int = 0         # >0: sliding-window (local) attention
    alt_local_global: bool = False  # gemma2: even layers local, odd global
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    qkv_bias: bool = False

    # --- hybrid / ssm ---
    attn_every: int = 1        # jamba: layer i is attention iff i % attn_every == 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0     # 0 => ceil(d_model / 16)
    xlstm: bool = False        # alternate mLSTM (even) / sLSTM (odd) blocks

    # --- encoder-decoder ---
    encoder_layers: int = 0    # >0 => enc-dec; num_layers is the decoder

    # --- modality stub ---
    modality: str = "text"     # text | audio_frames | vision_patches
    num_prefix_embeds: int = 0  # frontend-provided embeddings prepended

    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scan_period: int = 1       # layers per scanned super-block
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.num_layers % self.scan_period != 0:
            raise ValueError("num_layers must be divisible by scan_period")
        if self.encoder_layers and self.family not in ("encdec", "audio"):
            raise ValueError("encoder_layers requires encdec/audio family")

    # ---- derived ----

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.scan_period

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_kind(self, i: int) -> str:
        """Sub-layer mixer kind for layer i: attn | mamba | mlstm | slstm."""
        if self.xlstm:
            return "mlstm" if i % 2 == 0 else "slstm"
        if self.attn_every > 1:
            return "attn" if i % self.attn_every == 0 else "mamba"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if self.moe_num_experts == 0:
            return False
        return (i % self.moe_every) == (self.moe_every - 1)

    def layer_is_local_attn(self, i: int) -> bool:
        if not self.alt_local_global:
            return self.window_size > 0
        return i % 2 == 0  # gemma2: even layers sliding-window

    @property
    def uses_kv_cache(self) -> bool:
        return any(
            self.layer_kind(i) == "attn" for i in range(self.num_layers)
        )

    def period_kinds(self) -> tuple[str, ...]:
        """Kind signature of one scan super-block (must tile num_layers)."""
        kinds = tuple(
            (
                self.layer_kind(i),
                self.layer_is_moe(i),
                self.layer_is_local_attn(i),
            )
            for i in range(self.scan_period)
        )
        # verify the pattern is truly periodic
        for i in range(self.num_layers):
            j = i % self.scan_period
            if (
                self.layer_kind(i),
                self.layer_is_moe(i),
                self.layer_is_local_attn(i),
            ) != kinds[j]:
                raise ValueError(
                    f"layer pattern not periodic with scan_period="
                    f"{self.scan_period} at layer {i}"
                )
        return kinds

    def active_params_per_token(self) -> float:
        """~active params for 6ND MODEL_FLOPS accounting (dense: all)."""
        return count_params(self, active_only=True)

    def total_params(self) -> float:
        return count_params(self, active_only=False)


def count_params(cfg: ModelConfig, active_only: bool = False) -> float:
    """Closed-form parameter count (matches init; used for roofline 6ND)."""
    d = cfg.d_model
    emb = cfg.vocab_size * d
    total = emb * (1 if cfg.tie_embeddings else 2)
    total += d  # final_norm
    if cfg.num_prefix_embeds or cfg.encoder_layers:
        total += d * d  # modality adapter / encoder input projection

    def attn_params():
        p = d * cfg.q_dim + d * cfg.kv_dim * 2 + cfg.q_dim * d
        if cfg.qkv_bias:
            p += cfg.q_dim + 2 * cfg.kv_dim
        return p

    def mlp_params(hidden):
        n_mat = 3 if cfg.mlp_type == "swiglu" else 2
        return n_mat * d * hidden

    def mamba_params():
        di, n, r = cfg.d_inner, cfg.mamba_d_state, cfg.dt_rank
        return (
            d * 2 * di          # in_proj (x, z)
            + cfg.mamba_d_conv * di + di  # depthwise conv (w, b)
            + di * (r + 2 * n)  # x_proj
            + r * di + di       # dt_proj, dt_bias
            + di * n + di       # A_log, D
            + di * d            # out_proj
        )

    def mlstm_params():
        di = 2 * d
        h = cfg.num_heads
        # up(x,z), qkv, i/f gates (+biases), down
        return d * 2 * di + 3 * d * d + d * 2 * h + 2 * h + di * d

    def slstm_params():
        dh = d // max(cfg.num_heads, 1)
        # w_gates, recurrent block-diag, gate biases, out_proj
        return 4 * d * d + 4 * dh * d + 4 * d + d * d

    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            total += attn_params()
            if cfg.encoder_layers:
                total += attn_params() + d  # decoder cross-attn + its norm
        elif kind == "mamba":
            total += mamba_params()
        elif kind == "mlstm":
            total += mlstm_params()
        elif kind == "slstm":
            total += slstm_params()
        total += 2 * d  # norms
        if cfg.layer_is_moe(i):
            hidden = cfg.moe_d_ff or cfg.d_ff
            routed = cfg.moe_num_experts * mlp_params(hidden)
            shared = cfg.moe_num_shared * mlp_params(hidden)
            router = d * cfg.moe_num_experts
            if active_only:
                routed = cfg.moe_top_k * mlp_params(hidden)
            total += routed + shared + router
        elif cfg.d_ff > 0:
            total += mlp_params(cfg.d_ff)
        # xlstm blocks (d_ff = 0) have no separate MLP
    for i in range(cfg.encoder_layers):
        total += attn_params() + mlp_params(cfg.d_ff) + 2 * d
    if cfg.encoder_layers:
        total += d  # enc_norm
    return float(total)
