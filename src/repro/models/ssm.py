"""Mamba (selective SSM) block — TPU-adapted chunked selective scan.

GPU Mamba fuses a sequential scan into one kernel over SRAM; the TPU-native
formulation is a *chunked associative scan*: within a chunk the diagonal
recurrence h_t = dA_t * h_{t-1} + dBx_t is a parallel associative scan
(log-depth, VPU-friendly); across chunks a lax.scan carries the [B, di, N]
state.  Chunk size bounds the [B, Q, di, N] working set to VMEM-scale tiles.

Decode keeps (conv_state [B, d_conv-1, di], h [B, di, N]) and is O(1)/token —
this is what makes the `long_500k` cell tractable for hybrid archs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import sharding as sh
from repro.models.config import ModelConfig
from repro.models.layers import _init


def init_mamba(cfg: ModelConfig, key):
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.mamba_d_state, cfg.dt_rank
    dc = cfg.mamba_d_conv
    ks = jax.random.split(key, 6)
    params = {
        "in_proj": _init(ks[0], (d, 2 * di)),
        "conv_w": _init(ks[1], (dc, di), scale=1.0 / np.sqrt(dc)),
        "conv_b": jnp.zeros((di,)),
        "x_proj": _init(ks[2], (di, r + 2 * n)),
        "dt_proj": _init(ks[3], (r, di), scale=1.0 / np.sqrt(r)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01))),  # softplus^-1
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
        ),
        "D": jnp.ones((di,)),
        "out_proj": _init(ks[4], (di, d), scale=1.0 / np.sqrt(di)),
    }
    specs = {
        "in_proj": ("fsdp", "d_inner"),
        "conv_w": ("conv", "d_inner"),
        "conv_b": ("d_inner",),
        "x_proj": ("d_inner", None),
        "dt_proj": ("dt_rank", "d_inner"),
        "dt_bias": ("d_inner",),
        "A_log": ("d_inner", "d_state"),
        "D": ("d_inner",),
        "out_proj": ("d_inner", "fsdp"),
    }
    return params, specs


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. x: [B, S, di]; w: [dc, di]."""
    dc = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(dc):  # dc is tiny (4): unrolled taps, no gather
        out = out + pad[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def _ssm_scan_chunked(dA, dBx, C, h0, chunk: int):
    """Selective scan via chunked associative scan.

    dA, dBx: [B, S, di, N]; C: [B, S, N]; h0: [B, di, N].
    Returns (y [B, S, di], h_final).
    """
    b, s, di, n = dA.shape
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    dA = dA.reshape(b, nc, q, di, n)
    dBx = dBx.reshape(b, nc, q, di, n)
    Cc = C.reshape(b, nc, q, n)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b1 * a2 + b2

    def step(h, inputs):
        da, dbx, c = inputs  # [b, q, di, n], ..., [b, q, n]
        pref_a, scan_b = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h_t = scan_b + pref_a * h[:, None]        # [b, q, di, n]
        y = jnp.einsum("bqdn,bqn->bqd", h_t, c)
        return h_t[:, -1], y

    inputs = (
        jnp.moveaxis(dA, 1, 0),
        jnp.moveaxis(dBx, 1, 0),
        jnp.moveaxis(Cc, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, di)
    return y, h_final


def mamba(p, x: jax.Array, cfg: ModelConfig, chunk: int = 256) -> jax.Array:
    """Training/prefill forward. x: [B, S, d] -> [B, S, d]."""
    y, _ = mamba_with_state(p, x, cfg, h0=None, conv0=None, chunk=chunk)
    return y


def mamba_with_state(
    p, x: jax.Array, cfg: ModelConfig, h0, conv0, chunk: int = 256
):
    """Forward that also returns (h, conv_state) for prefill->decode."""
    b, s, d = x.shape
    di, n, r = cfg.d_inner, cfg.mamba_d_state, cfg.dt_rank
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = sh.constrain(x_in, "batch", "seq", "d_inner")
    if conv0 is not None:
        x_cat = jnp.concatenate([conv0.astype(x.dtype), x_in], axis=1)
        x_c = _causal_conv(x_cat, p["conv_w"].astype(x.dtype),
                           p["conv_b"].astype(x.dtype))[:, conv0.shape[1]:]
    else:
        x_c = _causal_conv(x_in, p["conv_w"].astype(x.dtype),
                           p["conv_b"].astype(x.dtype))
    x_c = jax.nn.silu(x_c)

    proj = jnp.einsum("bsi,ie->bse", x_c, p["x_proj"].astype(x.dtype))
    dt_r, bmat, cmat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, p["dt_proj"].astype(x.dtype)).astype(
            jnp.float32
        )
        + p["dt_bias"][None, None]
    )
    A = -jnp.exp(p["A_log"])                                    # [di, n]
    dA = jnp.exp(dt[..., None] * A[None, None])                 # [b,s,di,n]
    dBx = (
        dt[..., None]
        * bmat[:, :, None, :].astype(jnp.float32)
        * x_c[..., None].astype(jnp.float32)
    )
    h0 = jnp.zeros((b, di, n), jnp.float32) if h0 is None else h0
    y, h = _ssm_scan_chunked(dA, dBx, cmat.astype(jnp.float32), h0, chunk)
    y = y.astype(x.dtype) + x_c * p["D"].astype(x.dtype)[None, None]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))
    conv_state = (
        x_in[:, -(cfg.mamba_d_conv - 1):, :] if s >= cfg.mamba_d_conv - 1
        else x_in
    )
    return sh.constrain(out, "batch", "seq", None), (h, conv_state)


def mamba_decode(p, x: jax.Array, state, cfg: ModelConfig):
    """One-token step. x: [B, 1, d]; state = (h [B,di,N], conv [B,dc-1,di])."""
    h, conv_state = state
    di, n, r, dc = cfg.d_inner, cfg.mamba_d_state, cfg.dt_rank, cfg.mamba_d_conv
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    x_in, z = jnp.split(xz, 2, axis=-1)                 # [B,1,di]
    window = jnp.concatenate([conv_state.astype(x.dtype), x_in], axis=1)  # [B,dc,di]
    x_c = jnp.einsum("bti,ti->bi", window, p["conv_w"].astype(x.dtype)) + p[
        "conv_b"
    ].astype(x.dtype)
    x_c = jax.nn.silu(x_c)[:, None, :]                  # [B,1,di]

    proj = jnp.einsum("bsi,ie->bse", x_c, p["x_proj"].astype(x.dtype))
    dt_r, bmat, cmat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, p["dt_proj"].astype(x.dtype)).astype(
            jnp.float32
        )
        + p["dt_bias"][None, None]
    )[:, 0]                                             # [B,di]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A[None])               # [B,di,n]
    dBx = dt[..., None] * bmat[:, 0, None, :].astype(jnp.float32) * x_c[
        :, 0, :, None
    ].astype(jnp.float32)
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0].astype(jnp.float32)).astype(
        x.dtype
    )
    y = (y + x_c[:, 0] * p["D"].astype(x.dtype)[None])[:, None, :]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))
    new_conv = window[:, 1:, :]
    return out, (h, new_conv)
