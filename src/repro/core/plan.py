"""Shared probe planner: ONE query discipline for both runtimes (DESIGN.md
Sec. 3.1).

The paper's contribution is a single probing rule — the exact bucket
g_l(q) plus k 1-near buckets per table, split by the CAN geometry into
free local-bit probes and costed node-bit probes.  This module turns
`(queries, LshParams, variant, num_probes, ranked_probes)` into an
explicit `ProbePlan` pytree consumed by the `IndexRuntime` step kernels
(`repro.core.runtime` — on every topology, DESIGN.md Sec. 8) and the
benchmarks, so the discipline is implemented exactly once:

  * `ProbePlan.probes` — compact per-table probe codes (exact bucket
    first) for stacked gathers and benchmark sweeps;
  * `ProbePlan.probe_mask` — per-(query, table) bitmask of which of the k
    near buckets (bit flips) are probed; the runtime routes this mask
    with the query and applies it at the owner shard (local bits), the
    neighbor cache (node bits, CNB), and the XOR-neighbor forwards
    (node bits, NB) — on the 1-node topology every bit is local, so the
    mask application IS the reference probe set;
  * `ProbePlan.owner` / `ProbePlan.local_idx` — the CAN owner-shard /
    local-bucket split of each exact bucket.

Both views are derived from the same margin ranking / probe budget, so
runtimes on different topologies given the same `ProbeSpec` search the
same buckets — the equivalence the tests pin down.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import costmodel, hashing, multiprobe
from repro.core.can import CanTopology
from repro.core.hashing import LshParams


@dataclasses.dataclass(frozen=True)
class ProbeSpec:
    """Static description of the query discipline (what to probe)."""

    params: LshParams
    variant: str = "cnb"           # lsh | layered | nb | cnb
    num_probes: int | None = None  # None => all k 1-near buckets (the paper)
    ranked_probes: bool = False    # margin-ranked probe subset (beyond paper)

    def __post_init__(self):
        if self.variant not in costmodel.VARIANTS:
            raise ValueError(f"unknown variant {self.variant!r}")
        if self.num_probes is not None and self.num_probes < 0:
            raise ValueError(f"num_probes must be >= 0, got {self.num_probes}")

    @property
    def near_probes(self) -> int:
        """1-near buckets probed per table."""
        if self.variant in ("lsh", "layered"):
            return 0
        k = self.params.k
        return k if self.num_probes is None else min(self.num_probes, k)

    @property
    def probes_per_table(self) -> int:
        """Buckets searched per (query, table), exact bucket included."""
        return 1 + self.near_probes


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ProbePlan:
    """Per-query probe decisions (a pytree of device arrays).

    Shapes below use nq = leading query dims, L = tables, P = 1 + p
    probes per table (`ProbeSpec.probes_per_table`).
    """

    codes: jax.Array       # uint32 [nq, L]    exact sketch codes
    probes: jax.Array      # uint32 [nq, L, P] probe codes, exact first
    probe_mask: jax.Array  # uint32 [nq, L]    bit j set => 1-near bucket
    #                                          (flip of bit j) is probed
    owner: jax.Array       # int32  [nq, L]    owner shard of exact bucket
    local_idx: jax.Array   # int32  [nq, L]    bucket index within shard


def sketch(
    q: jax.Array, hyperplanes: jax.Array, *, use_kernels: bool = False
) -> jax.Array:
    """uint32 codes [..., L] — fused Pallas simhash kernel or the jnp oracle."""
    if use_kernels:
        from repro.kernels import ops

        return ops.simhash(q, hyperplanes)
    return hashing.sketch_codes(q, hyperplanes)


def make_plan(
    spec: ProbeSpec,
    q: jax.Array,                       # [..., d] unit queries
    hyperplanes: jax.Array,             # [L, k, d]
    topology: CanTopology | None = None,
    *,
    use_kernels: bool = False,
) -> ProbePlan:
    """Plan the probes for a batch of queries.

    jit-compatible (all branching is on static `spec` fields); the result
    is a pytree that can cross shard_map / jit boundaries.
    """
    k = spec.params.k
    topo = topology or CanTopology(k, 1 << k)  # paper: one bucket per node
    codes = sketch(q, hyperplanes, use_kernels=use_kernels)  # [..., L]

    p = spec.near_probes
    full_mask = jnp.uint32((1 << k) - 1)
    if p == 0:
        probes = codes[..., None].astype(jnp.uint32)
        mask = jnp.zeros_like(codes, dtype=jnp.uint32)
    elif p >= k:
        probes = multiprobe.probe_codes(codes, k)
        mask = jnp.full_like(codes, full_mask, dtype=jnp.uint32)
    elif spec.ranked_probes:
        margins = hashing.projection_margins(q, hyperplanes)  # [..., L, k]
        bits = jnp.argsort(margins, axis=-1)[..., :p].astype(jnp.uint32)
        flips = jnp.uint32(1) << bits                          # [..., L, p]
        near = codes[..., None].astype(jnp.uint32) ^ flips
        probes = jnp.concatenate(
            [codes[..., None].astype(jnp.uint32), near], axis=-1
        )
        # bits are distinct, so the sum of their powers of two == their OR
        mask = jnp.sum(flips, axis=-1, dtype=jnp.uint32)
    else:
        near = multiprobe.near_codes(codes, k)[..., :p]
        probes = jnp.concatenate(
            [codes[..., None].astype(jnp.uint32), near], axis=-1
        )
        mask = jnp.full_like(codes, jnp.uint32((1 << p) - 1), dtype=jnp.uint32)

    return ProbePlan(
        codes=codes,
        probes=probes,
        probe_mask=mask,
        owner=topo.node_of(codes).astype(jnp.int32),
        local_idx=topo.local_of(codes).astype(jnp.int32),
    )


# -----------------------------------------------------------------------------
# shard-side views (run inside shard_map at the owner shard)
# -----------------------------------------------------------------------------


def shard_local_probes(
    topo: CanTopology,
    local_idx: jax.Array,    # int32 [...]
    probe_mask: jax.Array,   # uint32/int32 [...] (routed with the query)
    *,
    include_near: bool,
) -> tuple[jax.Array, jax.Array]:
    """Local bucket indices to probe at the owner shard, with validity.

    Returns (buckets [..., P], valid [..., P]): exact bucket first, then
    one entry per local bit; entry 1 + j (the flip of local bit j) is
    valid iff bit j of `probe_mask` is set.  Local-bit probes are free —
    same device — which is why the budget mask, not the buffer layout,
    carries the num_probes discipline here.
    """
    exact = local_idx[..., None]
    always = jnp.ones_like(exact, dtype=bool)
    if not include_near or topo.local_bits == 0:
        return exact, always
    bits = jnp.arange(topo.local_bits, dtype=jnp.uint32)
    near = jnp.bitwise_xor(exact, (1 << bits).astype(local_idx.dtype))
    nvalid = ((probe_mask[..., None].astype(jnp.uint32) >> bits) & 1) > 0
    return (
        jnp.concatenate([exact, near], axis=-1),
        jnp.concatenate([always, nvalid], axis=-1),
    )


def node_bit_probe_valid(
    topo: CanTopology, probe_mask: jax.Array, bit: int
) -> jax.Array:
    """Is the near bucket reached by flipping node bit `bit` probed?

    Node-bit flips keep the local index and move to the XOR-neighbor
    shard; the distributed runtime covers them via the neighbor cache
    (CNB) or neighbor forwards (NB), gated per query by this mask bit.
    """
    shift = jnp.uint32(topo.local_bits + bit)
    return ((probe_mask.astype(jnp.uint32) >> shift) & 1) > 0
