"""Layered-LSH for cosine similarity (paper Sec. 3.3 + 5.2).

Layered-LSH (Haghani et al. EDBT'09; Bahmani et al. CIKM'12) maps *buckets*
to nodes with a second, bucket-level LSH so that near buckets land on the
same node.  For cosine-LSH sketches the natural second-level hash is
Hamming-LSH (Gionis et al.; Chierichetti & Kumar): pick k_node of the
k_inner sketch bits at random.

Sec. 5.2's observation, implemented and tested here: composing Hamming-LSH
over a cosine-LSH sketch just *selects k_node of the k_inner hyperplanes*,
i.e. it IS cosine-LSH with parameter k_node.  Hence Layered-LSH's result
set equals LSH(k_node, L)'s, and its costs match LSH's row of Table 1.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.hashing import LshParams


@dataclasses.dataclass(frozen=True)
class LayeredParams:
    inner: LshParams      # cosine-LSH mapping vectors -> buckets (k_inner bits)
    k_node: int           # Hamming-LSH output bits (buckets -> nodes)
    seed: int = 17

    def __post_init__(self):
        if self.k_node > self.inner.k:
            raise ValueError("k_node must be <= inner.k")


def make_bit_selection(params: LayeredParams) -> np.ndarray:
    """The Hamming-LSH: k_node bit positions per table, [L, k_node]."""
    rng = np.random.default_rng(params.seed)
    return np.stack(
        [
            rng.choice(params.inner.k, size=params.k_node, replace=False)
            for _ in range(params.inner.L)
        ]
    ).astype(np.int32)


def node_codes(
    sketch_codes: jax.Array, selection: np.ndarray
) -> jax.Array:
    """Map inner bucket codes [.., L] to node ids [.., L] by bit selection."""
    L, k_node = selection.shape
    sel = jnp.asarray(selection, jnp.uint32)
    out = jnp.zeros(sketch_codes.shape, jnp.uint32)
    for j in range(k_node):
        bit = (sketch_codes >> sel[:, j]) & jnp.uint32(1)
        out = out | (bit << jnp.uint32(j))
    return out


def equivalent_hyperplanes(
    params: LayeredParams, hyperplanes_inner: jax.Array, selection: np.ndarray
) -> jax.Array:
    """The cosine-LSH(k_node) family that Layered-LSH is equivalent to:
    row-select the chosen hyperplanes.  [L, k_node, d]."""
    gathered = []
    for l in range(params.inner.L):
        gathered.append(hyperplanes_inner[l, selection[l], :])
    return jnp.stack(gathered)


def layered_node_of(
    x: jax.Array, params: LayeredParams, hyperplanes_inner: jax.Array,
    selection: np.ndarray,
) -> jax.Array:
    """Node id of vector x under Layered-LSH: g_ham(g_cos(x)).  [.., L]."""
    inner_codes = hashing.sketch_codes(x, hyperplanes_inner)
    return node_codes(inner_codes, selection)
