"""Dynamic-OSN churn simulation (paper Sec. 2.2 + Sec. 4.1 soft state).

The paper's data model: users join/leave and update their interest
profiles; bucket nodes hold *soft state* that users re-announce
periodically, and entries older than a TTL are garbage-collected.  The
paper asserts this keeps the index fresh at negligible cost (update rate
<< query rate) but runs no churn experiment — this module does:

  epoch loop:
    1. a fraction `update_rate` of users mutate their interest vectors
       (their true buckets move);
    2. a fraction `churn_rate` of users leave and are replaced by fresh
       users (new ids, new vectors);
    3. every `refresh_every` epochs, all live users re-announce
       (insert_batch) and the store expires entries older than `ttl`;
    4. CNB-LSH recall@m is measured against the *current* ground truth.

Output: recall trajectory vs refresh period — the freshness/cost trade the
paper's design argues about, quantified.  Uses the same BucketStore /
engine code paths as production (streaming insert_batch + expire, not the
host bulk builder).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import hashing, metrics
from repro.core.corpus import DenseCorpus
from repro.core.engine import EngineConfig, LshEngine
from repro.core.hashing import LshParams
from repro.core.store import expire, insert_batch, make_store


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    num_users: int = 4000
    dim: int = 64
    k: int = 6
    L: int = 4
    capacity: int = 128
    epochs: int = 12
    update_rate: float = 0.05     # users mutating their vector per epoch
    churn_rate: float = 0.02      # users replaced per epoch
    refresh_every: int = 2        # re-announce period (epochs)
    ttl_epochs: int = 4           # GC horizon
    mutation: float = 0.5         # vector drift magnitude on update
    num_queries: int = 128
    m: int = 10
    seed: int = 0


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def run_churn(cfg: ChurnConfig) -> dict:
    """Returns dict with per-epoch recall and bookkeeping counters."""
    rng = np.random.default_rng(cfg.seed)
    params = LshParams(d=cfg.dim, k=cfg.k, L=cfg.L, seed=cfg.seed + 1)
    hp = hashing.make_hyperplanes(params)

    vecs = _unit(rng.standard_normal((cfg.num_users, cfg.dim))).astype(
        np.float32
    )
    alive = np.ones(cfg.num_users, bool)
    store = make_store(cfg.L, params.num_buckets, cfg.capacity)

    def announce(ids, epoch):
        codes = hashing.sketch_codes(jnp.asarray(vecs[ids]), hp)
        return insert_batch(
            store, jnp.asarray(ids, jnp.int32), codes, jnp.int32(epoch)
        )

    # initial announce
    store = announce(np.arange(cfg.num_users), 0)

    recalls, staleness = [], []
    for epoch in range(1, cfg.epochs + 1):
        # 1. profile updates (vector drift)
        n_upd = int(cfg.update_rate * cfg.num_users)
        upd = rng.choice(cfg.num_users, n_upd, replace=False)
        vecs[upd] = _unit(
            vecs[upd] + cfg.mutation * rng.standard_normal((n_upd, cfg.dim))
        ).astype(np.float32)
        # 2. churn: replace users (id reused; semantics = leave + join)
        n_churn = int(cfg.churn_rate * cfg.num_users)
        rep = rng.choice(cfg.num_users, n_churn, replace=False)
        vecs[rep] = _unit(
            rng.standard_normal((n_churn, cfg.dim))
        ).astype(np.float32)

        # 3. periodic refresh + GC (the paper's soft-state maintenance)
        if epoch % cfg.refresh_every == 0:
            store = announce(np.arange(cfg.num_users)[alive], epoch)
            store = expire(store, jnp.int32(epoch), ttl=cfg.ttl_epochs)

        # 4. measure recall against CURRENT ground truth
        corpus = DenseCorpus(jnp.asarray(vecs))
        engine = LshEngine(
            params, hp, store, corpus, None, EngineConfig(variant="cnb")
        )
        qidx = rng.choice(cfg.num_users, cfg.num_queries, replace=False)
        q = vecs[qidx]
        sims = q @ vecs.T
        sims[np.arange(cfg.num_queries), qidx] = -np.inf
        ideal = np.argsort(-sims, axis=1)[:, : cfg.m].astype(np.int32)
        res = engine.search(jnp.asarray(q), m=cfg.m, exclude=qidx)
        recalls.append(metrics.recall_at_m(res.ids, ideal))
        staleness.append(epoch % cfg.refresh_every)

    return dict(
        recalls=np.asarray(recalls),
        staleness=np.asarray(staleness),
        final_recall=float(recalls[-1]),
        mean_recall=float(np.mean(recalls)),
        refresh_every=cfg.refresh_every,
    )
