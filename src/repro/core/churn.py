"""Dynamic-OSN churn simulation (paper Sec. 2.2 + Sec. 4.1 soft state).

The paper's data model: users join/leave and update their interest
profiles; bucket nodes hold *soft state* that users re-announce
periodically, and entries older than a TTL are garbage-collected.  The
paper asserts this keeps the index fresh at negligible cost (update rate
<< query rate) but runs no churn experiment — this module does:

  epoch loop:
    1. a fraction `update_rate` of users mutate their interest vectors
       (their true buckets move);
    2. a fraction `churn_rate` of users leave and are replaced by fresh
       users (new ids, new vectors);
    3. every `refresh_every` epochs, all live users re-announce and the
       store expires entries older than `ttl`;
    4. CNB-LSH recall@m is measured against the *current* ground truth.

Output: recall trajectory vs refresh period — the freshness/cost trade the
paper's design argues about, quantified.

ONE driver (`run_churn_runtime`) over ONE trajectory generator and ONE
execution layer (`repro.core.runtime.IndexRuntime`): the scenario loop is
topology-blind by construction — announces go through the runtime's
insert step, GC through its expire step, payload freshness through its
payload-sync step, the CNB neighbor cache (when the topology has node
bits) through its refresh step, and queries through its search step.

  * `run_churn(cfg)`             — the 1-node topology (the reference);
  * `run_churn_distributed(cfg)` — the same loop on a >= 2-shard host
    mesh (the paper's actual P2P scenario on the production code path).
    The two trajectories share the RNG stream and match EXACTLY
    (tests/test_churn.py asserts <= 0.02; in practice maxdiff 0.0).

Scoring uses the ANNOUNCED snapshot of each vector, not the live one:
the paper's LocalSimSearch runs at the bucket node against the copies
users last announced (Alg. 1), so between refreshes both the buckets AND
the scores are stale — recall is measured against the current ground
truth, which is exactly the freshness cost being quantified.  The
payload-sync step keeps re-announce semantics id-keyed (an entry left in
a mover's OLD bucket scores with its LATEST announced vector).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import costmodel, hashing, metrics
from repro.core.hashing import LshParams
from repro.obs.flight import QueryRecord
from repro.core.runtime import IndexRuntime, RuntimeConfig, kill_node, reshard
from repro.core.store import make_store


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    num_users: int = 4000
    dim: int = 64
    k: int = 6
    L: int = 4
    capacity: int = 128
    epochs: int = 12
    update_rate: float = 0.05     # users mutating their vector per epoch
    churn_rate: float = 0.02      # users replaced per epoch
    refresh_every: int = 2        # re-announce period (epochs)
    ttl_epochs: int = 4           # GC horizon
    mutation: float = 0.5         # vector drift magnitude on update
    num_queries: int = 128
    m: int = 10
    seed: int = 0


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def _lsh_setup(cfg: ChurnConfig):
    params = LshParams(d=cfg.dim, k=cfg.k, L=cfg.L, seed=cfg.seed + 1)
    return params, hashing.make_hyperplanes(params)


def _trajectory(cfg: ChurnConfig):
    """Yield the per-epoch world state — one RNG stream shared by every
    driver, so 1-node and distributed runs see identical vectors, churn
    events, and query draws.

    Yields (epoch, vecs, do_refresh, qidx, ideal); epoch 0 is the initial
    announce (qidx/ideal None).
    """
    rng = np.random.default_rng(cfg.seed)
    vecs = _unit(rng.standard_normal((cfg.num_users, cfg.dim))).astype(
        np.float32
    )
    yield 0, vecs, True, None, None

    for epoch in range(1, cfg.epochs + 1):
        # 1. profile updates (vector drift)
        n_upd = int(cfg.update_rate * cfg.num_users)
        upd = rng.choice(cfg.num_users, n_upd, replace=False)
        vecs[upd] = _unit(
            vecs[upd] + cfg.mutation * rng.standard_normal((n_upd, cfg.dim))
        ).astype(np.float32)
        # 2. churn: replace users (id reused; semantics = leave + join)
        n_churn = int(cfg.churn_rate * cfg.num_users)
        rep = rng.choice(cfg.num_users, n_churn, replace=False)
        vecs[rep] = _unit(
            rng.standard_normal((n_churn, cfg.dim))
        ).astype(np.float32)

        # 4. current ground truth for this epoch's query draw
        qidx = rng.choice(cfg.num_users, cfg.num_queries, replace=False)
        sims = vecs[qidx] @ vecs.T
        sims[np.arange(cfg.num_queries), qidx] = -np.inf
        ideal = np.argsort(-sims, axis=1)[:, : cfg.m].astype(np.int32)

        yield epoch, vecs, epoch % cfg.refresh_every == 0, qidx, ideal


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    if x.shape[0] == n:
        return x
    pad = np.full((n - x.shape[0],) + x.shape[1:], fill, x.dtype)
    return np.concatenate([x, pad], axis=0)


def make_churn_runtime(
    cfg: ChurnConfig,
    n_shards: int = 1,
    mesh=None,
    cap_factor: float | None = None,
    replication: int = 1,
    read_mode: str = "first",
) -> IndexRuntime:
    """The runtime a churn trajectory executes on.

    `m` carries one result of wire headroom: the routed search path has no
    exclusion support (the id is not secret, paper Sec. 6), so the driver
    filters the query's own id host-side — the same convention on every
    topology, which is what keeps the trajectories comparable.
    cap_factor = n_shards guarantees zero drops (worst case routes every
    probe of a device to one owner shard); callers may lower it to trade
    buffer bytes for reported drops.
    """
    params, _ = _lsh_setup(cfg)
    rcfg = RuntimeConfig(
        params=params, n_nodes=n_shards, variant="cnb",
        m=cfg.m + 1,
        routing="alltoall",
        cap_factor=float(n_shards if cap_factor is None else cap_factor),
        replication=replication,
        read_mode=read_mode,
    )
    return IndexRuntime(rcfg, mesh=mesh)


def _expand_schedule(schedule, epochs: int) -> list[int]:
    """Per-epoch node counts (length epochs + 1, epoch 0 included): a
    short schedule holds its last value; a long one is clipped.  Every
    entry must be a power of two >= 1 (the `can.py` join/leave rounds)."""
    sched = [int(n) for n in schedule]
    if not sched:
        raise ValueError("empty membership schedule")
    for n in sched:
        if n < 1 or (n & (n - 1)):
            raise ValueError(f"schedule entries must be powers of two, "
                             f"got {n}")
    sched = (sched + [sched[-1]] * (epochs + 1))[: epochs + 1]
    return sched


def _zone_mesh(n: int):
    from repro.launch.mesh import make_zone_mesh

    return make_zone_mesh(n)


def _expand_kills(kills, epochs: int, n_nodes: int) -> dict[int, list[int]]:
    """Normalize a failure schedule ((epoch, node), ...) to epoch -> nodes.
    Kills fire at epoch START, before the epoch's announces and queries."""
    by_epoch: dict[int, list[int]] = {}
    for epoch, node in kills:
        epoch, node = int(epoch), int(node)
        if not (0 <= epoch <= epochs):
            raise ValueError(f"kill epoch {epoch} outside [0, {epochs}]")
        if not (0 <= node < n_nodes):
            raise ValueError(f"kill node {node} outside [0, {n_nodes})")
        by_epoch.setdefault(epoch, []).append(node)
    return by_epoch


def run_churn_runtime(
    cfg: ChurnConfig,
    rt: IndexRuntime,
    *,
    schedule=None,
    mesh_for=None,
    kills=None,
    obs=None,
) -> dict:
    """Drive the churn trajectory on ANY topology (the one driver).

    Announce epochs: runtime insert + expire + payload sync (+ CNB cache
    refresh when the topology has node bits — between refreshes that
    cache is STALE, the freshness/cost trade of the paper's periodic
    bucket exchange).  Read epochs: runtime search + host-side
    self-exclusion, recall against the current ground truth.

    With `schedule` (per-epoch node counts, see `_expand_schedule`) the
    topology itself churns: whenever the scheduled count differs from the
    current runtime's, a membership round fires FIRST (`runtime.reshard`
    — zone split/merge + bucket-state handoff + NB-cache rewarm), then
    the epoch's content churn and queries run on the new topology.
    Handoff and refresh bytes are charged per epoch (never silently);
    the world trajectory shares the static run's RNG stream, so recalls
    are directly comparable (in practice identical — the global bucket
    array is invariant under a round).  `mesh_for(n)` supplies the mesh
    for n-node topologies (default: a host-device-prefix zone mesh);
    runtimes are cached per node count so revisited topologies reuse
    their compiled steps.

    With `kills` (a failure schedule, ((epoch, node), ...)) nodes suffer
    FAIL-STOP losses with NO handoff (`runtime.kill_node` at epoch start,
    contrast the graceful `schedule` path — the two are mutually
    exclusive): the zone is gone, the node's liveness bit drops to 0, and
    queries read through the R-way replicas until the next announce epoch
    revives the node and repopulates its zone (recovery bytes charged per
    revival, `costmodel.estimate_recovery_bytes`).  Requires
    `rt.cfg.replication > 1`; each announce's R-1-way fan-out is charged
    via `costmodel.estimate_replication_bytes`, never silently.

    With `obs` (an `repro.obs.Observability`) the run feeds the flight
    recorder and metrics registry (DESIGN.md Sec. 12): one ``epoch``
    record per epoch whose stats and byte charges sum EXACTLY to the
    aggregate arrays returned here (the smoke drivers assert it), an
    anomaly dump on every `kill_node` and reshard, and the drop/byte
    totals as registry counters.
    """
    from repro.core import distributed as dist_mod

    params, hp = _lsh_setup(cfg)
    sched = (
        None if schedule is None
        else _expand_schedule(schedule, cfg.epochs)
    )
    if sched is not None and sched[0] != rt.cfg.n_nodes:
        raise ValueError(
            f"schedule[0]={sched[0]} != initial runtime n_nodes="
            f"{rt.cfg.n_nodes}"
        )
    kills_by_epoch = _expand_kills(kills or (), cfg.epochs, rt.cfg.n_nodes)
    if kills_by_epoch:
        if sched is not None:
            raise ValueError(
                "kills and schedule are mutually exclusive (a membership "
                "round re-keys zones; a fail-stop loss must not)"
            )
        if rt.cfg.replication < 2:
            raise ValueError(
                "a failure schedule needs replication >= 2 (a killed zone "
                "with no replicas is simply gone until the next announce)"
            )
    replication = rt.cfg.replication
    if sched is not None and replication > 1:
        raise ValueError(
            "membership schedules do not compose with replication > 1 "
            "(a zone split/merge re-keys the replica ring)"
        )
    live = np.ones(rt.cfg.n_nodes, np.int32)
    reps = None
    runtimes = {rt.cfg.n_nodes: rt}

    store = rt.shard_store(
        make_store(cfg.L, params.num_buckets, cfg.capacity,
                   payload_dim=cfg.dim)
    )

    def _charge_refresh() -> int:
        if rt.cfg.node_bits == 0:
            return 0
        return dist_mod.estimate_refresh_bytes(rt.cfg, cfg.capacity, cfg.dim)

    cache = None
    last_refresh = 0
    recalls, staleness, dropped = [], [], []
    handoff_b, refresh_b, nodes_traj, events = [], [], [], []
    repl_b, recov_b, live_traj, recoveries = [], [], [], []
    total_handoff = total_refresh = total_repl = total_recov = 0
    for epoch, vecs, do_refresh, qidx, ideal in _trajectory(cfg):
        ep_handoff = ep_refresh = ep_repl = ep_recov = 0
        for node in kills_by_epoch.get(epoch, ()):
            # fail-stop: the zone AND the node's held replica slices are
            # gone; replicas OF its zone on ring successors survive
            if not live[node]:
                raise ValueError(f"node {node} killed while already dead")
            store, reps = kill_node(rt, store, reps, node)
            live[node] = 0
            if obs is not None:
                obs.flight.note_anomaly(
                    "kill_node", node=int(node), epoch=int(epoch),
                    live_nodes=int(live.sum()),
                )
        if sched is not None and sched[epoch] != rt.cfg.n_nodes:
            # -- membership round: join/leave to the scheduled node count
            n_new = sched[epoch]
            tgt = runtimes.get(n_new)
            if tgt is not None:  # revisited topology: reuse compiled steps
                rt, store, ev = reshard(rt, store, runtime=tgt)
            else:
                mesh = (mesh_for or _zone_mesh)(n_new) if n_new > 1 else None
                rt, store, ev = reshard(
                    rt, store, n_new, mesh=mesh, cap_factor=float(n_new),
                )
            runtimes[n_new] = rt
            events.append(ev)
            if obs is not None:
                obs.flight.note_anomaly(
                    "reshard", epoch=int(epoch), old_n=int(ev.old_n),
                    new_n=int(ev.new_n), handoff_bytes=int(ev.handoff_bytes),
                )
            ep_handoff += ev.handoff_bytes
            total_handoff += ev.handoff_bytes
            # the new owners' NB caches are cold — rewarm immediately
            # (charged as refresh bytes; the store content is unchanged,
            # so this equals the cache of the last announce).  When the
            # round lands ON a refresh epoch the announce below rebuilds
            # the cache anyway: skip the duplicate rewarm and its charge.
            cache = None
            if not do_refresh:
                cache = rt.refresh_cache(store)
                b = _charge_refresh()
                ep_refresh += b
                total_refresh += b
        n_dev = rt.n_devices
        nu_pad = -(-cfg.num_users // n_dev) * n_dev
        nq_pad = -(-cfg.num_queries // n_dev) * n_dev
        if do_refresh:
            # a re-announce revives dead nodes first: the owner (or its
            # replacement) rejoins and this very announce repopulates its
            # zone — charged as one full-zone recovery per revival
            for node in np.flatnonzero(live == 0):
                b = costmodel.estimate_recovery_bytes(
                    cfg.L, rt.topology.buckets_per_node, cfg.capacity,
                    cfg.dim,
                )
                recoveries.append((epoch, int(node), b))
                ep_recov += b
                total_recov += b
                live[node] = 1
            vpad = _pad_to(vecs, nu_pad, 0.0)
            all_ids = _pad_to(
                np.arange(cfg.num_users, dtype=np.int32), nu_pad, -1)
            store = rt.insert(hp, store, vpad, all_ids, epoch)
            if epoch > 0:
                store = rt.expire(store, epoch, ttl=cfg.ttl_epochs)
            # entries left in a mover's OLD buckets must score with its
            # latest announced vector (the id-keyed reference semantics)
            store = rt.payload_sync(store, vpad)
            cache = rt.refresh_cache(store)
            b = _charge_refresh()
            ep_refresh += b
            total_refresh += b
            if replication > 1:
                # the announce fans out to the R-1 replica owners — the
                # replication of the insert/payload-sync writes
                reps = rt.replicate_store(store)
                b = costmodel.estimate_replication_bytes(
                    cfg.L, cfg.num_users, cfg.dim, replication)
                ep_repl += b
                total_repl += b
            last_refresh = epoch
        if epoch == 0:
            if obs is not None:
                # the initial announce: byte charges but no queries —
                # recorded so the ring's records sum to the run TOTALS
                # (per-read-epoch arrays exclude epoch 0 by convention)
                obs.flight.record(QueryRecord(
                    qid=0, kind="epoch",
                    extra=dict(
                        replication_bytes=ep_repl, recovery_bytes=ep_recov,
                        handoff_bytes=ep_handoff, refresh_bytes=ep_refresh,
                        live_nodes=int(live.sum()),
                    ),
                ))
            continue

        kw = {}
        if replication > 1:
            kw = dict(replicas=reps, live=live.copy())
        ids, _, drop = rt.search(
            hp, store, _pad_to(vecs[qidx], nq_pad, 0.0), cache=cache, **kw
        )
        ids = np.asarray(ids)[: cfg.num_queries]
        # host-side self-exclusion: drop the query's own id, keep top-m
        keep = ids != qidx[:, None]
        ids_m = np.full((cfg.num_queries, cfg.m), -1, np.int32)
        for i in range(cfg.num_queries):
            ids_m[i] = ids[i][keep[i]][: cfg.m]
        recalls.append(metrics.recall_at_m(ids_m, ideal))
        # epochs since the last announce (== epoch % refresh_every when
        # refreshes land on schedule) — one convention for all topologies
        staleness.append(epoch - last_refresh)
        dropped.append(int(drop))
        handoff_b.append(ep_handoff)
        refresh_b.append(ep_refresh)
        repl_b.append(ep_repl)
        recov_b.append(ep_recov)
        nodes_traj.append(rt.cfg.n_nodes)
        live_traj.append(int(live.sum()))
        if obs is not None:
            # one EXACT record per read epoch: the StepStats of the epoch's
            # search dispatch plus the epoch's byte charges — summing the
            # ring's ``epoch`` records reproduces the aggregate arrays
            # above bit-for-bit (asserted by the smoke drivers)
            hs = (drop.host() if hasattr(drop, "host")
                  else dict(dropped_probes=int(drop)))
            obs.flight.record(QueryRecord(
                qid=int(epoch), kind="epoch", batch_size=cfg.num_queries,
                **hs,
                extra=dict(
                    replication_bytes=ep_repl, recovery_bytes=ep_recov,
                    handoff_bytes=ep_handoff, refresh_bytes=ep_refresh,
                    recall=float(recalls[-1]), staleness=int(staleness[-1]),
                    live_nodes=int(live.sum()), n_nodes=rt.cfg.n_nodes,
                ),
            ))

    if obs is not None:
        reg = obs.registry
        reg.counter(
            "churn_dropped_probes_total",
            "router-overflow probe drops across all read epochs",
        ).inc(int(np.sum(dropped)))
        for name, total in (
            ("churn_replication_bytes_total", total_repl),
            ("churn_recovery_bytes_total", total_recov),
            ("churn_handoff_bytes_total", total_handoff),
            ("churn_refresh_bytes_total", total_refresh),
        ):
            reg.counter(name).inc(int(total))
        reg.gauge("churn_recall").set(float(recalls[-1]), window="last")
        reg.gauge("churn_recall").set(float(np.mean(recalls)), window="mean")
        reg.gauge("churn_live_nodes").set(int(live.sum()))

    stale_arr = np.asarray(staleness)
    return dict(
        recalls=np.asarray(recalls),
        # one measurement, two names: announce and cache rebuild share the
        # refresh schedule, so store staleness == cache staleness here
        staleness=stale_arr,
        cache_staleness=stale_arr,
        dropped_probes=np.asarray(dropped),
        final_recall=float(recalls[-1]),
        mean_recall=float(np.mean(recalls)),
        refresh_every=cfg.refresh_every,
        # membership accounting (all-zero / constant on a static topology):
        # per-read-epoch byte charges plus run totals, which additionally
        # include the epoch-0 initial announce's cache warm-up
        n_nodes=np.asarray(nodes_traj),
        handoff_bytes=np.asarray(handoff_b, dtype=np.int64),
        refresh_bytes=np.asarray(refresh_b, dtype=np.int64),
        total_handoff_bytes=int(total_handoff),
        total_refresh_bytes=int(total_refresh),
        reshard_events=events,
        # failure accounting (all-zero / constant with no kills): announce
        # fan-out to replicas, zone repopulation on revival, and the live
        # node count each read epoch.  Totals include the epoch-0 announce.
        replication=replication,
        live_nodes=np.asarray(live_traj),
        replication_bytes=np.asarray(repl_b, dtype=np.int64),
        recovery_bytes=np.asarray(recov_b, dtype=np.int64),
        total_replication_bytes=int(total_repl),
        total_recovery_bytes=int(total_recov),
        recoveries=recoveries,
        # store mutation counter after the run — the serving layer's cache
        # invalidation signal (every insert/expire/sync bumped it)
        store_generation=int(store.generation),
    )


def run_churn(cfg: ChurnConfig) -> dict:
    """The reference trajectory: the same driver on the 1-node topology
    (identity router, no collectives)."""
    return run_churn_runtime(cfg, make_churn_runtime(cfg))


def run_churn_distributed(
    cfg: ChurnConfig,
    n_shards: int = 2,
    mesh=None,
    cap_factor: float | None = None,
    obs=None,
) -> dict:
    """The same trajectory on the sharded mesh topology.

    Requires a host mesh whose `model` axis has n_shards devices — in a
    plain CPU process set XLA_FLAGS=--xla_force_host_platform_device_count
    before importing jax (see tests/test_churn.py / bench_churn.py).
    """
    if mesh is None:
        from repro.launch.mesh import make_host_mesh, require_host_devices

        require_host_devices(n_shards)
        mesh = make_host_mesh(data=1, model=n_shards)
    return run_churn_runtime(
        cfg, make_churn_runtime(cfg, n_shards, mesh, cap_factor), obs=obs
    )


@dataclasses.dataclass(frozen=True)
class NodeChurnConfig:
    """The elastic-membership scenario: content churn + queries while the
    node set itself joins and leaves on a schedule.

    `schedule[e]` is the node count during epoch e (0 = the initial
    announce epoch); a short schedule holds its last value.  Entries must
    be powers of two — each change is one `can.py` zone split/merge
    round.  The world trajectory (vectors, churn events, query draws) is
    the SAME RNG stream as the static drivers, so `run_node_churn`
    recalls are directly comparable to `run_churn` on the same
    `ChurnConfig`."""

    churn: ChurnConfig = ChurnConfig()
    schedule: tuple[int, ...] = (1, 2, 4, 2, 1)


def run_node_churn(cfg: NodeChurnConfig, mesh_for=None, obs=None) -> dict:
    """Interleave node join/leave epochs with content churn and queries.

    The topology axis becomes a runtime variable: membership rounds fire
    at the scheduled epochs (`runtime.reshard` — bucket-state handoff to
    the new zone owners, NB-cache rewarm), with handoff bytes charged to
    the cost model alongside the refresh bytes (`handoff_bytes` /
    `refresh_bytes` per epoch in the returned dict, plus run totals).
    Node counts > 1 need that many host devices (see
    `launch.mesh.make_zone_mesh`); pass `mesh_for(n)` to supply meshes
    yourself (e.g. device subsets of a production mesh).
    """
    sched = _expand_schedule(cfg.schedule, cfg.churn.epochs)
    n0 = sched[0]
    mesh = None if n0 == 1 else (mesh_for or _zone_mesh)(n0)
    rt = make_churn_runtime(cfg.churn, n0, mesh=mesh)
    return run_churn_runtime(cfg.churn, rt, schedule=sched,
                             mesh_for=mesh_for, obs=obs)


@dataclasses.dataclass(frozen=True)
class FailureChurnConfig:
    """The availability scenario: content churn + queries while nodes
    suffer FAIL-STOP losses (no handoff) and reads survive on R-way
    replicas (DESIGN.md Sec. 10).

    `kills` is ((epoch, node), ...): each node vanishes at that epoch's
    start and revives at the next announce epoch, which repopulates its
    zone.  The world trajectory is the same RNG stream as every other
    driver on the same `ChurnConfig`, so the no-failure reference run is
    directly comparable epoch by epoch."""

    churn: ChurnConfig = ChurnConfig()
    n_nodes: int = 4
    replication: int = 2
    read_mode: str = "first"        # first | quorum
    kills: tuple[tuple[int, int], ...] = ((3, 1),)


def run_failure_churn(cfg: FailureChurnConfig, mesh_for=None,
                      obs=None) -> dict:
    """Measure recall degradation and recovery across fail-stop kills.

    Runs the SAME runtime (same mesh, same compiled steps, same R and
    read mode) twice over the shared trajectory: once with the failure
    schedule, once without (the reference — at full liveness the replica
    redirect is the identity, so the reference equals the R=1 run).
    Returns the failure run's dict plus:

      reference_recalls   per-epoch recalls of the no-failure run
      recall_gap          reference - failure, per read epoch
      degraded            bool mask: epochs serving with a dead node
      degraded_gap        max gap over degraded epochs (0.0 if none)
      recovered_gap       max gap over post-recovery epochs (parity check)
      recovery_epochs     worst-case epochs from a kill to its revival
    """
    mesh = (mesh_for or _zone_mesh)(cfg.n_nodes)
    rt = make_churn_runtime(
        cfg.churn, cfg.n_nodes, mesh=mesh,
        replication=cfg.replication, read_mode=cfg.read_mode,
    )
    # only the failure run feeds obs: the reference would double-count
    # every byte charge and drop in the flight totals
    failure = run_churn_runtime(cfg.churn, rt, kills=cfg.kills, obs=obs)
    reference = run_churn_runtime(cfg.churn, rt)

    gap = reference["recalls"] - failure["recalls"]
    degraded = failure["live_nodes"] < cfg.n_nodes
    recovered = ~degraded
    # only epochs AFTER the first kill can attest recovery-to-parity
    if degraded.any():
        recovered &= np.arange(degraded.size) > int(np.argmax(degraded))
    recovery_epochs = 0
    for kill_epoch, _node in cfg.kills:
        revived = [e for e, _n, _b in failure["recoveries"]
                   if e > kill_epoch]
        if revived:
            recovery_epochs = max(recovery_epochs,
                                  min(revived) - int(kill_epoch))
    failure.update(
        reference_recalls=reference["recalls"],
        recall_gap=gap,
        degraded=degraded,
        degraded_gap=float(gap[degraded].max()) if degraded.any() else 0.0,
        recovered_gap=float(gap[recovered].max()) if recovered.any() else 0.0,
        recovery_epochs=int(recovery_epochs),
        kills=tuple(cfg.kills),
    )
    return failure
