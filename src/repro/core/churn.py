"""Dynamic-OSN churn simulation (paper Sec. 2.2 + Sec. 4.1 soft state).

The paper's data model: users join/leave and update their interest
profiles; bucket nodes hold *soft state* that users re-announce
periodically, and entries older than a TTL are garbage-collected.  The
paper asserts this keeps the index fresh at negligible cost (update rate
<< query rate) but runs no churn experiment — this module does:

  epoch loop:
    1. a fraction `update_rate` of users mutate their interest vectors
       (their true buckets move);
    2. a fraction `churn_rate` of users leave and are replaced by fresh
       users (new ids, new vectors);
    3. every `refresh_every` epochs, all live users re-announce
       (insert_batch) and the store expires entries older than `ttl`;
    4. CNB-LSH recall@m is measured against the *current* ground truth.

Output: recall trajectory vs refresh period — the freshness/cost trade the
paper's design argues about, quantified.  Uses the same BucketStore /
engine code paths as production (streaming insert_batch + expire, not the
host bulk builder).

Two drivers over ONE trajectory generator (same RNG stream, so their
recall curves are directly comparable):

  * `run_churn`             — single-host `LshEngine` (the reference);
  * `run_churn_distributed` — the shard_map runtime on a >= 2-shard host
    mesh, driving `make_insert_step` + `expire` + `make_refresh_cache`
    (the paper's actual P2P scenario on the production code path).  Also
    reports per-epoch CNB cache staleness and routed-probe drop counts.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import hashing, metrics
from repro.core.corpus import DenseCorpus
from repro.core.engine import EngineConfig, LshEngine
from repro.core.hashing import LshParams
from repro.core.store import expire, insert_batch, make_store


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    num_users: int = 4000
    dim: int = 64
    k: int = 6
    L: int = 4
    capacity: int = 128
    epochs: int = 12
    update_rate: float = 0.05     # users mutating their vector per epoch
    churn_rate: float = 0.02      # users replaced per epoch
    refresh_every: int = 2        # re-announce period (epochs)
    ttl_epochs: int = 4           # GC horizon
    mutation: float = 0.5         # vector drift magnitude on update
    num_queries: int = 128
    m: int = 10
    seed: int = 0


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def _lsh_setup(cfg: ChurnConfig):
    params = LshParams(d=cfg.dim, k=cfg.k, L=cfg.L, seed=cfg.seed + 1)
    return params, hashing.make_hyperplanes(params)


def _trajectory(cfg: ChurnConfig):
    """Yield the per-epoch world state — one RNG stream shared by both
    drivers, so single-host and distributed runs see identical vectors,
    churn events, and query draws.

    Yields (epoch, vecs, do_refresh, qidx, ideal); epoch 0 is the initial
    announce (qidx/ideal None).
    """
    rng = np.random.default_rng(cfg.seed)
    vecs = _unit(rng.standard_normal((cfg.num_users, cfg.dim))).astype(
        np.float32
    )
    yield 0, vecs, True, None, None

    for epoch in range(1, cfg.epochs + 1):
        # 1. profile updates (vector drift)
        n_upd = int(cfg.update_rate * cfg.num_users)
        upd = rng.choice(cfg.num_users, n_upd, replace=False)
        vecs[upd] = _unit(
            vecs[upd] + cfg.mutation * rng.standard_normal((n_upd, cfg.dim))
        ).astype(np.float32)
        # 2. churn: replace users (id reused; semantics = leave + join)
        n_churn = int(cfg.churn_rate * cfg.num_users)
        rep = rng.choice(cfg.num_users, n_churn, replace=False)
        vecs[rep] = _unit(
            rng.standard_normal((n_churn, cfg.dim))
        ).astype(np.float32)

        # 4. current ground truth for this epoch's query draw
        qidx = rng.choice(cfg.num_users, cfg.num_queries, replace=False)
        sims = vecs[qidx] @ vecs.T
        sims[np.arange(cfg.num_queries), qidx] = -np.inf
        ideal = np.argsort(-sims, axis=1)[:, : cfg.m].astype(np.int32)

        yield epoch, vecs, epoch % cfg.refresh_every == 0, qidx, ideal


def run_churn(cfg: ChurnConfig) -> dict:
    """Single-host reference trajectory: per-epoch recall and bookkeeping.

    Scoring uses the ANNOUNCED snapshot of each vector, not the live one:
    the paper's LocalSimSearch runs at the bucket node against the copies
    users last announced (Alg. 1), so between refreshes both the buckets
    AND the scores are stale — recall is measured against the current
    ground truth, which is exactly the freshness cost being quantified.
    """
    params, hp = _lsh_setup(cfg)
    store = make_store(cfg.L, params.num_buckets, cfg.capacity)
    announced = None

    recalls, staleness = [], []
    for epoch, vecs, do_refresh, qidx, ideal in _trajectory(cfg):
        # 3. periodic refresh + GC (the paper's soft-state maintenance)
        if do_refresh:
            announced = vecs.copy()
            codes = hashing.sketch_codes(jnp.asarray(announced), hp)
            store = insert_batch(
                store,
                jnp.arange(cfg.num_users, dtype=jnp.int32),
                codes,
                jnp.int32(epoch),
            )
            if epoch > 0:
                store = expire(store, jnp.int32(epoch), ttl=cfg.ttl_epochs)
        if epoch == 0:
            continue

        corpus = DenseCorpus(jnp.asarray(announced))
        engine = LshEngine(
            params, hp, store, corpus, None, EngineConfig(variant="cnb")
        )
        res = engine.search(jnp.asarray(vecs[qidx]), m=cfg.m, exclude=qidx)
        recalls.append(metrics.recall_at_m(res.ids, ideal))
        staleness.append(epoch % cfg.refresh_every)

    return dict(
        recalls=np.asarray(recalls),
        staleness=np.asarray(staleness),
        final_recall=float(recalls[-1]),
        mean_recall=float(np.mean(recalls)),
        refresh_every=cfg.refresh_every,
        # store mutation counter after the run — the serving layer's cache
        # invalidation signal (every insert/expire bumped it)
        store_generation=int(store.generation),
    )


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    if x.shape[0] == n:
        return x
    pad = np.full((n - x.shape[0],) + x.shape[1:], fill, x.dtype)
    return np.concatenate([x, pad], axis=0)


def run_churn_distributed(
    cfg: ChurnConfig,
    n_shards: int = 2,
    mesh=None,
    cap_factor: float | None = None,
) -> dict:
    """The same churn trajectory driven through the shard_map runtime.

    Buckets shard over `model`; announces go through `make_insert_step`
    (+ `expire`), queries through the all_to_all-routed search step, and
    the CNB neighbor cache is rebuilt by `make_refresh_cache` at each
    announce — so between refreshes the cache is STALE, which is the
    freshness/cost trade the paper's periodic bucket exchange makes.
    Returns the single-host dict plus `cache_staleness` (epochs since the
    cache was rebuilt) and `dropped_probes` (router overflow, per epoch).

    Requires a host mesh whose `model` axis has n_shards devices — in a
    plain CPU process set XLA_FLAGS=--xla_force_host_platform_device_count
    before importing jax (see tests/test_churn.py / bench_churn.py).
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import distributed as dist
    from repro.launch.mesh import make_host_mesh, require_host_devices

    if mesh is None:
        require_host_devices(n_shards)
        mesh = make_host_mesh(data=1, model=n_shards)
    params, hp = _lsh_setup(cfg)
    # cap_factor = n_shards guarantees zero drops (worst case routes every
    # probe of a device to one owner shard); callers may lower it to trade
    # buffer bytes for reported drops.
    dcfg = dist.DistConfig(
        params=params, n_shards=n_shards, variant="cnb",
        m=cfg.m + 1,  # +1: self-match is filtered on the host (no exclude
        #               support on the wire — the id is not secret, Sec. 6)
        routing="alltoall",
        cap_factor=float(n_shards if cap_factor is None else cap_factor),
    )
    n_dev = int(np.prod([mesh.shape[a] for a in ("data", "model")]))
    nu_pad = -(-cfg.num_users // n_dev) * n_dev
    nq_pad = -(-cfg.num_queries // n_dev) * n_dev

    store = dist.shard_store(
        mesh, make_store(cfg.L, params.num_buckets, cfg.capacity,
                         payload_dim=cfg.dim)
    )
    insert = dist.make_insert_step(dcfg, mesh)
    search = dist.make_search_step(dcfg, mesh)
    payload_sync = dist.make_payload_sync(dcfg, mesh)
    refresh_cache = (
        dist.make_refresh_cache(dcfg, mesh) if dcfg.node_bits > 0 else None
    )
    vspec = NamedSharding(mesh, P(("data", "model"), None))
    ispec = NamedSharding(mesh, P(("data", "model")))
    all_ids = _pad_to(np.arange(cfg.num_users, dtype=np.int32), nu_pad, -1)

    cache = None
    last_refresh = 0
    recalls, staleness, dropped = [], [], []
    for epoch, vecs, do_refresh, qidx, ideal in _trajectory(cfg):
        if do_refresh:
            vd = jax.device_put(
                jnp.asarray(_pad_to(vecs, nu_pad, 0.0)), vspec)
            store = insert(
                hp, store, vd, jax.device_put(jnp.asarray(all_ids), ispec),
                jnp.int32(epoch),
            )
            if epoch > 0:
                store = expire(store, jnp.int32(epoch), ttl=cfg.ttl_epochs)
            # entries left in a mover's OLD buckets must score with its
            # latest announced vector (the LshEngine corpus semantics)
            store = payload_sync(store, vd)
            if refresh_cache is not None:
                cache = refresh_cache(store.ids, store.payload)
            last_refresh = epoch
        if epoch == 0:
            continue

        q = jax.device_put(
            jnp.asarray(_pad_to(vecs[qidx], nq_pad, 0.0)), vspec)
        args = (hp, store.ids, store.payload)
        if cache is not None:
            args += cache
        ids, _, drop = search(*args, q)
        ids = np.asarray(ids)[: cfg.num_queries]
        # host-side self-exclusion: drop the query's own id, keep top-m
        keep = ids != qidx[:, None]
        ids_m = np.full((cfg.num_queries, cfg.m), -1, np.int32)
        for i in range(cfg.num_queries):
            ids_m[i] = ids[i][keep[i]][: cfg.m]
        recalls.append(metrics.recall_at_m(ids_m, ideal))
        # epochs since the last announce+cache rebuild — the single-host
        # driver's `epoch % refresh_every` convention, kept comparable
        staleness.append(epoch - last_refresh)
        dropped.append(int(drop))

    stale_arr = np.asarray(staleness)
    return dict(
        recalls=np.asarray(recalls),
        # one measurement, two names: announce and cache rebuild share the
        # refresh schedule, so store staleness == cache staleness here
        # (`staleness` mirrors the single-host dict's key).
        staleness=stale_arr,
        cache_staleness=stale_arr,
        dropped_probes=np.asarray(dropped),
        final_recall=float(recalls[-1]),
        mean_recall=float(np.mean(recalls)),
        refresh_every=cfg.refresh_every,
        store_generation=int(store.generation),
    )
