"""Corpus representations + exact (oracle) similarity search.

Two layouts, both unit-normalized so cosine == dot:
  * DenseCorpus : [n, d] float — model-produced embeddings (framework path).
  * SparseCorpus: padded CSR-ish (ids [n, nnz_max] int32 with -1 padding,
    vals [n, nnz_max] float) — the paper's sparse OSN interest vectors
    (d up to millions; nnz per user is tens).

The oracle (`exact_topk`) is the ground truth for recall@m / NCS@m and for
the kernel ref tests; it is chunked so multi-hundred-thousand-user corpora
fit CPU memory.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DenseCorpus:
    vectors: jax.Array  # [n, d], unit rows

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def d(self) -> int:
        return self.vectors.shape[1]

    def gather(self, idx: jax.Array) -> jax.Array:
        """Rows at idx (any shape), zeros for idx < 0."""
        safe = jnp.maximum(idx, 0)
        rows = self.vectors[safe]
        return jnp.where((idx >= 0)[..., None], rows, 0.0)

    def scores_against(self, q: jax.Array, idx: jax.Array) -> jax.Array:
        """Cosine of q [d] (unit) against rows at idx [...]."""
        return jnp.einsum("...d,d->...", self.gather(idx), q)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SparseCorpus:
    nnz_ids: jax.Array   # int32 [n, nnz_max], -1 padding
    nnz_vals: jax.Array  # f32   [n, nnz_max], zero padding; rows unit-norm
    d: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n(self) -> int:
        return self.nnz_ids.shape[0]

    def densify(self, idx: jax.Array) -> jax.Array:
        """Dense [.., d] rows for (small sets of) indices — used to sketch."""
        safe = jnp.maximum(idx, 0)
        ids = self.nnz_ids[safe]
        vals = jnp.where((idx >= 0)[..., None], self.nnz_vals[safe], 0.0)
        out = jnp.zeros(idx.shape + (self.d,), jnp.float32)
        return _scatter_dense(out, ids, vals)

    def scores_against_dense(self, q_dense: jax.Array, idx: jax.Array) -> jax.Array:
        """Cosine of dense unit query q [d] against sparse rows idx [...]."""
        safe = jnp.maximum(idx, 0)
        ids = self.nnz_ids[safe]             # [..., nnz]
        vals = self.nnz_vals[safe]
        gathered = q_dense[jnp.maximum(ids, 0)]
        gathered = jnp.where(ids >= 0, gathered, 0.0)
        s = jnp.sum(gathered * vals, axis=-1)
        return jnp.where(idx >= 0, s, 0.0)


def _scatter_dense(out, ids, vals):
    valid = ids >= 0
    safe_ids = jnp.where(valid, ids, 0)
    safe_vals = jnp.where(valid, vals, 0.0)
    # one-hot-free scatter-add along the last axis
    flat_out = out.reshape(-1, out.shape[-1])
    flat_ids = safe_ids.reshape(flat_out.shape[0], -1)
    flat_vals = safe_vals.reshape(flat_out.shape[0], -1)
    row = jnp.arange(flat_out.shape[0])[:, None]
    flat_out = flat_out.at[row, flat_ids].add(flat_vals)
    return flat_out.reshape(out.shape)


def normalize_rows_np(x: np.ndarray, eps: float = 1e-12) -> np.ndarray:
    n = np.linalg.norm(x, axis=-1, keepdims=True)
    return x / np.maximum(n, eps)


def sparse_from_lists(
    interest_ids: list[np.ndarray],
    interest_vals: list[np.ndarray],
    d: int,
    nnz_max: int,
) -> SparseCorpus:
    """Pack ragged per-user (ids, weights) lists; rows are L2-normalized."""
    n = len(interest_ids)
    ids = np.full((n, nnz_max), -1, np.int32)
    vals = np.zeros((n, nnz_max), np.float32)
    for i, (ii, vv) in enumerate(zip(interest_ids, interest_vals)):
        m = min(len(ii), nnz_max)
        # keep the heaviest interests if truncating
        order = np.argsort(-np.asarray(vv))[:m]
        ids[i, :m] = np.asarray(ii)[order]
        norm = np.linalg.norm(np.asarray(vv)[order])
        vals[i, :m] = np.asarray(vv)[order] / max(norm, 1e-12)
    return SparseCorpus(jnp.asarray(ids), jnp.asarray(vals), d=d)


def sparse_densify_host(c: SparseCorpus, rows: np.ndarray) -> np.ndarray:
    """Host-side dense rows (for sketching large sparse corpora in chunks)."""
    ids = np.asarray(c.nnz_ids[rows])
    vals = np.asarray(c.nnz_vals[rows])
    out = np.zeros((len(rows), c.d), np.float32)
    r = np.arange(len(rows))[:, None]
    valid = ids >= 0
    np.add.at(out, (np.broadcast_to(r, ids.shape)[valid], ids[valid]), vals[valid])
    return out


def exact_topk_dense(
    corpus: DenseCorpus, queries: jax.Array, m: int, chunk: int = 8192
) -> tuple[np.ndarray, np.ndarray]:
    """Oracle top-m over a dense corpus; returns (scores, ids) [nq, m]."""
    nq = queries.shape[0]
    best_s = np.full((nq, m), -np.inf, np.float32)
    best_i = np.full((nq, m), -1, np.int32)
    qs = jnp.asarray(queries)

    @jax.jit
    def score_chunk(vs, q):
        return q @ vs.T  # [nq, chunk]

    for s0 in range(0, corpus.n, chunk):
        e0 = min(s0 + chunk, corpus.n)
        sc = np.asarray(score_chunk(corpus.vectors[s0:e0], qs))
        merged_s = np.concatenate([best_s, sc], axis=1)
        merged_i = np.concatenate(
            [best_i, np.broadcast_to(np.arange(s0, e0, dtype=np.int32), sc.shape)],
            axis=1,
        )
        sel = np.argpartition(-merged_s, m - 1, axis=1)[:, :m]
        best_s = np.take_along_axis(merged_s, sel, axis=1)
        best_i = np.take_along_axis(merged_i, sel, axis=1)
    order = np.argsort(-best_s, axis=1)
    return np.take_along_axis(best_s, order, 1), np.take_along_axis(best_i, order, 1)


def exact_topk_sparse(
    corpus: SparseCorpus, q_dense: np.ndarray, m: int, chunk: int = 16384
) -> tuple[np.ndarray, np.ndarray]:
    """Oracle top-m over a sparse corpus given dense unit queries [nq, d]."""
    nq = q_dense.shape[0]
    best_s = np.full((nq, m), -np.inf, np.float32)
    best_i = np.full((nq, m), -1, np.int32)
    qj = jnp.asarray(q_dense)

    @jax.jit
    def score_chunk(ids, vals, q):
        g = q[:, jnp.maximum(ids, 0)]          # [nq, chunk, nnz]
        g = jnp.where(ids >= 0, g, 0.0)
        return jnp.einsum("qcn,cn->qc", g, vals)

    for s0 in range(0, corpus.n, chunk):
        e0 = min(s0 + chunk, corpus.n)
        sc = np.asarray(
            score_chunk(corpus.nnz_ids[s0:e0], corpus.nnz_vals[s0:e0], qj)
        )
        merged_s = np.concatenate([best_s, sc], axis=1)
        merged_i = np.concatenate(
            [best_i, np.broadcast_to(np.arange(s0, e0, dtype=np.int32), sc.shape)],
            axis=1,
        )
        sel = np.argpartition(-merged_s, m - 1, axis=1)[:, :m]
        best_s = np.take_along_axis(merged_s, sel, axis=1)
        best_i = np.take_along_axis(merged_i, sel, axis=1)
    order = np.argsort(-best_s, axis=1)
    return np.take_along_axis(best_s, order, 1), np.take_along_axis(best_i, order, 1)
