"""Capacitated compaction / routing shared across the system (DESIGN.md
Sec. 3.2).

One mechanism, three uses:
  * the bucket store's ring-buffer insert (`repro.core.store`) ranks each
    entry within its destination bucket to pick a write slot;
  * the distributed all_to_all query router (`repro.core.distributed`)
    ranks each (query, table) within its destination shard to pick a slot
    in the padded per-destination send buffer;
  * the MoE dispatch (`repro.models.moe`) ranks each routed token within
    its destination expert to pick a capacity slot.

All three are the same sort + run-rank + capacitated scatter; this module
owns that machinery so the semantics (stable destination-major compaction,
bounded buffers, explicit — never silent — overflow accounting) cannot
drift apart between the layers.

The router half (`plan_routes` / `build_send_buffer` / `return_to_origin`)
additionally owns the all_to_all send-buffer layout: `[n_dests, cap, ...]`
buffers whose leading axis is split by the collective, and the
origin-side gather that returns per-item results after the reverse
all_to_all.  Overflowed items are *counted* (`RoutePlan.dropped`) and
surfaced by the callers (the `dropped_probes` output of every
distributed step) instead of being silently eaten.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def run_ranks(sorted_keys: jax.Array) -> jax.Array:
    """Rank of each element within its run of equal keys.

    Args:
      sorted_keys: int [n], sorted ascending (equal keys contiguous).

    Returns:
      int32 [n]; the j-th occurrence of a key gets rank j.
    """
    n = sorted_keys.shape[0]
    if n == 0:
        # the concat below would build a shape-(1,) is_start against a
        # shape-(0,) pos and fail to broadcast; zero items have zero ranks
        return jnp.zeros((0,), jnp.int32)
    pos = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_keys[1:] != sorted_keys[:-1]]
    )
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, pos, 0)
    )
    return pos - run_start


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RoutePlan:
    """Where each of n items goes in a [n_dests, cap] buffer.

    All per-item arrays are in DESTINATION-SORTED order; `order` maps
    sorted position -> original index (`items[order]` is the sorted view).
    """

    order: jax.Array    # int32 [n] sort permutation (by destination)
    dest: jax.Array     # int32 [n] destination (sorted; overflow clamped to 0)
    slot: jax.Array     # int32 [n] slot within dest (clamped to cap - 1)
    ok: jax.Array       # bool  [n] item landed (slot < cap)
    dropped: jax.Array  # int32 scalar: items that overflowed their dest


def plan_routes(dest: jax.Array, n_dests: int, cap: int) -> RoutePlan:
    """Assign each item a (dest, slot) in a capacitated per-dest buffer.

    Items beyond `cap` for a destination are marked not-ok and counted in
    `dropped`; their (dest, slot) are clamped so downstream scatters and
    gathers stay in bounds.
    """
    order = jnp.argsort(dest)
    d_sorted = dest[order].astype(jnp.int32)
    slot = run_ranks(d_sorted)
    ok = slot < cap
    return RoutePlan(
        order=order.astype(jnp.int32),
        dest=jnp.where(ok, d_sorted, 0),
        slot=jnp.where(ok, slot, cap - 1),
        ok=ok,
        dropped=jnp.sum(~ok).astype(jnp.int32),
    )


def _expand(mask: jax.Array, ndim: int) -> jax.Array:
    return mask.reshape(mask.shape + (1,) * (ndim - 1))


def build_send_buffer(
    route: RoutePlan,
    n_dests: int,
    cap: int,
    values: jax.Array,  # [n, ...] per-item payload, ORIGINAL order
    fill,
) -> jax.Array:
    """Scatter per-item payloads into the [n_dests, cap, ...] send buffer.

    Empty slots hold `fill`, so receivers detect them by the fill sentinel
    of the metadata channel.  Overflowed items scatter to an out-of-bounds
    destination and are dropped by the scatter (mode='drop') — they can
    never clobber a surviving item's slot, no matter the scatter order.
    """
    v_sorted = values[route.order]
    buf = jnp.full((n_dests, cap) + values.shape[1:], fill, values.dtype)
    dest = jnp.where(route.ok, route.dest, n_dests)  # OOB => dropped
    return buf.at[dest, route.slot].set(v_sorted, mode="drop")


def return_to_origin(
    route: RoutePlan,
    back: jax.Array,  # [n_dests, cap, ...] returned per-slot results
    fill,
) -> jax.Array:
    """Gather each item's result back out of the returned buffer.

    Returns [n, ...] in ORIGINAL item order; overflowed (dropped) items
    get `fill`.
    """
    if back.shape[1] == 0:
        # cap == 0: everything was dropped and there is no slot axis to
        # gather from (XLA rejects a size-1 slice of a size-0 dim)
        n = route.order.shape[0]
        return jnp.full((n,) + back.shape[2:], fill, back.dtype)
    g = back[route.dest, route.slot]
    g = jnp.where(_expand(route.ok, back.ndim - 1), g, fill)
    unsort = jnp.argsort(route.order)
    return g[unsort]
