"""Bit-packed sketch-code layouts (DESIGN.md Sec. 11).

The staged query path carries candidate payloads as f32 vectors
[..., D]; at D = 128 that is 512 bytes per candidate where the sketch
itself — the thing LSH scoring actually needs — fits in k*L bits.  This
module owns the packed layout used by the hamming scoring mode and the
fused query kernel:

  * a vector's L k-bit sketch codes (`hashing.sketch_codes`, uint32
    [..., L], k <= 30 bits each) fold into W = ceil(L*k / 32) dense
    uint32 words [..., W]: global bit g = l*k + j lands in word g // 32
    at position g % 32 (little-endian within and across words);
  * `hamming_words` is the SWAR-popcount distance over that layout — the
    scoring primitive of `score="hamming"` runtimes and the oracle the
    multi-word `kernels/hamming.py` Pallas kernel must match;
  * `pack_store_payload` is the migration shim: it rewrites an embedded
    f32-payload `BucketStore` into the packed layout in place, so stores
    built for dot scoring can be re-used by hamming runtimes without a
    re-announce cycle.

The layout is round-trip exact (`unpack_codes(pack_codes(c)) == c`) and
distance-preserving (`hamming_words(pack(a), pack(b)) ==
sum_l hamming(a_l, b_l)`); both are property-tested in
tests/test_packed.py.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.hashing import MAX_K, popcount32


def _check_k(k: int) -> None:
    """The layout contract (and `hashing.sketch_codes`) supports k-bit
    codes with 1 <= k <= MAX_K only; an oversized k would silently break
    the `unpack(pack(c)) == c` round-trip, so reject it at the boundary."""
    if not (1 <= k <= MAX_K):
        raise ValueError(
            f"packed layout supports k in [1, {MAX_K}] bits per code, "
            f"got k={k}"
        )


def num_words(k: int, L: int) -> int:
    """uint32 words needed to hold L k-bit codes."""
    _check_k(k)
    return max(1, -(-(k * L) // 32))


def pack_codes(codes: jax.Array, k: int) -> jax.Array:
    """uint32 codes [..., L] (k live bits each) -> packed words [..., W].

    Bit j of table l lands at global position l*k + j; positions fill
    word 0 upward, little-endian.  Bits >= k of each input code are
    ignored (codes are masked), so callers may pass raw uint32 codes.
    """
    _check_k(k)
    L = codes.shape[-1]
    W = num_words(k, L)
    j = jnp.arange(k, dtype=jnp.uint32)
    bits = (codes[..., None].astype(jnp.uint32) >> j) & jnp.uint32(1)
    flat = bits.reshape(codes.shape[:-1] + (L * k,))     # [..., L*k]
    g = jnp.arange(L * k)
    shifted = flat << (g % 32).astype(jnp.uint32)
    words = [
        jnp.sum(jnp.where(g // 32 == w, shifted, jnp.uint32(0)),
                axis=-1, dtype=jnp.uint32)
        for w in range(W)
    ]
    return jnp.stack(words, axis=-1)


def unpack_codes(words: jax.Array, k: int, L: int) -> jax.Array:
    """Inverse of `pack_codes`: words [..., W] -> uint32 codes [..., L]."""
    _check_k(k)
    g = jnp.arange(L * k)
    bit = (
        jnp.take(words, g // 32, axis=-1) >> (g % 32).astype(jnp.uint32)
    ) & jnp.uint32(1)                                     # [..., L*k]
    bit = bit.reshape(words.shape[:-1] + (L, k))
    w = jnp.uint32(1) << jnp.arange(k, dtype=jnp.uint32)
    return jnp.sum(bit * w, axis=-1, dtype=jnp.uint32)


def hamming_words(a: jax.Array, b: jax.Array) -> jax.Array:
    """int32 [...]: popcount Hamming distance over the word axis (last).

    `a`/`b` broadcast against each other up to the trailing [W] axis —
    the jnp oracle for the packed scoring mode and the multi-word
    `kernels.ops.hamming` Pallas kernel.
    """
    return jnp.sum(
        popcount32(jnp.bitwise_xor(a.astype(jnp.uint32),
                                   b.astype(jnp.uint32))),
        axis=-1,
    )


def pack_store_payload(store, hyperplanes: jax.Array):
    """Migration shim: embedded f32 payloads -> packed sketch-code words.

    Re-sketches every live slot's payload vector with `hyperplanes`
    [L, k, d] and stores the packed words as the new payload
    (uint32 [T, NB, C, W]); empty slots become all-zero words.  The
    result is exactly the store an insert-from-scratch under
    `RuntimeConfig(score="hamming")` would build from the same vectors
    (pinned in tests/test_packed.py), so existing dot-mode stores
    migrate without a re-announce cycle.
    """
    from repro.core import hashing

    if store.payload is None:
        raise ValueError("pack_store_payload needs an embedded-payload store")
    t, nb, c, d = store.payload.shape
    if hyperplanes.ndim != 3 or hyperplanes.shape[0] != t \
            or hyperplanes.shape[2] != d:
        # a mismatched hyperplane stack would either shape-error deep in
        # sketch_codes or, worse, build a wrong-W payload that only fails
        # at insert time — reject it here, naming the expected layout
        raise ValueError(
            f"hyperplanes must be [L, k, d] = [{t}, k, {d}] to match this "
            f"store's payload {tuple(store.payload.shape)}; got "
            f"{tuple(hyperplanes.shape)}"
        )
    k = hyperplanes.shape[1]
    codes = hashing.sketch_codes(
        store.payload.reshape(-1, d), hyperplanes
    )                                                    # [T*NB*C, L]
    words = pack_codes(codes, k).reshape(t, nb, c, -1)
    words = jnp.where((store.ids >= 0)[..., None], words, jnp.uint32(0))
    return dataclasses.replace(store, payload=words)
