"""NearBucket-LSH core: the paper's contribution as a composable JAX module.

Layers:
  hashing     — cosine LSH (sign random projection), sketch packing
  multiprobe  — near-bucket enumeration (Sec. 4.2)
  plan        — the shared probe planner: ONE query discipline feeding the
                engine, the shard_map runtime, and the benchmarks
  routing     — capacitated compaction/routing (run ranks, send buffers,
                overflow accounting) shared by store/distributed/moe
  can         — CAN overlay geometry: bucket->node map, neighbors, hops
  store       — soft-state bucket store (insert/refresh/GC, Sec. 4.1)
  runtime     — the ONE topology-parameterized execution layer: the five
                step kernels + IndexRuntime (DESIGN.md Sec. 8)
  engine      — single-host reference engine, a façade over the 1-node
                runtime (Algorithms 1-2)
  distributed — mesh adapter: shard_map/sharding-spec bindings of the
                runtime kernels (all_to_all routing, neighbor permutes)
  churn       — dynamic-OSN soft-state trajectories, one driver on any
                topology
  layered     — Layered-LSH and its LSH-equivalence (Sec. 5.2)
  analysis    — Propositions 1-4 closed forms (Sec. 5)
  costmodel   — Table 1 cost accounting
  corpus      — dense/sparse corpora + exact oracle
  metrics     — recall@m, NCS@m (Sec. 6.1)
"""

from repro.core.hashing import (  # noqa: F401
    LshParams,
    make_hyperplanes,
    normalize,
    sketch_bits,
    sketch_codes,
    pack_bits,
    unpack_bits,
    hamming_distance,
    collision_probability,
)
from repro.core.can import CanTopology, paper_topology  # noqa: F401
from repro.core.store import BucketStore, make_store, insert_batch, expire  # noqa: F401
from repro.core.runtime import IndexRuntime, RuntimeConfig  # noqa: F401
from repro.core.engine import EngineConfig, LshEngine, SearchResult, dedupe_topk  # noqa: F401
from repro.core.corpus import DenseCorpus, SparseCorpus  # noqa: F401
from repro.core import analysis, costmodel, metrics, multiprobe  # noqa: F401
from repro.core import plan, routing  # noqa: F401
from repro.core.plan import ProbePlan, ProbeSpec, make_plan  # noqa: F401
from repro.core.routing import RoutePlan, plan_routes, run_ranks  # noqa: F401
