"""Search-quality metrics (paper Sec. 6.1): recall@m and NCS@m."""

from __future__ import annotations

import numpy as np


def recall_at_m(approx_ids: np.ndarray, ideal_ids: np.ndarray) -> float:
    """Definition 6.1/6.2: |A_m ∩ I_m| / |I_m| averaged over queries.

    ids arrays are [nq, m] with -1 padding for missing results.
    """
    nq = approx_ids.shape[0]
    vals = np.empty(nq, np.float64)
    for i in range(nq):
        ideal = set(int(x) for x in ideal_ids[i] if x >= 0)
        if not ideal:
            vals[i] = 1.0
            continue
        approx = set(int(x) for x in approx_ids[i] if x >= 0)
        vals[i] = len(approx & ideal) / len(ideal)
    return float(vals.mean())


def ncs_at_m(approx_scores: np.ndarray, ideal_scores: np.ndarray) -> float:
    """Definition 6.3: normalized cumulative similarity (precision proxy).

    scores arrays are [nq, m]; missing results contribute 0 (paper: CumSim
    of the approximate set can only fall short of the ideal's).
    """
    a = np.where(np.isfinite(approx_scores), np.maximum(approx_scores, 0.0), 0.0)
    i = np.where(np.isfinite(ideal_scores), np.maximum(ideal_scores, 0.0), 0.0)
    num = a.sum(axis=1)
    den = np.maximum(i.sum(axis=1), 1e-12)
    return float(np.mean(num / den))


def success_probability_by_interval(
    found: np.ndarray, similarities: np.ndarray, num_bins: int = 10
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Paper Sec. 6.3 / Fig. 4: fraction of (x, y) pairs found, binned by
    cosine similarity interval [i/10, (i+1)/10).

    Returns (bin_centers, success_fraction, bin_counts); empty bins are NaN.
    """
    edges = np.linspace(0.0, 1.0, num_bins + 1)
    centers = 0.5 * (edges[:-1] + edges[1:])
    frac = np.full(num_bins, np.nan)
    counts = np.zeros(num_bins, np.int64)
    which = np.clip(np.digitize(similarities, edges) - 1, 0, num_bins - 1)
    for b in range(num_bins):
        sel = which == b
        counts[b] = sel.sum()
        if counts[b]:
            frac[b] = float(np.mean(found[sel]))
    return centers, frac, counts
