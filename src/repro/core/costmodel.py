"""Network/storage/work cost accounting (paper Table 1).

Message unit = one CAN overlay hop (the paper's unit).  The distributed TPU
runtime additionally reports *collective bytes* measured from compiled HLO
(see benchmarks/bench_distributed.py); this module is the overlay-level
model that Table 1 is written in, and is what the simulator counts.

             nodes contacted   avg messages    vectors/node   vectors searched
  LSH              L              k L / 2            B               L B
  Layered          L              k L / 2            B               L B
  NB-LSH        L (1 + k)       3 k L / 2            B           L (k + 1) B
  CNB-LSH          L              k L / 2        (k + 1) B       L (k + 1) B
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class QueryCost:
    nodes_contacted: float
    messages: float
    vectors_stored_per_node: float
    vectors_searched: float


VARIANTS = ("lsh", "layered", "nb", "cnb")


def table1(variant: str, k: int, L: int, bucket_size: float = 1.0) -> QueryCost:
    """Closed-form per-query costs of paper Table 1."""
    B = float(bucket_size)
    if variant in ("lsh", "layered"):
        return QueryCost(L, 0.5 * k * L, B, L * B)
    if variant == "nb":
        return QueryCost(L * (1 + k), 1.5 * k * L, B, L * (k + 1) * B)
    if variant == "cnb":
        return QueryCost(L, 0.5 * k * L, (k + 1) * B, L * (k + 1) * B)
    raise ValueError(f"unknown variant {variant!r}")


def lsh_L_for_budget(variant: str, k: int, message_budget: float) -> int:
    """Largest L whose average message cost fits the budget (Fig. 3 setup)."""
    per_L = {"lsh": 0.5 * k, "layered": 0.5 * k, "nb": 1.5 * k, "cnb": 0.5 * k}[
        variant
    ]
    return max(int(message_budget // per_L), 0)


@dataclasses.dataclass
class MessageCounter:
    """Mutable per-run message accounting used by the overlay simulator."""

    dht_lookups: int = 0
    lookup_hops: int = 0
    neighbor_messages: int = 0
    result_messages: int = 0

    @property
    def total(self) -> int:
        # The paper counts routing hops + neighbor forwards as "messages";
        # result returns are symmetric across variants and excluded from
        # Table 1's accounting, so `total` matches Table 1.
        return self.lookup_hops + self.neighbor_messages

    def add_lookup(self, hops: int) -> None:
        self.dht_lookups += 1
        self.lookup_hops += int(hops)

    def add_neighbor(self, n: int = 1) -> None:
        self.neighbor_messages += int(n)

    def add_result(self, n: int = 1) -> None:
        self.result_messages += int(n)

    def publish(self, registry, **labels) -> None:
        """Mirror the counts into an `repro.obs` metrics registry (the
        unified export surface, DESIGN.md Sec. 12).  Gauges, not
        counters: a MessageCounter is itself the accumulator, so
        publishing is an idempotent snapshot."""
        for field in ("dht_lookups", "lookup_hops", "neighbor_messages",
                      "result_messages"):
            registry.gauge(f"overlay_{field}").set(
                getattr(self, field), **labels)
        registry.gauge(
            "overlay_messages_total",
            "Table-1 overlay messages (lookup hops + neighbor forwards)",
        ).set(self.total, **labels)


# -- elastic membership: bucket-state handoff (DESIGN.md Sec. 9) -------------


def estimate_handoff_bytes(
    L: int,
    num_buckets: int,
    capacity: int,
    d: int,
    old_n: int,
    new_n: int,
) -> int:
    """Protocol-level bytes of one power-of-two join/leave round.

    The Table-1 analogue for membership: every bucket row changing owner
    ships its id (4 B) and timestamp (4 B) slots, its embedded payload
    slots (4 B * d; 0 for id-only stores), and its ring pointer (4 B),
    across all L tables.  With contiguous prefix zones exactly
    NB * (1 - min(N, N')/max(N, N')) rows move per table — the closed
    form `repro.core.can.moved_buckets` is derived from.  Charged by the
    node-churn driver alongside the refresh bytes, never silently."""
    lo, hi = sorted((int(old_n), int(new_n)))
    if lo < 1:
        raise ValueError(f"node counts must be >= 1, got {old_n}, {new_n}")
    moved = num_buckets - num_buckets * lo // hi
    per_bucket = capacity * (8 + 4 * d) + 4
    return L * moved * per_bucket


# -- R-way replication: announce fan-out + zone recovery (DESIGN.md Sec. 10) --


def estimate_replication_bytes(L: int, n_vectors: int, d: int, R: int) -> int:
    """Protocol-level bytes of fanning ONE full announce out to the R-1
    replica owners (the availability analogue of Table 1's maintenance
    column).

    Soft state makes replication cheap to keep fresh (paper Sec. 4.1):
    replicas are not separately maintained — each re-announce simply
    lands on R owners instead of one, so the extra cost per announce is
    (R-1) copies of every announced entry: id (4 B) + timestamp (4 B) +
    embedded payload (4 B * d), per table.  0 when R == 1.  Charged by
    the failure-churn driver at every announce epoch, never silently."""
    R = int(R)
    if R < 1:
        raise ValueError(f"replication R must be >= 1, got {R}")
    return (R - 1) * int(L) * int(n_vectors) * (8 + 4 * int(d))


def estimate_recovery_bytes(
    L: int, buckets_per_node: int, capacity: int, d: int
) -> int:
    """Protocol-level bytes of repopulating ONE revived node's zone.

    A fail-stop kill loses the node's bucket state with NO handoff; the
    node rejoins at the next re-announce and receives its full zone back
    (ids + timestamps + embedded payloads + ring pointers across all L
    tables) — the same per-bucket form as `estimate_handoff_bytes`, over
    one zone.  Charged by the failure-churn driver on every revival."""
    per_bucket = int(capacity) * (8 + 4 * int(d)) + 4
    return int(L) * int(buckets_per_node) * per_bucket


# -- ICI byte model for the TPU runtime (DESIGN.md Sec. 2) --------------------

ICI_LINK_GBPS = 50e9  # ~50 GB/s per link, v5e 2-D torus


def collective_seconds(bytes_on_wire: float, n_links: int = 1) -> float:
    return bytes_on_wire / (ICI_LINK_GBPS * max(n_links, 1))
