"""Single-host reference search engine: LSH / Layered / NB-LSH / CNB-LSH.

This is the semantic reference for the distributed runtime
(`repro.core.distributed` must return identical result sets) and the engine
behind the paper-reproduction benchmarks (Figs. 4-5).

Algorithm 1/2 of the paper, with network cost accounted per Table 1:
  * lsh / layered : search the L exact buckets.
  * nb            : + the k 1-near buckets of each (forwarded to neighbors).
  * cnb           : + the k 1-near buckets of each (served from local cache).
Result sets of nb and cnb are identical; only the message cost differs.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel, hashing, multiprobe
from repro.core.can import CanTopology
from repro.core.corpus import DenseCorpus, SparseCorpus
from repro.core.hashing import LshParams
from repro.core.store import BucketStore

NEG_INF = jnp.float32(-jnp.inf)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    variant: str = "cnb"          # lsh | layered | nb | cnb
    num_probes: int | None = None  # None => all k 1-near buckets (the paper)
    ranked_probes: bool = False    # beyond-paper: margin-ranked probe subset
    chunk: int = 32                # queries scored per jit call


@dataclasses.dataclass
class SearchResult:
    ids: np.ndarray      # int32 [nq, m], -1 padded
    scores: np.ndarray   # f32   [nq, m]
    cost: costmodel.QueryCost          # closed-form per-query cost (Table 1)
    sim_messages: float | None = None  # simulated avg messages (hop-counted)


def dedupe_topk(ids: jax.Array, scores: jax.Array, m: int):
    """Top-m by score with duplicate ids collapsed (same id => same score).

    ids/scores: [..., K].  Invalid candidates are id -1 / score -inf.
    """
    order = jnp.argsort(ids, axis=-1)
    ids_s = jnp.take_along_axis(ids, order, -1)
    sc_s = jnp.take_along_axis(scores, order, -1)
    dup = jnp.concatenate(
        [jnp.zeros_like(ids_s[..., :1], bool), ids_s[..., 1:] == ids_s[..., :-1]],
        axis=-1,
    )
    sc_s = jnp.where(dup | (ids_s < 0), NEG_INF, sc_s)
    top_s, top_pos = jax.lax.top_k(sc_s, m)
    top_i = jnp.take_along_axis(ids_s, top_pos, -1)
    top_i = jnp.where(jnp.isfinite(top_s), top_i, -1)
    top_s = jnp.where(jnp.isfinite(top_s), top_s, -jnp.inf)
    return top_i, top_s


class LshEngine:
    """Reference engine over an id-only BucketStore + corpus."""

    def __init__(
        self,
        params: LshParams,
        hyperplanes: jax.Array,
        store: BucketStore,
        corpus: DenseCorpus | SparseCorpus,
        topology: CanTopology | None = None,
        config: EngineConfig = EngineConfig(),
    ):
        if config.variant not in costmodel.VARIANTS:
            raise ValueError(f"unknown variant {config.variant!r}")
        self.params = params
        self.hyperplanes = hyperplanes
        self.store = store
        self.corpus = corpus
        self.topology = topology or CanTopology(params.k, 1 << params.k)
        self.config = config
        self._search_chunk = jax.jit(self._search_chunk_impl, static_argnums=(2,))
        self._contains_chunk = jax.jit(self._contains_chunk_impl)

    # -- probe planning -------------------------------------------------------

    @property
    def probes_per_table(self) -> int:
        if self.config.variant in ("lsh", "layered"):
            return 1
        p = self.config.num_probes
        return 1 + (self.params.k if p is None else p)

    def _probe_codes(self, q: jax.Array) -> jax.Array:
        """[nq, L, P] bucket codes to search for each query."""
        codes = hashing.sketch_codes(q, self.hyperplanes)  # [nq, L]
        if self.config.variant in ("lsh", "layered"):
            return codes[..., None]
        k = self.params.k
        p = self.config.num_probes
        if p is None or p >= k:
            return multiprobe.probe_codes(codes, k)
        if self.config.ranked_probes:
            margins = hashing.projection_margins(q, self.hyperplanes)
            near = multiprobe.ranked_near_codes(codes, margins, k, p)
        else:
            near = multiprobe.near_codes(codes, k)[..., :p]
        return jnp.concatenate([codes[..., None], near], axis=-1)

    # -- candidate gathering + scoring ---------------------------------------

    def _candidates(self, probes: jax.Array) -> jax.Array:
        """[nq, L, P] probe codes -> candidate ids [nq, L*P*C]."""
        per_table = []
        for l in range(self.params.L):
            idx = probes[:, l, :].astype(jnp.int32) % self.store.num_buckets
            per_table.append(self.store.ids[l][idx])  # [nq, P, C]
        cand = jnp.stack(per_table, axis=1)  # [nq, L, P, C]
        return cand.reshape(cand.shape[0], -1)

    def _score(self, q: jax.Array, cand: jax.Array) -> jax.Array:
        if isinstance(self.corpus, DenseCorpus):
            return jax.vmap(self.corpus.scores_against)(q, cand)
        return jax.vmap(self.corpus.scores_against_dense)(q, cand)

    def _search_chunk_impl(self, q: jax.Array, exclude: jax.Array, m: int):
        probes = self._probe_codes(q)
        cand = self._candidates(probes)
        scores = self._score(q, cand)
        invalid = (cand < 0) | (cand == exclude[:, None])
        scores = jnp.where(invalid, NEG_INF, scores)
        cand = jnp.where(invalid, -1, cand)
        return dedupe_topk(cand, scores, m)

    def _contains_chunk_impl(self, q: jax.Array, targets: jax.Array):
        probes = self._probe_codes(q)
        cand = self._candidates(probes)
        return jnp.any(cand == targets[:, None], axis=-1)

    # -- public API -----------------------------------------------------------

    def search(
        self,
        queries: jax.Array,              # [nq, d] unit dense queries
        m: int,
        exclude: np.ndarray | None = None,  # [nq] self ids to drop, or None
        simulate_messages: bool = False,
        rng: np.random.Generator | None = None,
    ) -> SearchResult:
        nq = queries.shape[0]
        exclude = (
            np.full((nq,), -2, np.int32) if exclude is None
            else np.asarray(exclude, np.int32)
        )
        out_i = np.empty((nq, m), np.int32)
        out_s = np.empty((nq, m), np.float32)
        c = self.config.chunk
        for s0 in range(0, nq, c):
            e0 = min(s0 + c, nq)
            qi = jnp.asarray(queries[s0:e0])
            ti, ts = self._search_chunk(qi, jnp.asarray(exclude[s0:e0]), m)
            out_i[s0:e0], out_s[s0:e0] = np.asarray(ti), np.asarray(ts)
        bucket_b = float(np.mean(np.asarray(self.store.occupancy())))
        cost = costmodel.table1(
            self.config.variant, self.params.k, self.params.L, bucket_b
        )
        sim = (
            self.simulate_messages(queries, rng) if simulate_messages else None
        )
        return SearchResult(out_i, out_s, cost, sim)

    def contains(self, queries: jax.Array, target_ids: np.ndarray) -> np.ndarray:
        """Was target y searched for query x? (success-probability metric,
        paper Sec. 6.3 — membership in searched buckets, not top-m)."""
        nq = queries.shape[0]
        out = np.empty((nq,), bool)
        c = self.config.chunk
        for s0 in range(0, nq, c):
            e0 = min(s0 + c, nq)
            out[s0:e0] = np.asarray(
                self._contains_chunk(
                    jnp.asarray(queries[s0:e0]),
                    jnp.asarray(target_ids[s0:e0], jnp.int32),
                )
            )
        return out

    def simulate_messages(
        self, queries: jax.Array, rng: np.random.Generator | None = None
    ) -> float:
        """Hop-counted message simulation over the CAN topology; converges to
        Table 1's closed forms (tested)."""
        rng = rng or np.random.default_rng(0)
        codes = np.asarray(hashing.sketch_codes(jnp.asarray(queries), self.hyperplanes))
        topo = self.topology
        counter = costmodel.MessageCounter()
        nq = codes.shape[0]
        src = rng.integers(0, topo.n_nodes, size=(nq,))
        for i in range(nq):
            for l in range(self.params.L):
                dst = int(np.asarray(topo.node_of(np.uint32(codes[i, l]))))
                counter.add_lookup(topo.lookup_hops(int(src[i]), dst))
                counter.add_result()
                if self.config.variant == "nb":
                    # forward to the node-bit neighbors; local-bit flips are
                    # already on-node in the sharded geometry.
                    counter.add_neighbor(topo.node_bits)
                    counter.add_result(topo.node_bits)
        return counter.total / nq
