"""Single-host reference search engine: LSH / Layered / NB-LSH / CNB-LSH.

Since the runtime consolidation (DESIGN.md Sec. 8) this class is a thin
façade over a 1-node `repro.core.runtime.IndexRuntime`: the probe/gather/
score/top-m path is the SAME step kernel the sharded mesh runtime
executes — on the degenerate topology every near bucket is a free
local-bit probe, the router is the identity, and no collectives are
traced.  The public surface (`search` / `contains` / `simulate_messages`,
`SearchResult`) is unchanged and bit-identical to the pre-refactor
engine (pinned by tests/test_runtime.py against checked-in goldens).

Algorithm 1/2 of the paper, with network cost accounted per Table 1:
  * lsh / layered : search the L exact buckets.
  * nb            : + the k 1-near buckets of each (forwarded to neighbors).
  * cnb           : + the k 1-near buckets of each (served from local cache).
Result sets of nb and cnb are identical; only the message cost differs.

Query path (one jit'd dispatch over the whole padded batch):
  sketch -> probe plan -> per-(query, table) bucket gather -> shared
  score/top-m stage (`repro.core.scoring`) -> per-query merge.  With
  `use_kernels=True` the sketch runs through the fused Pallas simhash
  kernel and score/top-m through the fused `bucket_topk` kernel; result
  ids are bit-identical to the reference path (CI-checked).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel, hashing
from repro.core import plan as plan_mod
from repro.core import runtime as runtime_mod
from repro.core.can import CanTopology
from repro.core.corpus import DenseCorpus, SparseCorpus
from repro.core.hashing import LshParams
from repro.core.runtime import IndexRuntime, RuntimeConfig
from repro.core.scoring import dedupe_topk  # noqa: F401  (re-export)
from repro.core.store import BucketStore

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    variant: str = "cnb"          # lsh | layered | nb | cnb
    num_probes: int | None = None  # None => all k 1-near buckets (the paper)
    ranked_probes: bool = False    # beyond-paper: margin-ranked probe subset
    chunk: int = 32                # queries scored per dispatched chunk
    use_kernels: bool = False      # fused Pallas sketch + score/top-m path


@dataclasses.dataclass
class SearchResult:
    ids: np.ndarray      # int32 [nq, m], -1 padded
    scores: np.ndarray   # f32   [nq, m]
    cost: costmodel.QueryCost          # closed-form per-query cost (Table 1)
    sim_messages: float | None = None  # simulated avg messages (hop-counted)
    dropped_probes: int = 0  # probes lost to routing overflow — always 0 on
    #   the single-host engine (the 1-node runtime's router is the identity);
    #   kept for API parity with the mesh steps, which return the real count
    #   as their third output (not through this class)


class LshEngine:
    """Reference engine over an id-only BucketStore + corpus.

    The corpus is the id-keyed payload source (always the LATEST announced
    vector per id) — the single genuine data-model difference from the
    mesh runtime, whose shards embed payloads in their bucket slots.
    """

    def __init__(
        self,
        params: LshParams,
        hyperplanes: jax.Array,
        store: BucketStore,
        corpus: DenseCorpus | SparseCorpus,
        topology: CanTopology | None = None,
        config: EngineConfig = EngineConfig(),
    ):
        if config.variant not in costmodel.VARIANTS:
            raise ValueError(f"unknown variant {config.variant!r}")
        if config.use_kernels and not isinstance(corpus, DenseCorpus):
            raise ValueError(
                "use_kernels requires a DenseCorpus: the fused bucket_topk "
                "kernel scores dense candidate payloads"
            )
        self.params = params
        self.hyperplanes = hyperplanes
        self.store = store
        self.corpus = corpus
        # overlay topology for the message SIMULATION (paper: one bucket
        # per node); execution runs on the runtime's 1-node topology.
        self.topology = topology or CanTopology(params.k, 1 << params.k)
        self.config = config
        self.runtime = IndexRuntime(RuntimeConfig(
            params=params,
            variant=config.variant,
            n_nodes=1,
            num_probes=config.num_probes,
            ranked_probes=config.ranked_probes,
            use_kernels=config.use_kernels,
        ))
        self._search_batched = jax.jit(
            self._search_batched_impl, static_argnums=(2,)
        )
        self._contains_batched = jax.jit(self._contains_batched_impl)

    # -- probe planning (thin view over the shared planner, core.plan) --------

    @property
    def probe_spec(self) -> plan_mod.ProbeSpec:
        return self.runtime.cfg.probe_spec

    @property
    def probes_per_table(self) -> int:
        return self.probe_spec.probes_per_table

    # -- chunk bodies (the 1-node runtime kernels, closed over state) ---------

    def _search_chunk_impl(self, q: jax.Array, exclude: jax.Array, m: int):
        ids, scores, _ = runtime_mod.search_kernel(
            self.runtime.cfg, runtime_mod.LOCAL, m, self.hyperplanes,
            self.store.ids, None, None, None, q,
            corpus=self.corpus, exclude=exclude,
        )
        return ids, scores

    def _search_batched_impl(self, q: jax.Array, exclude: jax.Array, m: int):
        """q [nchunks, chunk, d], exclude [nchunks, chunk] -> [nchunks, chunk, m]."""
        return jax.lax.map(
            lambda qe: self._search_chunk_impl(qe[0], qe[1], m), (q, exclude)
        )

    def _contains_chunk_impl(self, q: jax.Array, targets: jax.Array):
        hits, _ = runtime_mod.contains_kernel(
            self.runtime.cfg, runtime_mod.LOCAL, self.hyperplanes,
            self.store.ids, None, q, targets,
        )
        return hits

    def _contains_batched_impl(self, q: jax.Array, targets: jax.Array):
        return jax.lax.map(
            lambda qt: self._contains_chunk_impl(qt[0], qt[1]), (q, targets)
        )

    def _pad_chunks(self, arrs: list[jax.Array], pad_vals: list):
        """Pad leading dim to a chunk multiple and add a [nchunks, chunk] axis.

        Pads with jnp so device-resident query batches stay on device (no
        host roundtrip).  The chunk count rounds up to a power of two (small
        batches) or a multiple of 16 chunks (large batches), so the batched
        jit sees few distinct shapes while dead-chunk compute stays bounded
        at <= 16 chunks, not a 2x blowup.  Padded rows are sliced off by
        the caller.
        """
        c = self.config.chunk
        nq = arrs[0].shape[0]
        nchunks = max(1, -(-nq // c))
        if nchunks <= 16:
            nchunks = 1 << (nchunks - 1).bit_length()
        else:
            nchunks = -(-nchunks // 16) * 16
        out = []
        for a, v in zip(arrs, pad_vals):
            a = jnp.asarray(a)
            pad = nchunks * c - nq
            if pad:
                widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
                a = jnp.pad(a, widths, constant_values=v)
            out.append(a.reshape(nchunks, c, *a.shape[1:]))
        return out

    # -- public API -----------------------------------------------------------

    def search(
        self,
        queries: jax.Array,              # [nq, d] unit dense queries
        m: int,
        exclude: np.ndarray | None = None,  # [nq] self ids to drop, or None
        simulate_messages: bool = False,
        rng: np.random.Generator | None = None,
    ) -> SearchResult:
        nq = queries.shape[0]
        exclude = (
            np.full((nq,), -2, np.int32) if exclude is None
            else np.asarray(exclude, np.int32)
        )
        qc, ec = self._pad_chunks(
            [jnp.asarray(queries, jnp.float32), jnp.asarray(exclude)], [0.0, -2]
        )
        ti, ts = self._search_batched(qc, ec, m)
        out_i = np.asarray(ti).reshape(-1, m)[:nq]
        out_s = np.asarray(ts).reshape(-1, m)[:nq]
        bucket_b = float(np.mean(np.asarray(self.store.occupancy())))
        cost = costmodel.table1(
            self.config.variant, self.params.k, self.params.L, bucket_b
        )
        sim = (
            self.simulate_messages(queries, rng) if simulate_messages else None
        )
        # the 1-node router is the identity: genuinely 0 drops
        return SearchResult(out_i, out_s, cost, sim, dropped_probes=0)

    def contains(self, queries: jax.Array, target_ids: np.ndarray) -> np.ndarray:
        """Was target y searched for query x? (success-probability metric,
        paper Sec. 6.3 — membership in searched buckets, not top-m)."""
        nq = queries.shape[0]
        qc, tc = self._pad_chunks(
            [jnp.asarray(queries, jnp.float32),
             jnp.asarray(np.asarray(target_ids, np.int32))],
            [0.0, -2],
        )
        out = self._contains_batched(qc, tc)
        return np.asarray(out).reshape(-1)[:nq]

    def simulate_messages(
        self, queries: jax.Array, rng: np.random.Generator | None = None,
        registry=None,
    ) -> float:
        """Hop-counted message simulation over the CAN topology; converges to
        Table 1's closed forms (tested).  With `registry=` the raw counts
        publish into the obs metrics registry (`MessageCounter.publish`),
        labeled by variant."""
        rng = rng or np.random.default_rng(0)
        codes = np.asarray(hashing.sketch_codes(jnp.asarray(queries), self.hyperplanes))
        topo = self.topology
        counter = costmodel.MessageCounter()
        nq = codes.shape[0]
        src = rng.integers(0, topo.n_nodes, size=(nq,))
        for i in range(nq):
            for l in range(self.params.L):
                dst = int(topo.node_of_np(np.uint32(codes[i, l])))
                counter.add_lookup(topo.lookup_hops(int(src[i]), dst))
                counter.add_result()
                if self.config.variant == "nb":
                    # forward to the node-bit neighbors; local-bit flips are
                    # already on-node in the sharded geometry.
                    counter.add_neighbor(topo.node_bits)
                    counter.add_result(topo.node_bits)
        if registry is not None:
            counter.publish(registry, variant=self.config.variant)
            registry.gauge("overlay_messages_per_query").set(
                counter.total / nq, variant=self.config.variant)
        return counter.total / nq
