"""Distributed NearBucket-LSH runtime (shard_map over the production mesh).

Geometry (DESIGN.md Sec. 2): bucket shards live on the `model` mesh axis —
device j owns the contiguous sketch-prefix zone {codes with high bits == j}
(the CAN zone).  The query batch is sharded over *all* mesh axes (every
device is both a peer that receives queries and a bucket node, exactly as in
the paper's P2P OSN).  Bucket state is replicated across the data/pod axes.

Per-variant communication on the query path (mirrors Table 1):
  lsh  : route each (query, table) to its owner shard  [all_to_all]
         + search the exact bucket + the local-bit near buckets? NO —
         plain LSH probes the exact bucket only.
  nb   : lsh + forward to the log2(n_shards) XOR-neighbors [2 ppermutes/bit]
         to cover node-bit near buckets; local-bit near buckets are free.
  cnb  : lsh routing, with node-bit near buckets served from a local cache
         of the neighbors' shards, refreshed OFF the query path by
         `refresh_cache` (the paper's periodic bucket exchange).

Routing modes (a §Perf knob):
  alltoall : per-destination padded send buffers, one fused all_to_all each
             way — bytes ~ L*cap_factor/n_shards of the all_gather cost.
  allgather: replicate queries along `model`, return per-origin results via
             all_to_all — simple, no overflow, more bytes.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import hashing, scoring
from repro.core.can import CanTopology
from repro.core.hashing import LshParams
from repro.core.scoring import dedupe_topk
from repro.core.store import BucketStore

NEG_INF = float("-inf")


@dataclasses.dataclass(frozen=True)
class DistConfig:
    params: LshParams
    n_shards: int                 # size of the `model` axis
    variant: str = "cnb"          # lsh | nb | cnb
    m: int = 10
    routing: str = "alltoall"     # alltoall | allgather
    cap_factor: float = 2.0       # per-destination buffer slack (alltoall)
    probe_local_near: bool = True  # search local-bit near buckets (nb/cnb)
    use_kernels: bool = False      # fused Pallas score/top-m on each shard

    @property
    def topo(self) -> CanTopology:
        return CanTopology(self.params.k, self.n_shards)

    @property
    def node_bits(self) -> int:
        return self.topo.node_bits

    @property
    def local_bits(self) -> int:
        return self.topo.local_bits

    def probes_per_table_local(self) -> int:
        """Buckets searched at the owner shard per (query, table)."""
        if self.variant == "lsh":
            return 1
        return 1 + (self.local_bits if self.probe_local_near else 0)


# -----------------------------------------------------------------------------
# local search helpers (run inside shard_map on one shard)
# -----------------------------------------------------------------------------


def _local_probe_buckets(cfg: DistConfig, local_idx: jax.Array) -> jax.Array:
    """Local bucket indices to probe for a query landing on this shard.

    local_idx: int32 [...]. Returns [..., P_local] — exact bucket first,
    then the local-bit 1-near buckets (free probes: same device).
    """
    if cfg.variant == "lsh" or not cfg.probe_local_near or cfg.local_bits == 0:
        return local_idx[..., None]
    flips = (1 << jnp.arange(cfg.local_bits, dtype=jnp.int32))
    near = jnp.bitwise_xor(local_idx[..., None], flips)
    return jnp.concatenate([local_idx[..., None], near], axis=-1)


def _score_local(
    cfg: DistConfig,
    store_ids: jax.Array,      # [T, NB_local, C]
    store_payload: jax.Array,  # [T, NB_local, C, D]
    q: jax.Array,              # [r, d]
    table: jax.Array,          # [r] int32
    local_idx: jax.Array,      # [r] int32 bucket index within shard
    m: int,
):
    """Top-m among (exact + local near) buckets of each routed query."""
    probes = _local_probe_buckets(cfg, local_idx)          # [r, P]
    cand_ids = store_ids[table[:, None], probes]           # [r, P, C]
    cand_vec = store_payload[table[:, None], probes]       # [r, P, C, D]
    r = q.shape[0]
    cand_ids = cand_ids.reshape(r, -1)
    cand_vec = cand_vec.reshape(r, cand_ids.shape[1], -1)
    return scoring.score_topk(
        q, cand_ids, cand_vec, m, use_kernels=cfg.use_kernels
    )


def _score_cache(
    cfg: DistConfig,
    cache_ids: jax.Array,      # [T, nbits, NB_local, C]
    cache_payload: jax.Array,  # [T, nbits, NB_local, C, D]
    q: jax.Array,              # [r, d]
    table: jax.Array,          # [r]
    local_idx: jax.Array,      # [r]
    m: int,
):
    """CNB: score the node-bit near buckets from the neighbor cache.

    Flipping node bit j keeps the local index unchanged, so the near bucket
    of bit j is cache[table, j, local_idx] — a pure local gather.
    """
    nbits = cache_ids.shape[1]
    cand_ids = cache_ids[table[:, None], jnp.arange(nbits)[None, :], local_idx[:, None]]
    cand_vec = cache_payload[
        table[:, None], jnp.arange(nbits)[None, :], local_idx[:, None]
    ]  # [r, nbits, C, D]
    r = q.shape[0]
    cand_ids = cand_ids.reshape(r, -1)
    cand_vec = cand_vec.reshape(r, cand_ids.shape[1], -1)
    return scoring.score_topk(
        q, cand_ids, cand_vec, m, use_kernels=cfg.use_kernels
    )


# -----------------------------------------------------------------------------
# the sharded search step
# -----------------------------------------------------------------------------


def _merge_topk(ids_list, scores_list, m):
    ids = jnp.concatenate(ids_list, axis=-1)
    scores = jnp.concatenate(scores_list, axis=-1)
    return dedupe_topk(ids, scores, m)


def _search_shard(
    cfg: DistConfig,
    hyperplanes: jax.Array,
    store_ids: jax.Array,
    store_payload: jax.Array,
    cache_ids: jax.Array | None,
    cache_payload: jax.Array | None,
    q: jax.Array,  # [b_loc, d] — this device's slice of the query batch
):
    """Runs on every device under shard_map; returns ([b_loc, m] ids, scores)."""
    L, k, m = cfg.params.L, cfg.params.k, cfg.m
    n = cfg.n_shards
    b_loc, d = q.shape
    codes = hashing.sketch_codes(q, hyperplanes)            # [b_loc, L]
    owner = (codes >> cfg.local_bits).astype(jnp.int32)     # [b_loc, L]
    local_idx = (codes & ((1 << cfg.local_bits) - 1)).astype(jnp.int32)

    if cfg.routing == "allgather":
        return _search_allgather(
            cfg, store_ids, store_payload, cache_ids, cache_payload,
            q, owner, local_idx,
        )

    # ---- all_to_all routing (DHT-lookup analogue) ---------------------------
    cap = int(np.ceil(b_loc * L / n * cfg.cap_factor))
    cap = max(cap, 1)
    flat_owner = owner.reshape(-1)              # [b_loc*L]
    flat_local = local_idx.reshape(-1)
    flat_table = jnp.tile(jnp.arange(L, dtype=jnp.int32), (b_loc,))
    flat_qidx = jnp.repeat(jnp.arange(b_loc, dtype=jnp.int32), L)

    order = jnp.argsort(flat_owner)
    o_sorted = flat_owner[order]
    pos = jnp.arange(o_sorted.shape[0], dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), o_sorted[1:] != o_sorted[:-1]]
    )
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, pos, 0)
    )
    slot = pos - run_start                      # rank within destination
    ok = slot < cap                             # overflow dropped (counted)

    dest = jnp.where(ok, o_sorted, 0)
    slot_c = jnp.where(ok, slot, cap - 1)

    send_q = jnp.zeros((n, cap, d), q.dtype)
    send_meta = jnp.full((n, cap, 3), -1, jnp.int32)  # (qidx, table, local)
    src_vals = jnp.stack(
        [flat_qidx[order], flat_table[order], flat_local[order]], axis=-1
    )
    send_q = send_q.at[dest, slot_c].set(
        jnp.where(ok[:, None], q[flat_qidx[order]], 0.0)
    )
    send_meta = send_meta.at[dest, slot_c].set(
        jnp.where(ok[:, None], src_vals, -1)
    )

    recv_q = jax.lax.all_to_all(send_q, "model", 0, 0, tiled=True)
    recv_meta = jax.lax.all_to_all(send_meta, "model", 0, 0, tiled=True)
    rq = recv_q.reshape(n * cap, d)
    rtable = recv_meta[..., 1].reshape(-1)
    rlocal = recv_meta[..., 2].reshape(-1)
    rvalid = rtable >= 0
    rtable_c = jnp.maximum(rtable, 0)
    rlocal_c = jnp.maximum(rlocal, 0)

    ids_o, sc_o = _score_local(
        cfg, store_ids, store_payload, rq, rtable_c, rlocal_c, m
    )
    ids_parts, sc_parts = [ids_o], [sc_o]

    if cfg.variant == "cnb" and cache_ids is not None and cfg.node_bits > 0:
        ids_c, sc_c = _score_cache(
            cfg, cache_ids, cache_payload, rq, rtable_c, rlocal_c, m
        )
        ids_parts.append(ids_c)
        sc_parts.append(sc_c)

    if cfg.variant == "nb":
        # forward routed queries to each XOR-neighbor; it scores ITS bucket
        # at the same local index (node-bit flip keeps local bits), then
        # returns the partial top-m. 2 ppermutes per node bit.
        for j in range(cfg.node_bits):
            perm = [(i, i ^ (1 << j)) for i in range(n)]
            nq = jax.lax.ppermute(rq, "model", perm)
            nt = jax.lax.ppermute(rtable_c, "model", perm)
            nl = jax.lax.ppermute(rlocal_c, "model", perm)
            ids_j, sc_j = _score_local(
                dataclasses.replace(cfg, variant="lsh"),  # exact bucket only
                store_ids, store_payload, nq, nt, nl, m,
            )
            ids_parts.append(jax.lax.ppermute(ids_j, "model", perm))
            sc_parts.append(jax.lax.ppermute(sc_j, "model", perm))

    ids_r, sc_r = _merge_topk(ids_parts, sc_parts, m)   # [n*cap, m]
    ids_r = jnp.where(rvalid[:, None], ids_r, -1)
    sc_r = jnp.where(rvalid[:, None], sc_r, NEG_INF)

    # ---- return results to origin -------------------------------------------
    back_i = jax.lax.all_to_all(ids_r.reshape(n, cap, m), "model", 0, 0, tiled=True)
    back_s = jax.lax.all_to_all(sc_r.reshape(n, cap, m), "model", 0, 0, tiled=True)
    # origin gathers its (query, table) slots: entry for flat index f went to
    # (dest[f], slot[f]); after all_to_all those live at [dest[f], slot[f]].
    gather_i = back_i[dest, slot_c]                     # [b_loc*L, m] (sorted order)
    gather_s = back_s[dest, slot_c]
    gather_i = jnp.where(ok[:, None], gather_i, -1)
    gather_s = jnp.where(ok[:, None], gather_s, NEG_INF)
    # unsort back to (query, table) order
    unsort = jnp.argsort(order)
    gather_i = gather_i[unsort].reshape(b_loc, L * m)
    gather_s = gather_s[unsort].reshape(b_loc, L * m)
    return dedupe_topk(gather_i, gather_s, m)


def _search_allgather(
    cfg, store_ids, store_payload, cache_ids, cache_payload, q, owner, local_idx
):
    """Dense fallback: replicate queries along `model`, each shard scores the
    (query, table) pairs it owns, results return via all_to_all."""
    L, m, n = cfg.params.L, cfg.m, cfg.n_shards
    b_loc = q.shape[0]
    me = jax.lax.axis_index("model")

    q_all = jax.lax.all_gather(q, "model", axis=0, tiled=True)          # [b_all, d]
    owner_all = jax.lax.all_gather(owner, "model", axis=0, tiled=True)  # [b_all, L]
    local_all = jax.lax.all_gather(local_idx, "model", axis=0, tiled=True)

    b_all = q_all.shape[0]
    rq = jnp.repeat(q_all, L, axis=0)                       # [b_all*L, d]
    rtable = jnp.tile(jnp.arange(L, dtype=jnp.int32), (b_all,))
    rlocal = local_all.reshape(-1)
    mine = owner_all.reshape(-1) == me

    ids_o, sc_o = _score_local(cfg, store_ids, store_payload, rq, rtable, rlocal, m)
    ids_parts, sc_parts = [ids_o], [sc_o]
    if cfg.variant == "cnb" and cache_ids is not None and cfg.node_bits > 0:
        ids_c, sc_c = _score_cache(cfg, cache_ids, cache_payload, rq, rtable, rlocal, m)
        ids_parts.append(ids_c)
        sc_parts.append(sc_c)
    if cfg.variant == "nb":
        for j in range(cfg.node_bits):
            perm = [(i, i ^ (1 << j)) for i in range(n)]
            nq = jax.lax.ppermute(rq, "model", perm)
            nt = jax.lax.ppermute(rtable, "model", perm)
            nl = jax.lax.ppermute(rlocal, "model", perm)
            ids_j, sc_j = _score_local(
                dataclasses.replace(cfg, variant="lsh"),
                store_ids, store_payload, nq, nt, nl, m,
            )
            ids_parts.append(jax.lax.ppermute(ids_j, "model", perm))
            sc_parts.append(jax.lax.ppermute(sc_j, "model", perm))

    ids_r, sc_r = _merge_topk(ids_parts, sc_parts, m)       # [b_all*L, m]
    ids_r = jnp.where(mine[:, None], ids_r, -1)
    sc_r = jnp.where(mine[:, None], sc_r, NEG_INF)

    # each origin needs rows of its own queries from ALL shards: all_to_all
    # over the origin-major reshape.
    ids_r = ids_r.reshape(n, b_loc * L * m)
    sc_r = sc_r.reshape(n, b_loc * L * m)
    got_i = jax.lax.all_to_all(ids_r, "model", 0, 0, tiled=True)  # [n, b*L*m]
    got_s = jax.lax.all_to_all(sc_r, "model", 0, 0, tiled=True)
    got_i = got_i.reshape(n, b_loc, L * m).transpose(1, 0, 2).reshape(b_loc, -1)
    got_s = got_s.reshape(n, b_loc, L * m).transpose(1, 0, 2).reshape(b_loc, -1)
    return dedupe_topk(got_i, got_s, m)


# -----------------------------------------------------------------------------
# public API
# -----------------------------------------------------------------------------


def shard_store(mesh, store: BucketStore) -> BucketStore:
    """Place a host-built store on the mesh: buckets sharded over `model`,
    replicated elsewhere."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec3 = NamedSharding(mesh, P(None, "model", None))
    spec4 = NamedSharding(mesh, P(None, "model", None, None))
    spec2 = NamedSharding(mesh, P(None, "model"))
    return BucketStore(
        ids=jax.device_put(store.ids, spec3),
        timestamps=jax.device_put(store.timestamps, spec3),
        write_ptr=jax.device_put(store.write_ptr, spec2),
        payload=None
        if store.payload is None
        else jax.device_put(store.payload, spec4),
    )


def make_refresh_cache(cfg: DistConfig, mesh):
    """jit'd CNB cache refresh: 1 ppermute per node bit, OFF the query path.

    Returns (cache_ids [T, nbits, NB/n, C], cache_payload [T, nbits, NB/n, C, D])
    sharded like the store.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    n = cfg.n_shards
    nbits = cfg.node_bits

    def _refresh(ids, payload):
        outs_i, outs_p = [], []
        for j in range(nbits):
            perm = [(i, i ^ (1 << j)) for i in range(n)]
            outs_i.append(jax.lax.ppermute(ids, "model", perm))
            outs_p.append(jax.lax.ppermute(payload, "model", perm))
        return jnp.stack(outs_i, axis=1), jnp.stack(outs_p, axis=1)

    fn = compat.shard_map(
        _refresh,
        mesh=mesh,
        in_specs=(P(None, "model", None), P(None, "model", None, None)),
        out_specs=(
            P(None, None, "model", None),
            P(None, None, "model", None, None),
        ),
    )
    return jax.jit(fn)


def make_search_step(cfg: DistConfig, mesh, batch_axes=("data", "model")):
    """jit'd distributed search: queries [B, d] sharded over batch_axes ->
    (ids [B, m], scores [B, m]) with the same sharding."""
    from jax.sharding import PartitionSpec as P

    qspec = P(batch_axes, None)
    store_i = P(None, "model", None)
    store_p = P(None, "model", None, None)
    cache_i = P(None, None, "model", None)
    cache_p = P(None, None, "model", None, None)

    has_cache = cfg.variant == "cnb" and cfg.node_bits > 0

    if has_cache:

        def step(hyperplanes, ids, payload, c_ids, c_payload, q):
            return _search_shard(cfg, hyperplanes, ids, payload, c_ids, c_payload, q)

        fn = compat.shard_map(
            step,
            mesh=mesh,
            in_specs=(P(), store_i, store_p, cache_i, cache_p, qspec),
            out_specs=(P(batch_axes, None), P(batch_axes, None)),
        )
    else:

        def step(hyperplanes, ids, payload, q):
            return _search_shard(cfg, hyperplanes, ids, payload, None, None, q)

        fn = compat.shard_map(
            step,
            mesh=mesh,
            in_specs=(P(), store_i, store_p, qspec),
            out_specs=(P(batch_axes, None), P(batch_axes, None)),
        )
    return jax.jit(fn)


def make_insert_step(cfg: DistConfig, mesh, batch_axes=("data", "model")):
    """jit'd distributed insert/refresh: vectors arrive sharded over the
    batch axes; each `model` shard takes the ones whose buckets it owns.

    Paper Sec. 2.2: update rate is orders of magnitude below query rate, so
    the simple all_gather path is the right trade (no routing buffers).
    Donates the store; returns the updated store.
    """
    from jax.sharding import PartitionSpec as P

    def _insert(hyperplanes, ids_store, ts_store, ptr, payload_store,
                vec, vid, now):
        from repro.core import store as store_mod

        me = jax.lax.axis_index("model")
        # gather over ALL batch axes: every store replica (data axis) must
        # see every vector, not just its own data-row's slice.
        vec_all = jax.lax.all_gather(vec, batch_axes, axis=0, tiled=True)
        vid_all = jax.lax.all_gather(vid, batch_axes, axis=0, tiled=True)
        codes = hashing.sketch_codes(vec_all, hyperplanes)      # [nv, L]
        owner = (codes >> cfg.local_bits).astype(jnp.int32)
        local = (codes & ((1 << cfg.local_bits) - 1)).astype(jnp.uint32)
        # mark foreign (table, vector) entries invalid: ring insert skips id<0?
        # store.insert_batch inserts everything, so blank foreign rows by
        # pointing them at bucket 0 with id -1 (harmless: -1 ids are invalid
        # everywhere and get overwritten by the ring buffer).
        st = store_mod.BucketStore(ids_store, ts_store, ptr, payload_store)
        mine_any = owner == me[None, None]                       # [nv, L]
        new = st
        for l in range(cfg.params.L):
            sel = mine_any[:, l]
            ids_l = jnp.where(sel, vid_all, -1)
            codes_l = jnp.where(sel, local[:, l], 0).astype(jnp.uint32)
            new = store_mod.insert_masked(
                new, l, ids_l, codes_l, now, vec_all
            )
        return new.ids, new.timestamps, new.write_ptr, new.payload

    fn = compat.shard_map(
        _insert,
        mesh=mesh,
        in_specs=(
            P(),
            P(None, "model", None),
            P(None, "model", None),
            P(None, "model"),
            P(None, "model", None, None),
            P(batch_axes, None),
            P(batch_axes),
            P(),
        ),
        out_specs=(
            P(None, "model", None),
            P(None, "model", None),
            P(None, "model"),
            P(None, "model", None, None),
        ),
    )

    @jax.jit
    def insert(hyperplanes, store: BucketStore, vec, vid, now):
        i, t, p, pay = fn(
            hyperplanes, store.ids, store.timestamps, store.write_ptr,
            store.payload, vec, vid, now,
        )
        return BucketStore(i, t, p, pay)

    return insert


def estimate_query_bytes(cfg: DistConfig, batch: int, d: int, n_total: int) -> dict:
    """Closed-form ICI bytes per search step (the Table-1 analogue in the
    byte domain); verified against HLO in benchmarks/bench_distributed.py."""
    n = cfg.n_shards
    b_loc = batch // n_total
    m = cfg.m
    L = cfg.params.L
    if cfg.routing == "alltoall":
        cap = int(np.ceil(b_loc * L / n * cfg.cap_factor))
        q_bytes = n * cap * d * 4 + n * cap * 3 * 4
        r_bytes = 2 * n * cap * m * 4
    else:
        q_bytes = (n - 1) * b_loc * d * 4  # all_gather
        r_bytes = 2 * n * b_loc * L * m * 4
    nb_bytes = 0
    if cfg.variant == "nb":
        per_bit = (
            (n * cap if cfg.routing == "alltoall" else n * b_loc * L)
        )
        nb_bytes = cfg.node_bits * per_bit * (d * 4 + 8 + 2 * m * 4 * 2)
    return dict(query_routing=q_bytes, results=r_bytes, neighbor=nb_bytes,
                total=q_bytes + r_bytes + nb_bytes)
