"""Mesh adapter for the IndexRuntime (shard_map over the production mesh).

Since the runtime consolidation (DESIGN.md Sec. 8) the query/maintenance
logic lives in `repro.core.runtime` as topology-generic step kernels; this
module is ONLY the mesh side of that layer:

  * the sharding geometry (DESIGN.md Sec. 2): bucket shards on the `model`
    axis — device j owns the contiguous sketch-prefix zone (the CAN zone);
    the query batch shards over ALL mesh axes; bucket state replicates
    across data/pod — `shard_store` and the PartitionSpecs below;
  * `shard_map` wrappers binding each runtime kernel to the mesh
    collectives (`make_search_step`, `make_contains_step`,
    `make_insert_step`, `make_payload_sync`, `make_refresh_cache`) plus
    the global psum of the per-device overflow-drop counts;
  * the ICI byte model (`estimate_query_bytes`, `estimate_refresh_bytes`)
    — the Table-1 analogue in the byte domain, verified against compiled
    HLO in benchmarks/bench_distributed.py.

Per-variant communication on the query path (mirrors Table 1):
  lsh  : route each (query, table) to its owner shard  [all_to_all]
         and search the exact bucket only.
  nb   : lsh + forward to the log2(n_shards) XOR-neighbors [2 ppermutes/bit]
         to cover node-bit near buckets; local-bit near buckets are free.
  cnb  : lsh routing, with node-bit near buckets served from a local cache
         of the neighbors' shards, refreshed OFF the query path by
         `refresh_cache` (the paper's periodic bucket exchange).

Routing modes (a §Perf knob):
  alltoall : per-destination padded send buffers built by
             `repro.core.routing` (one fused all_to_all each way) — bytes
             ~ L*cap_factor/n_shards of the all_gather cost.  Overflowed
             probes are COUNTED, not silently eaten: every step returns a
             `dropped_probes` scalar (0 in healthy operation; raise
             `cap_factor` if it isn't).
  allgather: replicate queries along `model`, return per-origin results via
             all_to_all — simple, no overflow, more bytes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import runtime as runtime_mod
from repro.core.runtime import (  # noqa: F401  (canonical home moved)
    MeshCollectives,
    RuntimeConfig,
    _route_cap,
)
from repro.core.store import BucketStore


def DistConfig(*, n_shards: int, **kw) -> RuntimeConfig:
    """Legacy constructor name: a mesh RuntimeConfig with n_shards nodes.

    `n_shards` is captured at construction and the returned config is
    FROZEN — it does not track membership changes.  When node membership
    changes post-construction (`repro.core.runtime.reshard`), a NEW
    config is derived via `dataclasses.replace(cfg, n_nodes=...)` and the
    old one simply describes the pre-round topology; code holding a
    DistConfig across a reshard must re-read `runtime.cfg`, never the
    factory argument it originally passed (DESIGN.md Sec. 9).
    """
    return RuntimeConfig(n_nodes=n_shards, **kw)


def _collectives(cfg: RuntimeConfig, batch_axes) -> MeshCollectives:
    return MeshCollectives(n=cfg.n_nodes, axis="model",
                           batch_axes=tuple(batch_axes))


def _psum_axes(batch_axes) -> tuple[str, ...]:
    """Axes the per-device drop counts are distinct over (dedup'd)."""
    return tuple(dict.fromkeys(tuple(batch_axes) + ("model",)))


# -----------------------------------------------------------------------------
# store placement
# -----------------------------------------------------------------------------


def shard_store(mesh, store: BucketStore) -> BucketStore:
    """Place a host-built store on the mesh: buckets sharded over `model`,
    replicated elsewhere."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec3 = NamedSharding(mesh, P(None, "model", None))
    spec4 = NamedSharding(mesh, P(None, "model", None, None))
    spec2 = NamedSharding(mesh, P(None, "model"))
    return BucketStore(
        ids=jax.device_put(store.ids, spec3),
        timestamps=jax.device_put(store.timestamps, spec3),
        write_ptr=jax.device_put(store.write_ptr, spec2),
        payload=None
        if store.payload is None
        else jax.device_put(store.payload, spec4),
        generation=jax.device_put(store.generation, NamedSharding(mesh, P())),
    )


def make_refresh_cache(cfg: RuntimeConfig, mesh):
    """jit'd CNB cache refresh: 1 ppermute per node bit, OFF the query path.

    Returns (cache_ids [T, nbits, NB/n, C], cache_payload [T, nbits, NB/n, C, D])
    sharded like the store.
    """
    from jax.sharding import PartitionSpec as P

    n = cfg.n_nodes
    nbits = cfg.node_bits

    def _refresh(ids, payload):
        outs_i, outs_p = [], []
        for j in range(nbits):
            perm = [(i, i ^ (1 << j)) for i in range(n)]
            outs_i.append(jax.lax.ppermute(ids, "model", perm))
            outs_p.append(jax.lax.ppermute(payload, "model", perm))
        return jnp.stack(outs_i, axis=1), jnp.stack(outs_p, axis=1)

    fn = compat.shard_map(
        _refresh,
        mesh=mesh,
        in_specs=(P(None, "model", None), P(None, "model", None, None)),
        out_specs=(
            P(None, None, "model", None),
            P(None, None, "model", None, None),
        ),
    )
    return jax.jit(fn)


def make_replicate_store(cfg: RuntimeConfig, mesh):
    """jit'd replica-slice construction (R-way availability, DESIGN.md
    Sec. 10): one ppermute per replica rank, OFF the query path — the
    announce-time fan-out `costmodel.estimate_replication_bytes` charges.

    Returns (rep_ids [T, R-1, NB, C], rep_payload [T, R-1, NB, C, D])
    sharded like the CNB neighbor cache (replica slices on `model`).
    """
    from jax.sharding import PartitionSpec as P

    cx = MeshCollectives(n=cfg.n_nodes, axis="model", batch_axes=())

    def _replicate(ids, payload):
        return runtime_mod.replicate_kernel(cfg, cx, ids, payload)

    fn = compat.shard_map(
        _replicate,
        mesh=mesh,
        in_specs=(P(None, "model", None), P(None, "model", None, None)),
        out_specs=(
            P(None, None, "model", None),
            P(None, None, "model", None, None),
        ),
    )
    return jax.jit(fn)


# -----------------------------------------------------------------------------
# the step wrappers (runtime kernels bound to the mesh)
# -----------------------------------------------------------------------------


def _stats_spec():
    """shard_map out-spec for a `StepStats` pytree: every leaf replicated
    (the global psum below makes them identical across devices)."""
    from jax.sharding import PartitionSpec as P

    return runtime_mod.StepStats(
        dropped=P(), probes_issued=P(), probes_routed=P(),
        nodes_contacted=P(), replica_fanout=P(), dropped_by_dest=P(),
    )


def _psum_stats(stats, psum_axes):
    """Global `StepStats`: sum the additive accounting fields across the
    mesh.  `replica_fanout` is a per-step constant (identical on every
    device), so it is carried through rather than summed."""
    summed = jax.lax.psum(
        dataclasses.replace(stats, replica_fanout=jnp.int32(0)), psum_axes)
    return dataclasses.replace(summed, replica_fanout=stats.replica_fanout)


def search_step_fn(cfg: RuntimeConfig, batch_axes=("data", "model")):
    """The un-jitted shard_map'd search callable (serve backends wrap it
    with their own jit to count retraces); `make_search_step` is the jit'd
    form.  Signature: (hyperplanes, store_ids, store_payload, [cache_ids,
    cache_payload,] q) with `m = cfg.m` baked in.
    """
    cx = _collectives(cfg, batch_axes)
    psum_axes = _psum_axes(batch_axes)
    has_cache = cfg.variant == "cnb" and cfg.node_bits > 0
    has_reps = cfg.replication > 1

    def _mesh(mesh):
        from jax.sharding import PartitionSpec as P

        qspec = P(batch_axes, None)
        store_i = P(None, "model", None)
        store_p = P(None, "model", None, None)
        cache_i = P(None, None, "model", None)
        cache_p = P(None, None, "model", None, None)
        out_specs = (P(batch_axes, None), P(batch_axes, None), _stats_spec())

        # positional layout: hyperplanes, store, [cache], [reps + live], q
        in_specs = [P(), store_i, store_p]
        if has_cache:
            in_specs += [cache_i, cache_p]
        if has_reps:
            # replica slices shard like the CNB cache; live replicates
            in_specs += [cache_i, cache_p, P()]
        in_specs.append(qspec)

        def step(hyperplanes, ids, payload, *rest):
            rest = list(rest)
            c_ids = c_payload = None
            if has_cache:
                c_ids, c_payload = rest.pop(0), rest.pop(0)
            kw = {}
            if has_reps:
                kw = dict(rep_ids=rest.pop(0), rep_payload=rest.pop(0),
                          live=rest.pop(0))
            (q,) = rest
            i, s, stats = runtime_mod.search_kernel(
                cfg, cx, cfg.m, hyperplanes, ids, payload,
                c_ids, c_payload, q, **kw,
            )
            return i, s, _psum_stats(stats, psum_axes)

        return compat.shard_map(
            step, mesh=mesh, in_specs=tuple(in_specs), out_specs=out_specs
        )

    return _mesh


def make_search_step(cfg: RuntimeConfig, mesh, batch_axes=("data", "model")):
    """jit'd distributed search: queries [B, d] sharded over batch_axes ->
    (ids [B, m], scores [B, m], stats `StepStats`).

    ids/scores keep the query sharding; the stats pytree carries GLOBAL
    (psum'd, replicated) accounting — `int(stats)` is the count of
    (query, table) probes that overflowed the capacitated all_to_all
    buffers this step (0 under allgather routing).
    """
    return jax.jit(search_step_fn(cfg, batch_axes)(mesh))


def make_contains_step(cfg: RuntimeConfig, mesh, batch_axes=("data", "model")):
    """jit'd distributed `contains` (paper Sec. 6.3 success probability):
    (hyperplanes, store_ids, [cache_ids,] queries [B, d], targets [B]) ->
    (hits bool [B], stats `StepStats` — `int(stats)` = dropped probes).

    Uses the same `ProbePlan` and router as the search step, so the
    measured success probability is exactly the deployed query
    discipline's.
    """
    from jax.sharding import PartitionSpec as P

    cx = _collectives(cfg, batch_axes)
    qspec = P(batch_axes, None)
    tspec = P(batch_axes)
    store_i = P(None, "model", None)
    cache_i = P(None, None, "model", None)
    out_specs = (P(batch_axes), _stats_spec())
    psum_axes = _psum_axes(batch_axes)

    has_cache = cfg.variant == "cnb" and cfg.node_bits > 0
    has_reps = cfg.replication > 1

    # positional layout: hyperplanes, store_ids, [cache], [reps + live],
    # q, targets — mirrors the search step
    in_specs = [P(), store_i]
    if has_cache:
        in_specs.append(cache_i)
    if has_reps:
        in_specs += [cache_i, P()]
    in_specs += [qspec, tspec]

    def step(hyperplanes, ids, *rest):
        rest = list(rest)
        c_ids = rest.pop(0) if has_cache else None
        kw = {}
        if has_reps:
            kw = dict(rep_ids=rest.pop(0), live=rest.pop(0))
        q, targets = rest
        h, stats = runtime_mod.contains_kernel(
            cfg, cx, hyperplanes, ids, c_ids, q, targets, **kw
        )
        return h, _psum_stats(stats, psum_axes)

    fn = compat.shard_map(
        step, mesh=mesh, in_specs=tuple(in_specs), out_specs=out_specs
    )
    return jax.jit(fn)


def make_insert_step(cfg: RuntimeConfig, mesh, batch_axes=("data", "model")):
    """jit'd distributed insert/refresh: vectors arrive sharded over the
    batch axes; each `model` shard takes the ones whose buckets it owns.
    Donates the store; returns the updated store.
    """
    from jax.sharding import PartitionSpec as P

    cx = _collectives(cfg, batch_axes)

    def _insert(hyperplanes, ids_store, ts_store, ptr, payload_store, gen,
                vec, vid, now):
        st = BucketStore(ids_store, ts_store, ptr, payload_store, gen)
        new = runtime_mod.insert_kernel(cfg, cx, hyperplanes, st, vec, vid,
                                        now)
        return new.ids, new.timestamps, new.write_ptr, new.payload, \
            new.generation

    fn = compat.shard_map(
        _insert,
        mesh=mesh,
        in_specs=(
            P(),
            P(None, "model", None),
            P(None, "model", None),
            P(None, "model"),
            P(None, "model", None, None),
            P(),
            P(batch_axes, None),
            P(batch_axes),
            P(),
        ),
        out_specs=(
            P(None, "model", None),
            P(None, "model", None),
            P(None, "model"),
            P(None, "model", None, None),
            P(),
        ),
    )

    @jax.jit
    def insert(hyperplanes, store: BucketStore, vec, vid, now):
        i, t, p, pay, gen = fn(
            hyperplanes, store.ids, store.timestamps, store.write_ptr,
            store.payload, store.generation, vec, vid, now,
        )
        return BucketStore(i, t, p, pay, gen)

    return insert


def make_payload_sync(cfg: RuntimeConfig, mesh, batch_axes=("data", "model")):
    """jit'd payload re-sync (`runtime.payload_sync_kernel` on the mesh).
    Donates and returns the store."""
    from jax.sharding import PartitionSpec as P

    cx = _collectives(cfg, batch_axes)

    def _sync(ids_store, payload_store, vec):
        return runtime_mod.payload_sync_kernel(cx, ids_store, payload_store,
                                               vec)

    fn = compat.shard_map(
        _sync,
        mesh=mesh,
        in_specs=(
            P(None, "model", None),
            P(None, "model", None, None),
            P(batch_axes, None),
        ),
        out_specs=P(None, "model", None, None),
    )

    def _apply(store: BucketStore, vec):
        # a payload rewrite changes scores, so it invalidates cached results
        # the same way insert/expire do: bump the store generation.
        return dataclasses.replace(
            store,
            payload=fn(store.ids, store.payload, vec),
            generation=store.generation + 1,
        )

    # donate the store: payload is the system's largest buffer and the old
    # generation is dead after the sync (same convention as store.expire)
    return jax.jit(_apply, donate_argnums=(0,))


# -----------------------------------------------------------------------------
# ICI byte model (the Table-1 analogue in the byte domain)
# -----------------------------------------------------------------------------


def estimate_query_bytes(cfg: RuntimeConfig, batch: int, d: int,
                         n_total: int) -> dict:
    """Closed-form ICI bytes per search step; verified against HLO in
    benchmarks/bench_distributed.py.

    Under `score="hamming"` the routed query row is the bit-packed
    sketch — W uint32 words instead of d f32 lanes — so every term that
    ships a query row charges `W*4` bytes, the ~`W/d` wire saving the
    packed mesh path exists for (gated bench cell in bench_kernels)."""
    from repro.core import packed

    n = cfg.n_nodes
    b_loc = batch // n_total
    m = cfg.m
    L = cfg.params.L
    row_lanes = (
        packed.num_words(cfg.params.k, L) if cfg.score == "hamming" else d
    )
    if cfg.routing == "alltoall":
        cap = _route_cap(cfg, b_loc)
        q_bytes = n * cap * row_lanes * 4 + n * cap * _META_INTS * 4
        r_bytes = 2 * n * cap * m * 4
    else:
        q_bytes = (n - 1) * b_loc * row_lanes * 4  # all_gather
        r_bytes = 2 * n * b_loc * L * m * 4
    nb_bytes = 0
    if cfg.variant == "nb":
        per_bit = (
            (n * cap if cfg.routing == "alltoall" else n * b_loc * L)
        )
        nb_bytes = cfg.node_bits * per_bit * (row_lanes * 4 + 8 + 2 * m * 4 * 2)
    return dict(query_routing=q_bytes, results=r_bytes, neighbor=nb_bytes,
                total=q_bytes + r_bytes + nb_bytes)


_META_INTS = 4  # (qidx, table, local, probe_mask) per routed probe


def estimate_refresh_bytes(cfg: RuntimeConfig, capacity: int, d: int) -> int:
    """ICI bytes of one CNB cache refresh per device: `node_bits` ppermutes
    of the full local store shard (ids + payload).  A hamming store's
    payload is the packed words [.., W], so each slot ships W*4 bytes."""
    from repro.core import packed

    slot_lanes = (
        packed.num_words(cfg.params.k, cfg.params.L)
        if cfg.score == "hamming" else d
    )
    nb_local = cfg.params.num_buckets // cfg.n_nodes
    per_permute = cfg.params.L * nb_local * capacity * (4 + slot_lanes * 4)
    return cfg.node_bits * per_permute


def estimate_reshard_bytes(cfg: RuntimeConfig, new_n: int, capacity: int,
                           d: int) -> int:
    """ICI bytes of one membership round `cfg.n_nodes -> new_n`.

    Delegates to the overlay handoff model (`costmodel`) — the same
    closed form `runtime.reshard` stamps into its `ReshardEvent`, exposed
    here in config-typed form for byte-model consumers (the
    bench_distributed-style estimators) next to `estimate_query_bytes` /
    `estimate_refresh_bytes`.  Consistency with the event charge is
    pinned in tests/test_costmodel.py."""
    from repro.core import costmodel

    return costmodel.estimate_handoff_bytes(
        cfg.params.L, cfg.params.num_buckets, capacity, d, cfg.n_nodes,
        new_n,
    )
