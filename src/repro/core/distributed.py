"""Distributed NearBucket-LSH runtime (shard_map over the production mesh).

Geometry (DESIGN.md Sec. 2): bucket shards live on the `model` mesh axis —
device j owns the contiguous sketch-prefix zone {codes with high bits == j}
(the CAN zone).  The query batch is sharded over *all* mesh axes (every
device is both a peer that receives queries and a bucket node, exactly as in
the paper's P2P OSN).  Bucket state is replicated across the data/pod axes.

Probe planning is NOT implemented here: `repro.core.plan` turns each query
into a `ProbePlan` (owner shard, local bucket, probe bitmask), exactly the
planner the single-host `LshEngine` runs — so `ranked_probes` and the
`num_probes` budget behave identically on both runtimes (equivalence
CI-checked in tests/test_distributed.py).  The probe bitmask rides the
routed metadata: the owner shard applies its local bits, the neighbor
cache / XOR-neighbor forwards apply its node bits.

Per-variant communication on the query path (mirrors Table 1):
  lsh  : route each (query, table) to its owner shard  [all_to_all]
         and search the exact bucket only.
  nb   : lsh + forward to the log2(n_shards) XOR-neighbors [2 ppermutes/bit]
         to cover node-bit near buckets; local-bit near buckets are free.
  cnb  : lsh routing, with node-bit near buckets served from a local cache
         of the neighbors' shards, refreshed OFF the query path by
         `refresh_cache` (the paper's periodic bucket exchange).

Routing modes (a §Perf knob):
  alltoall : per-destination padded send buffers built by
             `repro.core.routing` (one fused all_to_all each way) — bytes
             ~ L*cap_factor/n_shards of the all_gather cost.  Overflowed
             probes are COUNTED, not silently eaten: every step returns a
             `dropped_probes` scalar (0 in healthy operation; raise
             `cap_factor` if it isn't).
  allgather: replicate queries along `model`, return per-origin results via
             all_to_all — simple, no overflow, more bytes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import plan as plan_mod
from repro.core import routing as routing_mod
from repro.core import scoring
from repro.core.can import CanTopology
from repro.core.hashing import LshParams
from repro.core.scoring import dedupe_topk
from repro.core.store import BucketStore

NEG_INF = float("-inf")


@dataclasses.dataclass(frozen=True)
class DistConfig:
    params: LshParams
    n_shards: int                 # size of the `model` axis
    variant: str = "cnb"          # lsh | nb | cnb
    m: int = 10
    routing: str = "alltoall"     # alltoall | allgather
    cap_factor: float = 2.0       # per-destination buffer slack (alltoall)
    probe_local_near: bool = True  # search local-bit near buckets (nb/cnb)
    num_probes: int | None = None  # None => all k 1-near buckets (the paper)
    ranked_probes: bool = False    # margin-ranked probe subset (beyond paper)
    use_kernels: bool = False      # fused Pallas score/top-m on each shard

    @property
    def topo(self) -> CanTopology:
        return CanTopology(self.params.k, self.n_shards)

    @property
    def node_bits(self) -> int:
        return self.topo.node_bits

    @property
    def local_bits(self) -> int:
        return self.topo.local_bits

    @property
    def probe_spec(self) -> plan_mod.ProbeSpec:
        """The shared probe discipline (same planner as `LshEngine`)."""
        return plan_mod.ProbeSpec(
            params=self.params,
            variant=self.variant,
            num_probes=self.num_probes,
            ranked_probes=self.ranked_probes,
        )


# -----------------------------------------------------------------------------
# local search helpers (run inside shard_map on one shard)
# -----------------------------------------------------------------------------


def _local_include_near(cfg: DistConfig) -> bool:
    return cfg.variant != "lsh" and cfg.probe_local_near


def _node_bit_valid(cfg: DistConfig, mask: jax.Array) -> jax.Array:
    """[r, node_bits] — is the flip of node bit j probed for each query?
    (the planner's mask-layout helper, stacked over this config's bits)"""
    if cfg.node_bits == 0:
        return jnp.zeros(mask.shape + (0,), bool)
    topo = cfg.topo
    return jnp.stack(
        [plan_mod.node_bit_probe_valid(topo, mask, b)
         for b in range(cfg.node_bits)],
        axis=-1,
    )


def _score_local(
    cfg: DistConfig,
    store_ids: jax.Array,      # [T, NB_local, C]
    store_payload: jax.Array,  # [T, NB_local, C, D]
    q: jax.Array,              # [r, d]
    table: jax.Array,          # [r] int32
    local_idx: jax.Array,      # [r] int32 bucket index within shard
    mask: jax.Array,           # [r] int32/uint32 probe bitmask (plan)
    m: int,
):
    """Top-m among (exact + masked local near) buckets of a routed query."""
    probes, pvalid = plan_mod.shard_local_probes(
        cfg.topo, local_idx, mask, include_near=_local_include_near(cfg)
    )                                                      # [r, P] both
    cand_ids = store_ids[table[:, None], probes]           # [r, P, C]
    cand_ids = jnp.where(pvalid[..., None], cand_ids, -1)
    cand_vec = store_payload[table[:, None], probes]       # [r, P, C, D]
    r = q.shape[0]
    cand_ids = cand_ids.reshape(r, -1)
    cand_vec = cand_vec.reshape(r, cand_ids.shape[1], -1)
    return scoring.score_topk(
        q, cand_ids, cand_vec, m, use_kernels=cfg.use_kernels
    )


def _score_cache(
    cfg: DistConfig,
    cache_ids: jax.Array,      # [T, nbits, NB_local, C]
    cache_payload: jax.Array,  # [T, nbits, NB_local, C, D]
    q: jax.Array,              # [r, d]
    table: jax.Array,          # [r]
    local_idx: jax.Array,      # [r]
    mask: jax.Array,           # [r]
    m: int,
):
    """CNB: score the masked node-bit near buckets from the neighbor cache.

    Flipping node bit j keeps the local index unchanged, so the near bucket
    of bit j is cache[table, j, local_idx] — a pure local gather, gated per
    query by node bit j of the probe mask.
    """
    nbits = cache_ids.shape[1]
    jj = jnp.arange(nbits)[None, :]
    cand_ids = cache_ids[table[:, None], jj, local_idx[:, None]]  # [r, nbits, C]
    cand_ids = jnp.where(_node_bit_valid(cfg, mask)[..., None], cand_ids, -1)
    cand_vec = cache_payload[table[:, None], jj, local_idx[:, None]]
    r = q.shape[0]
    cand_ids = cand_ids.reshape(r, -1)
    cand_vec = cand_vec.reshape(r, cand_ids.shape[1], -1)
    return scoring.score_topk(
        q, cand_ids, cand_vec, m, use_kernels=cfg.use_kernels
    )


def _neighbor_parts(
    cfg: DistConfig, store_ids, store_payload, rq, rtable, rlocal, rmask, m
):
    """NB: forward routed queries to each XOR-neighbor; it scores ITS exact
    bucket at the same local index (node-bit flip keeps local bits), then
    returns the partial top-m.  2 ppermutes per node bit; the origin query's
    probe mask gates each bit's contribution."""
    nbit_valid = _node_bit_valid(cfg, rmask)           # [r, nbits]
    ids_parts, sc_parts = [], []
    for j in range(cfg.node_bits):
        perm = cfg.topo.neighbor_perm(j)
        nq = jax.lax.ppermute(rq, "model", perm)
        nt = jax.lax.ppermute(rtable, "model", perm)
        nl = jax.lax.ppermute(rlocal, "model", perm)
        ids_j, sc_j = _score_local(
            dataclasses.replace(cfg, variant="lsh"),   # exact bucket only
            store_ids, store_payload, nq, nt, nl,
            jnp.zeros_like(rmask), m,
        )
        ids_j = jax.lax.ppermute(ids_j, "model", perm)
        sc_j = jax.lax.ppermute(sc_j, "model", perm)
        keep = nbit_valid[:, j][:, None]
        ids_parts.append(jnp.where(keep, ids_j, -1))
        sc_parts.append(jnp.where(keep, sc_j, NEG_INF))
    return ids_parts, sc_parts


# -----------------------------------------------------------------------------
# the sharded search step
# -----------------------------------------------------------------------------


def _merge_topk(ids_list, scores_list, m):
    ids = jnp.concatenate(ids_list, axis=-1)
    scores = jnp.concatenate(scores_list, axis=-1)
    return dedupe_topk(ids, scores, m)


def _flat_plan(cfg: DistConfig, q: jax.Array, hyperplanes: jax.Array):
    """Run the shared planner and flatten to (query, table) granularity."""
    L = cfg.params.L
    b_loc = q.shape[0]
    plan = plan_mod.make_plan(cfg.probe_spec, q, hyperplanes, cfg.topo)
    flat = dict(
        owner=plan.owner.reshape(-1),                   # [b_loc*L]
        local=plan.local_idx.reshape(-1),
        mask=plan.probe_mask.astype(jnp.int32).reshape(-1),
        table=jnp.tile(jnp.arange(L, dtype=jnp.int32), (b_loc,)),
        qidx=jnp.repeat(jnp.arange(b_loc, dtype=jnp.int32), L),
    )
    return plan, flat


def _route_cap(cfg: DistConfig, b_loc: int) -> int:
    cap = int(np.ceil(b_loc * cfg.params.L / cfg.n_shards * cfg.cap_factor))
    return max(cap, 1)


def _search_shard(
    cfg: DistConfig,
    hyperplanes: jax.Array,
    store_ids: jax.Array,
    store_payload: jax.Array,
    cache_ids: jax.Array | None,
    cache_payload: jax.Array | None,
    q: jax.Array,  # [b_loc, d] — this device's slice of the query batch
):
    """Runs on every device under shard_map.

    Returns (ids [b_loc, m], scores [b_loc, m], dropped int32) — `dropped`
    counts this device's (query, table) probes that overflowed the
    capacitated all_to_all send buffers (always 0 for allgather routing).
    """
    L, m = cfg.params.L, cfg.m
    n = cfg.n_shards
    b_loc, d = q.shape
    _, flat = _flat_plan(cfg, q, hyperplanes)

    if cfg.routing == "allgather":
        ids, sc = _search_allgather(
            cfg, store_ids, store_payload, cache_ids, cache_payload, q, flat
        )
        return ids, sc, jnp.int32(0)

    # ---- all_to_all routing (DHT-lookup analogue) ---------------------------
    cap = _route_cap(cfg, b_loc)
    route = routing_mod.plan_routes(flat["owner"], n, cap)
    meta = jnp.stack(
        [flat["qidx"], flat["table"], flat["local"], flat["mask"]], axis=-1
    )
    send_q = routing_mod.build_send_buffer(route, n, cap, q[flat["qidx"]], 0.0)
    send_meta = routing_mod.build_send_buffer(route, n, cap, meta, -1)

    recv_q = jax.lax.all_to_all(send_q, "model", 0, 0, tiled=True)
    recv_meta = jax.lax.all_to_all(send_meta, "model", 0, 0, tiled=True)
    rq = recv_q.reshape(n * cap, d)
    rtable = recv_meta[..., 1].reshape(-1)
    rlocal = recv_meta[..., 2].reshape(-1)
    rmask = recv_meta[..., 3].reshape(-1)
    rvalid = rtable >= 0
    rtable_c = jnp.maximum(rtable, 0)
    rlocal_c = jnp.maximum(rlocal, 0)
    rmask_c = jnp.maximum(rmask, 0)

    ids_o, sc_o = _score_local(
        cfg, store_ids, store_payload, rq, rtable_c, rlocal_c, rmask_c, m
    )
    ids_parts, sc_parts = [ids_o], [sc_o]

    if cfg.variant == "cnb" and cache_ids is not None and cfg.node_bits > 0:
        ids_c, sc_c = _score_cache(
            cfg, cache_ids, cache_payload, rq, rtable_c, rlocal_c, rmask_c, m
        )
        ids_parts.append(ids_c)
        sc_parts.append(sc_c)

    if cfg.variant == "nb":
        ids_n, sc_n = _neighbor_parts(
            cfg, store_ids, store_payload, rq, rtable_c, rlocal_c, rmask_c, m
        )
        ids_parts += ids_n
        sc_parts += sc_n

    ids_r, sc_r = _merge_topk(ids_parts, sc_parts, m)   # [n*cap, m]
    ids_r = jnp.where(rvalid[:, None], ids_r, -1)
    sc_r = jnp.where(rvalid[:, None], sc_r, NEG_INF)

    # ---- return results to origin -------------------------------------------
    back_i = jax.lax.all_to_all(ids_r.reshape(n, cap, m), "model", 0, 0, tiled=True)
    back_s = jax.lax.all_to_all(sc_r.reshape(n, cap, m), "model", 0, 0, tiled=True)
    gather_i = routing_mod.return_to_origin(route, back_i, -1)      # [b_loc*L, m]
    gather_s = routing_mod.return_to_origin(route, back_s, NEG_INF)
    gather_i = gather_i.reshape(b_loc, L * m)
    gather_s = gather_s.reshape(b_loc, L * m)
    ids, sc = dedupe_topk(gather_i, gather_s, m)
    return ids, sc, route.dropped


def _gather_flat_meta(flat: dict, b_loc: int, L: int, names):
    """all_gather the named per-(query, table) flat fields along `model`.

    Shared prologue of the two allgather branches (search + contains), so
    the [b_loc, L] re-flatten layout cannot drift between them.  Returns
    ({name: [b_all*L]}, table index [b_all*L], b_all).
    """
    gathered = {
        name: jax.lax.all_gather(
            flat[name].reshape(b_loc, L), "model", axis=0, tiled=True
        ).reshape(-1)
        for name in names
    }
    b_all = next(iter(gathered.values())).shape[0] // L
    rtable = jnp.tile(jnp.arange(L, dtype=jnp.int32), (b_all,))
    return gathered, rtable, b_all


def _search_allgather(
    cfg, store_ids, store_payload, cache_ids, cache_payload, q, flat
):
    """Dense fallback: replicate queries along `model`, each shard scores the
    (query, table) pairs it owns, results return via all_to_all."""
    L, m, n = cfg.params.L, cfg.m, cfg.n_shards
    b_loc = q.shape[0]
    me = jax.lax.axis_index("model")

    g, rtable, b_all = _gather_flat_meta(
        flat, b_loc, L, ("owner", "local", "mask"))
    q_all = jax.lax.all_gather(q, "model", axis=0, tiled=True)  # [b_all, d]
    rq = jnp.repeat(q_all, L, axis=0)                       # [b_all*L, d]
    rlocal = g["local"]
    rmask = g["mask"]
    mine = g["owner"] == me

    ids_o, sc_o = _score_local(
        cfg, store_ids, store_payload, rq, rtable, rlocal, rmask, m
    )
    ids_parts, sc_parts = [ids_o], [sc_o]
    if cfg.variant == "cnb" and cache_ids is not None and cfg.node_bits > 0:
        ids_c, sc_c = _score_cache(
            cfg, cache_ids, cache_payload, rq, rtable, rlocal, rmask, m
        )
        ids_parts.append(ids_c)
        sc_parts.append(sc_c)
    if cfg.variant == "nb":
        ids_n, sc_n = _neighbor_parts(
            cfg, store_ids, store_payload, rq, rtable, rlocal, rmask, m
        )
        ids_parts += ids_n
        sc_parts += sc_n

    ids_r, sc_r = _merge_topk(ids_parts, sc_parts, m)       # [b_all*L, m]
    ids_r = jnp.where(mine[:, None], ids_r, -1)
    sc_r = jnp.where(mine[:, None], sc_r, NEG_INF)

    # each origin needs rows of its own queries from ALL shards: all_to_all
    # over the origin-major reshape.
    ids_r = ids_r.reshape(n, b_loc * L * m)
    sc_r = sc_r.reshape(n, b_loc * L * m)
    got_i = jax.lax.all_to_all(ids_r, "model", 0, 0, tiled=True)  # [n, b*L*m]
    got_s = jax.lax.all_to_all(sc_r, "model", 0, 0, tiled=True)
    got_i = got_i.reshape(n, b_loc, L * m).transpose(1, 0, 2).reshape(b_loc, -1)
    got_s = got_s.reshape(n, b_loc, L * m).transpose(1, 0, 2).reshape(b_loc, -1)
    return dedupe_topk(got_i, got_s, m)


# -----------------------------------------------------------------------------
# the sharded contains step (success-probability metric, paper Sec. 6.3)
# -----------------------------------------------------------------------------


def _contains_local(cfg, store_ids, table, local_idx, mask, target):
    """bool [r]: does `target` sit in the (exact + masked local near)
    buckets of each routed query?  Metadata-only — no payload gathers."""
    probes, pvalid = plan_mod.shard_local_probes(
        cfg.topo, local_idx, mask, include_near=_local_include_near(cfg)
    )
    cand = store_ids[table[:, None], probes]                # [r, P, C]
    hit = (cand == target[:, None, None]) & pvalid[..., None]
    return jnp.any(hit, axis=(1, 2))


def _contains_shard(
    cfg: DistConfig,
    hyperplanes: jax.Array,
    store_ids: jax.Array,
    cache_ids: jax.Array | None,
    q: jax.Array,        # [b_loc, d]
    targets: jax.Array,  # [b_loc] int32
):
    """Distributed `LshEngine.contains`: was target y's id in ANY searched
    bucket of query x?  Routes only metadata (no query payload): membership
    needs bucket ids, not vectors.  Returns (hits bool [b_loc], dropped)."""
    L, n = cfg.params.L, cfg.n_shards
    b_loc = q.shape[0]
    _, flat = _flat_plan(cfg, q, hyperplanes)
    flat_tgt = jnp.repeat(targets.astype(jnp.int32), L)

    if cfg.routing == "allgather":
        me = jax.lax.axis_index("model")
        g, rtable, b_all = _gather_flat_meta(
            dict(flat, target=flat_tgt), b_loc, L,
            ("owner", "local", "mask", "target"))
        hit = _contains_hits(
            cfg, store_ids, cache_ids, rtable, g["local"], g["mask"],
            g["target"],
        )
        hit = hit & (g["owner"] == me)
        # OR across shards == psum of disjoint indicators, then own slice.
        hit_all = jax.lax.psum(
            hit.reshape(b_all, L).any(axis=-1).astype(jnp.int32), "model"
        )
        hits = jax.lax.dynamic_slice_in_dim(hit_all, me * b_loc, b_loc) > 0
        return hits, jnp.int32(0)

    cap = _route_cap(cfg, b_loc)
    route = routing_mod.plan_routes(flat["owner"], n, cap)
    meta = jnp.stack(
        [flat["qidx"], flat["table"], flat["local"], flat["mask"], flat_tgt],
        axis=-1,
    )
    send_meta = routing_mod.build_send_buffer(route, n, cap, meta, -1)
    recv_meta = jax.lax.all_to_all(send_meta, "model", 0, 0, tiled=True)
    rtable = jnp.maximum(recv_meta[..., 1].reshape(-1), 0)
    rlocal = jnp.maximum(recv_meta[..., 2].reshape(-1), 0)
    rmask = jnp.maximum(recv_meta[..., 3].reshape(-1), 0)
    rtgt = recv_meta[..., 4].reshape(-1)

    hit = _contains_hits(cfg, store_ids, cache_ids, rtable, rlocal, rmask, rtgt)
    # empty-slot rows carry rtgt = -1, which DOES match empty bucket ids
    # (-1); this validity mask is what discards those spurious hits.
    hit = hit & (recv_meta[..., 1].reshape(-1) >= 0)

    back = jax.lax.all_to_all(
        hit.reshape(n, cap).astype(jnp.int32), "model", 0, 0, tiled=True
    )
    got = routing_mod.return_to_origin(route, back, 0)       # [b_loc*L]
    hits = got.reshape(b_loc, L).any(axis=-1)
    return hits, route.dropped


def _contains_hits(cfg, store_ids, cache_ids, rtable, rlocal, rmask, rtgt):
    """Membership across owner buckets + node-bit coverage (cache or
    neighbor forwards), mirroring the search step's candidate pool."""
    hit = _contains_local(cfg, store_ids, rtable, rlocal, rmask, rtgt)
    if cfg.variant == "cnb" and cache_ids is not None and cfg.node_bits > 0:
        nbits = cache_ids.shape[1]
        jj = jnp.arange(nbits)[None, :]
        cand = cache_ids[rtable[:, None], jj, rlocal[:, None]]  # [r, nbits, C]
        valid = _node_bit_valid(cfg, rmask)[..., None]
        hit |= jnp.any((cand == rtgt[:, None, None]) & valid, axis=(1, 2))
    if cfg.variant == "nb":
        nbit_valid = _node_bit_valid(cfg, rmask)
        for j in range(cfg.node_bits):
            perm = cfg.topo.neighbor_perm(j)
            nt = jax.lax.ppermute(rtable, "model", perm)
            nl = jax.lax.ppermute(rlocal, "model", perm)
            ntgt = jax.lax.ppermute(rtgt, "model", perm)
            hit_j = _contains_local(
                dataclasses.replace(cfg, variant="lsh"),
                store_ids, nt, nl, jnp.zeros_like(nl), ntgt,
            )
            hit_j = jax.lax.ppermute(hit_j, "model", perm)
            hit |= hit_j & nbit_valid[:, j]
    return hit


# -----------------------------------------------------------------------------
# public API
# -----------------------------------------------------------------------------


def shard_store(mesh, store: BucketStore) -> BucketStore:
    """Place a host-built store on the mesh: buckets sharded over `model`,
    replicated elsewhere."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec3 = NamedSharding(mesh, P(None, "model", None))
    spec4 = NamedSharding(mesh, P(None, "model", None, None))
    spec2 = NamedSharding(mesh, P(None, "model"))
    return BucketStore(
        ids=jax.device_put(store.ids, spec3),
        timestamps=jax.device_put(store.timestamps, spec3),
        write_ptr=jax.device_put(store.write_ptr, spec2),
        payload=None
        if store.payload is None
        else jax.device_put(store.payload, spec4),
        generation=jax.device_put(store.generation, NamedSharding(mesh, P())),
    )


def make_refresh_cache(cfg: DistConfig, mesh):
    """jit'd CNB cache refresh: 1 ppermute per node bit, OFF the query path.

    Returns (cache_ids [T, nbits, NB/n, C], cache_payload [T, nbits, NB/n, C, D])
    sharded like the store.
    """
    from jax.sharding import PartitionSpec as P

    n = cfg.n_shards
    nbits = cfg.node_bits

    def _refresh(ids, payload):
        outs_i, outs_p = [], []
        for j in range(nbits):
            perm = [(i, i ^ (1 << j)) for i in range(n)]
            outs_i.append(jax.lax.ppermute(ids, "model", perm))
            outs_p.append(jax.lax.ppermute(payload, "model", perm))
        return jnp.stack(outs_i, axis=1), jnp.stack(outs_p, axis=1)

    fn = compat.shard_map(
        _refresh,
        mesh=mesh,
        in_specs=(P(None, "model", None), P(None, "model", None, None)),
        out_specs=(
            P(None, None, "model", None),
            P(None, None, "model", None, None),
        ),
    )
    return jax.jit(fn)


def _psum_axes(batch_axes) -> tuple[str, ...]:
    """Axes the per-device drop counts are distinct over (dedup'd)."""
    return tuple(dict.fromkeys(tuple(batch_axes) + ("model",)))


def make_search_step(cfg: DistConfig, mesh, batch_axes=("data", "model")):
    """jit'd distributed search: queries [B, d] sharded over batch_axes ->
    (ids [B, m], scores [B, m], dropped_probes int32 scalar).

    ids/scores keep the query sharding; `dropped_probes` is the GLOBAL
    count of (query, table) probes that overflowed the capacitated
    all_to_all buffers this step (replicated; 0 under allgather routing).
    """
    from jax.sharding import PartitionSpec as P

    qspec = P(batch_axes, None)
    store_i = P(None, "model", None)
    store_p = P(None, "model", None, None)
    cache_i = P(None, None, "model", None)
    cache_p = P(None, None, "model", None, None)
    out_specs = (P(batch_axes, None), P(batch_axes, None), P())
    psum_axes = _psum_axes(batch_axes)

    has_cache = cfg.variant == "cnb" and cfg.node_bits > 0

    if has_cache:

        def step(hyperplanes, ids, payload, c_ids, c_payload, q):
            i, s, drop = _search_shard(
                cfg, hyperplanes, ids, payload, c_ids, c_payload, q
            )
            return i, s, jax.lax.psum(drop, psum_axes)

        fn = compat.shard_map(
            step,
            mesh=mesh,
            in_specs=(P(), store_i, store_p, cache_i, cache_p, qspec),
            out_specs=out_specs,
        )
    else:

        def step(hyperplanes, ids, payload, q):
            i, s, drop = _search_shard(
                cfg, hyperplanes, ids, payload, None, None, q
            )
            return i, s, jax.lax.psum(drop, psum_axes)

        fn = compat.shard_map(
            step,
            mesh=mesh,
            in_specs=(P(), store_i, store_p, qspec),
            out_specs=out_specs,
        )
    return jax.jit(fn)


def make_contains_step(cfg: DistConfig, mesh, batch_axes=("data", "model")):
    """jit'd distributed `contains` (paper Sec. 6.3 success probability):
    (hyperplanes, store_ids, [cache_ids,] queries [B, d], targets [B]) ->
    (hits bool [B], dropped_probes int32).

    Was target y's id inside ANY bucket the query searched — membership in
    the probed buckets, not top-m.  Uses the same `ProbePlan` and router
    as the search step, so the measured success probability is exactly the
    deployed query discipline's.
    """
    from jax.sharding import PartitionSpec as P

    qspec = P(batch_axes, None)
    tspec = P(batch_axes)
    store_i = P(None, "model", None)
    cache_i = P(None, None, "model", None)
    out_specs = (P(batch_axes), P())
    psum_axes = _psum_axes(batch_axes)

    has_cache = cfg.variant == "cnb" and cfg.node_bits > 0

    if has_cache:

        def step(hyperplanes, ids, c_ids, q, targets):
            h, drop = _contains_shard(cfg, hyperplanes, ids, c_ids, q, targets)
            return h, jax.lax.psum(drop, psum_axes)

        fn = compat.shard_map(
            step,
            mesh=mesh,
            in_specs=(P(), store_i, cache_i, qspec, tspec),
            out_specs=out_specs,
        )
    else:

        def step(hyperplanes, ids, q, targets):
            h, drop = _contains_shard(cfg, hyperplanes, ids, None, q, targets)
            return h, jax.lax.psum(drop, psum_axes)

        fn = compat.shard_map(
            step,
            mesh=mesh,
            in_specs=(P(), store_i, qspec, tspec),
            out_specs=out_specs,
        )
    return jax.jit(fn)


def make_insert_step(cfg: DistConfig, mesh, batch_axes=("data", "model")):
    """jit'd distributed insert/refresh: vectors arrive sharded over the
    batch axes; each `model` shard takes the ones whose buckets it owns.

    Paper Sec. 2.2: update rate is orders of magnitude below query rate, so
    the simple all_gather path is the right trade (no routing buffers).
    Donates the store; returns the updated store.
    """
    from jax.sharding import PartitionSpec as P

    def _insert(hyperplanes, ids_store, ts_store, ptr, payload_store, gen,
                vec, vid, now):
        from repro.core import store as store_mod

        me = jax.lax.axis_index("model")
        # gather over ALL batch axes: every store replica (data axis) must
        # see every vector, not just its own data-row's slice.
        vec_all = jax.lax.all_gather(vec, batch_axes, axis=0, tiled=True)
        vid_all = jax.lax.all_gather(vid, batch_axes, axis=0, tiled=True)
        plan = plan_mod.make_plan(
            # insert wants only the owner/local split of the exact bucket
            dataclasses.replace(cfg.probe_spec, variant="lsh"),
            vec_all, hyperplanes, cfg.topo,
        )
        owner, local = plan.owner, plan.local_idx.astype(jnp.uint32)
        # mark foreign (table, vector) entries invalid: blank foreign rows
        # with id -1; insert_masked routes them out of bounds (mode='drop')
        # so they can't clobber live slots.
        st = store_mod.BucketStore(ids_store, ts_store, ptr, payload_store,
                                   gen)
        mine_any = owner == me[None, None]                       # [nv, L]
        new = st
        for l in range(cfg.params.L):
            sel = mine_any[:, l]
            ids_l = jnp.where(sel, vid_all, -1)
            codes_l = jnp.where(sel, local[:, l], 0).astype(jnp.uint32)
            new = store_mod.insert_masked(
                new, l, ids_l, codes_l, now, vec_all
            )
        # every shard bumps its replica by the same L, so the replicated
        # generation stays consistent across the mesh.
        return new.ids, new.timestamps, new.write_ptr, new.payload, \
            new.generation

    fn = compat.shard_map(
        _insert,
        mesh=mesh,
        in_specs=(
            P(),
            P(None, "model", None),
            P(None, "model", None),
            P(None, "model"),
            P(None, "model", None, None),
            P(),
            P(batch_axes, None),
            P(batch_axes),
            P(),
        ),
        out_specs=(
            P(None, "model", None),
            P(None, "model", None),
            P(None, "model"),
            P(None, "model", None, None),
            P(),
        ),
    )

    @jax.jit
    def insert(hyperplanes, store: BucketStore, vec, vid, now):
        i, t, p, pay, gen = fn(
            hyperplanes, store.ids, store.timestamps, store.write_ptr,
            store.payload, store.generation, vec, vid, now,
        )
        return BucketStore(i, t, p, pay, gen)

    return insert


def make_payload_sync(cfg: DistConfig, mesh, batch_axes=("data", "model")):
    """jit'd payload re-sync: point every live bucket entry's payload at the
    latest announced vector of its id.

    The semantic reference (`LshEngine`) scores candidates through an
    id-keyed corpus — always the LATEST announced vector — while the
    embedded-payload store keeps whatever was announced into each bucket.
    After a re-announce moves a user to new buckets, copies left in its
    old buckets (alive until the TTL GC collects them) would score with
    outdated vectors; this step restores the reference semantics.
    Timestamps are untouched, so GC behaviour is unchanged.

    Contract: `vec` row i must be the vector of user id i (dense 0-based
    ids), sharded over `batch_axes` — the layout the churn driver uses.
    Donates and returns the store.
    """
    from jax.sharding import PartitionSpec as P

    def _sync(ids_store, payload_store, vec):
        vec_all = jax.lax.all_gather(vec, batch_axes, axis=0, tiled=True)
        nv = vec_all.shape[0]
        live = (ids_store >= 0) & (ids_store < nv)
        gathered = vec_all[jnp.clip(ids_store, 0, nv - 1)]
        return jnp.where(live[..., None], gathered, payload_store)

    fn = compat.shard_map(
        _sync,
        mesh=mesh,
        in_specs=(
            P(None, "model", None),
            P(None, "model", None, None),
            P(batch_axes, None),
        ),
        out_specs=P(None, "model", None, None),
    )

    def _apply(store: BucketStore, vec):
        # a payload rewrite changes scores, so it invalidates cached results
        # the same way insert/expire do: bump the store generation.
        return dataclasses.replace(
            store,
            payload=fn(store.ids, store.payload, vec),
            generation=store.generation + 1,
        )

    # donate the store: payload is the system's largest buffer and the old
    # generation is dead after the sync (same convention as store.expire)
    return jax.jit(_apply, donate_argnums=(0,))


def estimate_query_bytes(cfg: DistConfig, batch: int, d: int, n_total: int) -> dict:
    """Closed-form ICI bytes per search step (the Table-1 analogue in the
    byte domain); verified against HLO in benchmarks/bench_distributed.py."""
    n = cfg.n_shards
    b_loc = batch // n_total
    m = cfg.m
    L = cfg.params.L
    if cfg.routing == "alltoall":
        cap = _route_cap(cfg, b_loc)
        q_bytes = n * cap * d * 4 + n * cap * _META_INTS * 4
        r_bytes = 2 * n * cap * m * 4
    else:
        q_bytes = (n - 1) * b_loc * d * 4  # all_gather
        r_bytes = 2 * n * b_loc * L * m * 4
    nb_bytes = 0
    if cfg.variant == "nb":
        per_bit = (
            (n * cap if cfg.routing == "alltoall" else n * b_loc * L)
        )
        nb_bytes = cfg.node_bits * per_bit * (d * 4 + 8 + 2 * m * 4 * 2)
    return dict(query_routing=q_bytes, results=r_bytes, neighbor=nb_bytes,
                total=q_bytes + r_bytes + nb_bytes)


_META_INTS = 4  # (qidx, table, local, probe_mask) per routed probe


def estimate_refresh_bytes(cfg: DistConfig, capacity: int, d: int) -> int:
    """ICI bytes of one CNB cache refresh per device: `node_bits` ppermutes
    of the full local store shard (ids + payload)."""
    nb_local = cfg.params.num_buckets // cfg.n_shards
    per_permute = cfg.params.L * nb_local * capacity * (4 + d * 4)
    return cfg.node_bits * per_permute
