"""Device-resident LSH bucket store with soft-state maintenance.

Paper Sec. 4.1 "Bucket Maintenance": buckets hold *soft state* — users
periodically re-hash and re-announce their vectors; entries that are not
refreshed within a TTL are garbage-collected; buckets are created lazily on
first insert.  This module implements that lifecycle as fixed-capacity
ring-buffer buckets, fully in JAX (scatter-based, jit-compatible), so the
same code runs inside the sharded runtime.

Two payload modes:
  * id-only  — buckets store (id, timestamp); scoring gathers vectors from a
    corpus array at search time (single-host engine / paper benchmarks).
  * embedded — buckets additionally store the (unit-norm) vector payload
    [capacity, dim]; used by the distributed runtime where each shard owns
    its vectors' bytes (no global gathers across shards).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.routing import run_ranks

EMPTY = jnp.int32(-1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BucketStore:
    """Pytree of bucket state, one hash table per l in [0, L).

    Shapes (T = L tables, NB = buckets per table (possibly a shard),
    C = capacity, D = payload dim or 0):
      ids:        int32 [T, NB, C]   (-1 = empty slot)
      timestamps: int32 [T, NB, C]
      write_ptr:  int32 [T, NB]      (ring pointer)
      payload:    f32   [T, NB, C, D] or None
      generation: int32 scalar       (mutation counter, see below)

    `generation` counts store mutations: every `insert_masked` and every
    `expire` bumps it.  Readers that cache derived results (the serving
    layer's sketch-keyed query cache, `repro.serve.qcache`) record the
    generation they computed at and treat any bump as invalidation — the
    DESIGN.md Sec. 7 read/write-epoch discipline.  It is a traced data
    field (not static), so bumping never retriggers compilation.
    """

    ids: jax.Array
    timestamps: jax.Array
    write_ptr: jax.Array
    payload: jax.Array | None
    generation: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.zeros((), jnp.int32)
    )

    @property
    def num_tables(self) -> int:
        return self.ids.shape[0]

    @property
    def num_buckets(self) -> int:
        return self.ids.shape[1]

    @property
    def capacity(self) -> int:
        return self.ids.shape[2]

    def occupancy(self) -> jax.Array:
        """Live entries per (table, bucket)."""
        return jnp.sum(self.ids >= 0, axis=-1)


def make_store(
    num_tables: int,
    num_buckets: int,
    capacity: int,
    payload_dim: int | None = None,
    dtype=jnp.float32,
) -> BucketStore:
    shape = (num_tables, num_buckets, capacity)
    payload = (
        None
        if payload_dim is None
        else jnp.zeros(shape + (payload_dim,), dtype=dtype)
    )
    return BucketStore(
        ids=jnp.full(shape, EMPTY, dtype=jnp.int32),
        timestamps=jnp.zeros(shape, dtype=jnp.int32),
        write_ptr=jnp.zeros(shape[:2], dtype=jnp.int32),
        payload=payload,
    )


def insert_masked(
    store: BucketStore,
    table: int,
    ids: jax.Array,        # int32 [n]; entries with id < 0 are skipped
    buckets: jax.Array,    # uint32/int32 [n] local bucket index per entry
    timestamp: jax.Array,  # int32 scalar
    payload: jax.Array | None = None,  # [n, D]
) -> BucketStore:
    """Soft-state insert/refresh into one table (Sec. 4.1 semantics).

    An entry whose id already sits in its target bucket is REFRESHED IN
    PLACE (timestamp + payload updated, slot kept) — re-announcing is an
    update, not an append, so a bucket never holds two copies of one user
    and stale payload generations cannot accumulate between GC passes.
    New ids ring-append, overwriting the oldest slots on overflow.

    Invalid (id < 0) entries are routed to an out-of-bounds bucket and
    dropped by the scatter (mode='drop'), so they can't clobber live
    slots — this is what lets the sharded runtime insert 'only the
    vectors I own' branch-free.

    Duplicate ids WITHIN one batch are deduplicated keep-last before any
    scatter: without this, two rows carrying the same (new) id both miss
    the refresh-in-place match and both ring-append — two live copies of
    one user in a bucket, which double-counts it in scoring and survives
    GC twice.  Keep-last matches `build_store_host`'s bulk-build
    semantics ('later duplicates overwrite earlier ones') and the
    re-announce discipline: the last announcement is the current one.
    """
    l = table
    nb, cap = store.num_buckets, store.capacity
    valid = ids >= 0
    n = ids.shape[0]
    if n > 1:
        # in-batch dedupe, keep-last: stable-sort by id, keep only the
        # final row of each equal-id run (stable => batch order preserved
        # within a run), and route the rest out-of-bounds via `valid`.
        order_d = jnp.argsort(ids, stable=True)
        s = ids[order_d]
        last = jnp.concatenate([s[:-1] != s[1:], jnp.ones((1,), bool)])
        valid &= jnp.zeros((n,), bool).at[order_d].set(last)
    bucket = jnp.where(valid, buckets.astype(jnp.int32) % nb, nb)  # nb = OOB
    bucket_c = jnp.minimum(bucket, nb - 1)

    # -- split: refresh-in-place (id already present) vs ring-append ------
    match = store.ids[l, bucket_c] == ids[:, None]        # [n, C]
    found = jnp.any(match, axis=-1) & valid
    exist_slot = jnp.argmax(match, axis=-1)               # first match
    upd_bucket = jnp.where(found, bucket_c, nb)           # not-found -> OOB

    # -- ring-append the new ids (shared sort+rank machinery, core.routing)
    app_bucket = jnp.where(found, nb, bucket)             # found -> OOB
    order = jnp.argsort(app_bucket)
    b_sorted = app_bucket[order]
    ranks = run_ranks(b_sorted)
    base = store.write_ptr[l, jnp.minimum(b_sorted, nb - 1)]
    slot = (base + ranks) % cap

    # refresh scatter FIRST, append scatter second: if an append wraps the
    # ring onto a slot being refreshed, the appended entry wins wholesale
    # (ids/ts/payload all from the append == a consistent ring eviction).
    new_ids = store.ids.at[l, b_sorted, slot].set(ids[order], mode="drop")
    new_ts = (
        store.timestamps
        .at[l, upd_bucket, exist_slot].set(timestamp, mode="drop")
        .at[l, b_sorted, slot].set(timestamp, mode="drop")
    )
    counts = jnp.zeros((nb,), jnp.int32).at[b_sorted].add(1, mode="drop")
    new_ptr = store.write_ptr.at[l].set((store.write_ptr[l] + counts) % cap)
    new_payload = store.payload
    if store.payload is not None:
        if payload is None:
            raise ValueError("store has payload; insert must provide vectors")
        new_payload = (
            store.payload
            .at[l, upd_bucket, exist_slot].set(payload, mode="drop")
            .at[l, b_sorted, slot].set(payload[order], mode="drop")
        )
    return BucketStore(
        new_ids, new_ts, new_ptr, new_payload, store.generation + 1
    )


@partial(jax.jit, donate_argnums=(0,))
def insert_batch(
    store: BucketStore,
    ids: jax.Array,            # int32 [n]
    codes: jax.Array,          # uint32 [n, T] — bucket id per table
    timestamp: jax.Array,      # int32 scalar
    payload: jax.Array | None = None,  # [n, D] unit-norm vectors
) -> BucketStore:
    """Insert/refresh a batch of vectors into every table (ring-buffer).

    Overwrites the oldest slots when a bucket overflows — the soft-state
    discipline makes this safe (evicted entries reappear on their next
    refresh if still alive).
    """
    # T is small (<= ~8); a Python loop keeps shapes static and readable.
    for l in range(store.num_tables):
        store = insert_masked(store, l, ids, codes[:, l], timestamp, payload)
    return store


@partial(jax.jit, donate_argnums=(0,))
def expire(store: BucketStore, now: jax.Array, ttl: int) -> BucketStore:
    """Garbage-collect entries not refreshed within `ttl` ticks (Sec. 4.1).

    `generation` bumps only when something was actually collected: a
    no-op GC pass leaves the readable state bit-identical, and bumping
    anyway would evict every sketch-keyed query-cache entry for nothing
    (the serving layer's invalidation is generation-based).  The bump is
    computed from traced data (`jnp.any` cast to int32), so the
    conditional costs no retrace.  Note the `ids != EMPTY` guard: empty
    slots carry timestamp 0 and would otherwise read as 'stale' forever,
    making every pass look like a collection."""
    stale = (now - store.timestamps) > ttl
    collected = stale & (store.ids != EMPTY)
    return dataclasses.replace(
        store,
        ids=jnp.where(collected, EMPTY, store.ids),
        generation=store.generation + jnp.any(collected).astype(jnp.int32),
    )


def build_store_host(
    codes: np.ndarray,         # uint32 [n, T]
    num_buckets: int,
    capacity: int,
    payload: np.ndarray | None = None,
    timestamp: int = 0,
) -> BucketStore:
    """Fast host-side bulk build for large corpora (preprocessing).

    Keeps the *last* `capacity` entries per bucket when overflowing, matching
    the ring-buffer semantics of `insert_batch`.  Ids here are positional
    (`arange(n)`), so an in-batch duplicate cannot occur by construction —
    the same keep-last outcome `insert_batch` now enforces explicitly
    (tests/test_store.py checks the two builds agree).
    """
    n, T = codes.shape
    ids_arr = np.full((T, num_buckets, capacity), -1, dtype=np.int32)
    ts_arr = np.zeros((T, num_buckets, capacity), dtype=np.int32)
    ptr = np.zeros((T, num_buckets), dtype=np.int32)
    pay = (
        None
        if payload is None
        else np.zeros((T, num_buckets, capacity, payload.shape[1]),
                      payload.dtype)
    )
    all_ids = np.arange(n, dtype=np.int32)
    for l in range(T):
        bucket = (codes[:, l].astype(np.int64)) % num_buckets
        order = np.argsort(bucket, kind="stable")
        b_sorted = bucket[order]
        # rank within runs
        is_start = np.ones(n, bool)
        is_start[1:] = b_sorted[1:] != b_sorted[:-1]
        run_start = np.maximum.accumulate(np.where(is_start, np.arange(n), 0))
        ranks = np.arange(n) - run_start
        counts = np.bincount(b_sorted, minlength=num_buckets)
        slot = ranks % capacity
        # later duplicates in a slot overwrite earlier ones == keep last.
        ids_arr[l, b_sorted, slot] = all_ids[order]
        ts_arr[l, b_sorted, slot] = timestamp
        ptr[l] = counts % capacity
        if pay is not None:
            pay[l, b_sorted, slot] = payload[order]
    return BucketStore(
        ids=jnp.asarray(ids_arr),
        timestamps=jnp.asarray(ts_arr),
        write_ptr=jnp.asarray(ptr),
        payload=None if pay is None else jnp.asarray(pay),
    )
